"""Paper §2 reproduced end-to-end at small scale: train a real LM, then
measure perplexity under every pruning strategy × sparsity — the ordering
the paper reports (unstructured per-token > structured; V robust at 0.7)
emerges on an actual trained model, not just synthetic caches.

    PYTHONPATH=src python examples/sparsity_sweep.py [--steps 150]
"""
import argparse
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_config
from repro.models import forward_train
from repro.serving.engine import decode_step, prefill
from repro.training import train
from repro.training.data import synthetic_batch


def eval_nll(cfg, params, toks, T_prefill):
    """Teacher-forced NLL of the decode phase under cfg's cache settings."""
    B, total = toks.shape
    lg, cache = prefill(params, toks[:, :T_prefill], cfg,
                        max_total_tokens=total + 8)
    step = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))
    nll = 0.0
    count = 0
    logits = lg
    for t in range(T_prefill, total - 1):
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll -= float(jnp.mean(jnp.take_along_axis(
            logp, toks[:, t][:, None], axis=-1)))
        count += 1
        logits, cache = step(params, toks[:, t], cache)
    return nll / count


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    base = get_config("llama3-8b").reduced()
    tc = TrainConfig(total_steps=args.steps, warmup_steps=10,
                     learning_rate=1e-2, checkpoint_every=10_000,
                     checkpoint_dir="/tmp/sweep_ckpt")
    state = train(base, tc, batch_size=8, seq_len=96, log_every=40,
                  resume=False)

    toks = synthetic_batch(tc.seed, 99, 4, 96, base)["tokens"]
    T_prefill = 48

    dense_cfg = replace(base, mustafar=replace(base.mustafar, enabled=False))
    dense = eval_nll(dense_cfg, state.params, toks, T_prefill)
    print(f"\n{'config':24s} nll    delta")
    print(f"{'dense':24s} {dense:.4f}  --")
    for ks, vs in ((0.5, 0.0), (0.7, 0.0), (0.0, 0.5), (0.0, 0.7),
                   (0.5, 0.5), (0.7, 0.7)):
        cfg = base.with_sparsity(ks, vs)
        nll = eval_nll(cfg, state.params, toks, T_prefill)
        print(f"{'K%.1f V%.1f' % (ks, vs):24s} {nll:.4f}  {nll-dense:+.4f}")


if __name__ == "__main__":
    main()
