"""Quickstart: train a tiny LM, then serve it with the Mustafar cache.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_config
from repro.serving.cache import cache_hbm_bytes
from repro.serving.engine import Engine
from repro.training import train


def main():
    cfg = get_config("llama3-8b").reduced()          # paper model, tiny
    print(f"arch={cfg.name} mustafar: K_s={cfg.mustafar.key_sparsity} "
          f"V_s={cfg.mustafar.value_sparsity} window={cfg.mustafar.local_window}")

    # 1. train a few steps on the synthetic bigram stream
    tc = TrainConfig(total_steps=30, warmup_steps=5, learning_rate=1e-2,
                     checkpoint_every=1000, checkpoint_dir="/tmp/quickstart_ckpt")
    state = train(cfg, tc, batch_size=8, seq_len=64, log_every=10,
                  resume=False)

    # 2. serve with the Mustafar compressed KV cache
    eng = Engine(cfg, state.params, max_total_tokens=256)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (4, 48), 0,
                                cfg.vocab_size)
    out = eng.generate(prompt, n_new=32, temperature=0.8)
    print("generated:", out.shape, out[0, :10].tolist())

    # 3. show what the compressed cache buys (paper Fig. 6b)
    acct = cache_hbm_bytes(get_config("llama3-8b"), B=1,
                           max_total_tokens=8192)
    print(f"llama3-8b @8k ctx: dense={acct['dense']/2**20:.0f}MiB "
          f"mustafar={acct['mustafar']/2**20:.0f}MiB "
          f"({acct['ratio']*100:.1f}% — paper reports ~45%)")


if __name__ == "__main__":
    main()
