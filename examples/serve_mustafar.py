"""End-to-end serving driver (the paper is an inference paper): a
continuous-batching Scheduler over the Mustafar cache — requests arrive on a
Poisson trace with ragged prompt lengths, get admitted into free slots,
decode as one batch, and release their slot on completion.

    PYTHONPATH=src python examples/serve_mustafar.py \
        --arch starcoder2-3b --slots 4 --requests 12 --gen 32 [--dense]
"""
import argparse
import os
import time
from dataclasses import replace

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving.cache import cache_hbm_bytes
from repro.serving.engine import Request, Scheduler


OBS_EPILOG = """\
quantized pools (--pool-dtype):

  --pool-dtype int8 stores the compressed value pools as int8 with one
    fp32 symmetric absmax scale per (page, head, tile_tokens tile) riding
    in a sibling pool leaf — roughly halving compressed-value HBM bytes
    on the memory-bound decode path. Bitmap planes, block tables, paging,
    prefix sharing and preemption spooling are format-transparent (scales
    ride in the page). The default bf16 keeps the exact PR 9 layout.
    Accuracy: symmetric per-tile absmax on top-k magnitude-pruned values
    (see benchmarks/bench_quant.py for the logit-MSE sweep).

observability (repro.obs — default-on metrics, opt-in tracing):

  --metrics-json PATH writes the full telemetry snapshot after the drain:
    {"stats": Scheduler.stats(), "roofline_drift": ...}. Metric names:
      step/{step,admit,prefill,provision,compaction,decode,sample,
            preempt_out,restore_in}_s   per-phase wall-time histograms
                                        (count/sum/p50/p90/p99 + buckets)
      engine.{steps,decode_steps,submitted,admitted,finished,rejected,
              tokens_sampled,prefill_tokens,compactions,cow_events,
              preempts,restores,swapped_pages,restored_pages}   counters
      pool.pages_{total,in_use,free,reserved,peak,owned,shared}  gauges
      spool.{bytes_out,bytes_in,held_bytes,entries}   swap-tier traffic
      prefix.{hits,misses,demotions,promotions,evictions,
              device_entries,spooled_entries}         prefix-cache tier
    With --engines N the snapshot is the fleet aggregate (counters sum,
    histograms merge exactly; per-engine summaries under "per_engine").

  --trace PATH exports a Chrome trace-event JSON: open ui.perfetto.dev
    and drop the file in. Scheduler phases render as nested B/E spans per
    step; request lifecycles as async "req" tracks (submit -> admit ->
    first_token -> finish, with preempt/restore/chunk instants). Engines
    of a router get separate tid rows. Timers wrap existing host-side
    boundaries only — without --trace-sync the decode span measures
    DISPATCH (JAX async dispatch), and device time drains into the next
    blocking phase; --trace-sync adds one block_until_ready per step for
    true per-phase device attribution (slower: serializes the pipeline).

  roofline drift (printed + in the metrics JSON): measured/modeled
    ratios against repro.roofline. swap ratios must be exactly 1.0
    whenever traffic moved (byte accounting is exact; anything else is a
    bug). decode drift_ratio ~ 1 on TPU means decode is memory-bound at
    roofline bandwidth (the paper's claim); >> 1 means overhead-bound —
    expected by orders of magnitude on this CPU reference path, where
    its trend across runs is the useful signal.

  Validate artifacts (the CI obs-smoke gate):
    python -m repro.obs.validate TRACE.json --metrics METRICS.json
"""


def main():
    ap = argparse.ArgumentParser(
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog=OBS_EPILOG)
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--slots", type=int, default=4,
                    help="batch slots in the shared cache")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--gen", type=int, default=32,
                    help="max new tokens per request")
    ap.add_argument("--rate", type=float, default=1.0,
                    help="Poisson arrival rate (requests per engine step)")
    ap.add_argument("--dense", action="store_true",
                    help="disable Mustafar (dense-cache baseline)")
    ap.add_argument("--sparsity", type=float, default=0.7)
    ap.add_argument("--pool-dtype", default="bf16",
                    choices=("bf16", "int8"),
                    help="storage width of the compressed value pools "
                         "(int8 halves value bytes and adds per-tile fp32 "
                         "scale leaves; see epilog)")
    ap.add_argument("--page-tokens", default="0",
                    help="paged compressed pools: tokens per page (multiple "
                         "of tile_tokens; 0 = contiguous per-slot pools; "
                         "'auto' = roofline-tuned page size, see "
                         "repro.roofline.auto_page_tokens)")
    ap.add_argument("--n-pages", type=int, default=0,
                    help="physical page-pool size (0 = full contiguous "
                         "capacity; smaller overcommits under the page-"
                         "budget admission gate)")
    ap.add_argument("--share-prefix", action="store_true",
                    help="share retired compressed prefix pages across "
                         "requests (refcounted copy-on-write block tables; "
                         "requires --page-tokens). The trace then gives "
                         "every prompt a common system prefix so sharing "
                         "actually fires.")
    ap.add_argument("--prefix-len", type=int, default=48,
                    help="common-prefix tokens prepended to every prompt "
                         "when --share-prefix is on")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="split admission prefills into N-token chunks "
                         "interleaved with decode steps (0 = one-shot solo "
                         "prefill; bounds the per-step decode stall to N "
                         "prompt tokens)")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="per-step prefill-token budget across ALL "
                         "admissions (0 = one chunk; requires "
                         "--prefill-chunk)")
    ap.add_argument("--no-pack-prefill", action="store_true",
                    help="opt OUT of packed prefill (the default whenever "
                         "--prefill-chunk is set packs chunks from "
                         "multiple waiting admissions into one batched "
                         "prefill call per step, Sarathi-style)")
    ap.add_argument("--no-fused-compaction", action="store_true",
                    help="opt OUT of compress-as-you-evict (the default "
                         "for paged pools retires window tile groups "
                         "into their destination page in the decode "
                         "dispatch's epilogue; this flag restores the "
                         "separate two-dispatch compaction)")
    ap.add_argument("--prefill-lanes", type=int, default=0,
                    help="cap the packed-prefill carry's lane count (0 = "
                         "one lane per slot; small caps keep the "
                         "persistent K/V carry from scaling with --slots)")
    ap.add_argument("--tile-overhead-bytes", type=int, default=0,
                    help="re-fit --page-tokens auto from a measured "
                         "per-tile dispatch cost in HBM-byte equivalents "
                         "(0 = roofline.TILE_OVERHEAD_BYTES or the "
                         "REPRO_TILE_OVERHEAD_BYTES env var)")
    ap.add_argument("--mesh-model", type=int, default=0,
                    help="shard the engine over N devices (KV heads on "
                         "the \"model\" axis, shard_map decode; 0 = "
                         "single-device). Needs N visible devices and "
                         "head counts divisible by N.")
    ap.add_argument("--engines", type=int, default=1,
                    help="data-parallel engine replicas behind one "
                         "router (--slots and --n-pages partition across "
                         "them; idle replicas skip steps entirely)")
    ap.add_argument("--admission-policy", default="wait",
                    choices=("wait", "reject", "preempt"),
                    help="what a full engine does with new arrivals: "
                         "queue them (wait), shed them (reject), or swap "
                         "a lower-priority decoder's pages to the host "
                         "spool and take its slot (preempt; paged only)")
    ap.add_argument("--persist-prefix", default="",
                    help="path for restart persistence of the shared-"
                         "prefix cache: load it before serving (if the "
                         "file exists and its config fingerprint "
                         "matches) and save the surviving chains after "
                         "the drain. Requires --share-prefix and a "
                         "single engine.")
    ap.add_argument("--metrics-json", default="",
                    help="write the post-drain telemetry snapshot "
                         "(Scheduler.stats() + roofline drift report) to "
                         "this path as JSON")
    ap.add_argument("--trace", default="",
                    help="record a structured event timeline and export "
                         "Chrome trace-event JSON to this path (open in "
                         "ui.perfetto.dev; see epilog)")
    ap.add_argument("--trace-sync", action="store_true",
                    help="block on each decode step's output for accurate "
                         "per-phase device attribution in the trace "
                         "(opt-in: serializes JAX's async dispatch)")
    ap.add_argument("--stats-every", type=int, default=100,
                    help="print a one-line stats log every N engine steps "
                         "(0 = off)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.page_tokens != "auto":
        try:
            args.page_tokens = int(args.page_tokens)
        except ValueError:
            ap.error("--page-tokens takes an integer or 'auto'")

    cfg = get_config(args.arch).reduced()
    if args.dense:
        if args.pool_dtype != "bf16":
            ap.error("--pool-dtype quantizes the MUSTAFAR pools; "
                     "drop --dense")
        cfg = replace(cfg, mustafar=replace(cfg.mustafar, enabled=False))
    else:
        cfg = cfg.with_sparsity(args.sparsity, args.sparsity)
        cfg = replace(cfg, mustafar=replace(cfg.mustafar,
                                            pool_dtype=args.pool_dtype))
    params = init_params(jax.random.PRNGKey(0), cfg)
    max_total = 64 + args.gen + 64 \
        + (args.prefix_len if args.share_prefix else 0)
    if args.page_tokens and args.dense:
        ap.error("--page-tokens requires the Mustafar cache (drop --dense)")
    if args.n_pages and not args.page_tokens:
        ap.error("--n-pages only bounds PAGED pools; pass --page-tokens too")
    if args.share_prefix and not args.page_tokens:
        ap.error("--share-prefix aliases PAGED pools; pass --page-tokens too")
    if args.prefill_budget and not args.prefill_chunk:
        ap.error("--prefill-budget requires --prefill-chunk")
    if args.engines < 1:
        ap.error("--engines must be >= 1")
    if args.admission_policy == "preempt" and not args.page_tokens:
        ap.error("--admission-policy preempt swaps PAGES to the host "
                 "spool; pass --page-tokens too")
    if args.persist_prefix and not args.share_prefix:
        ap.error("--persist-prefix saves the shared-prefix cache; pass "
                 "--share-prefix too")
    if args.persist_prefix and args.engines > 1:
        ap.error("--persist-prefix needs a single engine (page ids are "
                 "engine-local)")
    mesh = None
    if args.mesh_model:
        from repro.serving.sharded import make_serving_mesh
        mesh = make_serving_mesh(args.mesh_model)
    tracer = None
    if args.trace:
        from repro.obs import EventTracer
        tracer = EventTracer()
    sched_kw = dict(
        tracer=tracer,
        trace_sync=args.trace_sync,
        max_total_tokens=max_total,
        page_tokens=args.page_tokens or None,
        n_pages=args.n_pages or None,
        share_prefix=args.share_prefix,
        prefill_chunk=args.prefill_chunk or None,
        prefill_budget=args.prefill_budget or None,
        pack_prefill=False if args.no_pack_prefill else None,
        fused_compaction=False if args.no_fused_compaction else None,
        prefill_lanes=args.prefill_lanes or None,
        tile_overhead_bytes=args.tile_overhead_bytes or None,
        admission_policy=args.admission_policy,
        mesh=mesh)
    if args.engines > 1:
        from repro.serving.router import Router
        sched = Router(cfg, params, n_engines=args.engines,
                       n_slots=args.slots,
                       meshes=[mesh] * args.engines if mesh else None,
                       **{k: v for k, v in sched_kw.items() if k != "mesh"})
        print(f"# router: {args.engines} engine replicas x "
              f"{sched.engines[0].n_slots} slots")
        page_tokens_used = sched.engines[0].page_tokens
    else:
        sched = Scheduler(cfg, params, n_slots=args.slots, **sched_kw)
        page_tokens_used = sched.page_tokens
    if args.page_tokens == "auto":
        print(f"# page_tokens=auto -> {page_tokens_used} "
              f"(roofline-tuned for {args.slots} slots x "
              f"{max_total} tokens)")
    if args.persist_prefix and os.path.exists(args.persist_prefix):
        try:
            n = sched.load_prefix_cache(args.persist_prefix)
            print(f"# warm start: {n} prefix entries from "
                  f"{args.persist_prefix}")
        except ValueError as err:
            # config/pruning-mode fingerprint changed since the save —
            # compressed pages from another config are garbage here
            print(f"# cold start: stale prefix cache ignored ({err})")

    # Poisson arrival trace with ragged prompts (a few length buckets so the
    # per-length prefill executables amortize across requests); with
    # --share-prefix every prompt opens with the same system prefix
    rng = np.random.default_rng(args.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate,
                                         size=args.requests)).astype(int)
    buckets = (16, 24, 40, 64)
    prefix = list(rng.integers(0, cfg.vocab_size, size=args.prefix_len)) \
        if args.share_prefix else []
    reqs = [Request(prompt=np.asarray(
                        prefix + list(rng.integers(
                            0, cfg.vocab_size,
                            size=int(rng.choice(buckets))))),
                    max_new_tokens=args.gen,
                    temperature=0.7)
            for _ in range(args.requests)]

    from repro.obs import format_stats_line
    t0 = time.perf_counter()
    i = 0
    while i < args.requests or sched.has_work:
        while i < args.requests and arrivals[i] <= sched.step_count:
            sched.submit(reqs[i])
            i += 1
        sched.step()
        if args.stats_every and sched.step_count % args.stats_every == 0:
            print(format_stats_line(sched.stats(),
                                    prefix=f"# [{sched.step_count:>5}]"))
    dt = time.perf_counter() - t0
    if args.persist_prefix:
        n = sched.save_prefix_cache(args.persist_prefix)
        print(f"# persisted {n} prefix entries -> {args.persist_prefix}")

    new_tokens = sum(r.num_generated for r in sched.finished)
    lat = [r.finish_step - r.arrival_step for r in sched.finished]
    mode = "dense" if args.dense else f"mustafar(s={args.sparsity})"
    print(f"[{mode}] {args.requests} requests x <= {args.gen} tokens over "
          f"{sched.step_count} engine steps in {dt:.2f}s")
    print(f"  decode throughput: {new_tokens/dt:.1f} tok/s "
          f"(CPU reference path, incl. compiles)")
    st = sched.stats()          # registry snapshot + occupancy dict
    occ = st["occupancy"]
    print(f"  batch occupancy:   {occ['slots']*100:.1f}% of "
          f"{args.slots} slots")
    if args.engines > 1:
        loads = [len(e.finished) for e in sched.engines]
        print(f"  router:            finished per engine {loads}; "
              f"{sched.pages_in_use} pages still held "
              f"({sched.page_leaks} leaked)")
    else:
        if occ["pages"] is not None:
            print(f"  page occupancy:    {occ['pages']*100:.1f}% of "
                  f"{sched.n_pages} pages "
                  f"(peak {st['gauges']['pool.pages_peak']} drawn)")
        if args.share_prefix:
            print(f"  prefix sharing:    {sched.shared_admissions}/"
                  f"{args.requests} admissions aliased pages "
                  f"({st['counters']['prefix.hits']} page hits, "
                  f"{st['counters']['engine.cow_events']} "
                  f"copy-on-writes; occupancy "
                  f"owned={occ['pages_owned']*100:.1f}% "
                  f"shared={occ['pages_shared']*100:.1f}%)")
        if args.prefill_chunk:
            mode_note = ", packed" if sched.pack_prefill else ""
            print(f"  chunked prefill:   <= "
                  f"{sched.max_prefill_step_tokens} "
                  f"prefill tokens/step (budget {sched.prefill_budget}"
                  f"{mode_note}); "
                  f"mean {occ['prefill_tokens_per_step']:.1f} tok/step, "
                  f"stall p50={occ['prefill_stall_p50']:.0f} "
                  f"p99={occ['prefill_stall_p99']:.0f}")
        if occ["ttft_p50"] is not None:
            print(f"  ttft (steps):      p50={occ['ttft_p50']:.0f} "
                  f"p99={occ['ttft_p99']:.0f}")
        if args.admission_policy == "preempt" and sched.preempt_count:
            c = st["counters"]
            print(f"  preemption:        {c['engine.preempts']} swaps out, "
                  f"{c['engine.restores']} restores, "
                  f"{c['engine.swapped_pages']} pages via host spool "
                  f"({c['spool.bytes_out'] + c['spool.bytes_in']} "
                  f"bytes moved)")
        if args.admission_policy == "reject" and sched.rejected:
            print(f"  rejected:          {len(sched.rejected)} requests "
                  f"shed at admission")
    print(f"  latency (steps):   p50={int(np.median(lat))} "
          f"max={int(np.max(lat))}")
    acct = cache_hbm_bytes(cfg, args.slots, max_total,
                           page_tokens=page_tokens_used,
                           n_pages=args.n_pages or None,
                           mesh_model=args.mesh_model or 1)
    print(f"  cache bytes: dense={acct['dense']/2**20:.1f}MiB "
          f"mustafar={acct['mustafar']/2**20:.1f}MiB "
          f"ratio={acct['ratio']*100:.1f}%")
    if "paged" in acct:
        print(f"  paged bytes: pool={acct['paged_pool']/2**20:.2f}MiB "
              f"meta={acct['page_meta']/2**10:.1f}KiB "
              f"total={acct['paged']/2**20:.2f}MiB")
    if "paged_per_device" in acct:
        print(f"  per-device bytes:  "
              f"{acct['paged_per_device']/2**20:.2f}MiB across "
              f"{args.mesh_model} devices (KV heads sharded, "
              f"metadata replicated)")
    print("  sample:", sched.finished[0].output_tokens[:12])

    # --- telemetry artifacts: roofline drift report, metrics JSON, trace
    from repro.obs.drift import roofline_drift
    if args.engines > 1:
        drift = {"per_engine": [roofline_drift(e) for e in sched.engines]}
        decs = [d["decode_step"] for d in drift["per_engine"]]
        ratios = [d["drift_ratio"] for d in decs if d["decode_steps"]]
        if ratios:
            print(f"  roofline drift:    decode measured/modeled = "
                  f"{min(ratios):.3g}..{max(ratios):.3g} across "
                  f"{args.engines} engines (CPU reference path: >> 1 "
                  f"expected; trend is the signal)")
    else:
        drift = roofline_drift(sched)
        dec = drift["decode_step"]
        print(f"  roofline drift:    decode measured/modeled = "
              f"{dec['drift_ratio']:.3g} "
              f"(p50 {dec['measured_p50_s']*1e3:.3f}ms vs modeled "
              f"{dec['modeled_s']*1e6:.2f}us over {dec['decode_steps']} "
              f"steps; CPU reference path: >> 1 expected)")
        for key, label in (("swap_bytes_out", "swap out"),
                           ("swap_bytes_in", "swap in")):
            if key in drift:
                sec = drift[key]
                print(f"  roofline drift:    {label} measured/modeled = "
                      f"{sec['ratio']:.6f} ({sec['measured']} vs "
                      f"{sec['modeled']} bytes)")
    if args.metrics_json:
        import json
        with open(args.metrics_json, "w") as f:
            json.dump({"stats": st, "roofline_drift": drift}, f, indent=1)
        print(f"# metrics -> {args.metrics_json}")
    if tracer is not None:
        tracer.export(args.trace)
        print(f"# trace   -> {args.trace}  (open in ui.perfetto.dev)")


if __name__ == "__main__":
    main()
