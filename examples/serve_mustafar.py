"""End-to-end serving driver (the paper is an inference paper): a
continuous-batching Scheduler over the Mustafar cache — requests arrive on a
Poisson trace with ragged prompt lengths, get admitted into free slots,
decode as one batch, and release their slot on completion.

    PYTHONPATH=src python examples/serve_mustafar.py \
        --arch starcoder2-3b --slots 4 --requests 12 --gen 32 [--dense]
"""
import argparse
import os
import time
from dataclasses import replace

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving.cache import cache_hbm_bytes
from repro.serving.engine import Request, Scheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--slots", type=int, default=4,
                    help="batch slots in the shared cache")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--gen", type=int, default=32,
                    help="max new tokens per request")
    ap.add_argument("--rate", type=float, default=1.0,
                    help="Poisson arrival rate (requests per engine step)")
    ap.add_argument("--dense", action="store_true",
                    help="disable Mustafar (dense-cache baseline)")
    ap.add_argument("--sparsity", type=float, default=0.7)
    ap.add_argument("--page-tokens", default="0",
                    help="paged compressed pools: tokens per page (multiple "
                         "of tile_tokens; 0 = contiguous per-slot pools; "
                         "'auto' = roofline-tuned page size, see "
                         "repro.roofline.auto_page_tokens)")
    ap.add_argument("--n-pages", type=int, default=0,
                    help="physical page-pool size (0 = full contiguous "
                         "capacity; smaller overcommits under the page-"
                         "budget admission gate)")
    ap.add_argument("--share-prefix", action="store_true",
                    help="share retired compressed prefix pages across "
                         "requests (refcounted copy-on-write block tables; "
                         "requires --page-tokens). The trace then gives "
                         "every prompt a common system prefix so sharing "
                         "actually fires.")
    ap.add_argument("--prefix-len", type=int, default=48,
                    help="common-prefix tokens prepended to every prompt "
                         "when --share-prefix is on")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="split admission prefills into N-token chunks "
                         "interleaved with decode steps (0 = one-shot solo "
                         "prefill; bounds the per-step decode stall to N "
                         "prompt tokens)")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="per-step prefill-token budget across ALL "
                         "admissions (0 = one chunk; requires "
                         "--prefill-chunk)")
    ap.add_argument("--no-pack-prefill", action="store_true",
                    help="opt OUT of packed prefill (the default whenever "
                         "--prefill-chunk is set packs chunks from "
                         "multiple waiting admissions into one batched "
                         "prefill call per step, Sarathi-style)")
    ap.add_argument("--no-fused-compaction", action="store_true",
                    help="opt OUT of compress-as-you-evict (the default "
                         "for paged pools retires window tile groups "
                         "into their destination page in the decode "
                         "dispatch's epilogue; this flag restores the "
                         "separate two-dispatch compaction)")
    ap.add_argument("--prefill-lanes", type=int, default=0,
                    help="cap the packed-prefill carry's lane count (0 = "
                         "one lane per slot; small caps keep the "
                         "persistent K/V carry from scaling with --slots)")
    ap.add_argument("--tile-overhead-bytes", type=int, default=0,
                    help="re-fit --page-tokens auto from a measured "
                         "per-tile dispatch cost in HBM-byte equivalents "
                         "(0 = roofline.TILE_OVERHEAD_BYTES or the "
                         "REPRO_TILE_OVERHEAD_BYTES env var)")
    ap.add_argument("--mesh-model", type=int, default=0,
                    help="shard the engine over N devices (KV heads on "
                         "the \"model\" axis, shard_map decode; 0 = "
                         "single-device). Needs N visible devices and "
                         "head counts divisible by N.")
    ap.add_argument("--engines", type=int, default=1,
                    help="data-parallel engine replicas behind one "
                         "router (--slots and --n-pages partition across "
                         "them; idle replicas skip steps entirely)")
    ap.add_argument("--admission-policy", default="wait",
                    choices=("wait", "reject", "preempt"),
                    help="what a full engine does with new arrivals: "
                         "queue them (wait), shed them (reject), or swap "
                         "a lower-priority decoder's pages to the host "
                         "spool and take its slot (preempt; paged only)")
    ap.add_argument("--persist-prefix", default="",
                    help="path for restart persistence of the shared-"
                         "prefix cache: load it before serving (if the "
                         "file exists and its config fingerprint "
                         "matches) and save the surviving chains after "
                         "the drain. Requires --share-prefix and a "
                         "single engine.")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.page_tokens != "auto":
        try:
            args.page_tokens = int(args.page_tokens)
        except ValueError:
            ap.error("--page-tokens takes an integer or 'auto'")

    cfg = get_config(args.arch).reduced()
    if args.dense:
        cfg = replace(cfg, mustafar=replace(cfg.mustafar, enabled=False))
    else:
        cfg = cfg.with_sparsity(args.sparsity, args.sparsity)
    params = init_params(jax.random.PRNGKey(0), cfg)
    max_total = 64 + args.gen + 64 \
        + (args.prefix_len if args.share_prefix else 0)
    if args.page_tokens and args.dense:
        ap.error("--page-tokens requires the Mustafar cache (drop --dense)")
    if args.n_pages and not args.page_tokens:
        ap.error("--n-pages only bounds PAGED pools; pass --page-tokens too")
    if args.share_prefix and not args.page_tokens:
        ap.error("--share-prefix aliases PAGED pools; pass --page-tokens too")
    if args.prefill_budget and not args.prefill_chunk:
        ap.error("--prefill-budget requires --prefill-chunk")
    if args.engines < 1:
        ap.error("--engines must be >= 1")
    if args.admission_policy == "preempt" and not args.page_tokens:
        ap.error("--admission-policy preempt swaps PAGES to the host "
                 "spool; pass --page-tokens too")
    if args.persist_prefix and not args.share_prefix:
        ap.error("--persist-prefix saves the shared-prefix cache; pass "
                 "--share-prefix too")
    if args.persist_prefix and args.engines > 1:
        ap.error("--persist-prefix needs a single engine (page ids are "
                 "engine-local)")
    mesh = None
    if args.mesh_model:
        from repro.serving.sharded import make_serving_mesh
        mesh = make_serving_mesh(args.mesh_model)
    sched_kw = dict(
        max_total_tokens=max_total,
        page_tokens=args.page_tokens or None,
        n_pages=args.n_pages or None,
        share_prefix=args.share_prefix,
        prefill_chunk=args.prefill_chunk or None,
        prefill_budget=args.prefill_budget or None,
        pack_prefill=False if args.no_pack_prefill else None,
        fused_compaction=False if args.no_fused_compaction else None,
        prefill_lanes=args.prefill_lanes or None,
        tile_overhead_bytes=args.tile_overhead_bytes or None,
        admission_policy=args.admission_policy,
        mesh=mesh)
    if args.engines > 1:
        from repro.serving.router import Router
        sched = Router(cfg, params, n_engines=args.engines,
                       n_slots=args.slots,
                       meshes=[mesh] * args.engines if mesh else None,
                       **{k: v for k, v in sched_kw.items() if k != "mesh"})
        print(f"# router: {args.engines} engine replicas x "
              f"{sched.engines[0].n_slots} slots")
        page_tokens_used = sched.engines[0].page_tokens
    else:
        sched = Scheduler(cfg, params, n_slots=args.slots, **sched_kw)
        page_tokens_used = sched.page_tokens
    if args.page_tokens == "auto":
        print(f"# page_tokens=auto -> {page_tokens_used} "
              f"(roofline-tuned for {args.slots} slots x "
              f"{max_total} tokens)")
    if args.persist_prefix and os.path.exists(args.persist_prefix):
        try:
            n = sched.load_prefix_cache(args.persist_prefix)
            print(f"# warm start: {n} prefix entries from "
                  f"{args.persist_prefix}")
        except ValueError as err:
            # config/pruning-mode fingerprint changed since the save —
            # compressed pages from another config are garbage here
            print(f"# cold start: stale prefix cache ignored ({err})")

    # Poisson arrival trace with ragged prompts (a few length buckets so the
    # per-length prefill executables amortize across requests); with
    # --share-prefix every prompt opens with the same system prefix
    rng = np.random.default_rng(args.seed)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate,
                                         size=args.requests)).astype(int)
    buckets = (16, 24, 40, 64)
    prefix = list(rng.integers(0, cfg.vocab_size, size=args.prefix_len)) \
        if args.share_prefix else []
    reqs = [Request(prompt=np.asarray(
                        prefix + list(rng.integers(
                            0, cfg.vocab_size,
                            size=int(rng.choice(buckets))))),
                    max_new_tokens=args.gen,
                    temperature=0.7)
            for _ in range(args.requests)]

    t0 = time.perf_counter()
    i = 0
    while i < args.requests or sched.has_work:
        while i < args.requests and arrivals[i] <= sched.step_count:
            sched.submit(reqs[i])
            i += 1
        sched.step()
    dt = time.perf_counter() - t0
    if args.persist_prefix:
        n = sched.save_prefix_cache(args.persist_prefix)
        print(f"# persisted {n} prefix entries -> {args.persist_prefix}")

    new_tokens = sum(r.num_generated for r in sched.finished)
    lat = [r.finish_step - r.arrival_step for r in sched.finished]
    mode = "dense" if args.dense else f"mustafar(s={args.sparsity})"
    print(f"[{mode}] {args.requests} requests x <= {args.gen} tokens over "
          f"{sched.step_count} engine steps in {dt:.2f}s")
    print(f"  decode throughput: {new_tokens/dt:.1f} tok/s "
          f"(CPU reference path, incl. compiles)")
    occ = sched.occupancy
    print(f"  batch occupancy:   {occ.slots*100:.1f}% of {args.slots} slots")
    if args.engines > 1:
        loads = [len(e.finished) for e in sched.engines]
        print(f"  router:            finished per engine {loads}; "
              f"{sched.pages_in_use} pages still held "
              f"({sched.page_leaks} leaked)")
    else:
        if occ.pages is not None:
            print(f"  page occupancy:    {occ.pages*100:.1f}% of "
                  f"{sched.n_pages} pages "
                  f"(peak {sched.allocator.peak_in_use} drawn)")
        if args.share_prefix:
            print(f"  prefix sharing:    {sched.shared_admissions}/"
                  f"{args.requests} admissions aliased pages "
                  f"({sched.prefix.hits} page hits, {sched.cow_count} "
                  f"copy-on-writes; occupancy "
                  f"owned={occ.pages_owned*100:.1f}% "
                  f"shared={occ.pages_shared*100:.1f}%)")
        if args.prefill_chunk:
            mode_note = ", packed" if sched.pack_prefill else ""
            print(f"  chunked prefill:   <= "
                  f"{sched.max_prefill_step_tokens} "
                  f"prefill tokens/step (budget {sched.prefill_budget}"
                  f"{mode_note}); "
                  f"mean {occ.prefill_tokens_per_step:.1f} tok/step, "
                  f"stall p50={occ.prefill_stall_p50:.0f} "
                  f"p99={occ.prefill_stall_p99:.0f}")
        if occ.ttft_p50 is not None:
            print(f"  ttft (steps):      p50={occ.ttft_p50:.0f} "
                  f"p99={occ.ttft_p99:.0f}")
        if args.admission_policy == "preempt" and sched.preempt_count:
            print(f"  preemption:        {sched.preempt_count} swaps out, "
                  f"{sched.restore_count} restores, "
                  f"{sched.swapped_pages} pages via host spool "
                  f"({sched.spool.bytes_out + sched.spool.bytes_in} "
                  f"bytes moved)")
        if args.admission_policy == "reject" and sched.rejected:
            print(f"  rejected:          {len(sched.rejected)} requests "
                  f"shed at admission")
    print(f"  latency (steps):   p50={int(np.median(lat))} "
          f"max={int(np.max(lat))}")
    acct = cache_hbm_bytes(cfg, args.slots, max_total,
                           page_tokens=page_tokens_used,
                           n_pages=args.n_pages or None,
                           mesh_model=args.mesh_model or 1)
    print(f"  cache bytes: dense={acct['dense']/2**20:.1f}MiB "
          f"mustafar={acct['mustafar']/2**20:.1f}MiB "
          f"ratio={acct['ratio']*100:.1f}%")
    if "paged" in acct:
        print(f"  paged bytes: pool={acct['paged_pool']/2**20:.2f}MiB "
              f"meta={acct['page_meta']/2**10:.1f}KiB "
              f"total={acct['paged']/2**20:.2f}MiB")
    if "paged_per_device" in acct:
        print(f"  per-device bytes:  "
              f"{acct['paged_per_device']/2**20:.2f}MiB across "
              f"{args.mesh_model} devices (KV heads sharded, "
              f"metadata replicated)")
    print("  sample:", sched.finished[0].output_tokens[:12])


if __name__ == "__main__":
    main()
