"""End-to-end serving driver (the paper is an inference paper): batched
requests through prefill + Mustafar decode, with per-phase stats.

    PYTHONPATH=src python examples/serve_mustafar.py \
        --arch starcoder2-3b --batch 4 --prompt-len 160 --gen 96 [--dense]
"""
import argparse
import time
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_params
from repro.serving.cache import cache_hbm_bytes
from repro.serving.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=160)
    ap.add_argument("--gen", type=int, default=96)
    ap.add_argument("--dense", action="store_true",
                    help="disable Mustafar (dense-cache baseline)")
    ap.add_argument("--sparsity", type=float, default=0.7)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if args.dense:
        cfg = replace(cfg, mustafar=replace(cfg.mustafar, enabled=False))
    else:
        cfg = cfg.with_sparsity(args.sparsity, args.sparsity)
    params = init_params(jax.random.PRNGKey(0), cfg)
    max_total = args.prompt_len + args.gen + 64
    eng = Engine(cfg, params, max_total_tokens=max_total)

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    # warmup (compile)
    _ = eng.generate(prompts, n_new=2)
    t0 = time.perf_counter()
    out = jax.block_until_ready(eng.generate(prompts, n_new=args.gen,
                                             temperature=0.7))
    dt = time.perf_counter() - t0
    mode = "dense" if args.dense else f"mustafar(s={args.sparsity})"
    print(f"[{mode}] {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"-> {args.batch*args.gen/dt:.1f} tok/s (CPU reference path)")
    acct = cache_hbm_bytes(cfg, args.batch, max_total)
    print(f"cache bytes: dense={acct['dense']/2**20:.1f}MiB "
          f"mustafar={acct['mustafar']/2**20:.1f}MiB "
          f"ratio={acct['ratio']*100:.1f}%")
    print("sample:", out[0, :12].tolist())


if __name__ == "__main__":
    main()
