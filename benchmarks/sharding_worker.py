"""Subprocess body for BENCH_sharding (see bench_throughput.sharding_main).

Runs in its own interpreter because the 8-virtual-device CPU topology must
be configured through XLA_FLAGS BEFORE jax first imports; the parent
benchmark process has long since initialized its backend. Three sections:

* **sharded decode** — the SAME seeded Poisson trace served by a
  single-device Scheduler, a ``model=1`` mesh (shard_map wrapper overhead
  only — the 0.95x CI gate), and a ``model=8`` mesh (KV heads split across
  all 8 virtual devices). Outputs must agree token-for-token, and the
  measured per-device peak pool bytes on the 8-way mesh must land at
  ``single/8 + replicated metadata`` — the layout contract of
  ``sharding.specs.cache_partition_spec``.
* **router** — a 4x4-slot Router vs one 16-slot Scheduler on an identical
  moderate-concurrency trace (equal total slots). The router's win is
  static-shape waste: the single engine pays all 16 slot-rows every decode
  step while the router packs load onto one replica and lets idle siblings
  skip their steps outright. Gate: aggregate tok/s >= 1.5x.
* **fleet model** — ``cache_hbm_bytes`` at a 4096-slot fleet (the
  thousands-of-slots regime no single host serves live) with
  ``mesh_model=8``, reporting the per-device pool residency the sharded
  layout needs.

Timing is STEADY-STATE: every engine first drains a warmup trace covering
each prefill shape (jit compiles land there) before the seeded trace is
timed. Emits one ``SHARDING_JSON {...}`` line on stdout for the parent to
parse; gates are asserted by the parent so the failure shows up in the
benchmark run, not a silent subprocess death.

    PYTHONPATH=src python benchmarks/sharding_worker.py [--smoke]
"""
import argparse
import json
import os
import time

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax                                                  # noqa: E402
import jax.numpy as jnp                                     # noqa: E402
import numpy as np                                          # noqa: E402
from dataclasses import replace                             # noqa: E402

from repro.configs import get_config                        # noqa: E402
from repro.models import init_params                        # noqa: E402
from repro.serving import sharded                           # noqa: E402
from repro.serving.cache import cache_hbm_bytes             # noqa: E402
from repro.serving.engine import Request, Scheduler         # noqa: E402
from repro.serving.router import Router                     # noqa: E402

PROMPT_BUCKETS = (16, 32)


# warmup requests carry uids >= WARM_UID so the timed trace (uids 0..n-1)
# filters cleanly out of any engine's aggregated ``finished`` list
WARM_UID = 9000


def make_trace(cfg, n, gens, mean_gap, seed=0):
    r = np.random.default_rng(seed)
    arrivals = np.cumsum(r.exponential(mean_gap, size=n)).astype(int)
    reqs = [Request(prompt=r.integers(0, cfg.vocab_size,
                                      size=int(r.choice(PROMPT_BUCKETS))),
                    max_new_tokens=int(r.choice(gens)), uid=i)
            for i in range(n)]
    return arrivals, reqs


def warmup(engine, cfg, submit_to=None):
    """Drain one tiny request per prefill bucket so compiles precede the
    clock. ``submit_to`` bypasses the router so EVERY replica compiles."""
    r = np.random.default_rng(99)
    uid = WARM_UID
    for tgt in (submit_to or [engine]):
        for L in PROMPT_BUCKETS:
            tgt.submit(Request(prompt=r.integers(0, cfg.vocab_size, size=L),
                               max_new_tokens=2, uid=uid))
            uid += 1
    while engine.has_work:
        engine.step()
    return engine.step_count


def serve(engine, arrivals, reqs, base_step=0):
    t0 = time.perf_counter()
    i = 0
    while i < len(reqs) or engine.has_work:
        while i < len(reqs) and arrivals[i] + base_step <= engine.step_count:
            engine.submit(reqs[i])
            i += 1
        engine.step()
    dt = time.perf_counter() - t0
    return dt


def timed_tokens(engine, reqs):
    timed = [r for r in engine.finished if r.uid < WARM_UID]
    assert len(timed) == len(reqs), (len(timed), len(reqs))
    return sum(r.num_generated for r in timed), \
        [r.output_tokens for r in sorted(timed, key=lambda r: r.uid)]


def sharded_section(smoke):
    """Single-device vs model=1 vs model=8 on one trace."""
    cfg = replace(get_config("starcoder2-3b").reduced()
                  .with_sparsity(0.5, 0.5), n_heads=8, n_kv_heads=8)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n = 6 if smoke else 12
    arrivals, reqs = make_trace(cfg, n, gens=(8, 16), mean_gap=3)

    out = {}
    runs = {}
    for tag, model in (("single", 0), ("model1", 1), ("model8", 8)):
        mesh = sharded.make_serving_mesh(model) if model else None
        s = Scheduler(cfg, params, n_slots=4, max_total_tokens=96,
                      page_tokens=16, collect_logits=True, mesh=mesh)
        base = warmup(s, cfg)
        dt = serve(s, arrivals, [fresh(r) for r in reqs], base)
        toks, _ = timed_tokens(s, reqs)
        logits = {r.uid: r.logits for r in s.finished if r.uid < WARM_UID}
        toks_by_uid = {r.uid: r.output_tokens for r in s.finished
                       if r.uid < WARM_UID}
        runs[tag] = (s, toks_by_uid, logits)
        out[f"tokens_per_s_{tag}"] = toks / dt
        assert s.allocator.in_use == 0, f"{tag}: page leak"

    # model=1 shard_map runs the identical single-device program (the psum
    # over one device is an identity) -> bit-exact tokens. model=8 sums
    # head-shard partials in a different order -> fp32 tolerance on logits
    # (greedy argmax over a random-init model's near-flat logits can flip
    # on ties, so token equality is NOT the right check there).
    assert runs["model1"][1] == runs["single"][1], \
        "model1 outputs diverged from single-device"
    max_err = 0.0
    for uid, ref in runs["single"][2].items():
        toks_a = runs["model8"][1][uid]
        toks_b = runs["single"][1][uid]
        # a tie-flip at step k forks the context, so logits are only
        # comparable through step k (whose inputs are still identical)
        k = next((i for i, (x, y) in enumerate(zip(toks_a, toks_b))
                  if x != y), len(toks_b) - 1)
        for a, b in zip(runs["model8"][2][uid][:k + 1], ref[:k + 1]):
            max_err = max(max_err, float(np.max(np.abs(a - b))))
            np.testing.assert_allclose(a, b, rtol=5e-2, atol=5e-2)
    out["model8_max_logit_err"] = max_err

    s8 = runs["model8"][0]
    sharded.assert_cache_shardings(s8)
    pdb = sharded.per_device_cache_bytes(s8.cache)
    full = sum(leaf.nbytes for leaf in jax.tree.leaves(runs["single"][0].cache))
    # replicated metadata = every cache leaf whose spec carries no "model"
    from jax.sharding import PartitionSpec as P
    specs = jax.tree.leaves(s8._sharded.cache_specs,
                            is_leaf=lambda x: isinstance(x, P))
    meta = sum(leaf.nbytes
               for leaf, spec in zip(jax.tree.leaves(s8.cache), specs)
               if "model" not in spec)
    out.update(per_device_bytes_model8=pdb, single_device_bytes=full,
               replicated_meta_bytes=meta,
               per_device_bound=full / 8 + meta,
               speed_ratio_model1=(out["tokens_per_s_model1"]
                                   / out["tokens_per_s_single"]))
    counts = sharded.collective_audit(
        s8._decode, s8.params, s8.next_tokens, s8.cache,
        active=jnp.ones((4,), bool))
    sharded.assert_no_resharding(counts)
    out["decode_collectives"] = counts
    return out


def router_section(smoke):
    """4x4-slot router vs one 16-slot engine, equal total slots."""
    cfg = get_config("starcoder2-3b").reduced().with_sparsity(0.5, 0.5)
    params = init_params(jax.random.PRNGKey(0), cfg)
    n = 12 if smoke else 28
    gens = (12, 24) if smoke else (16, 32)
    arrivals, reqs = make_trace(cfg, n, gens=gens, mean_gap=6, seed=1)
    kw = dict(max_total_tokens=96, page_tokens=16)

    single = Scheduler(cfg, params, n_slots=16, **kw)
    base = warmup(single, cfg)
    dt_s = serve(single, arrivals, [fresh(r) for r in reqs], base)
    toks_s, _ = timed_tokens(single, reqs)

    router = Router(cfg, params, n_engines=4, n_slots=16, **kw)
    base = warmup(router, cfg, submit_to=router.engines)
    dt_r = serve(router, arrivals, [fresh(r) for r in reqs], base)
    toks_r, _ = timed_tokens(router, reqs)

    assert router.page_leaks == 0, "router leaked pages after drain"
    assert toks_r == toks_s, (toks_r, toks_s)
    per_engine = [len(e.finished) for e in router.engines]
    return {"tokens_per_s_single16": toks_s / dt_s,
            "tokens_per_s_router4x4": toks_r / dt_r,
            "speed_ratio_router": (toks_r / dt_r) / (toks_s / dt_s),
            "router_finished_per_engine": per_engine,
            "router_occupancy_slots": router.occupancy.slots,
            "single_occupancy_slots": single.occupancy.slots}


def fresh(r):
    """Fresh Request per serve (per-request progress state is mutable)."""
    return Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens,
                   temperature=r.temperature, uid=r.uid)


def fleet_section():
    """Per-device residency at fleet scale: 4096 slots, 8-way mesh."""
    cfg = get_config("llama2-7b").with_sparsity(0.7, 0.7)
    acct = cache_hbm_bytes(cfg, 4096, 4096, page_tokens=64, mesh_model=8)
    return {"fleet_slots": 4096, "fleet_mesh_model": 8,
            "fleet_paged_bytes": acct["paged"],
            "fleet_per_device_bytes": acct["paged_per_device"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    assert len(jax.devices()) >= 8, "virtual device topology missing"
    result = {}
    result.update(sharded_section(args.smoke))
    result.update(router_section(args.smoke))
    result.update(fleet_section())
    print("SHARDING_JSON " + json.dumps(result))


if __name__ == "__main__":
    main()
