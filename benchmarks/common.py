"""Shared benchmark utilities: timing, CSV + JSON output, small models."""
from __future__ import annotations

import json
import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock microseconds per call (jit'd fn, blocked)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


# every emit() call also lands here so run.py can write BENCH_<suite>.json
# (machine-readable perf trajectory across PRs, not just printed CSV)
_RECORDS: List[Dict] = []


def emit(name: str, us: float, derived: str = "", **metrics) -> None:
    """CSV row ``name,us_per_call,derived`` + a JSON record.

    ``metrics`` carries machine-readable extras (e.g. ``hbm_bytes`` — the
    modeled HBM traffic of the component — or ``speedup_vs_legacy``)."""
    print(f"{name},{us:.1f},{derived}")
    rec = {"name": name, "us_per_call": round(float(us), 3)}
    if derived:
        rec["derived"] = derived
    for k, v in metrics.items():
        rec[k] = v.item() if hasattr(v, "item") else v
    _RECORDS.append(rec)


def drain_records() -> List[Dict]:
    """Return and clear the records accumulated since the last drain."""
    out = list(_RECORDS)
    _RECORDS.clear()
    return out


def write_bench_json(path: str, records: List[Dict]) -> None:
    with open(path, "w") as f:
        json.dump(records, f, indent=1, sort_keys=True)
        f.write("\n")


def attn_output_error(k_cache, k_pruned, v_cache, v_pruned, rng, n_q=16):
    """Mean relative decode-attention output error (accuracy proxy)."""
    from repro.core.attention import decode_attention_dense
    B, H, T, d = k_cache.shape
    L = jnp.full((B,), T)
    errs = []
    for _ in range(n_q):
        q = jnp.asarray(rng.normal(size=(B, H, d)).astype(np.float32))
        ref = decode_attention_dense(q, k_cache, v_cache, L)
        out = decode_attention_dense(q, k_pruned, v_pruned, L)
        errs.append(float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref)))
    return float(np.mean(errs))


def synthetic_kv(rng, B=2, H=4, T=256, d=128, key_like=True):
    """Key caches get outlier channels (paper Fig. 2a); Values are uniform."""
    x = rng.normal(size=(B, H, T, d)).astype(np.float32)
    if key_like:
        outliers = rng.choice(d, size=max(4, d // 16), replace=False)
        x[..., outliers] *= 8.0
    return jnp.asarray(x)
