"""Paper Fig. 6a: decode-kernel latency breakdown.

Two views per component (this container has no TPU):
  * measured — wall-clock of the jit'd jnp formulation on CPU (relative
    sanity between components);
  * modeled — HBM-bytes/819GB/s on the v5e target (the quantity the paper's
    normalized-latency plot reports, since decode is memory-bound).
Components mirror Fig. 6a: dense batched MV (cuBLAS analogue), batched SpMV
over the compressed cache, dense MV of the local window, runtime pruning,
and compression.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from functools import partial

from benchmarks.common import emit, time_fn
from repro.configs import get_config
from repro.core.attention import (MustafarCacheView, decode_attention_dense,
                                  decode_attention_mustafar_chunked,
                                  hbm_bytes_dense, hbm_bytes_mustafar)
from repro.core.sparse_format import pack_fixedk, topk_mask
from repro.kernels import ref as kref
from repro.roofline import HBM_BW


def main(rng=None) -> None:
    rng = rng or np.random.default_rng(2)
    for arch, T in (("llama2-7b", 2048), ("llama3-8b", 4096)):
        cfg = get_config(arch)
        # one layer's decode operands, batch 1 (paper: per-kernel breakdown)
        B, Hkv, Hq, d = 1, cfg.n_kv_heads, cfg.n_heads, cfg.d_head
        W = cfg.mustafar.local_window
        s = 0.7
        kk = cfg.mustafar.keep_k(d, s)
        k_cache = jnp.asarray(rng.normal(size=(B, Hkv, T, d))
                              ).astype(jnp.bfloat16)
        v_cache = jnp.asarray(rng.normal(size=(B, Hkv, T, d))
                              ).astype(jnp.bfloat16)
        q = jnp.asarray(rng.normal(size=(B, Hq, d))).astype(jnp.bfloat16)
        L = jnp.full((B,), T)

        # dense decode MV (cuBLAS analogue)
        f_dense = jax.jit(lambda q, k, v: decode_attention_dense(q, k, v, L))
        us_dense = time_fn(f_dense, q, k_cache, v_cache)
        by_dense = 2 * Hkv * T * d * 2
        t_dense = by_dense / HBM_BW * 1e6
        emit(f"fig6a/{arch}/dense_mv", us_dense,
             f"model_us={t_dense:.1f} bytes={by_dense}")

        # pruning (top-k mask) + compression (pack) on one tile group
        tile = cfg.mustafar.tile_tokens
        k_tile = k_cache[:, :, :tile, :]
        f_prune = jax.jit(lambda x: topk_mask(x, kk))
        us_prune = time_fn(f_prune, k_tile)
        f_pack = jax.jit(lambda x: pack_fixedk(x, topk_mask(x, kk), kk))
        us_pack = time_fn(f_pack, k_tile)
        amort = T / tile  # one tile compression per tile_tokens decode steps
        emit(f"fig6a/{arch}/prune", us_prune,
             f"pct_of_dense={us_prune/amort/us_dense*100:.2f}% (amortized)")
        emit(f"fig6a/{arch}/compress", us_pack,
             f"pct_of_dense={us_pack/amort/us_dense*100:.2f}% (amortized)")

        # SpMV over compressed + window MV (Mustafar attention)
        km = topk_mask(k_cache, kk)
        vm = topk_mask(v_cache, kk)
        ckv, ckb = pack_fixedk(k_cache, km, kk)
        cvv, cvb = pack_fixedk(v_cache, vm, kk)
        k_win = k_cache[:, :, :W + tile, :]
        v_win = v_cache[:, :, :W + tile, :]
        view = MustafarCacheView(ckv, ckb, cvv, cvb, jnp.full((B,), T),
                                 k_win, v_win, jnp.full((B,), W))
        f_sp = jax.jit(partial(decode_attention_mustafar_chunked,
                               chunk=min(4096, T)))
        us_sp = time_fn(f_sp, q, view)
        by_sp = hbm_bytes_mustafar(T, W, d, kk, kk) * Hkv
        t_sp = by_sp / HBM_BW * 1e6
        emit(f"fig6a/{arch}/spmv_plus_window", us_sp,
             f"model_us={t_sp:.1f} model_pct_of_dense="
             f"{by_sp/by_dense*100:.1f}%")


if __name__ == "__main__":
    main()
