"""Paper Fig. 6a: decode-kernel latency breakdown + PR-2 kernel overhaul.

Two views per component (this container has no TPU):
  * measured — wall-clock of the jit'd jnp formulation on CPU (relative
    sanity between components);
  * modeled — HBM-bytes/819GB/s on the v5e target (the quantity the paper's
    normalized-latency plot reports, since decode is memory-bound).
Components mirror Fig. 6a: dense batched MV (cuBLAS analogue), batched SpMV
over the compressed cache, dense MV of the local window, runtime pruning,
and compression.

The ``kernels/`` components time the ACTUAL kernel-body formulations (the
same jnp the Pallas kernels execute per tile) against the legacy
formulations they replaced — one-hot decompression vs gather, rank-cube
top-k vs threshold search — and record modeled compressed-cache bytes at
bf16 vs fp32 value width, so the overhaul's ≥2× gains are machine-checked
in BENCH_kernels.json across PRs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from functools import partial

from benchmarks.common import emit, time_fn
from repro.configs import get_config
from repro.core.attention import (MustafarCacheView, decode_attention_dense,
                                  decode_attention_mustafar_chunked,
                                  hbm_bytes_dense, hbm_bytes_mustafar)
from repro.core.sparse_format import (pack_fixedk, pad_to_words, topk_mask)
from repro.kernels import legacy
from repro.kernels import ref as kref
from repro.kernels.bitmap_compress import (_compact_gather,
                                           _topk_threshold_keep)
from repro.kernels.sparse_decode import _decompress
from repro.roofline import HBM_BW


def _bench_overhaul(rng) -> None:
    """kernels/: new vs legacy kernel-body formulations (d=128, k=40 ≈ the
    paper's s=0.7 keep), timed as jit'd jnp on CPU + modeled HBM bytes."""
    d, k, T, R = 128, 40, 2048, 4
    W32 = pad_to_words(d) // 32
    x = jnp.asarray(rng.normal(size=(R, T, d)).astype(np.float32)
                    ).astype(jnp.bfloat16)
    vals, bm = kref.mustafar_compress_ref(x, k)

    # --- decompression: gather expansion vs legacy one-hot contraction ---
    f_new = jax.jit(jax.vmap(partial(_decompress, d=d, k=k)))
    f_old = jax.jit(jax.vmap(partial(legacy.decompress_onehot, k=k)))
    us_new = time_fn(f_new, vals, bm)
    us_old = time_fn(f_old, vals, bm)
    by_tile = R * T * (k * 2 + W32 * 4)         # bf16 values + bitmap words
    emit("kernels/decompress_gather", us_new,
         f"speedup_vs_onehot={us_old/us_new:.1f}x",
         hbm_bytes=by_tile, speedup_vs_legacy=us_old / us_new)
    emit("kernels/decompress_onehot_legacy", us_old, hbm_bytes=by_tile)

    # --- compress selection+compaction: threshold+gather vs rank cube ---
    tile = 64
    xt = x[:, :tile, :]

    def comp_new(xr):
        keep = _topk_threshold_keep(xr, k, d)
        return _compact_gather(xr, keep, k), keep

    def comp_old(xr):
        keep = legacy.topk_mask_rankcube(xr, k, d)
        return legacy.compact_onehot(xr, keep, k), keep

    f_cnew = jax.jit(jax.vmap(comp_new))
    f_cold = jax.jit(jax.vmap(comp_old))
    us_cnew = time_fn(f_cnew, xt)
    us_cold = time_fn(f_cold, xt)
    by_comp = R * tile * d * 2                  # read one bf16 tile group
    emit("kernels/compress_threshold", us_cnew,
         f"speedup_vs_rankcube={us_cold/us_cnew:.1f}x tile_t={tile}",
         hbm_bytes=by_comp, speedup_vs_legacy=us_cold / us_cnew)
    emit("kernels/compress_rankcube_legacy", us_cold, hbm_bytes=by_comp)

    # --- compressed-cache byte model: bf16 pools vs an fp32-value pool ---
    by_bf16 = hbm_bytes_mustafar(T, 0, d, k, k, itemsize=2)
    by_fp32 = hbm_bytes_mustafar(T, 0, d, k, k, itemsize=4)
    emit("kernels/compressed_bytes_bf16", by_bf16 / HBM_BW * 1e6,
         f"vs_fp32={by_fp32/by_bf16:.2f}x",
         hbm_bytes=by_bf16, hbm_bytes_fp32=by_fp32)

    # --- DMA-skip model: ragged rows pay bytes for their own depth only ---
    n_valid = np.array([T, T // 2, T // 8, 0])
    by_ragged = int(sum(hbm_bytes_mustafar(int(nv), 0, d, k, k)
                        for nv in n_valid))
    by_full = hbm_bytes_mustafar(T, 0, d, k, k) * len(n_valid)
    emit("kernels/fused_dma_skip", by_ragged / HBM_BW * 1e6,
         f"bytes_vs_full_pool={by_ragged/by_full:.2f}x",
         hbm_bytes=by_ragged, hbm_bytes_no_skip=by_full)


def dispatch_overhead_main(rng=None) -> int:
    """Calibrate ``roofline.TILE_OVERHEAD_BYTES`` from a measured dispatch.

    The paged decode kernel pays a fixed per-grid-step cost (DMA issue +
    scalar-prefetch index math) that ``auto_page_tokens`` models in
    HBM-byte equivalents. This micro-benchmark measures it by DIFFERENCE:
    the same compressed stream is decoded once as many small chunks and
    once as one big chunk — identical bytes, different step counts — so

        overhead_s    = (t_many - t_one) / (n_many - n_one)
        overhead_bytes = overhead_s * HBM_BW          (819e9 on v5e)

    and prints the ``REPRO_TILE_OVERHEAD_BYTES`` export to re-fit the
    page-size model to THIS machine without editing source."""
    import os

    from repro.roofline import _tile_overhead_bytes

    rng = rng or np.random.default_rng(7)
    d, k, T = 128, 40, 2048
    B, Hkv, Hq = 1, 4, 8
    chunk_small, chunk_big = 128, T
    x = jnp.asarray(rng.normal(size=(B, Hkv, T, d))).astype(jnp.bfloat16)
    y = jnp.asarray(rng.normal(size=(B, Hkv, T, d))).astype(jnp.bfloat16)
    ckv, ckb = pack_fixedk(x, topk_mask(x, k), k)
    cvv, cvb = pack_fixedk(y, topk_mask(y, k), k)
    W = 8
    view = MustafarCacheView(ckv, ckb, cvv, cvb, jnp.full((B,), T),
                             x[:, :, :W + 16, :], y[:, :, :W + 16, :],
                             jnp.full((B,), W))
    q = jnp.asarray(rng.normal(size=(B, Hq, d))).astype(jnp.bfloat16)
    f_many = jax.jit(partial(decode_attention_mustafar_chunked,
                             chunk=chunk_small))
    f_one = jax.jit(partial(decode_attention_mustafar_chunked,
                            chunk=chunk_big))
    us_many = time_fn(f_many, q, view, iters=9)
    us_one = time_fn(f_one, q, view, iters=9)
    n_many, n_one = T // chunk_small, T // chunk_big
    raw_s = (us_many - us_one) * 1e-6 / (n_many - n_one)
    per_step_s = max(0.0, raw_s)
    if raw_s <= 0:
        print("# NOTE: negative/zero difference — no measurable per-step "
              "cost on this backend (typical off-TPU, where there is no "
              "DMA issue to pay); calibrate on the serving target")
    suggested = int(round(per_step_s * HBM_BW))
    current = _tile_overhead_bytes()
    emit("kernels/dispatch_overhead", per_step_s * 1e6,
         f"suggested_tile_overhead_bytes={suggested} (current {current})",
         suggested_tile_overhead_bytes=suggested,
         current_tile_overhead_bytes=current,
         chunk_steps=(n_many, n_one))
    print(f"# per-step dispatch overhead: {per_step_s*1e6:.1f} us "
          f"({n_many} vs {n_one} chunks over T={T})")
    print(f"# suggested calibration (overhead_s * {HBM_BW:.0f} B/s):")
    print(f"export REPRO_TILE_OVERHEAD_BYTES={suggested}")
    if os.environ.get("REPRO_TILE_OVERHEAD_BYTES"):
        print("# (env override currently active: "
              f"{os.environ['REPRO_TILE_OVERHEAD_BYTES']})")
    return suggested


def main(rng=None) -> None:
    rng = rng or np.random.default_rng(2)
    _bench_overhaul(rng)
    for arch, T in (("llama2-7b", 2048), ("llama3-8b", 4096)):
        cfg = get_config(arch)
        # one layer's decode operands, batch 1 (paper: per-kernel breakdown)
        B, Hkv, Hq, d = 1, cfg.n_kv_heads, cfg.n_heads, cfg.d_head
        W = cfg.mustafar.local_window
        s = 0.7
        kk = cfg.mustafar.keep_k(d, s)
        k_cache = jnp.asarray(rng.normal(size=(B, Hkv, T, d))
                              ).astype(jnp.bfloat16)
        v_cache = jnp.asarray(rng.normal(size=(B, Hkv, T, d))
                              ).astype(jnp.bfloat16)
        q = jnp.asarray(rng.normal(size=(B, Hq, d))).astype(jnp.bfloat16)
        L = jnp.full((B,), T)

        # dense decode MV (cuBLAS analogue)
        f_dense = jax.jit(lambda q, k, v: decode_attention_dense(q, k, v, L))
        us_dense = time_fn(f_dense, q, k_cache, v_cache)
        by_dense = 2 * Hkv * T * d * 2
        t_dense = by_dense / HBM_BW * 1e6
        emit(f"fig6a/{arch}/dense_mv", us_dense,
             f"model_us={t_dense:.1f} bytes={by_dense}",
             hbm_bytes=by_dense, model_us=t_dense)

        # pruning (top-k mask) + compression (pack) on one tile group
        tile = cfg.mustafar.tile_tokens
        k_tile = k_cache[:, :, :tile, :]
        f_prune = jax.jit(lambda x: topk_mask(x, kk))
        us_prune = time_fn(f_prune, k_tile)
        f_pack = jax.jit(lambda x: pack_fixedk(x, topk_mask(x, kk), kk))
        us_pack = time_fn(f_pack, k_tile)
        amort = T / tile  # one tile compression per tile_tokens decode steps
        emit(f"fig6a/{arch}/prune", us_prune,
             f"pct_of_dense={us_prune/amort/us_dense*100:.2f}% (amortized)")
        emit(f"fig6a/{arch}/compress", us_pack,
             f"pct_of_dense={us_pack/amort/us_dense*100:.2f}% (amortized)")

        # SpMV over compressed + window MV (Mustafar attention)
        km = topk_mask(k_cache, kk)
        vm = topk_mask(v_cache, kk)
        ckv, ckb = pack_fixedk(k_cache, km, kk)
        cvv, cvb = pack_fixedk(v_cache, vm, kk)
        k_win = k_cache[:, :, :W + tile, :]
        v_win = v_cache[:, :, :W + tile, :]
        view = MustafarCacheView(ckv, ckb, cvv, cvb, jnp.full((B,), T),
                                 k_win, v_win, jnp.full((B,), W))
        f_sp = jax.jit(partial(decode_attention_mustafar_chunked,
                               chunk=min(4096, T)))
        us_sp = time_fn(f_sp, q, view)
        by_sp = hbm_bytes_mustafar(T, W, d, kk, kk) * Hkv
        t_sp = by_sp / HBM_BW * 1e6
        emit(f"fig6a/{arch}/spmv_plus_window", us_sp,
             f"model_us={t_sp:.1f} model_pct_of_dense="
             f"{by_sp/by_dense*100:.1f}%",
             hbm_bytes=by_sp, model_us=t_sp)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dispatch-overhead", action="store_true",
                    help="measure the per-grid-step kernel dispatch cost "
                         "and print the suggested "
                         "REPRO_TILE_OVERHEAD_BYTES calibration")
    args = ap.parse_args()
    if args.dispatch_overhead:
        dispatch_overhead_main()
    else:
        main()
