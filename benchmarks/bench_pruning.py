"""Paper Tables 1/2/3/11/12 + Appendix A: pruning-strategy accuracy proxies.

LongBench + pretrained Llama are unavailable offline; the accuracy metric is
the mean relative decode-attention output error on caches with the paper's
magnitude distributions (Key: outlier channels, Value: uniform). The paper's
claimed ORDERINGS are what these benches reproduce:
  Table 1: Key   — unstructured (mag/output-aware) beats ThinK at 0.5/0.7
  Table 2: Value — per-token mag beats per-channel mag; output-aware rescues
                   per-channel; structured worst
  Table 12: 2:4 semi-structured worse than unstructured at the same 0.5
  Table 11: 0.8/0.9 sparsity degrade gracefully (V more robust than K)
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import attn_output_error, emit, synthetic_kv
from repro.core import pruning


def key_strategies(rng) -> None:
    k = synthetic_kv(rng, key_like=True)
    v = synthetic_kv(rng, key_like=False)
    q_acc = jnp.asarray(np.abs(rng.normal(size=(2, 4, 128))).astype(np.float32))
    for s in (0.5, 0.7):
        rows = {
            "think_structured": pruning.prune(k, s, "think", q_acc=q_acc),
            "unstructured_magnitude": pruning.prune(k, s, "per_token_magnitude"),
            "unstructured_output_aware": pruning.prune(
                k, s, "per_token_output_aware", q_acc=q_acc),
        }
        for name, kp in rows.items():
            err = attn_output_error(k, kp, v, v, rng)
            emit(f"table1/key_s{s}/{name}", 0.0, f"rel_err={err:.4f}")


def value_strategies(rng) -> None:
    k = synthetic_kv(rng, key_like=True)
    v = synthetic_kv(rng, key_like=False)
    attn_acc = jnp.asarray(np.abs(rng.normal(size=(2, 4, 256))
                                  ).astype(np.float32))
    for s in (0.5, 0.7):
        rows = {
            "think_structured": pruning.prune(
                v, s, "think",
                q_acc=jnp.asarray(np.abs(rng.normal(size=(2, 4, 128))
                                         ).astype(np.float32))),
            "per_channel_magnitude": pruning.prune(v, s, "per_channel_magnitude"),
            "per_channel_output_aware": pruning.prune(
                v, s, "per_channel_output_aware", attn_acc=attn_acc),
            "per_token_magnitude": pruning.prune(v, s, "per_token_magnitude"),
        }
        for name, vp in rows.items():
            err = attn_output_error(k, k, v, vp, rng)
            emit(f"table2/value_s{s}/{name}", 0.0, f"rel_err={err:.4f}")


def joint(rng) -> None:
    """Table 3: joint K+V per-token magnitude pruning across sparsities."""
    k = synthetic_kv(rng, key_like=True)
    v = synthetic_kv(rng, key_like=False)
    for ks, vs in ((0.5, 0.0), (0.7, 0.0), (0.0, 0.5), (0.0, 0.7),
                   (0.5, 0.5), (0.7, 0.7)):
        kp = pruning.prune(k, ks, "per_token_magnitude") if ks else k
        vp = pruning.prune(v, vs, "per_token_magnitude") if vs else v
        err = attn_output_error(k, kp, v, vp, rng)
        emit(f"table3/K{ks}_V{vs}", 0.0, f"rel_err={err:.4f}")


def semi_structured(rng) -> None:
    """Appendix B / Table 12: 2:4 vs unstructured at 50%."""
    k = synthetic_kv(rng, key_like=True)
    v = synthetic_kv(rng, key_like=False)
    pairs = {
        "K0.5_2to4": (pruning.prune(k, 0.5, "semi_structured_2_4"), v),
        "K0.5_unstructured": (pruning.prune(k, 0.5, "per_token_magnitude"), v),
    }
    for name, (kp, vp) in pairs.items():
        emit(f"table12/{name}", 0.0,
             f"rel_err={attn_output_error(k, kp, v, vp, rng):.4f}")
    vpairs = {
        "V0.5_2to4": pruning.prune(v, 0.5, "semi_structured_2_4"),
        "V0.5_unstructured": pruning.prune(v, 0.5, "per_token_magnitude"),
    }
    for name, vp in vpairs.items():
        emit(f"table12/{name}", 0.0,
             f"rel_err={attn_output_error(k, k, v, vp, rng):.4f}")


def high_sparsity(rng) -> None:
    """Table 11: 0.8 / 0.9 sparsity."""
    k = synthetic_kv(rng, key_like=True)
    v = synthetic_kv(rng, key_like=False)
    for s in (0.8, 0.9):
        kp = pruning.prune(k, s, "per_token_magnitude")
        vp = pruning.prune(v, s, "per_token_magnitude")
        emit(f"table11/K{s}", 0.0,
             f"rel_err={attn_output_error(k, kp, v, v, rng):.4f}")
        emit(f"table11/V{s}", 0.0,
             f"rel_err={attn_output_error(k, k, v, vp, rng):.4f}")


def main(rng=None) -> None:
    rng = rng or np.random.default_rng(0)
    key_strategies(rng)
    value_strategies(rng)
    joint(rng)
    semi_structured(rng)
    high_sparsity(rng)


if __name__ == "__main__":
    main()
