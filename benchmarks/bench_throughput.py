"""Paper Fig. 7: decode throughput, Mustafar vs dense.

Decode is memory-bound, so tokens/sec is modeled from per-step HBM traffic
on the v5e target (819 GB/s, 16 GiB HBM): params + KV reads per step, plus
amortized prune/compress overhead for Mustafar. The paper's two effects both
reproduce: (a) higher tokens/s at equal batch, (b) larger feasible batch
before HBM exhaustion -> up to ~2.2x total throughput.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.roofline import HBM_BW
from repro.serving.cache import cache_hbm_bytes

# Capacity matches the paper's efficiency setup (RTX 6000 Ada, 48 GB) so the
# batch-size-feasibility effect reproduces; bandwidth stays the v5e target.
# (On v5e the same model would be TP-sharded: see the dry-run cells.)
HBM_CAP = 48 * 2**30
COMPRESS_OVERHEAD = 0.02      # prune+compress, amortized (paper: 1.5-8%)


def step_time_s(cfg, B, T, mustafar: bool) -> float:
    acct = cache_hbm_bytes(cfg, B, T)
    cache = acct["mustafar"] if mustafar else acct["dense"]
    params = cfg.param_count() * 2                  # bf16 weights read
    t = (params + cache) / HBM_BW
    if mustafar:
        t *= (1 + COMPRESS_OVERHEAD)
    return t


def fits(cfg, B, T, mustafar: bool) -> bool:
    acct = cache_hbm_bytes(cfg, B, T)
    cache = acct["mustafar"] if mustafar else acct["dense"]
    return cfg.param_count() * 2 + cache < HBM_CAP * 0.9


def main(rng=None) -> None:
    for arch, ctx in (("llama2-7b", 4096), ("llama3-8b", 8192)):
        cfg = get_config(arch)
        best = {True: 0.0, False: 0.0}
        for mustafar in (False, True):
            tag = "mustafar" if mustafar else "dense"
            for B in (1, 2, 4, 6, 8, 12, 16, 24, 32):
                if not fits(cfg, B, ctx, mustafar):
                    emit(f"fig7/{arch}/{tag}/batch{B}", 0.0, "OOM")
                    continue
                t = step_time_s(cfg, B, ctx, mustafar)
                tps = B / t
                best[mustafar] = max(best[mustafar], tps)
                emit(f"fig7/{arch}/{tag}/batch{B}", t * 1e6,
                     f"tokens_per_s={tps:.1f}")
        if best[False] > 0:
            emit(f"fig7/{arch}/speedup_best_batch", 0.0,
                 f"{best[True]/best[False]:.2f}x (paper: up to 2.23x)")


if __name__ == "__main__":
    main()
