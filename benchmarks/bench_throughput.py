"""Paper Fig. 7: decode throughput, Mustafar vs dense.

Decode is memory-bound, so tokens/sec is modeled from per-step HBM traffic
on the v5e target (819 GB/s, 16 GiB HBM): params + KV reads per step, plus
amortized prune/compress overhead for Mustafar. The paper's two effects both
reproduce: (a) higher tokens/s at equal batch, (b) larger feasible batch
before HBM exhaustion -> up to ~2.2x total throughput.

``--scheduler`` additionally runs the LIVE continuous-batching path: a
reduced model served end-to-end by the Scheduler under a Poisson arrival
trace with ragged prompts, reporting measured tokens/sec and batch
occupancy (the lockstep engine would idle slots between uneven requests;
the scheduler keeps them > 80% busy under load).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.roofline import HBM_BW
from repro.serving.cache import cache_hbm_bytes

# Capacity matches the paper's efficiency setup (RTX 6000 Ada, 48 GB) so the
# batch-size-feasibility effect reproduces; bandwidth stays the v5e target.
# (On v5e the same model would be TP-sharded: see the dry-run cells.)
HBM_CAP = 48 * 2**30
COMPRESS_OVERHEAD = 0.02      # prune+compress, amortized (paper: 1.5-8%)


def step_time_s(cfg, B, T, mustafar: bool) -> float:
    acct = cache_hbm_bytes(cfg, B, T)
    cache = acct["mustafar"] if mustafar else acct["dense"]
    params = cfg.param_count() * 2                  # bf16 weights read
    t = (params + cache) / HBM_BW
    if mustafar:
        t *= (1 + COMPRESS_OVERHEAD)
    return t


def fits(cfg, B, T, mustafar: bool) -> bool:
    acct = cache_hbm_bytes(cfg, B, T)
    cache = acct["mustafar"] if mustafar else acct["dense"]
    return cfg.param_count() * 2 + cache < HBM_CAP * 0.9


def main(rng=None) -> None:
    for arch, ctx in (("llama2-7b", 4096), ("llama3-8b", 8192)):
        cfg = get_config(arch)
        best = {True: 0.0, False: 0.0}
        for mustafar in (False, True):
            tag = "mustafar" if mustafar else "dense"
            for B in (1, 2, 4, 6, 8, 12, 16, 24, 32):
                if not fits(cfg, B, ctx, mustafar):
                    emit(f"fig7/{arch}/{tag}/batch{B}", 0.0, "OOM")
                    continue
                t = step_time_s(cfg, B, ctx, mustafar)
                tps = B / t
                best[mustafar] = max(best[mustafar], tps)
                emit(f"fig7/{arch}/{tag}/batch{B}", t * 1e6,
                     f"tokens_per_s={tps:.1f}")
        if best[False] > 0:
            emit(f"fig7/{arch}/speedup_best_batch", 0.0,
                 f"{best[True]/best[False]:.2f}x (paper: up to 2.23x)")


def scheduler_main(arch: str = "starcoder2-3b", n_slots: int = 4,
                   n_requests: int = 16, gen: int = 24, rate: float = 1.0,
                   sparsity: float = 0.7, seed: int = 0) -> dict:
    """Live continuous-batching run: Poisson arrivals, ragged prompts."""
    import time

    import jax

    from repro.models import init_params
    from repro.serving.engine import Request, Scheduler

    cfg = get_config(arch).reduced().with_sparsity(sparsity, sparsity)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    max_total = 64 + gen + 64
    sched = Scheduler(cfg, params, n_slots=n_slots,
                      max_total_tokens=max_total)
    arrivals = np.cumsum(rng.exponential(1.0 / rate,
                                         size=n_requests)).astype(int)
    buckets = (16, 24, 40)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.choice(buckets))),
                    max_new_tokens=gen)
            for _ in range(n_requests)]

    t0 = time.perf_counter()
    i = 0
    while i < n_requests or sched.has_work:
        while i < n_requests and arrivals[i] <= sched.step_count:
            sched.submit(reqs[i])
            i += 1
        sched.step()
    dt = time.perf_counter() - t0
    new_tokens = sum(r.num_generated for r in sched.finished)
    tps = new_tokens / dt
    st = sched.stats()
    occ = st["occupancy"]["slots"]
    emit(f"fig7/scheduler/{arch}/slots{n_slots}", dt * 1e6 / max(1, new_tokens),
         f"tokens_per_s={tps:.1f} occupancy={occ*100:.1f}%",
         metrics=st)
    return {"tokens_per_s": tps, "occupancy": occ,
            "steps": sched.step_count, "requests": len(sched.finished)}


def paging_main(rng=None, smoke: bool = False) -> dict:
    """BENCH_paging: paged vs contiguous pools on a heterogeneous-length
    Poisson trace (the slot-size-decoupling payoff).

    Both runs serve the SAME seeded trace — a mix of short chatty requests
    and a few long generations — through the live Scheduler. Timing is
    STEADY-STATE: each scheduler first drains a tiny warmup trace covering
    every prefill shape in the benchmark (jit compiles land there), then
    the seeded trace is served and timed — so the tok/s ratio compares the
    hot paths, not XLA compile times. Reported per mode: measured decode
    tokens/sec (CPU reference path), peak compressed-pool HBM bytes, and
    TTFT p50/p99 over the timed requests. Contiguous
    allocation pays ``n_slots × Tc_max`` token rows up front regardless of
    what the trace uses; paged allocation pays only the high-water mark of
    drawn pages (+ the int32 block table), which on this trace is well
    over the 20% saving the acceptance bar asks for. The paged run uses
    the full PR-6 hot path — batched page draws + fused epilogue
    compaction — and must hold ≥ 0.95× contiguous tokens/sec (the CI
    smoke gate; the committed full run clears 1.0×).

    ``smoke=True`` (CI) serves a shortened trace — same shape, fewer and
    shorter generations — so the gate runs in minutes on the CPU
    interpreter path."""
    import time

    import jax

    from repro.models import init_params
    from repro.serving.cache import page_bytes, plan_pages, plan_pools
    from repro.serving.engine import Request, Scheduler

    arch, n_slots, seed = "starcoder2-3b", 4, 0
    n_requests = 8 if smoke else 14
    cfg = get_config(arch).reduced().with_sparsity(0.7, 0.7)
    params = init_params(jax.random.PRNGKey(0), cfg)
    m = cfg.mustafar
    page_tokens = 2 * m.tile_tokens
    max_total = 160                      # sized for the longest request

    def trace():
        r = np.random.default_rng(seed)
        arrivals = np.cumsum(r.exponential(1.0, size=n_requests)).astype(int)
        lens = r.choice((12, 20, 28, 48), size=n_requests, p=(.4, .3, .2, .1))
        gen_buckets = (8, 16, 32) if smoke else (8, 16, 96)
        gens = r.choice(gen_buckets, size=n_requests, p=(.5, .3, .2))
        reqs = [Request(prompt=r.integers(0, cfg.vocab_size, size=int(L)),
                        max_new_tokens=int(g))
                for L, g in zip(lens, gens)]
        return arrivals, reqs

    def serve(paged: bool):
        sched = Scheduler(cfg, params, n_slots=n_slots,
                          max_total_tokens=max_total,
                          page_tokens=page_tokens if paged else None,
                          fused_compaction=paged)
        # warmup: one request per prompt-length bucket (each prefill length
        # is its own jit specialization) + enough decode to compile the
        # step; drained before the clock starts
        wr = np.random.default_rng(10_000 + seed)
        for L in (12, 20, 28, 48):
            sched.submit(Request(prompt=wr.integers(0, cfg.vocab_size,
                                                    size=L),
                         max_new_tokens=2))
        while sched.has_work:
            sched.step()
        n_warm, base = len(sched.finished), sched.step_count
        arrivals, reqs = trace()
        t0 = time.perf_counter()
        i = 0
        while i < n_requests or sched.has_work:
            while i < n_requests and arrivals[i] + base <= sched.step_count:
                sched.submit(reqs[i])
                i += 1
            sched.step()
        dt = time.perf_counter() - t0
        timed = sched.finished[n_warm:]
        toks = sum(r.num_generated for r in timed)
        ttft = [r.first_token_step - r.arrival_step for r in timed]
        return sched, dt, toks, ttft

    pb = page_bytes(cfg, page_tokens)
    Tc_max, _ = plan_pools(cfg, max_total, batch=n_slots)
    max_pages = plan_pages(cfg, max_total, page_tokens, batch=n_slots)
    # contiguous pools in page-equivalent units: n_slots * Tc_max token rows
    contig_bytes = n_slots * (Tc_max // page_tokens + (Tc_max % page_tokens > 0)) \
        * pb

    sched_c, dt_c, toks_c, ttft_c = serve(paged=False)
    st_c = sched_c.stats()
    emit("paging/contiguous", dt_c * 1e6 / max(1, toks_c),
         f"tokens_per_s={toks_c/dt_c:.1f} "
         f"occupancy={st_c['occupancy']['slots']*100:.1f}%",
         peak_pool_bytes=contig_bytes, tokens_per_s=toks_c / dt_c,
         ttft_steps_p50=float(np.percentile(ttft_c, 50)),
         ttft_steps_p99=float(np.percentile(ttft_c, 99)),
         metrics=st_c)

    sched_p, dt_p, toks_p, ttft_p = serve(paged=True)
    peak = sched_p.allocator.peak_in_use
    meta = 4 * n_slots * max_pages
    paged_bytes = peak * pb + meta
    saving = 1.0 - paged_bytes / contig_bytes
    speed_ratio = (toks_p / dt_p) / (toks_c / dt_c)
    emit("paging/paged", dt_p * 1e6 / max(1, toks_p),
         f"tokens_per_s={toks_p/dt_p:.1f} ({speed_ratio:.2f}x contiguous) "
         f"peak_pages={peak}/"
         f"{sched_p.n_pages} saving={saving*100:.1f}%",
         peak_pool_bytes=paged_bytes, tokens_per_s=toks_p / dt_p,
         peak_pages=peak, page_tokens=page_tokens,
         pool_bytes_saving=saving, speed_ratio_vs_contiguous=speed_ratio,
         ttft_steps_p50=float(np.percentile(ttft_p, 50)),
         ttft_steps_p99=float(np.percentile(ttft_p, 99)),
         metrics=sched_p.stats())
    assert toks_p == toks_c, (toks_p, toks_c)   # same trace, same tokens
    assert saving >= 0.2, f"paging saved only {saving*100:.1f}% (<20%)"
    assert speed_ratio >= 0.95, \
        f"paged decode at {speed_ratio:.2f}x contiguous (< 0.95x gate)"
    return {"saving": saving, "peak_pages": peak,
            "tokens_per_s_paged": toks_p / dt_p,
            "tokens_per_s_contiguous": toks_c / dt_c,
            "speed_ratio": speed_ratio}


def prefix_main(rng=None) -> dict:
    """BENCH_prefix: shared-prefix CoW paging + chunked prefill vs the
    no-sharing baseline (the PR-5 serving-tier payoff).

    One seeded Poisson trace of requests that all carry the same 56-token
    system prefix plus a short private suffix (the chat-template pattern
    prefix sharing exists for) is served three ways through the live
    Scheduler on identical paged pools:

      * ``baseline``  — paged, no sharing: every request compresses and
        stores its own copy of the prefix pages;
      * ``shared``    — ``share_prefix=True``: admissions alias the retired
        prefix pages read-only (refcounted, copy-on-write at the boundary);
      * ``shared+chunked`` — sharing plus ``prefill_chunk``-token admission
        chunks, bounding the per-step decode stall to one chunk (the PR-5
        serial path: one admission advances per step, so concurrent
        arrivals queue and TTFT balloons);
      * ``shared+packed`` — the PR-6 hot path: same chunk size, but chunks
        from up to ``prefill_budget // chunk`` admissions pack into ONE
        batched ``prefill_chunk_step`` per engine step. The per-step
        executed-token bound moves from one chunk to the configured
        budget (still asserted), and the TTFT regression collapses — this
        run must land mean TTFT ≤ 15 steps (from 43.8 serial).

    Outputs must be IDENTICAL across all four (sharing is storage dedup,
    chunking and packing are exact-math re-schedules). Reported per mode:
    peak drawn pool bytes, mean/max/p50/p99 admission-to-first-token
    latency in engine steps, and per-step prefill-token stall percentiles.
    The acceptance bars are the peak-pool-bytes ratio baseline/shared
    >= 1.5x and the packed-mode TTFT collapse."""
    import time

    import jax

    from repro.models import init_params
    from repro.serving.cache import page_bytes, plan_pages
    from repro.serving.engine import Request, Scheduler

    arch, n_slots, n_requests, seed = "starcoder2-3b", 4, 12, 0
    prefix_len, chunk = 56, 8
    cfg = get_config(arch).reduced().with_sparsity(0.7, 0.7)
    params = init_params(jax.random.PRNGKey(0), cfg)
    page_tokens = cfg.mustafar.tile_tokens
    max_total = 128
    max_pages = plan_pages(cfg, max_total, page_tokens, batch=n_slots)

    def trace():
        r = np.random.default_rng(seed)
        prefix = list(r.integers(0, cfg.vocab_size, size=prefix_len))
        arrivals = np.cumsum(r.exponential(1.2, size=n_requests)).astype(int)
        lens = r.choice((4, 6, 8), size=n_requests)
        gens = r.choice((8, 16, 24), size=n_requests, p=(.4, .4, .2))
        reqs = [Request(prompt=np.asarray(
                            prefix + list(r.integers(0, cfg.vocab_size,
                                                     size=int(L)))),
                        max_new_tokens=int(g))
                for L, g in zip(lens, gens)]
        return arrivals, reqs

    def serve(share: bool, prefill_chunk=None, prefill_budget=None,
              pack: bool = False):
        sched = Scheduler(cfg, params, n_slots=n_slots,
                          max_total_tokens=max_total,
                          page_tokens=page_tokens, share_prefix=share,
                          prefill_chunk=prefill_chunk,
                          prefill_budget=prefill_budget,
                          pack_prefill=pack)
        arrivals, reqs = trace()
        t0 = time.perf_counter()
        i = 0
        while i < n_requests or sched.has_work:
            while i < n_requests and arrivals[i] <= sched.step_count:
                sched.submit(reqs[i])
                i += 1
            sched.step()
        dt = time.perf_counter() - t0
        toks = sum(r.num_generated for r in sched.finished)
        ttft = [r.first_token_step - r.arrival_step for r in sched.finished]
        return sched, reqs, dt, toks, ttft

    pb = page_bytes(cfg, page_tokens)
    # STORAGE metadata: the int32 block table is held once, shared by all
    # layers (same convention as paging_main and cache_hbm_bytes). The
    # n_attn-scaled roofline.paged_metadata_bytes models per-step READ
    # traffic, not pool residency — don't swap one in for the other.
    meta = 4 * n_slots * max_pages
    budget = chunk * n_slots             # packed mode: one chunk per slot
    results = {}
    outputs = {}
    ttft_means = {}
    modes = (("baseline", False, None, None, False),
             ("shared", True, None, None, False),
             ("shared+chunked", True, chunk, None, False),
             ("shared+packed", True, chunk, budget, True))
    for tag, share, pchunk, pbudget, pack in modes:
        sched, reqs, dt, toks, ttft = serve(share, pchunk, pbudget, pack)
        st = sched.stats()
        occ = st["occupancy"]
        peak_bytes = st["gauges"]["pool.pages_peak"] * pb + meta
        derived = (f"tokens_per_s={toks/dt:.1f} "
                   f"peak_pages={st['gauges']['pool.pages_peak']} "
                   f"ttft_steps_mean={np.mean(ttft):.1f}")
        extra = {}
        if share:
            extra["shared_admissions"] = sched.shared_admissions
            extra["prefix_hits"] = st["counters"]["prefix.hits"]
            extra["pages_shared_occupancy"] = occ["pages_shared"]
        if pchunk is not None:
            bound = pbudget if pbudget is not None else pchunk
            derived += (f" stall_max={sched.max_prefill_step_tokens}"
                        f"<=budget={bound}")
            extra["max_prefill_step_tokens"] = sched.max_prefill_step_tokens
            extra["prefill_tokens_per_step"] = occ["prefill_tokens_per_step"]
            extra["prefill_stall_p50"] = occ["prefill_stall_p50"]
            extra["prefill_stall_p99"] = occ["prefill_stall_p99"]
            assert sched.max_prefill_step_tokens <= bound
        emit(f"prefix/{tag}", dt * 1e6 / max(1, toks), derived,
             peak_pool_bytes=peak_bytes,
             peak_pages=st["gauges"]["pool.pages_peak"],
             ttft_steps_mean=float(np.mean(ttft)),
             ttft_steps_max=int(np.max(ttft)),
             ttft_steps_p50=occ["ttft_p50"], ttft_steps_p99=occ["ttft_p99"],
             tokens_per_s=toks / dt, page_tokens=page_tokens,
             metrics=st, **extra)
        results[tag] = peak_bytes
        outputs[tag] = [r.output_tokens for r in reqs]
        ttft_means[tag] = float(np.mean(ttft))

    assert all(outputs[t] == outputs["baseline"] for t, *_ in modes), \
        "modes diverged"
    saving = results["baseline"] / results["shared"]
    emit("prefix/peak_bytes_reduction", 0.0, f"{saving:.2f}x (bar: 1.5x)",
         reduction=saving)
    assert saving >= 1.5, f"sharing cut peak pool bytes only {saving:.2f}x"
    ttft = ttft_means["shared+packed"]
    emit("prefix/ttft_collapse", 0.0,
         f"packed mean TTFT {ttft:.1f} steps vs "
         f"{ttft_means['shared+chunked']:.1f} serial (bar: <=15)",
         ttft_steps_mean_packed=ttft,
         ttft_steps_mean_serial=ttft_means["shared+chunked"])
    assert ttft <= 15, f"packed mean TTFT {ttft:.1f} steps (> 15)"
    return {"reduction": saving, "ttft_mean_packed": ttft}


def sharding_main(rng=None, smoke: bool = False) -> dict:
    """BENCH_sharding: KV-head-sharded decode + the multi-engine router
    (the PR-7 tentpole), measured on an 8-virtual-device CPU mesh.

    The measurement runs in a SUBPROCESS (``benchmarks/sharding_worker.py``)
    because the virtual topology is an ``XLA_FLAGS`` setting that must be
    in place before jax first initializes its backend — this process is
    long past that point. The worker serves one seeded trace through a
    single-device Scheduler, a ``model=1`` mesh and a ``model=8`` mesh,
    then races a 4x4-slot Router against a 16-slot engine, and prints a
    ``SHARDING_JSON`` line this wrapper parses, emits and gates:

      * per-device peak pool bytes at model=8 <= single-device bytes / 8
        + replicated metadata (the layout contract — KV-head pool shards,
        block tables and counters replicate);
      * model=1 tok/s >= 0.95x single-device (shard_map wrapper overhead
        must be noise — the CI smoke gate);
      * router aggregate tok/s >= 1.5x the single engine at EQUAL total
        slots (static-shape waste reclaimed: idle replicas skip steps);
      * zero resharding collectives in the compiled decode (all-gather /
        all-to-all / collective-permute), only the logit all-reduces;
      * modeled fleet scale: per-device residency for 4096 slots on an
        8-way mesh (the thousands-of-slots regime no CPU host serves
        live)."""
    import json
    import os
    import subprocess
    import sys

    worker = os.path.join(os.path.dirname(__file__), "sharding_worker.py")
    cmd = [sys.executable, worker] + (["--smoke"] if smoke else [])
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=3000,
                          env=env, cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(worker))))
    line = next((ln for ln in proc.stdout.splitlines()
                 if ln.startswith("SHARDING_JSON ")), None)
    assert line is not None, (
        f"sharding worker died:\n{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
    r = json.loads(line[len("SHARDING_JSON "):])

    emit("sharding/decode_model8", 0.0,
         f"tokens_per_s={r['tokens_per_s_model8']:.1f} "
         f"per_device_bytes={r['per_device_bytes_model8']} "
         f"(bound {r['per_device_bound']:.0f}) "
         f"max_logit_err={r['model8_max_logit_err']:.1e}",
         tokens_per_s=r["tokens_per_s_model8"],
         per_device_bytes=r["per_device_bytes_model8"],
         single_device_bytes=r["single_device_bytes"],
         replicated_meta_bytes=r["replicated_meta_bytes"],
         collectives=r["decode_collectives"])
    emit("sharding/decode_model1", 0.0,
         f"tokens_per_s={r['tokens_per_s_model1']:.1f} "
         f"({r['speed_ratio_model1']:.2f}x single-device; gate 0.95x)",
         tokens_per_s=r["tokens_per_s_model1"],
         speed_ratio=r["speed_ratio_model1"])
    emit("sharding/router_4x4_vs_16", 0.0,
         f"tokens_per_s={r['tokens_per_s_router4x4']:.1f} vs "
         f"{r['tokens_per_s_single16']:.1f} single "
         f"({r['speed_ratio_router']:.2f}x; gate 1.5x) "
         f"per_engine={r['router_finished_per_engine']}",
         tokens_per_s_router=r["tokens_per_s_router4x4"],
         tokens_per_s_single=r["tokens_per_s_single16"],
         speed_ratio=r["speed_ratio_router"],
         router_occupancy=r["router_occupancy_slots"],
         single_occupancy=r["single_occupancy_slots"])
    emit("sharding/fleet_4096_slots", 0.0,
         f"per_device={r['fleet_per_device_bytes']/2**30:.1f}GiB of "
         f"{r['fleet_paged_bytes']/2**30:.1f}GiB total on 8 devices",
         fleet_slots=r["fleet_slots"], mesh_model=r["fleet_mesh_model"],
         paged_bytes=r["fleet_paged_bytes"],
         per_device_bytes=r["fleet_per_device_bytes"])

    assert r["per_device_bytes_model8"] <= r["per_device_bound"], \
        "sharded pool exceeds single/8 + replicated metadata"
    assert r["speed_ratio_model1"] >= 0.95, \
        f"model=1 mesh at {r['speed_ratio_model1']:.2f}x single (< 0.95x)"
    assert r["speed_ratio_router"] >= 1.5, \
        f"router at {r['speed_ratio_router']:.2f}x single engine (< 1.5x)"
    c = r["decode_collectives"]
    assert c["all-gather"] == c["all-to-all"] == c["collective-permute"] == 0
    assert c["all-reduce"] > 0
    return r


def preemption_main(rng=None, smoke: bool = False) -> dict:
    """BENCH_preemption: page-aware preemption + the hierarchical cache
    tier (HBM → host spool → restart persistence), the PR-8 tentpole.

    PHASE 1 — admission policies on an OVERCOMMITTED pool. One seeded
    Poisson trace mixes a background lane (priority 0, long generations
    that monopolize the page pool) with an interactive lane (priority 1,
    short requests). The pool is sized so one background request's
    worst-case reservation fills it — concurrent work MUST wait, shed, or
    preempt. The same trace is served three ways:

      * ``wait``    — head-of-line blocking (the pre-PR-8 behavior):
        everything completes, but interactive requests queue behind
        background ones for their whole lifetime (the p99 TTFT tail);
      * ``reject``  — admissions that cannot reserve shed immediately:
        the tail collapses, but shed requests are GONE (completions drop);
      * ``preempt`` — a blocked higher-priority admission swaps a
        lowest-priority victim's pages to the host ``PageSpool``
        (device_get of the gathered page leaves + window/state + the
        per-slot counters), admits, and restores the victim later by
        splicing the spooled bytes back. No recomputation happens, so
        every preempted request's outputs are BIT-IDENTICAL to the
        ``wait`` run's (asserted — the core correctness gate), and
        completions match ``wait`` while the interactive tail matches
        ``reject``.

    Gates: preempt completes >= 1.2x reject's requests (smoke: >= 1.0x,
    same direction on the shortened trace), >= 1 actual swap round-trip,
    bit-exact outputs, and the spool's measured ``bytes_out`` must equal
    the ``roofline.swap_bytes`` model (pages + window; the model's 12
    counter bytes/event are host ints the spool doesn't count).

    PHASE 2 — restart persistence. A builder scheduler serves a shared-
    prefix trace, then ``save_prefix_cache``. A WARM scheduler ``load``s
    the file (entries arrive spooled; the first admission promotes them
    onto fresh device pages) and serves new same-prefix requests against
    a COLD scheduler serving identically. Both are compile-warmed on a
    disjoint prefix family (including one demote→promote round so the
    scatter executables are hot) before per-step wall-clock timing.
    Warm-start mean TTFT must beat cold-start (asserted in the full run;
    smoke still asserts the warm run actually shared spooled chains and
    that outputs match cold exactly)."""
    import os
    import tempfile
    import time

    import jax

    from repro import roofline
    from repro.models import init_params
    from repro.serving.engine import Request, Scheduler

    arch, seed = "starcoder2-3b", 0
    cfg = get_config(arch).reduced().with_sparsity(0.7, 0.7)
    params = init_params(jax.random.PRNGKey(0), cfg)
    page_tokens = cfg.mustafar.tile_tokens
    max_total = 96
    n_slots = 4
    n_pages = 4          # one background request's worst case == the pool
    n_requests = 10 if smoke else 18
    bg_gen = 32 if smoke else 56

    def trace():
        r = np.random.default_rng(seed)
        arrivals = np.cumsum(r.exponential(2.0,
                                           size=n_requests)).astype(int)
        reqs = []
        for k in range(n_requests):
            if k % 3 == 1:           # interactive lane
                L, g, prio = int(r.choice((12, 16))), 16, 1
            else:                    # background lane
                L, g, prio = int(r.choice((16, 24))), bg_gen, 0
            reqs.append(Request(
                prompt=list(r.integers(0, cfg.vocab_size, size=L)),
                max_new_tokens=g, priority=prio))
        return arrivals, reqs

    def serve(policy: str):
        sched = Scheduler(cfg, params, n_slots=n_slots,
                          max_total_tokens=max_total,
                          page_tokens=page_tokens, n_pages=n_pages,
                          admission_policy=policy)
        arrivals, reqs = trace()
        i = 0
        while i < n_requests or sched.has_work:
            while i < n_requests and arrivals[i] <= sched.step_count:
                sched.submit(reqs[i])
                i += 1
            sched.step()
            assert sched.step_count < 20_000, f"{policy} failed to drain"
        return sched, reqs

    results = {}
    for policy in ("wait", "reject", "preempt"):
        sched, reqs = serve(policy)
        done = [r for r in reqs if r.done]
        ttft = [r.first_token_step - r.arrival_step for r in done]
        hi_ttft = [r.first_token_step - r.arrival_step for r in done
                   if r.priority > 0]
        swap_out = sched.spool.bytes_out
        swap_in = sched.spool.bytes_in
        emit(f"preemption/{policy}", 0.0,
             f"completed={len(done)}/{n_requests} "
             f"ttft_p99={float(np.percentile(ttft, 99)):.1f} "
             f"preempts={sched.preempt_count} swap_out_bytes={swap_out}",
             completed=len(done), rejected=len(sched.rejected),
             preempt_count=sched.preempt_count,
             restore_count=sched.restore_count,
             ttft_steps_p50=float(np.percentile(ttft, 50)),
             ttft_steps_p99=float(np.percentile(ttft, 99)),
             ttft_steps_p99_interactive=float(np.percentile(hi_ttft, 99)),
             swap_bytes_out=swap_out, swap_bytes_in=swap_in,
             metrics=sched.stats())
        results[policy] = {"sched": sched, "reqs": reqs,
                           "completed": len(done)}

    sched_p = results["preempt"]["sched"]
    # bit-exact victims: wait never swaps, so its per-request outputs ARE
    # the uninterrupted reference
    for rw, rp in zip(results["wait"]["reqs"], results["preempt"]["reqs"]):
        assert rw.output_tokens == rp.output_tokens, \
            f"uid {rp.uid} diverged after {rp.preempt_count} preemptions"
    assert sched_p.preempt_count >= 1, "trace never actually preempted"
    assert sched_p.restore_count == sched_p.preempt_count
    # swap accounting: measured spool traffic == roofline model (pages +
    # window per event; the model's 3 int32 counters per event are host
    # ints the spool stores at zero numpy bytes)
    # swap_bytes is affine in n_pages; sum it over events as
    # per_page * total_pages + per_event_fixed * events
    per_page = (roofline.swap_bytes(cfg, page_tokens, 1)
                - roofline.swap_bytes(cfg, page_tokens, 0))
    modeled_out = (per_page * sched_p.swapped_pages
                   + sched_p.preempt_count
                   * roofline.swap_bytes(cfg, page_tokens, 0))
    measured = sched_p.spool.bytes_out + 12 * sched_p.preempt_count
    assert measured == modeled_out, (measured, modeled_out)
    # the same invariant, as the drift auditor reports it (ratio == 1.0)
    from repro.obs.drift import roofline_drift
    dr = roofline_drift(sched_p)
    assert dr["swap_bytes_out"]["ratio"] == 1.0, dr["swap_bytes_out"]
    assert dr["swap_bytes_in"]["ratio"] == 1.0, dr["swap_bytes_in"]
    emit("preemption/swap_model", 0.0,
         f"modeled_bytes_per_trace={modeled_out} "
         f"(measured {sched_p.spool.bytes_out} + counters)",
         modeled_swap_bytes=modeled_out,
         measured_swap_bytes=sched_p.spool.bytes_out,
         swapped_pages=sched_p.swapped_pages)
    ratio = results["preempt"]["completed"] / max(1, results["reject"]
                                                  ["completed"])
    bar = 1.0 if smoke else 1.2
    emit("preemption/completions", 0.0,
         f"preempt/reject={ratio:.2f}x (bar: {bar:.1f}x) at bit-exact "
         f"outputs", completion_ratio=ratio)
    assert ratio >= bar, \
        f"preemption completed only {ratio:.2f}x reject's requests"

    # ---------------- phase 2: restart persistence -------------------
    prefix_len, suffix_len, k_timed = 64, 6, 3 if smoke else 4
    r = np.random.default_rng(seed + 1)
    real_prefix = [int(t) for t in r.integers(0, cfg.vocab_size,
                                              size=prefix_len)]
    warm_prefix = [int(t) for t in r.integers(0, cfg.vocab_size,
                                              size=prefix_len)]

    def prefix_req(prefix, rr):
        suffix = [int(t) for t in rr.integers(0, cfg.vocab_size,
                                              size=suffix_len)]
        return Request(prompt=prefix + suffix, max_new_tokens=4)

    def make_sched(s):
        return Scheduler(cfg, params, n_slots=2,
                         max_total_tokens=max_total,
                         page_tokens=page_tokens, share_prefix=True,
                         seed=s)

    path = os.path.join(tempfile.mkdtemp(prefix="mustafar_bench_"),
                        "prefix_cache.pkl")
    builder = make_sched(0)
    rb = np.random.default_rng(seed + 2)
    builder.submit(prefix_req(real_prefix, rb))
    builder.run(max_steps=4000)
    n_saved = builder.save_prefix_cache(path)

    def warm_compiles(sched):
        """Drain every executable the timed run needs: both prefill
        specializations (shared_tokens 0 and the real offset) and one
        demote→promote round (the gather/scatter page executables)."""
        rw = np.random.default_rng(seed + 3)
        for _ in range(2):                 # second run hits the shared path
            sched.submit(prefix_req(warm_prefix, rw))
            sched.run(max_steps=4000)
        sched.prefix.evict_until(sched.allocator, sched.n_pages,
                                 spool=True, cache=sched.cache)
        sched.submit(prefix_req(warm_prefix, rw))   # promote path
        sched.run(max_steps=4000)

    def timed_serve(sched):
        rt = np.random.default_rng(seed + 4)
        reqs = [prefix_req(real_prefix, rt) for _ in range(k_timed)]
        base = sched.step_count          # warmup steps already elapsed
        for q in reqs:
            sched.submit(q)
        step_t = []
        while sched.has_work:
            t0 = time.perf_counter()
            sched.step()
            step_t.append(time.perf_counter() - t0)
        cum = np.cumsum([0.0] + step_t)
        ttft_s = [float(cum[q.first_token_step - base + 1]
                        - cum[q.arrival_step - base])
                  for q in reqs]
        return reqs, ttft_s

    cold = make_sched(1)
    warm_compiles(cold)
    cold_reqs, cold_ttft = timed_serve(cold)

    warm = make_sched(1)
    n_loaded = warm.load_prefix_cache(path)
    warm_compiles(warm)
    warm_reqs, warm_ttft = timed_serve(warm)

    assert n_loaded == n_saved
    warm_shared = sum(q.shared_prefix_tokens for q in warm_reqs)
    assert warm_shared > 0, "warm start never hit the persisted chains"
    assert [q.output_tokens for q in warm_reqs] \
        == [q.output_tokens for q in cold_reqs], "warm start diverged"
    cold_mean, warm_mean = float(np.mean(cold_ttft)), float(np.mean(warm_ttft))
    emit("preemption/persisted_restart", 0.0,
         f"warm_ttft_mean_s={warm_mean:.4f} cold={cold_mean:.4f} "
         f"({n_loaded} entries, {warm_shared} shared tokens)",
         warm_ttft_mean_s=warm_mean, cold_ttft_mean_s=cold_mean,
         entries_persisted=n_saved, warm_shared_tokens=warm_shared)
    if not smoke:        # CPU wall-clock is too noisy for a CI smoke gate
        assert warm_mean < cold_mean, \
            f"warm start TTFT {warm_mean:.4f}s not below cold {cold_mean:.4f}s"
    return {"completion_ratio": ratio,
            "preempt_count": sched_p.preempt_count,
            "swap_bytes": sched_p.spool.bytes_out,
            "warm_ttft_mean_s": warm_mean, "cold_ttft_mean_s": cold_mean}


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--scheduler", action="store_true",
                    help="run the live continuous-batching benchmark "
                         "instead of the analytic Fig. 7 model")
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--rate", type=float, default=1.0)
    ap.add_argument("--sparsity", type=float, default=0.7)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.scheduler:
        r = scheduler_main(args.arch, args.slots, args.requests, args.gen,
                           args.rate, args.sparsity, args.seed)
        print(f"# scheduler: {r['requests']} requests, {r['steps']} steps, "
              f"{r['tokens_per_s']:.1f} tok/s, "
              f"occupancy {r['occupancy']*100:.1f}%")
    else:
        main()
