"""Paper Fig. 6b: KV-cache compression rate vs sparsity / strategy.

Compression rate = compressed bytes as % of dense KV bytes. Compares our
fixed-k bitmap format, the paper's GPU format (offsets + padding), ThinK's
key-only structured removal, and KIVI quantization storage."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.quantization import quant_bytes_per_token
from repro.core.sparse_format import (compression_rate,
                                      paper_compression_rate)
from repro.serving.cache import cache_hbm_bytes


def main(rng=None) -> None:
    for arch in ("llama2-7b", "llama3-8b"):
        cfg = get_config(arch)
        d = cfg.d_head
        m = cfg.mustafar
        for s in (0.5, 0.7, 0.8, 0.9):
            kk = m.keep_k(d, s)
            ours = compression_rate(d, kk)
            paper = paper_compression_rate(d, s)
            emit(f"fig6b/{arch}/KV_s{s}", 0.0,
                 f"ours={ours*100:.1f}% paper_fmt={paper*100:.1f}%")
        # ThinK key-only: keeps (1-s) of key channels, value cache dense
        for s in (0.5, 0.7):
            think = (1 + (1 - s)) / 2
            emit(f"fig6b/{arch}/ThinK_K{s}", 0.0,
                 f"rate={think*100:.1f}% (paper reports "
                 f"{'75' if s == 0.5 else '65'}%)")
        # single-cache pruning (paper: 83% / 72.5%)
        for s in (0.5, 0.7):
            kk = m.keep_k(d, s)
            single = (1 + compression_rate(d, kk)) / 2
            emit(f"fig6b/{arch}/single_cache_s{s}", 0.0,
                 f"rate={single*100:.1f}%")
        # KIVI storage for context
        for bits in (4, 2):
            q = quant_bytes_per_token(d, bits) / (d * 2)
            emit(f"fig6b/{arch}/KIVI_{bits}bit", 0.0, f"rate={q*100:.1f}%")
        # end-to-end engine accounting (pools + window + rounding overheads)
        acct = cache_hbm_bytes(cfg, B=1, max_total_tokens=32768)
        emit(f"fig6b/{arch}/engine_total_s0.7", 0.0,
             f"rate={acct['ratio']*100:.1f}% (incl. window+pool rounding)")


if __name__ == "__main__":
    main()
