"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only fig6a,...]
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks import (bench_compression, bench_joint, bench_kernel,
                        bench_pruning, bench_throughput)

SUITES = {
    "pruning": bench_pruning.main,        # Tables 1,2,3,11,12
    "joint": bench_joint.main,            # Tables 5,6
    "kernel": bench_kernel.main,          # Fig 6a
    "compression": bench_compression.main,  # Fig 6b
    "throughput": bench_throughput.main,  # Fig 7
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all")
    args = ap.parse_args()
    names = list(SUITES) if args.only == "all" else args.only.split(",")
    print("name,us_per_call,derived")
    for n in names:
        SUITES[n](np.random.default_rng(0))


if __name__ == "__main__":
    main()
