"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows AND writes one machine-readable
``BENCH_<suite>.json`` per suite (us_per_call + modeled HBM bytes per
component) so the perf trajectory is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.run [--only kernels,...] [--out-dir .]
"""
from __future__ import annotations

import argparse
import os

import numpy as np

from benchmarks import (bench_compression, bench_joint, bench_kernel,
                        bench_pruning, bench_quant, bench_throughput, common)

# suite key doubles as the BENCH_<key>.json filename stem
SUITES = {
    "pruning": bench_pruning.main,        # Tables 1,2,3,11,12
    "joint": bench_joint.main,            # Tables 5,6
    "kernels": bench_kernel.main,         # Fig 6a + PR-2 kernel overhaul
    "compression": bench_compression.main,  # Fig 6b
    "throughput": bench_throughput.main,  # Fig 7
    "paging": bench_throughput.paging_main,  # paged vs contiguous pools
    "prefix": bench_throughput.prefix_main,  # shared-prefix CoW + chunked
    "sharding": bench_throughput.sharding_main,  # KV-head shards + router
    "preemption": bench_throughput.preemption_main,  # swap-to-host tier
    "quant": bench_quant.main,            # int8 vs bf16 pool storage
}
_ALIASES = {"kernel": "kernels"}          # pre-PR-2 suite name


def main() -> None:
    import inspect

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all")
    ap.add_argument("--out-dir", default=".",
                    help="directory for BENCH_<suite>.json files")
    ap.add_argument("--smoke", action="store_true",
                    help="shortened traces for CI gates (suites that "
                         "support it); asserts still enforced")
    args = ap.parse_args()
    names = list(SUITES) if args.only == "all" else [
        _ALIASES.get(n, n) for n in args.only.split(",")]
    os.makedirs(args.out_dir, exist_ok=True)
    print("name,us_per_call,derived")
    for n in names:
        fn = SUITES[n]
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(fn).parameters:
            kwargs["smoke"] = True
        common.drain_records()
        fn(np.random.default_rng(0), **kwargs)
        path = os.path.join(args.out_dir, f"BENCH_{n}.json")
        common.write_bench_json(path, common.drain_records())


if __name__ == "__main__":
    main()
