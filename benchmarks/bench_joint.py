"""Paper §4.2 (Tables 5/6): joint application with H2O eviction and KIVI
quantization — accuracy proxies showing composition does not break pruning."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import attn_output_error, emit, synthetic_kv
from repro.core import pruning
from repro.core.eviction import h2o_keep_mask
from repro.core.quantization import kivi_quantize_key, kivi_quantize_value


def h2o(rng) -> None:
    """Table 5: Mustafar on top of a 20% H2O budget."""
    B, H, T, d = 2, 4, 256, 128
    k = synthetic_kv(rng, T=T, key_like=True)
    v = synthetic_kv(rng, T=T, key_like=False)
    attn_acc = jnp.asarray(np.abs(rng.normal(size=(B, H, T))).astype(np.float32))
    keep = h2o_keep_mask(attn_acc, T, heavy_budget=T // 10,
                         recent_budget=T // 10)              # 20% budget
    keep4 = keep[..., None]
    k_h2o = jnp.where(keep4, k, 0.0)
    v_h2o = jnp.where(keep4, v, 0.0)
    base = attn_output_error(k, k_h2o, v, v_h2o, rng)
    emit("table5/h2o20_dense", 0.0, f"rel_err={base:.4f}")
    for ks, vs in ((0.5, 0.0), (0.0, 0.5), (0.5, 0.5), (0.7, 0.7)):
        kp = pruning.prune(k_h2o, ks, "per_token_magnitude") if ks else k_h2o
        vp = pruning.prune(v_h2o, vs, "per_token_magnitude") if vs else v_h2o
        err = attn_output_error(k, kp, v, vp, rng)
        emit(f"table5/h2o20_K{ks}_V{vs}", 0.0,
             f"rel_err={err:.4f} delta_vs_h2o={err-base:+.4f}")


def kivi(rng) -> None:
    """Table 6: prune-then-quantize (Harma et al. ordering), 4- and 2-bit."""
    k = synthetic_kv(rng, key_like=True)
    v = synthetic_kv(rng, key_like=False)
    for bits in (4, 2):
        kq = kivi_quantize_key(k, bits)
        vq = kivi_quantize_value(v, bits)
        base = attn_output_error(k, kq, v, vq, rng)
        emit(f"table6/kivi{bits}_dense", 0.0, f"rel_err={base:.4f}")
        for ks, vs in ((0.5, 0.0), (0.0, 0.5), (0.5, 0.5), (0.7, 0.7)):
            kp = pruning.prune(k, ks, "per_token_magnitude") if ks else k
            vp = pruning.prune(v, vs, "per_token_magnitude") if vs else v
            kpq = kivi_quantize_key(kp, bits)
            vpq = kivi_quantize_value(vp, bits)
            err = attn_output_error(k, kpq, v, vpq, rng)
            emit(f"table6/kivi{bits}_K{ks}_V{vs}", 0.0,
                 f"rel_err={err:.4f} delta_vs_quant={err-base:+.4f}")


def main(rng=None) -> None:
    rng = rng or np.random.default_rng(1)
    h2o(rng)
    kivi(rng)


if __name__ == "__main__":
    main()
