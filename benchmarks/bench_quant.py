"""BENCH_quant: int8 quantized sparse pools vs bf16 (PR 10).

Decode is memory-bound, so halving the compressed-VALUE bytes is the whole
point of ``pool_dtype="int8"``. This suite sweeps (sparsity x pool_dtype)
and reports, per combo:

  * accuracy proxy — mean squared error of the greedy decode logits vs an
    UNCOMPRESSED (mustafar-disabled) run of the same prompt. Pruning
    dominates this error; int8-on-top must add almost nothing (the
    per-tile symmetric absmax scale tracks the fake-quant oracle exactly);
  * pool bytes — ``pool_value_bytes`` (packed values + scale leaves, the
    component the dtype actually changes) and the total compressed-cache
    bytes from ``cache_hbm_bytes``;
  * measured steady-state decode tokens/sec through the live paged
    Scheduler on a seeded trace (jit warmup drained before the clock);
  * the decode roofline drift ratio (must be FINITE — accounting that
    forgot the scale leaves or mis-sized int8 pools shows up here).

Gates (asserted, also run as the CI ``quant-smoke`` job):
  * int8 and bf16 produce IDENTICAL sampled outputs on the trace;
  * int8 value-pool bytes <= 0.55x bf16 (0.5x + per-tile scales);
  * int8 tokens/sec >= 0.9x bf16 (the dequant is one fused multiply on
    the read path; it must not eat the byte savings).

``smoke=True`` (CI) serves a shorter trace at one sparsity.
"""
from __future__ import annotations

import time
from dataclasses import replace

import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.serving.cache import cache_hbm_bytes, pool_value_bytes

ARCH = "starcoder2-3b"
N_SLOTS = 2
MAX_TOTAL = 96


def _cfg(sparsity: float, pool_dtype: str):
    cfg = get_config(ARCH).reduced().with_sparsity(sparsity, sparsity)
    return replace(cfg, mustafar=replace(cfg.mustafar,
                                         pool_dtype=pool_dtype))


def _dense_logit_trace(params, cfg, prompt, n_new):
    """Greedy decode logits under ``cfg`` (list of [V] arrays). The token
    fed at each step comes from THIS run's own argmax."""
    import jax
    import jax.numpy as jnp

    from repro.serving.engine import decode_step, prefill

    lg, cache = prefill(params, jnp.asarray(prompt, jnp.int32)[None], cfg,
                        max_total_tokens=MAX_TOTAL)
    logits = [np.asarray(lg[0], np.float32)]
    step = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))
    tok = int(jnp.argmax(lg[0]))
    while len(logits) < n_new:
        lg, cache = step(params, jnp.asarray([tok], jnp.int32), cache)
        logits.append(np.asarray(lg[0], np.float32))
        tok = int(jnp.argmax(lg[0]))
    return logits


def _serve(cfg, params, page_tokens, trace_fn):
    """Warmed, timed Scheduler run -> (finished requests, tok/s, drift)."""
    from repro.obs.drift import roofline_drift
    from repro.serving.engine import Request, Scheduler

    sched = Scheduler(cfg, params, n_slots=N_SLOTS,
                      max_total_tokens=MAX_TOTAL, page_tokens=page_tokens,
                      fused_compaction=True)
    wr = np.random.default_rng(77)
    for L in (16, 24):                    # compile both prefill shapes
        sched.submit(Request(prompt=wr.integers(0, cfg.vocab_size, size=L),
                             max_new_tokens=2))
    while sched.has_work:
        sched.step()
    n_warm = len(sched.finished)
    arrivals, reqs = trace_fn()
    base = sched.step_count
    t0 = time.perf_counter()
    i = 0
    while i < len(reqs) or sched.has_work:
        while i < len(reqs) and arrivals[i] + base <= sched.step_count:
            sched.submit(reqs[i])
            i += 1
        sched.step()
    dt = time.perf_counter() - t0
    timed = sched.finished[n_warm:]
    toks = sum(r.num_generated for r in timed)
    return timed, toks / dt, roofline_drift(sched)


def main(rng=None, smoke: bool = False) -> dict:
    rng = rng or np.random.default_rng(0)
    sparsities = (0.5,) if smoke else (0.5, 0.7)
    n_requests = 4 if smoke else 8
    gen = 8 if smoke else 16
    n_logit_steps = 6 if smoke else 12
    prompt_len = 40

    import jax

    from repro.models import init_params
    from repro.serving.engine import Request

    results = {}
    for s in sparsities:
        cfg_b = _cfg(s, "bf16")
        cfg_q = _cfg(s, "int8")
        params = init_params(jax.random.PRNGKey(0), cfg_b)
        page_tokens = cfg_b.mustafar.tile_tokens

        def trace():
            r = np.random.default_rng(42)
            arrivals = np.cumsum(r.exponential(1.0, size=n_requests)
                                 ).astype(int)
            lens = r.choice((16, 24), size=n_requests)
            reqs = [Request(prompt=r.integers(0, cfg_b.vocab_size,
                                              size=int(L)),
                            max_new_tokens=gen) for L in lens]
            return arrivals, reqs

        # accuracy proxy: logit MSE vs the uncompressed cache
        prompt = [int(t) for t in rng.integers(0, cfg_b.vocab_size,
                                               size=prompt_len)]
        cfg_d = replace(cfg_b, mustafar=replace(cfg_b.mustafar,
                                                enabled=False))
        lg_dense = _dense_logit_trace(params, cfg_d, prompt, n_logit_steps)
        mse = {}
        for tag, cfg in (("bf16", cfg_b), ("int8", cfg_q)):
            lg = _dense_logit_trace(params, cfg, prompt, n_logit_steps)
            mse[tag] = float(np.mean([np.mean((a - b) ** 2)
                                      for a, b in zip(lg, lg_dense)]))

        # live serving: same trace under both pool dtypes
        per = {}
        for tag, cfg in (("bf16", cfg_b), ("int8", cfg_q)):
            timed, tps, drift = _serve(cfg, params, page_tokens, trace)
            ratio = drift["decode_step"]["drift_ratio"]
            assert ratio is not None and np.isfinite(ratio), \
                f"{tag} s={s}: decode drift ratio {ratio!r} not finite"
            pool_by = pool_value_bytes(cfg, MAX_TOTAL)
            total_by = cache_hbm_bytes(cfg, N_SLOTS, MAX_TOTAL)["mustafar"]
            per[tag] = {"timed": timed, "tps": tps, "pool_bytes": pool_by,
                        "drift": ratio}
            emit(f"quant/s{s}/{tag}", 1e6 / max(tps, 1e-9),
                 f"tokens_per_s={tps:.1f} pool_bytes={pool_by} "
                 f"logit_mse={mse[tag]:.3e} drift={ratio:.3g}",
                 tokens_per_s=tps, pool_value_bytes=pool_by,
                 cache_hbm_bytes=total_by, logit_mse_vs_dense=mse[tag],
                 roofline_drift=ratio)

        # -------- gates --------
        outs_b = [r.output_tokens for r in per["bf16"]["timed"]]
        outs_q = [r.output_tokens for r in per["int8"]["timed"]]
        assert outs_b == outs_q, \
            f"s={s}: int8 changed sampled outputs"
        byte_ratio = per["int8"]["pool_bytes"] / per["bf16"]["pool_bytes"]
        assert byte_ratio <= 0.55, \
            f"s={s}: int8 pool bytes {byte_ratio:.3f}x bf16 (bar 0.55x)"
        tps_ratio = per["int8"]["tps"] / per["bf16"]["tps"]
        assert tps_ratio >= 0.9, \
            f"s={s}: int8 {tps_ratio:.2f}x bf16 tokens/s (bar 0.9x)"
        emit(f"quant/s{s}/gates", 0.0,
             f"pool_bytes={byte_ratio:.3f}x tok_s={tps_ratio:.2f}x "
             f"outputs_equal=True mse_excess="
             f"{mse['int8'] - mse['bf16']:+.3e}",
             pool_bytes_ratio=byte_ratio, tokens_per_s_ratio=tps_ratio,
             outputs_equal=True, logit_mse_bf16=mse["bf16"],
             logit_mse_int8=mse["int8"])
        results[s] = {"pool_bytes_ratio": byte_ratio,
                      "tokens_per_s_ratio": tps_ratio,
                      "logit_mse": mse}
    return results


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    r = main(smoke=args.smoke)
    for s, v in r.items():
        print(f"# s={s}: pool_bytes {v['pool_bytes_ratio']:.3f}x, "
              f"tok/s {v['tokens_per_s_ratio']:.2f}x, "
              f"mse bf16={v['logit_mse']['bf16']:.3e} "
              f"int8={v['logit_mse']['int8']:.3e}")
