"""Serving engine: prefill/decode consistency with the full causal forward,
compaction boundaries, dense-vs-mustafar behaviour, cache accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from repro.configs import get_config
from repro.models import forward_train, init_params
from repro.serving.cache import cache_hbm_bytes, init_cache, plan_pools
from repro.serving.engine import Engine, decode_step, prefill

KEY = jax.random.PRNGKey(0)


def _run_serve(cfg, params, toks, T, extra=None):
    total = toks.shape[1]
    lg, cache = prefill(params, toks[:, :T], cfg,
                        max_total_tokens=total + 8, extra=extra)
    outs = [lg]
    step = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))
    for t in range(T, total - 1):
        lg, cache = step(params, toks[:, t], cache)
        outs.append(lg)
    return jnp.stack(outs, axis=1), cache


def _ref_logits(cfg, params, toks, extra=None):
    logits, _ = forward_train(params, toks, cfg, extra=extra, remat="none")
    if cfg.family == "vlm":
        logits = logits[:, cfg.n_vision_tokens:, :]
    return logits


@pytest.mark.parametrize("arch", ["starcoder2-3b", "stablelm-3b",
                                  "jamba-1.5-large-398b", "rwkv6-7b",
                                  "whisper-medium", "internvl2-1b"])
def test_dense_decode_matches_full_forward(arch):
    """No pruning -> serving must reproduce the training forward exactly
    (up to bf16 noise)."""
    cfg = get_config(arch).reduced()
    # no-drop MoE capacity: capacity policy legitimately differs between a
    # T-token forward and a decode step (documented); exactness needs no-drop
    cfg = replace(cfg, mustafar=replace(cfg.mustafar, enabled=False),
                  moe_capacity_factor=64.0)
    params = init_params(KEY, cfg)
    B, T, n_dec = 2, 37, 12
    toks = jax.random.randint(KEY, (B, T + n_dec), 0, cfg.vocab_size)
    extra = {}
    if cfg.family == "audio":
        extra["frames"] = jax.random.normal(KEY, (B, cfg.encoder_ctx,
                                                  cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        extra["patches"] = jax.random.normal(KEY, (B, cfg.n_vision_tokens,
                                                   cfg.d_model), jnp.float32)
    serve, _ = _run_serve(cfg, params, toks, T, extra or None)
    ref = _ref_logits(cfg, params, toks, extra or None)[:, T - 1:-1, :]
    err = float(jnp.max(jnp.abs(serve - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert err < 0.03, err


def test_moe_dense_decode_rank_agreement():
    """MoE: bf16 routing-tie flips make exact equality impossible; require
    near-total argmax agreement instead (no-drop capacity)."""
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    cfg = replace(cfg, moe_capacity_factor=64.0,
                  mustafar=replace(cfg.mustafar, enabled=False))
    params = init_params(KEY, cfg)
    B, T, n_dec = 2, 37, 16
    toks = jax.random.randint(KEY, (B, T + n_dec), 0, cfg.vocab_size)
    serve, _ = _run_serve(cfg, params, toks, T)
    ref = _ref_logits(cfg, params, toks)[:, T - 1:-1, :]
    agree = float(jnp.mean(jnp.argmax(serve, -1) == jnp.argmax(ref, -1)))
    assert agree >= 0.9, agree


def test_mustafar_decode_crosses_compaction_boundary():
    """Decode across a window-full boundary: compaction must fire and the
    output must stay close to the unpruned reference (s=0.5 reduced)."""
    cfg = get_config("starcoder2-3b").reduced()   # lw=8, tile=16 -> Wbuf=24
    cfg = cfg.with_sparsity(0.5, 0.5)
    params = init_params(KEY, cfg)
    B, T, n_dec = 2, 20, 40                       # crosses >=2 compactions
    toks = jax.random.randint(KEY, (B, T + n_dec), 0, cfg.vocab_size)
    serve, cache = _run_serve(cfg, params, toks, T)
    # per-sequence [B] state vectors: lockstep batch advances uniformly
    assert (np.asarray(cache["n_compressed"]) > 0).all()   # compaction fired
    np.testing.assert_array_equal(np.asarray(cache["position"]),
                                  T + n_dec - 1)
    ref = _ref_logits(cfg, params, toks)[:, T - 1:-1, :]
    rel = float(jnp.linalg.norm(serve - ref) / jnp.linalg.norm(ref))
    assert np.isfinite(rel) and rel < 0.5, rel


def test_mustafar_zero_sparsity_equals_dense():
    """s -> keep_k = d: pruning keeps everything; serving must match the
    dense-cache path exactly."""
    cfg = get_config("stablelm-3b").reduced()
    cfg_m = cfg.with_sparsity(0.0, 0.0)
    cfg_d = replace(cfg, mustafar=replace(cfg.mustafar, enabled=False))
    params = init_params(KEY, cfg_m)
    B, T, n_dec = 2, 20, 24
    toks = jax.random.randint(KEY, (B, T + n_dec), 0, cfg.vocab_size)
    s_m, _ = _run_serve(cfg_m, params, toks, T)
    s_d, _ = _run_serve(cfg_d, params, toks, T)
    np.testing.assert_allclose(np.asarray(s_m, np.float32),
                               np.asarray(s_d, np.float32),
                               rtol=6e-2, atol=6e-2)


def test_cache_accounting_matches_paper_ballpark():
    cfg = get_config("llama3-8b")                 # paper's model
    acct = cache_hbm_bytes(cfg, B=1, max_total_tokens=8192)
    # paper Fig. 6b: KV 70% sparsity -> ~45% of dense (ours is tighter: no
    # offsets), plus our window/pool rounding overhead
    assert 0.30 < acct["ratio"] < 0.50, acct


def test_engine_generate_shapes():
    cfg = get_config("starcoder2-3b").reduced()
    params = init_params(KEY, cfg)
    eng = Engine(cfg, params, max_total_tokens=128)
    toks = jax.random.randint(KEY, (2, 24), 0, cfg.vocab_size)
    out = eng.generate(toks, n_new=8, temperature=0.7, rng=KEY)
    assert out.shape == (2, 8)
    assert (np.asarray(out) >= 0).all()
    assert (np.asarray(out) < cfg.vocab_size).all()
