"""Continuous-batching scheduler: ragged-batch decode equivalence (each
sequence's logits match a solo lockstep run), slot release/reuse on EOS and
max-length, and per-slot compaction triggering at different steps."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving import cache as cache_mod
from repro.serving.engine import (Request, Scheduler, decode_step, prefill,
                                  prefill_into_slot)

KEY = jax.random.PRNGKey(0)
CFG = get_config("starcoder2-3b").reduced().with_sparsity(0.5, 0.5)
PARAMS = init_params(KEY, CFG)
MAX_TOTAL = 96          # reduced cfg: local_window=8, tile=16 -> Wbuf=24


def _prompt(length, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, CFG.vocab_size, size=length), jnp.int32)


def _solo_greedy(prompt, n_new, cfg=CFG, params=PARAMS):
    """Old lockstep path, batch of one: the equivalence reference."""
    lg, cache = prefill(params, prompt[None], cfg, max_total_tokens=MAX_TOTAL)
    logits = [np.asarray(lg[0], np.float32)]
    toks = [int(jnp.argmax(lg[0]))]
    step = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))
    for _ in range(n_new - 1):
        lg, cache = step(params, jnp.asarray([toks[-1]], jnp.int32), cache)
        logits.append(np.asarray(lg[0], np.float32))
        toks.append(int(jnp.argmax(lg[0])))
    return toks, logits


def test_ragged_batch_matches_solo_lockstep():
    """Prompts of different lengths admitted at different steps: every
    sequence's per-token logits must be identical (atol 1e-5) to running
    that sequence alone through the lockstep path."""
    prompts = [_prompt(9, 0), _prompt(17, 1), _prompt(26, 2)]
    n_new = [18, 12, 20]
    solos = [_solo_greedy(p, n) for p, n in zip(prompts, n_new)]

    sched = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL,
                      collect_logits=True)
    reqs = [Request(prompt=p, max_new_tokens=n)
            for p, n in zip(prompts, n_new)]
    sched.submit(reqs[0])
    sched.step(); sched.step()                    # r0 decodes alone
    sched.submit(reqs[1])
    sched.step(); sched.step(); sched.step()      # r0 + r1 share the batch
    sched.submit(reqs[2])                         # queued until a slot frees
    sched.run()

    assert all(r.done for r in reqs)
    for req, (solo_toks, solo_logits) in zip(reqs, solos):
        assert req.output_tokens == solo_toks
        for got, want in zip(req.logits, solo_logits):
            np.testing.assert_allclose(got, want, rtol=0, atol=1e-5)


def test_slot_release_and_reuse():
    """More requests than slots: finished sequences must free their slot
    for the next waiting request, and every request still completes with
    solo-equivalent tokens."""
    prompts = [_prompt(9 + 2 * i, seed=10 + i) for i in range(4)]
    solos = [_solo_greedy(p, 6)[0] for p in prompts]

    sched = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL)
    reqs = [Request(prompt=p, max_new_tokens=6) for p in prompts]
    for r in reqs:
        sched.submit(r)
    sched.run()

    assert all(r.done for r in reqs)
    assert sched.slots == [None, None]            # all slots released
    for req, solo_toks in zip(reqs, solos):
        assert req.output_tokens == solo_toks
    # later arrivals were admitted only after a slot freed
    assert max(r.prefill_step for r in reqs[2:]) > 0


def test_eos_retires_request_and_frees_slot():
    """EOS mid-generation retires the request early; the freed slot admits
    the next waiting request."""
    prompt = _prompt(12, seed=3)
    solo_toks, _ = _solo_greedy(prompt, 8)
    # cut at the first token value not seen earlier (greedy can repeat)
    cut = next(i for i in range(1, len(solo_toks))
               if solo_toks[i] not in solo_toks[:i])
    eos = solo_toks[cut]

    sched = Scheduler(CFG, PARAMS, n_slots=1, max_total_tokens=MAX_TOTAL)
    first = sched.submit(Request(prompt=prompt, max_new_tokens=8,
                                 eos_token_id=eos))
    second = sched.submit(Request(prompt=_prompt(9, seed=4),
                                  max_new_tokens=3))
    sched.run()

    assert first.done and first.output_tokens == solo_toks[:cut + 1]
    assert first.output_tokens[-1] == eos
    assert len(first.output_tokens) < 8            # retired early
    assert second.done and len(second.output_tokens) == 3
    assert second.prefill_step > first.prefill_step   # reused the one slot


def test_per_slot_compaction_triggers_independently():
    """Two slots at different depths: the deep slot's window fills (and
    compacts) steps before the shallow slot's does — per-slot counters, not
    a global one."""
    m = CFG.mustafar
    wbuf = m.local_window + m.tile_tokens         # 24 in the reduced cfg
    cache = cache_mod.init_cache(CFG, 2, MAX_TOTAL)
    # slot 0 one token below a full window; slot 1 nearly empty
    _, cache = prefill_into_slot(PARAMS, _prompt(wbuf - 1, 5)[None], cache, 0,
                                 CFG, MAX_TOTAL)
    _, cache = prefill_into_slot(PARAMS, _prompt(9, 6)[None], cache, 1,
                                 CFG, MAX_TOTAL)
    np.testing.assert_array_equal(np.asarray(cache["w_len"]), [wbuf - 1, 9])
    np.testing.assert_array_equal(np.asarray(cache["n_compressed"]), [0, 0])

    step = jax.jit(lambda p, t, c: decode_step(p, t, c, CFG))
    tok = jnp.zeros((2,), jnp.int32)
    for _ in range(3):
        lg, cache = step(PARAMS, tok, cache)
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)

    nc = np.asarray(cache["n_compressed"])
    wl = np.asarray(cache["w_len"])
    pos = np.asarray(cache["position"])
    assert nc[0] == m.tile_tokens and nc[1] == 0   # only slot 0 compacted
    np.testing.assert_array_equal(nc + wl, pos)    # invariant per slot
    assert (wl < wbuf).all()
    assert np.isfinite(np.asarray(lg, np.float32)).all()


def test_active_mask_freezes_empty_slots():
    """Slots outside the active mask must not advance their counters."""
    cache = cache_mod.init_cache(CFG, 2, MAX_TOTAL)
    _, cache = prefill_into_slot(PARAMS, _prompt(11, 7)[None], cache, 0,
                                 CFG, MAX_TOTAL)
    before = {k: np.asarray(cache[k]).copy()
              for k in ("position", "w_len", "n_compressed")}
    step = jax.jit(lambda p, t, c, a: decode_step(p, t, c, CFG, active=a))
    active = jnp.asarray([True, False])
    for _ in range(2):
        lg, cache = step(PARAMS, jnp.zeros((2,), jnp.int32), cache, active)
    after = {k: np.asarray(cache[k]) for k in before}
    assert after["position"][0] == before["position"][0] + 2
    assert after["position"][1] == before["position"][1]      # frozen
    assert after["w_len"][1] == before["w_len"][1]
    assert after["n_compressed"][1] == before["n_compressed"][1]
    assert np.isfinite(np.asarray(lg, np.float32)).all()


def test_occupancy_accounting():
    """Saturated queue -> occupancy near 1; stats stay in [0, 1]."""
    sched = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL)
    for i in range(4):
        sched.submit(Request(prompt=_prompt(9, seed=20 + i),
                             max_new_tokens=8))
    sched.run()
    occ = sched.occupancy
    assert 0.0 < occ.slots <= 1.0
    assert occ.slots > 0.8                         # queue kept slots busy
    assert occ.pages is None                       # contiguous cache
    # sharing/chunking stats are paged/chunked-mode-only: the contiguous
    # one-shot scheduler must report None, not zeros masquerading as data
    assert occ.pages_owned is None and occ.pages_shared is None
    assert occ.prefill_tokens_per_step is None


def test_one_shot_admission_latency_bookkeeping():
    """Without chunking, the first sampled token lands in the same engine
    step the admission began (first_token_step == prefill_step), and
    shared_prefix_tokens stays 0 outside sharing mode — the baselines the
    chunked-prefill and prefix-sharing stats are measured against."""
    sched = Scheduler(CFG, PARAMS, n_slots=1, max_total_tokens=MAX_TOTAL)
    req = Request(prompt=_prompt(14, seed=25), max_new_tokens=4)
    sched.submit(req)
    sched.run()
    assert req.done
    assert req.prefill_step >= 0
    assert req.first_token_step == req.prefill_step
    assert req.shared_prefix_tokens == 0
    assert sched.max_prefill_step_tokens == 0      # no chunked tokens ran


def test_admit_rejects_oversized_request():
    """A request whose prompt + max_new_tokens exceeds slot capacity is
    REJECTED with a clear error — both at submit() and, for requests that
    reach the queue without it, at admission time inside step(). Silent
    truncation via max-length retirement would deadlock the queue under
    page-budget gating (the head request would wait forever for pages that
    can never materialise)."""
    import pytest

    sched = Scheduler(CFG, PARAMS, n_slots=1, max_total_tokens=MAX_TOTAL)
    big = Request(prompt=_prompt(40, seed=30), max_new_tokens=MAX_TOTAL)
    with pytest.raises(ValueError, match="rejecting rather than truncating"):
        sched.submit(big)
    # sneak past submit(): _admit must still reject, not truncate
    sched.waiting.append(big)
    with pytest.raises(ValueError, match="rejecting rather than truncating"):
        sched.step()

    # paged: a request needing more pages than the whole pool can never be
    # admitted -> rejected upfront instead of deadlocking the queue
    sched_p = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL,
                        page_tokens=CFG.mustafar.tile_tokens, n_pages=1)
    with pytest.raises(ValueError, match="could never be admitted"):
        sched_p.submit(Request(prompt=_prompt(40, seed=31),
                               max_new_tokens=40))
    # a request that DOES fit still round-trips
    ok = sched_p.submit(Request(prompt=_prompt(9, seed=32), max_new_tokens=4))
    sched_p.run()
    assert ok.done and len(ok.output_tokens) == 4
