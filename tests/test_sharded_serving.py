"""PR-7 serving-tier sharding: KV-head-sharded paged pools + shard_map
decode (subprocess with 8 fake CPU devices, like test_sharding.py) and the
in-process multi-engine Router / new Scheduler knobs.

The subprocess script asserts the layout contract end to end: model=1 is
BIT-EXACT vs the single-device scheduler (the psum over one device is an
identity), model=2 matches tokens with fp32 tolerance on logits (cross-
device reduction order), the cache keeps its declared shardings through
decode + fused compaction + dense-window merges, the compiled decode
contains NO resharding collectives (only the per-layer logit all-reduces),
and per-device pool bytes land at single-device/M + replicated metadata.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import init_params
from repro.roofline import auto_page_tokens
from repro.serving import cache as cache_mod
from repro.serving.cache import cache_hbm_bytes
from repro.serving.engine import Request, Scheduler
from repro.serving.router import Router, _split_evenly
from repro.sharding import specs as sh

KEY = jax.random.PRNGKey(0)
CFG = get_config("starcoder2-3b").reduced().with_sparsity(0.5, 0.5)
PARAMS = init_params(KEY, CFG)
MAX_TOTAL = 96          # reduced cfg: local_window=8, tile=16 -> Wbuf=24


def make_reqs(n, seed=0, gen=6, max_len=35):
    rng = np.random.default_rng(seed)
    lens = rng.integers(6, max_len + 1, size=n)
    return [Request(prompt=rng.integers(0, CFG.vocab_size,
                                        size=int(L)).tolist(),
                    max_new_tokens=gen, uid=i)
            for i, L in enumerate(lens)]


def serve(engine, reqs, arrivals):
    i = 0
    while i < len(reqs) or engine.has_work:
        while i < len(reqs) and arrivals[i] <= engine.step_count:
            engine.submit(reqs[i])
            i += 1
        engine.step()
    return {r.uid: r.output_tokens for r in engine.finished}


# ---------------------------------------------------------------------------
# multi-engine router (data parallelism above the mesh — runs in-process)

def test_router_matches_single_engine_and_skips_idle():
    reqs = make_reqs(5)
    arrivals = [0, 0, 2, 4, 6]
    single = Scheduler(CFG, PARAMS, n_slots=4, max_total_tokens=MAX_TOTAL,
                       page_tokens=16)
    base = serve(single, reqs, arrivals)

    router = Router(CFG, PARAMS, n_engines=2, n_slots=4,
                    max_total_tokens=MAX_TOTAL, page_tokens=16)
    got = serve(router, [Request(prompt=r.prompt, max_new_tokens=6,
                                 uid=r.uid) for r in reqs], arrivals)

    # per-slot decode math is row-independent, so routing requests across
    # replicas cannot change any request's tokens
    assert got == base
    assert router.page_leaks == 0
    assert sorted(router.engine_of) == [0, 1, 2, 3, 4]
    # occupancy invariant: the fleet fraction is over steps each engine
    # ACTUALLY ran, and pack-first routing keeps it at or above what the
    # same trace yields on one engine paying all 4 slots every step
    assert 0.0 < router.occupancy.slots <= 1.0
    assert router.occupancy.slots >= single.occupancy.slots - 1e-9
    # idle replicas skip steps outright — the throughput mechanism
    ran = sum(e.step_count for e in router.engines)
    assert ran < router.step_count * router.n_engines


def test_router_pack_policy_concentrates_load():
    """Light load lands on ONE replica; spread policy fans it out."""
    for policy, n_busy in (("pack", 1), ("spread", 2)):
        router = Router(CFG, PARAMS, n_engines=2, n_slots=4,
                        max_total_tokens=MAX_TOTAL, policy=policy)
        reqs = make_reqs(2, seed=3, gen=4, max_len=12)
        serve(router, reqs, [0, 0])
        busy = sum(1 for e in router.engines if e.finished)
        assert busy == n_busy, (policy, busy)


def test_router_prefix_affinity():
    """A prompt family concentrates on the replica already holding its
    compressed prefix pages (read-only probe of every engine's trie)."""
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, CFG.vocab_size, size=48).tolist()
    router = Router(CFG, PARAMS, n_engines=2, n_slots=4,
                    max_total_tokens=MAX_TOTAL + 48, page_tokens=16,
                    share_prefix=True)
    first = Request(prompt=prefix + rng.integers(
        0, CFG.vocab_size, size=6).tolist(), max_new_tokens=4, uid=0)
    serve(router, [first], [0])
    owner = router.engine_of[0]
    # decoys load the OTHER engine so pack-routing alone would pick it
    other = router.engines[1 - owner]
    for k in range(2):
        other.submit(Request(prompt=rng.integers(
            0, CFG.vocab_size, size=8).tolist(), max_new_tokens=8,
            uid=100 + k))
    sibling = Request(prompt=prefix + rng.integers(
        0, CFG.vocab_size, size=7).tolist(), max_new_tokens=4, uid=1)
    router.submit(sibling)
    assert router.engine_of[1] == owner
    while router.has_work:
        router.step()
    assert router.page_leaks == 0
    # index-held prefix pages are deliberate cache, not leaks
    assert router.pages_in_use > 0


def test_router_validation():
    with pytest.raises(ValueError):
        Router(CFG, PARAMS, n_engines=0, n_slots=4, max_total_tokens=96)
    with pytest.raises(ValueError):
        Router(CFG, PARAMS, n_engines=4, n_slots=2, max_total_tokens=96)
    with pytest.raises(ValueError):
        Router(CFG, PARAMS, n_engines=2, n_slots=4, max_total_tokens=96,
               policy="round-robin")
    with pytest.raises(ValueError):
        Router(CFG, PARAMS, n_engines=2, n_slots=4, max_total_tokens=96,
               meshes=[None])
    assert _split_evenly(10, 3) == [4, 3, 3]
    assert _split_evenly(3, 3) == [1, 1, 1]


# ---------------------------------------------------------------------------
# new Scheduler knobs

def test_default_flips():
    """Paged pools default to fused compaction; chunked prefill defaults
    to packing — flags stay explicit opt-outs."""
    s = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL,
                  page_tokens=16, prefill_chunk=16)
    assert s.fused_compaction and s.pack_prefill
    s = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL,
                  page_tokens=16, prefill_chunk=16,
                  pack_prefill=False, fused_compaction=False)
    assert not s.fused_compaction and not s.pack_prefill
    s = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL)
    assert not s.fused_compaction and not s.pack_prefill


def test_prefill_lanes_cap():
    """A lane cap bounds concurrent packed admissions (the carry stops
    scaling with --slots) without changing any request's output."""
    reqs = make_reqs(4, seed=5, gen=4, max_len=30)
    arrivals = [0, 0, 0, 1]

    base = serve(Scheduler(CFG, PARAMS, n_slots=4,
                           max_total_tokens=MAX_TOTAL, prefill_chunk=16),
                 reqs, arrivals)

    s = Scheduler(CFG, PARAMS, n_slots=4, max_total_tokens=MAX_TOTAL,
                  prefill_chunk=16, prefill_lanes=1)
    assert s.prefill_lanes == 1
    peak = 0
    i = 0
    reqs2 = [Request(prompt=r.prompt, max_new_tokens=4, uid=r.uid)
             for r in reqs]
    while i < len(reqs2) or s.has_work:
        while i < len(reqs2) and arrivals[i] <= s.step_count:
            s.submit(reqs2[i])
            i += 1
        s.step()
        peak = max(peak, len(s._lane_of))
    assert peak <= 1
    assert {r.uid: r.output_tokens for r in s.finished} == base
    with pytest.raises(ValueError):
        Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL,
                  prefill_chunk=16, prefill_lanes=0)


def test_tile_overhead_bytes_override(monkeypatch):
    """Explicit arg > env var > module constant, end to end through
    Scheduler(page_tokens="auto")."""
    T = 256                       # large enough that the optimum moves
    default = auto_page_tokens(CFG, 4, T)
    # zero measured dispatch cost shifts the page-size optimum
    zero = auto_page_tokens(CFG, 4, T, tile_overhead_bytes=0)
    assert zero != default
    monkeypatch.setenv("REPRO_TILE_OVERHEAD_BYTES", "0")
    assert auto_page_tokens(CFG, 4, T) == zero
    # explicit argument wins over the env var
    assert auto_page_tokens(CFG, 4, T, tile_overhead_bytes=2048) == default
    monkeypatch.delenv("REPRO_TILE_OVERHEAD_BYTES")
    s = Scheduler(CFG, PARAMS, n_slots=4, max_total_tokens=T,
                  page_tokens="auto", tile_overhead_bytes=0)
    assert s.page_tokens == zero


# ---------------------------------------------------------------------------
# partition-spec rules (shape-only — no devices needed)

class FakeMesh:
    def __init__(self, shape):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)


MESH = FakeMesh({"model": 2})


def test_serving_param_specs_megatron():
    """wq/wk/wv column-sharded, wo row-sharded, everything else
    replicated — and every sharded dim divides by the axis size."""
    specs = sh.serving_param_specs(PARAMS, CFG, MESH)
    flat = jax.tree_util.tree_flatten_with_path(PARAMS)[0]
    flat_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat) == len(flat_sp)
    seen = set()
    for (path, leaf), spec in zip(flat, flat_sp):
        name = str(getattr(path[-1], "key", path[-1]))
        core = tuple(spec)[-leaf.ndim:] if leaf.ndim else ()
        if name in ("wq", "wk", "wv"):
            assert core[-1] == "model", (name, spec)
            seen.add(name)
        elif name == "wo":
            # row-sharded: the contraction (input) dim, not the output
            assert core[-2] == "model" and core[-1] is None, (name, spec)
            seen.add(name)
        for dim, entry in zip(leaf.shape, core):
            if entry == "model":
                assert dim % MESH.shape["model"] == 0, (name, leaf.shape)
    assert {"wq", "wk", "wv", "wo"} <= seen


def test_paged_cache_specs_shard_kv_heads():
    """Paged pool leaves shard Hkv on "model" (physical-page dim stays
    unsharded so page ids are device-agnostic); block tables and counters
    replicate. Autodetected from the block_table key."""
    shapes = jax.eval_shape(
        lambda: cache_mod.init_cache(CFG, 4, MAX_TOTAL, page_tokens=16))
    specs = sh.cache_specs(shapes, CFG, MESH)
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    n_pool = 0
    for (path, leaf), spec in zip(flat, flat_sp):
        name = str(getattr(path[-1], "key", path[-1]))
        if name in ("ck_vals", "ck_bm", "cv_vals", "cv_bm"):
            assert tuple(spec)[-4:] == (None, "model", None, None), spec
            assert leaf.shape[-3] % MESH.shape["model"] == 0
            n_pool += 1
        elif name in ("block_table", "n_valid", "n_compressed", "w_len"):
            assert all(e is None for e in tuple(spec)), (name, spec)
    assert n_pool > 0


def test_cache_hbm_bytes_mesh_model():
    acct = cache_hbm_bytes(CFG, 8, MAX_TOTAL, page_tokens=16, mesh_model=2)
    assert "paged_per_device" in acct
    # Hkv-carrying terms halve; the replicated block table does not
    win = acct["paged"] - acct["paged_pool"] - acct["page_meta"]
    assert acct["paged_per_device"] == (acct["paged_pool"] // 2
                                        + acct["page_meta"] + win // 2)
    assert acct["paged_per_device"] < acct["paged"]
    with pytest.raises(ValueError):
        cache_hbm_bytes(CFG, 8, MAX_TOTAL, page_tokens=16, mesh_model=3)


# ---------------------------------------------------------------------------
# real multi-device run (subprocess: 8 fake CPU devices)

_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")
os.environ["JAX_PLATFORMS"] = "cpu"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import init_params
from repro.serving import sharded
from repro.serving.engine import Request, Scheduler

assert len(jax.devices()) >= 8
CFG = get_config("starcoder2-3b").reduced().with_sparsity(0.5, 0.5)
PARAMS = init_params(jax.random.PRNGKey(0), CFG)


def trace(**kw):
    rng = np.random.default_rng(0)
    s = Scheduler(CFG, PARAMS, n_slots=3, max_total_tokens=96,
                  page_tokens=16, prefill_chunk=16, collect_logits=True,
                  **kw)
    # one long generation so decode-time tile retirement (fused
    # compaction, default-on for paged pools) definitely fires sharded
    for i, (L, gen, arr) in enumerate([(20, 6, 0), (35, 30, 0),
                                       (9, 6, 2), (27, 6, 4)]):
        pr = rng.integers(0, CFG.vocab_size, size=L).tolist()
        s.submit(Request(prompt=pr, max_new_tokens=gen, uid=i))
    s.run(max_steps=300)
    assert not s.has_work
    return s, {r.uid: (r.output_tokens, r.logits) for r in s.finished}


base_s, base = trace()
assert base_s.allocator.in_use == 0

for M in (1, 2):
    mesh = sharded.make_serving_mesh(M)
    s, out = trace(mesh=mesh)
    assert s.allocator.in_use == 0, f"page leak at M={M}"
    for uid in base:
        assert out[uid][0] == base[uid][0], (
            f"M={M} uid={uid} tokens diverged")
        for a, b in zip(out[uid][1], base[uid][1]):
            if M == 1:
                # one-device psum is an identity: bit-exact
                assert np.array_equal(a, b), f"M=1 not bit-exact uid={uid}"
            else:
                # cross-device reduction order: fp32 tolerance
                np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-2)
    # the cache keeps its declared shardings through decode + retirement
    sharded.assert_cache_shardings(s)
    counts = sharded.collective_audit(
        s._decode, s.params, s.next_tokens, s.cache,
        active=jnp.ones((3,), bool))
    sharded.assert_no_resharding(counts)
    if M == 2:
        assert counts["all-reduce"] > 0, counts
        pdb = sharded.per_device_cache_bytes(s.cache)
        full = sum(l.nbytes for l in jax.tree.leaves(base_s.cache))
        from jax.sharding import PartitionSpec as P
        specs = jax.tree.leaves(s._sharded.cache_specs,
                                is_leaf=lambda x: isinstance(x, P))
        meta = sum(l.nbytes for l, sp in
                   zip(jax.tree.leaves(s.cache), specs)
                   if "model" not in sp)
        assert pdb <= full / 2 + meta, (pdb, full, meta)
print("SHARDED_SERVING_OK")
"""


def test_sharded_scheduler_8dev():
    """model=1 bit-exact, model=2 fp32-tolerance; shardings stable through
    the full serve loop; compiled decode free of resharding collectives;
    per-device pool bytes = single/M + replicated metadata."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "SHARDED_SERVING_OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-3000:]
