"""Sharding rules + a real multi-device pjit run (subprocess with 8 fake
CPU devices so the main test process keeps its single real device)."""
import subprocess
import sys
import os

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models.model import param_shapes
from repro.serving import cache as cache_mod
from repro.sharding import specs as sh


class FakeMesh:
    """Shape-only mesh stand-in (rules only read axis_names/shape)."""
    def __init__(self, shape):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("mesh", [MESH1, MESH2], ids=["1pod", "2pod"])
def test_param_specs_divisible(arch, mesh):
    """Every sharded dim must divide by its axis product — pjit hard rule."""
    cfg = get_config(arch)
    shapes = param_shapes(cfg)
    specs = sh.param_specs(shapes, mesh, fsdp=True, cfg=cfg)
    flat_sh = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_sh) == len(flat_sp)
    n_sharded = 0
    for (path, leaf), spec in zip(flat_sh, flat_sp):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = (entry,) if isinstance(entry, str) else entry
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % size == 0, (jax.tree_util.keystr(path), leaf.shape,
                                     spec)
            n_sharded += 1
    assert n_sharded > 0        # rules actually shard something


@pytest.mark.parametrize("arch", ["command-r-35b", "jamba-1.5-large-398b"])
def test_param_state_fits_512_chips(arch):
    """Params+optimizer bytes per chip under the multi-pod mesh must fit
    16 GiB-class HBM with room for activations."""
    cfg = get_config(arch)
    n = cfg.param_count()
    total = n * 2 + n * 12          # bf16 params + fp32 master/mu/nu
    per_chip = total / 512
    assert per_chip < 15 * 2**30, f"{per_chip/2**30:.1f} GiB/chip"


@pytest.mark.parametrize("arch,B", [("command-r-35b", 128),
                                    ("command-r-35b", 1),
                                    ("jamba-1.5-large-398b", 1)])
def test_cache_specs_divisible(arch, B):
    cfg = get_config(arch)
    shapes = jax.eval_shape(
        lambda: cache_mod.init_cache(cfg, B, 32768 + 128))
    specs = sh.cache_specs(shapes, cfg, MESH2)
    flat_sh = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(flat_sh, flat_sp):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = (entry,) if isinstance(entry, str) else entry
            size = int(np.prod([MESH2.shape[a] for a in axes]))
            assert dim % size == 0, (jax.tree_util.keystr(path), spec)


_PJIT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, TrainConfig
from repro.sharding import specs as sh
from repro.sharding.constraints import constraint_mesh
from repro.training import init_train_state, make_train_step
from repro.training.optimizer import OptState
from repro.training.train_loop import TrainState
from repro.training.data import synthetic_batch
from jax.sharding import PartitionSpec as P

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_config("stablelm-3b").reduced()
tc = TrainConfig()
state = init_train_state(jax.random.PRNGKey(0), cfg)
pspecs = sh.param_specs(state.params, mesh, fsdp=True, cfg=cfg)
ospecs = OptState(P(), pspecs, pspecs, pspecs)
sspec = TrainState(sh.to_named(pspecs, mesh), sh.to_named(ospecs, mesh))
state = jax.device_put(state, sspec)
batch = synthetic_batch(0, 0, 8, 64, cfg)
bspec = sh.to_named(sh.train_batch_specs(cfg, 8, mesh), mesh)
batch = jax.device_put(batch, bspec)
with constraint_mesh(mesh):
    step = jax.jit(make_train_step(cfg, tc), in_shardings=(sspec, bspec),
                   donate_argnums=(0,))
    state1, m1 = step(state, batch)
loss_sharded = float(m1["loss"])

# single-device reference
state_r = init_train_state(jax.random.PRNGKey(0), cfg)
step_r = jax.jit(make_train_step(cfg, tc))
_, m2 = step_r(state_r, batch)
loss_ref = float(m2["loss"])
assert abs(loss_sharded - loss_ref) / abs(loss_ref) < 2e-2, (loss_sharded, loss_ref)

# decode under the mesh
from repro.serving.engine import prefill, decode_step
from repro.serving import cache as cm
from functools import partial
params = state1.params
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 40), 0, cfg.vocab_size)
with constraint_mesh(mesh):
    lg, cache = jax.jit(partial(prefill, cfg=cfg, max_total_tokens=96))(params, toks)
    lg2, cache = jax.jit(partial(decode_step, cfg=cfg))(params, jnp.argmax(lg, -1).astype(jnp.int32), cache)
assert np.isfinite(np.asarray(lg2, np.float32)).all()
print("PJIT_OK", loss_sharded, loss_ref)
"""


def test_pjit_train_and_serve_8dev():
    """End-to-end: sharded train step == single-device step; sharded
    prefill+decode runs. Separate process for the 8-device CPU mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", _PJIT_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert "PJIT_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]
