"""Bitmap fixed-k sparse format: roundtrip, invariants, compression rates."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import import_hypothesis

import_hypothesis()   # hard requirement in CI (CI_REQUIRE_HYPOTHESIS=1)
from hypothesis import given, settings, strategies as st

from repro.core.sparse_format import (compressed_bytes, compression_rate,
                                      pack_fixedk, pad_to_words,
                                      paper_compression_rate, prune_and_pack,
                                      topk_mask, unpack_fixedk)


@pytest.mark.parametrize("d,k", [(128, 40), (128, 64), (64, 24), (80, 24),
                                 (96, 8), (128, 128)])
def test_roundtrip(rng, d, k):
    x = jnp.asarray(rng.normal(size=(3, 16, d)).astype(np.float32))
    vals, bm = prune_and_pack(x, k)
    assert vals.shape == (3, 16, k)
    assert bm.shape == (3, 16, pad_to_words(d) // 32)
    assert bm.dtype == jnp.uint32
    dense = unpack_fixedk(vals, bm, d)
    mask = topk_mask(x, k)
    np.testing.assert_allclose(np.asarray(dense),
                               np.asarray(jnp.where(mask, x, 0)), rtol=1e-6)


def test_topk_exact_count(rng):
    x = jnp.asarray(rng.normal(size=(5, 7, 128)).astype(np.float32))
    for k in (8, 40, 64, 127):
        mask = topk_mask(x, k)
        assert int(mask.sum()) == 5 * 7 * k                 # exactly k per row


def test_topk_keeps_largest(rng):
    x = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32))
    mask = np.asarray(topk_mask(x, 40))
    mag = np.abs(np.asarray(x))
    for r in range(4):
        kept_min = mag[r][mask[r]].min()
        dropped_max = mag[r][~mask[r]].max()
        assert kept_min >= dropped_max


def test_tie_break_deterministic():
    x = jnp.ones((1, 128), jnp.float32)                     # all ties
    mask = np.asarray(topk_mask(x, 40))[0]
    assert mask[:40].all() and not mask[40:].any()          # low channel wins


@settings(max_examples=30, deadline=None)
@given(rows=st.integers(1, 6),
       d_pow=st.sampled_from([32, 64, 96, 128]),
       seed=st.integers(0, 2**31 - 1),
       frac=st.floats(0.1, 1.0))
def test_roundtrip_property(rows, d_pow, seed, frac):
    """Property: pack/unpack is exact for any shape/k/values incl. ties."""
    g = np.random.default_rng(seed)
    k = max(1, int(d_pow * frac))
    x = jnp.asarray(np.round(g.normal(size=(rows, d_pow)) * 4) / 4
                    ).astype(jnp.float32)                   # force ties
    vals, bm = prune_and_pack(x, k)
    dense = unpack_fixedk(vals, bm, d_pow)
    mask = topk_mask(x, k)
    np.testing.assert_allclose(np.asarray(dense),
                               np.asarray(jnp.where(mask, x, 0)), rtol=1e-6)
    # bitmap popcount == k per row
    bits = np.unpackbits(np.asarray(bm).view(np.uint8), bitorder="little")
    assert bits.sum() == rows * k


def test_compression_rates_match_paper_trend():
    """Our fixed-k format beats the paper's offset+padding format; both match
    the paper's reported ballpark (0.45 at s=0.7 incl. overheads)."""
    ours_70 = compression_rate(128, 40)
    ours_50 = compression_rate(128, 64)
    paper_70 = paper_compression_rate(128, 0.7)
    paper_50 = paper_compression_rate(128, 0.5)
    assert ours_70 < paper_70 < 0.47
    assert ours_50 < paper_50 < 0.66
    assert abs(paper_70 - 0.45) < 0.06                      # paper Fig. 6b
    assert compressed_bytes(64, 128, 40) == 64 * (40 * 2 + 16)
