"""Training substrate: optimizer math, schedules, grad accumulation
equivalence, checkpoint atomicity/corruption/restore, data determinism."""
import json
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_config
from repro.training import (Checkpointer, init_train_state, make_train_step,
                            train)
from repro.training.data import synthetic_batch
from repro.training.optimizer import (adamw_update, clip_by_global_norm,
                                      cosine_schedule, global_norm,
                                      init_opt_state)

CFG = get_config("starcoder2-3b").reduced()
KEY = jax.random.PRNGKey(0)


def test_schedule_warmup_and_decay():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lr = cosine_schedule(tc)
    assert float(lr(jnp.asarray(5))) == pytest.approx(5e-4)
    assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-9)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(10.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_adamw_decays_matrices_not_norms():
    params = {"blocks": {"wq": jnp.ones((4, 4), jnp.float32)},
              "norm": {"scale": jnp.ones((4,), jnp.float32)}}
    opt = init_opt_state(params)
    grads = jax.tree.map(jnp.zeros_like, params)
    tc = TrainConfig(learning_rate=1e-2, weight_decay=0.5, warmup_steps=0,
                     total_steps=10)
    new_params, _, _ = adamw_update(grads, opt, params, tc)
    assert float(new_params["blocks"]["wq"][0, 0]) < 1.0      # decayed
    assert float(new_params["norm"]["scale"][0]) == 1.0       # not decayed


def test_grad_accumulation_equivalence():
    """microbatch=2 must produce (nearly) the same update as full batch."""
    state = init_train_state(KEY, CFG)
    batch = synthetic_batch(0, 0, 4, 32, CFG)
    tc_full = TrainConfig(microbatch=0)
    tc_micro = TrainConfig(microbatch=2)
    s_full, m_full = jax.jit(make_train_step(CFG, tc_full))(state, batch)
    s_micro, m_micro = jax.jit(make_train_step(CFG, tc_micro))(state, batch)
    assert float(m_full["loss"]) == pytest.approx(float(m_micro["loss"]),
                                                  rel=2e-2)
    a = jax.tree.leaves(s_full.opt.master)[0]
    b = jax.tree.leaves(s_micro.opt.master)[0]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-2,
                               atol=1e-4)


def test_data_stateless_determinism():
    b1 = synthetic_batch(7, 42, 4, 64, CFG)
    b2 = synthetic_batch(7, 42, 4, 64, CFG)
    b3 = synthetic_batch(7, 43, 4, 64, CFG)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1["labels"][:, :-1]),
                                  np.asarray(b1["tokens"][:, 1:]))


def test_checkpoint_roundtrip_and_gc():
    d = tempfile.mkdtemp()
    try:
        ck = Checkpointer(d, keep=2, async_save=False)
        state = init_train_state(KEY, CFG)
        for s in (1, 2, 3):
            ck.save(s, state)
        assert ck.complete_steps() == [2, 3]                  # GC keeps 2
        step, restored = ck.restore_latest(state)
        assert step == 3
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
    finally:
        shutil.rmtree(d)


def test_checkpoint_corruption_fallback():
    """A corrupted latest checkpoint must fall back to the previous one —
    the node-failure-during-save scenario."""
    d = tempfile.mkdtemp()
    try:
        ck = Checkpointer(d, keep=3, async_save=False)
        state = init_train_state(KEY, CFG)
        ck.save(1, state)
        ck.save(2, state)
        # corrupt step 2's shard
        with open(os.path.join(d, "step_00000002", "shard_0.npz"), "wb") as f:
            f.write(b"garbage")
        step, _ = ck.restore_latest(state)
        assert step == 1
    finally:
        shutil.rmtree(d)


def test_checkpoint_tmp_dirs_ignored():
    d = tempfile.mkdtemp()
    try:
        ck = Checkpointer(d, async_save=False)
        os.makedirs(os.path.join(d, "step_00000009.tmp-123"))   # crashed write
        assert ck.complete_steps() == []
        step, _ = ck.restore_latest({"x": jnp.zeros(1)})
        assert step is None
    finally:
        shutil.rmtree(d)


def test_end_to_end_loss_decreases():
    d = tempfile.mkdtemp()
    try:
        tc = TrainConfig(total_steps=15, warmup_steps=3, learning_rate=1e-2,
                         checkpoint_every=100, checkpoint_dir=d)
        losses = []
        train(CFG, tc, batch_size=4, seq_len=64, log_every=5,
              on_metrics=lambda s, m: losses.append(m["loss"]), resume=False)
        assert losses[-1] < losses[0]
    finally:
        shutil.rmtree(d)
