"""Roofline machinery: HLO collective parser, terms, corrections."""
import jax.numpy as jnp
import pytest

from repro import roofline
from repro.configs import get_config, get_shape


HLO = """
  %all-reduce.1 = f32[256,512]{1,0} all-reduce(%dot.1), channel_id=1
  %ag = bf16[1024,64]{1,0} all-gather(%p0), channel_id=2
  %ar2-start = f32[8]{0} all-reduce-start(%x), channel_id=3
  %ar2-done = f32[8]{0} all-reduce-done(%ar2-start)
  %rs = (f32[16,16]{1,0}, f32[16,16]{1,0}) reduce-scatter(%a, %b), channel_id=4
  %cp = u32[4,4]{1,0} collective-permute(%y), channel_id=5
  %dot.5 = f32[128,128]{1,0} dot(%a, %b)
"""


def test_collective_parser():
    out = roofline.collective_bytes_from_hlo(HLO)
    assert out["all-reduce"] == 2 * (256 * 512 * 4) + 2 * (8 * 4)
    assert out["all-gather"] == 1024 * 64 * 2
    assert out["reduce-scatter"] == 2 * 16 * 16 * 4
    assert out["collective-permute"] == 4 * 4 * 4
    # -done lines not double counted
    assert sum(out.values()) == (2 * 524288 + 64 + 131072 + 2048 + 64)


def test_terms_bottleneck():
    t = roofline.RooflineTerms(flops=197e12, bytes_hbm=1e9,
                               bytes_collective=1e9)
    assert t.bottleneck == "compute"
    assert t.t_compute == pytest.approx(1.0)
    t2 = roofline.RooflineTerms(flops=1e9, bytes_hbm=819e9,
                                bytes_collective=0)
    assert t2.bottleneck == "memory"
    assert t2.t_memory == pytest.approx(1.0)


def test_model_flops():
    cfg = get_config("qwen3-moe-30b-a3b")
    tr = roofline.model_flops(cfg, get_shape("train_4k"))
    # 6 * N_active * tokens
    assert tr == pytest.approx(6.0 * cfg.active_param_count() * 256 * 4096)
    de = roofline.model_flops(cfg, get_shape("decode_32k"))
    assert de == pytest.approx(2.0 * cfg.active_param_count() * 128)


def test_scan_corrections_present_where_expected():
    cfg_d = get_config("starcoder2-3b")
    c = roofline.scan_corrections(cfg_d, get_shape("train_4k"), "train")
    assert c["flops"] > 0                         # chunked attention + CE
    cfg_r = get_config("rwkv6-7b")
    c2 = roofline.scan_corrections(cfg_r, get_shape("prefill_32k"), "prefill")
    assert c2["flops"] > 0                        # WKV time scan
    c3 = roofline.scan_corrections(cfg_d, get_shape("decode_32k"), "decode")
    assert c3["bytes"] > 0                        # chunked pool scan


def test_prefix_sharing_and_stall_models():
    """PR-5 serving models: shared-prefix byte saving scales with
    (sharers-1)·full-pages, and the chunked stall model matches the
    engine's charge-the-padded-chunk accounting."""
    from repro.serving.cache import page_bytes

    cfg = get_config("starcoder2-3b")
    pt, prefix = 16, 56                      # 3 full pages + 8-token tail
    saved = roofline.prefix_shared_pool_bytes_saved(cfg, pt, prefix, 4)
    assert saved == 3 * 3 * page_bytes(cfg, pt)
    assert roofline.prefix_shared_pool_bytes_saved(cfg, pt, prefix, 1) == 0
    m = roofline.chunked_prefill_stall_model(60, 8, 1e-3)
    assert m["solo_stall_s"] == pytest.approx(60e-3)
    # padded chunks: the per-step stall is the FULL chunk, prompt < chunk
    # included (the engine executes the padded forward either way)
    assert m["chunked_stall_per_step_s"] == pytest.approx(8e-3)
    assert roofline.chunked_prefill_stall_model(3, 8, 1e-3)[
        "chunked_stall_per_step_s"] == pytest.approx(8e-3)
    assert m["first_token_extra_steps"] == 7.0
