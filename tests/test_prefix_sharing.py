"""Shared-prefix copy-on-write paged pools + chunked prefill.

The three claims this module pins down:

  * BIT-EXACTNESS — a shared-prefix scheduler run (prefix pages aliased
    through refcounted block tables) and a chunked-prefill run both produce
    per-token logits IDENTICAL (fp32, ``assert_array_equal``) to running
    each request alone through the contiguous lockstep path. Sharing is a
    storage-level dedup (per-token magnitude pruning is deterministic, so a
    shared page is bit-identical to the page the slot would have written)
    and chunked prefill's masked tails underflow to exact zeros.
  * COPY-ON-WRITE ISOLATION — a compaction that would append into a
    refcount>1 boundary page copies first; the other holders' page content
    and outputs are untouched, and the write-target invariant
    (``kernels.sparse_decode.validate_block_table``) holds every step.
  * NO REFERENCE LEAKS — after a drain the only live pages are the prefix
    index's cache entries; clearing the index restores the full free list.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.sparse_decode import validate_block_table
from repro.models import init_params
from repro.serving import cache as cache_mod
from repro.serving.engine import (Request, Scheduler, decode_step, prefill,
                                  prefill_chunk_step, prefill_chunk_supported,
                                  init_chunk_carry)

KEY = jax.random.PRNGKey(0)
CFG = get_config("starcoder2-3b").reduced().with_sparsity(0.5, 0.5)
PARAMS = init_params(KEY, CFG)
MAX_TOTAL = 96
TT = CFG.mustafar.tile_tokens          # 16 in the reduced cfg
_PREFIX_RNG = np.random.default_rng(100)
PREFIX = [int(t) for t in _PREFIX_RNG.integers(0, CFG.vocab_size, size=56)]


def _req(seed, suffix_len, gen, prefix=PREFIX):
    r = np.random.default_rng(seed)
    prompt = list(prefix) + [int(t) for t in
                             r.integers(0, CFG.vocab_size, size=suffix_len)]
    return Request(prompt=prompt, max_new_tokens=gen)


def _solo_greedy(prompt, n_new):
    """Contiguous lockstep reference: (tokens, fp32 logits per step)."""
    lg, cache = prefill(PARAMS, jnp.asarray(prompt, jnp.int32)[None], CFG,
                        max_total_tokens=MAX_TOTAL)
    logits = [np.asarray(lg[0], np.float32)]
    toks = [int(jnp.argmax(lg[0]))]
    step = jax.jit(lambda p, t, c: decode_step(p, t, c, CFG))
    while len(toks) < n_new:
        lg, cache = step(PARAMS, jnp.asarray([toks[-1]], jnp.int32), cache)
        logits.append(np.asarray(lg[0], np.float32))
        toks.append(int(jnp.argmax(lg[0])))
    return toks, logits


def _drain(sched, reqs):
    for r in reqs:
        sched.submit(r)
    sched.run()
    assert all(r.done for r in reqs)


def _assert_bit_exact(reqs, solos):
    for req, (toks, logits) in zip(reqs, solos):
        assert req.output_tokens == toks, (req.uid, req.output_tokens, toks)
        assert len(req.logits) == len(logits)
        for got, want in zip(req.logits, logits):
            np.testing.assert_array_equal(np.asarray(got, np.float32), want)


def _assert_leak_free(sched):
    """Only the prefix index may hold pages after a drain; clearing it must
    restore the whole free list and leave nothing reserved."""
    held = sched.prefix.held_pages
    assert sched.allocator.in_use == len(set(held)), \
        (sched.allocator.in_use, held)
    assert sched.allocator.n_reserved == 0
    sched.prefix.clear(sched.allocator)
    assert sched.allocator.in_use == 0
    assert sorted(sched.allocator._free) == list(range(sched.n_pages))


# ----------------------------------------------------------------------
# bit-exact equivalence

def test_shared_prefix_bit_exact_vs_solo():
    """Three requests sharing a 56-token prefix, paged pools with sharing
    on: every request's per-step logits must be bit-identical (fp32) to its
    own solo lockstep run, sharing must actually fire, and nothing leaks."""
    specs = [(1, 4, 12), (2, 6, 10), (3, 4, 14)]
    solos = [_solo_greedy(_req(*s).prompt, s[2]) for s in specs]
    sched = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT, share_prefix=True,
                      collect_logits=True, debug_invariants=True)
    reqs = [_req(*s) for s in specs]
    _drain(sched, reqs)
    _assert_bit_exact(reqs, solos)
    assert sched.shared_admissions >= 2          # later arrivals matched
    assert sched.prefix.hits > 0
    # the later requests mapped the whole retired prefix region:
    # comp(60) = 48 -> pages 0..2 shared at page_tokens=16
    assert reqs[1].shared_prefix_tokens == 48
    _assert_leak_free(sched)


def test_shared_prefix_saves_pool_pages():
    """Same trace with and without sharing: identical outputs, but the
    shared run's peak drawn pages must be well below the duplicate-pages
    baseline (the BENCH_prefix acceptance bar, in miniature)."""
    specs = [(11, 4, 16), (12, 6, 16), (13, 4, 16), (14, 6, 16)]

    def serve(share):
        sched = Scheduler(CFG, PARAMS, n_slots=4, max_total_tokens=MAX_TOTAL,
                          page_tokens=TT, share_prefix=share,
                          debug_invariants=True)
        reqs = [_req(*s) for s in specs]
        _drain(sched, reqs)
        return sched, [r.output_tokens for r in reqs]

    base, out_base = serve(False)
    shared, out_shared = serve(True)
    assert out_base == out_shared                # identical outputs
    saving = base.allocator.peak_in_use / shared.allocator.peak_in_use
    assert saving >= 1.5, \
        f"sharing only cut peak pages {base.allocator.peak_in_use} -> " \
        f"{shared.allocator.peak_in_use} ({saving:.2f}x < 1.5x)"


def test_chunked_prefill_bit_exact_and_bounded_stall():
    """Chunked admissions (8-token chunks) must reproduce solo logits
    bit-exactly while never running more than 8 prefill tokens in any
    engine step, and the first token must land ceil(T/8)-1 steps after
    admission began (the prefill genuinely spread over steps)."""
    specs = [(21, 4, 8), (22, 6, 8)]
    solos = [_solo_greedy(_req(*s).prompt, s[2]) for s in specs]
    sched = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT, prefill_chunk=8,
                      collect_logits=True, debug_invariants=True)
    reqs = [_req(*s) for s in specs]
    _drain(sched, reqs)
    _assert_bit_exact(reqs, solos)
    assert sched.max_prefill_step_tokens <= 8
    assert sched.occupancy.prefill_tokens_per_step > 0
    n_chunks = -(-len(reqs[0].prompt) // 8)
    assert reqs[0].first_token_step - reqs[0].prefill_step == n_chunks - 1


def test_chunked_prefill_interleaves_decode():
    """While a long admission prefills in chunks, the already-running
    request must keep decoding — the whole point of bounding the stall."""
    first = _req(31, 4, 24)
    second = _req(32, 6, 4)
    sched = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT, prefill_chunk=8, debug_invariants=True)
    sched.submit(first)
    sched.step(); sched.step()                   # first decodes alone
    produced = len(first.output_tokens)
    sched.submit(second)
    while second.first_token_step < 0:           # second still prefilling
        sched.step()
    assert len(first.output_tokens) > produced + 1, \
        "decode stalled for the whole chunked prefill"
    sched.run()
    want_first, _ = _solo_greedy(first.prompt, first.max_new_tokens)
    want_second, _ = _solo_greedy(second.prompt, second.max_new_tokens)
    assert first.output_tokens == want_first
    assert second.output_tokens == want_second


def test_chunk_forward_matches_full_prefill():
    """Unit check under the scheduler: prefill_chunk_step over 3 chunks
    reproduces the one-shot prefill's last-position logits bit-exactly."""
    assert prefill_chunk_supported(CFG)
    prompt = jnp.asarray(PREFIX[:24], jnp.int32)[None]
    full_lg, _ = prefill(PARAMS, prompt, CFG, max_total_tokens=MAX_TOTAL)
    C = 8
    carry = init_chunk_carry(CFG, 24)
    step = jax.jit(lambda p, t, c, o: prefill_chunk_step(p, t, c, o, CFG))
    for off in range(0, 24, C):
        lg, carry = step(PARAMS, prompt[:, off:off + C], carry,
                         jnp.int32(off))
    np.testing.assert_array_equal(np.asarray(lg[0, -1], np.float32),
                                  np.asarray(full_lg[0], np.float32))


# ----------------------------------------------------------------------
# copy-on-write mechanics

def test_cow_isolates_shared_boundary_page():
    """page_tokens = 2·tile -> the prefix's last page is a partially filled
    BOUNDARY page. Two sharers alias it; the first one to compact past its
    prefill fill must copy-on-write, leaving the other sharer's mapping,
    content, and outputs untouched."""
    pt = 2 * TT
    specs = [(41, 4, 20), (42, 6, 20)]
    solos = [_solo_greedy(_req(*s).prompt, s[2]) for s in specs]
    sched = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL,
                      page_tokens=pt, share_prefix=True,
                      collect_logits=True, debug_invariants=True)
    reqs = [_req(*s) for s in specs]
    for r in reqs:
        sched.submit(r)
    saw_divergence = False
    while sched.has_work:
        sched.step()
        # READ-side invariants every step (the WRITE-side check — no
        # compaction targets a refcount>1 page — runs inside the scheduler
        # at decode time via debug_invariants; here, after the step, a
        # boundary page may legitimately be shared again because
        # _register_prefix re-cached it)
        live = [s for s, r in enumerate(sched.slots) if r is not None]
        if live:
            nc = [sched._n_comp[s] if sched.slots[s] is not None else 0
                  for s in range(sched.n_slots)]
            validate_block_table(
                np.asarray(sched.cache["block_table"]),
                sched.n_pages + 1, page_tokens=pt,
                n_compressed=np.asarray(nc))
            bt = np.asarray(sched.cache["block_table"])
            rows = [set(p for p in bt[s] if p >= 0) for s in live]
            if len(live) == 2 and rows[0] and rows[1] \
                    and rows[0] != rows[1] and (rows[0] & rows[1]):
                saw_divergence = True        # aliased prefix + private pages
    assert sched.cow_count >= 1, "no copy-on-write fired"
    assert saw_divergence, "slots never simultaneously aliased and diverged"
    _assert_bit_exact(reqs, solos)
    _assert_leak_free(sched)


def test_cow_budget_never_underflows_with_owned_boundary():
    """Regression: a request that draws its whole worst-case budget and has
    its own boundary page cached by the index must still have CoW headroom
    when its first compaction hits that page (admission reserves +1)."""
    pt = 2 * TT
    solo_req = _req(51, 4, 24)
    sched = Scheduler(CFG, PARAMS, n_slots=1, max_total_tokens=MAX_TOTAL,
                      page_tokens=pt, share_prefix=True,
                      debug_invariants=True)
    _drain(sched, [solo_req])                    # would assert on underflow
    assert sched.cow_count >= 1                  # index ref forced the copy
    _assert_leak_free(sched)


def test_prefix_index_eviction_under_pressure():
    """DISTINCT prompts fill the index until the pool can't also fit a new
    admission: the scheduler must LRU-evict index entries instead of
    deadlocking, and outputs stay solo-equivalent throughout."""
    specs = [(61, 60, 8), (62, 60, 8), (63, 60, 8)]   # no common prefix
    reqs = [_req(s, L, g, prefix=[]) for s, L, g in specs]
    solos = [_solo_greedy(r.prompt, r.max_new_tokens)[0] for r in reqs]
    # each prompt retires 3 pages; 6 physical pages hold only two cached
    # chains, so the third admission must evict the first
    sched = Scheduler(CFG, PARAMS, n_slots=1, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT, n_pages=6, share_prefix=True,
                      debug_invariants=True)
    _drain(sched, reqs)
    for r, want in zip(reqs, solos):
        assert r.output_tokens == want
    assert len(sched.prefix.held_pages) <= sched.n_pages
    assert sched.prefix.misses >= 2              # distinct prompts: no hits
    _assert_leak_free(sched)


def test_eviction_covers_cow_headroom():
    """Regression: the admission-time eviction target must include the +1
    CoW headroom a mid-page compressed fill needs. pt=2·tile makes comp(60)
    = 48 end mid-page (+1 headroom); the pool is sized to the exact worst
    case, so each admission fits only once the index is FULLY evicted — the
    old undiscounted target stopped one page short and deadlocked."""
    pt = 2 * TT
    specs = [(91, 60, 8), (92, 60, 8), (93, 60, 8)]   # distinct prompts
    reqs = [_req(s, L, g, prefix=[]) for s, L, g in specs]
    need = cache_mod.pages_for_request(CFG, 60 + 8, pt) + 1
    sched = Scheduler(CFG, PARAMS, n_slots=1, max_total_tokens=MAX_TOTAL,
                      page_tokens=pt, n_pages=need, share_prefix=True,
                      debug_invariants=True)
    for r in reqs:
        sched.submit(r)
    guard = 0
    while sched.has_work:
        sched.step()
        guard += 1
        assert guard < 500, "admission deadlocked (eviction under-target)"
    assert all(r.done for r in reqs)
    _assert_leak_free(sched)


def test_unsupported_family_fallback_reports_stall():
    """prefill_chunk on a family that cannot chunk falls back to one-shot
    admission — the stall stats must then report the whole-prompt stall
    honestly instead of claiming a zero-stall chunked run."""
    sched = Scheduler(CFG, PARAMS, n_slots=1, max_total_tokens=MAX_TOTAL,
                      prefill_chunk=8)
    sched._can_chunk = False          # simulate a recurrent/encoder family
    req = _req(99, 4, 2)
    _drain(sched, [req])
    assert sched.max_prefill_step_tokens == len(req.prompt)
    assert sched.occupancy.prefill_tokens_per_step > 0


def test_stall_budget_bounds_concurrent_admissions():
    """The decode-stall budget is a bound ACROSS admissions: four short
    prompts admitted together must serialize through the chunk queue (one
    per step at budget == prompt length), never running 4 one-shot prefills
    in a single engine step."""
    reqs = [_req(95 + i, 7, 2, prefix=[]) for i in range(4)]
    sched = Scheduler(CFG, PARAMS, n_slots=4, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT, prefill_chunk=8,
                      debug_invariants=True)
    _drain(sched, reqs)
    assert 0 < sched.max_prefill_step_tokens <= 8
    firsts = sorted(r.first_token_step for r in reqs)
    assert len(set(firsts)) == 4, \
        f"admissions did not serialize under the budget: {firsts}"
    for r in reqs:   # outputs still solo-exact
        want, _ = _solo_greedy(r.prompt, r.max_new_tokens)
        assert r.output_tokens == want


def _indexed_chain(idx, alloc, prompt, comp):
    """Register ``prompt`` into ``idx`` as a retired slot would: draw the
    backing pages, register (index takes its own refs), release the slot
    refs. Returns the drawn pages."""
    pt = idx.page_tokens
    n = comp // pt + (1 if comp % pt else 0)
    alloc.reserve(n)
    pages = alloc.draw_many(n)
    idx.register(prompt, comp, pages, alloc)
    for p in pages:
        alloc.release(p)
    return pages


def test_partial_lru_just_matched_partial_survives():
    """Regression: partial boundary entries used to live on a separate
    LRU list that was never recency-compared against full chains, so a
    JUST-MATCHED boundary page could be evicted while a stone-cold full
    chain survived. Eviction must take the truly-LRU entry across both
    kinds."""
    rng = np.random.default_rng(0)
    alloc = cache_mod.PageAllocator(4)
    idx = cache_mod.PrefixIndex(TT)
    prompt_a = tuple(int(t) for t in rng.integers(0, 500, size=2 * TT))
    prompt_b = tuple(int(t) for t in rng.integers(500, 999, size=2 * TT))
    _indexed_chain(idx, alloc, prompt_a, 2 * TT)      # cold: 2 full pages
    _indexed_chain(idx, alloc, prompt_b, TT + 8)      # full page + partial
    # touch B's chain INCLUDING the boundary page (comp ends mid-page)
    full, boundary, shared = idx.match(prompt_b, TT + 8, touch_lru=True)
    assert boundary is not None and shared == TT + 8
    idx.evict_until(alloc, 1)
    # the cold A chain went (both its pages — descendants drop with the
    # root); the just-matched partial and its base page survived
    assert idx.match(prompt_a, 2 * TT)[0] == []
    full, boundary, shared = idx.match(prompt_b, TT + 8)
    assert len(full) == 1 and boundary is not None and shared == TT + 8
    idx.clear(alloc)
    assert alloc.in_use == 0


def test_partial_lru_cold_partial_evicts_first():
    """The mirror case: when the boundary page IS the least-recently-used
    entry, eviction must take it — not reflexively drop the oldest full
    chain."""
    rng = np.random.default_rng(1)
    alloc = cache_mod.PageAllocator(4)
    idx = cache_mod.PrefixIndex(TT)
    prompt_b = tuple(int(t) for t in rng.integers(500, 999, size=2 * TT))
    prompt_a = tuple(int(t) for t in rng.integers(0, 500, size=2 * TT))
    _indexed_chain(idx, alloc, prompt_b, TT + 8)      # partial is oldest...
    _indexed_chain(idx, alloc, prompt_a, 2 * TT)
    # ...because only B's FULL page gets re-touched (comp=TT stops the
    # walk before the boundary)
    idx.match(prompt_b, TT, touch_lru=True)
    idx.evict_until(alloc, 1)
    # the stale partial went alone; both chains' full pages survived
    assert idx.match(prompt_b, TT + 8)[1] is None     # boundary gone
    assert len(idx.match(prompt_b, TT)[0]) == 1
    assert len(idx.match(prompt_a, 2 * TT)[0]) == 2
    idx.clear(alloc)
    assert alloc.in_use == 0


# ----------------------------------------------------------------------
# satellites: occupancy split, sampler plumbing, aliased-view reads

def test_occupancy_splits_owned_and_shared_pages():
    # gen 28 -> decode compactions lazily draw private (owned) pages on top
    # of the aliased prefix pages, so both splits are exercised
    specs = [(71, 4, 28), (72, 6, 28), (73, 4, 28)]
    sched = Scheduler(CFG, PARAMS, n_slots=3, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT, share_prefix=True,
                      debug_invariants=True)
    _drain(sched, [_req(*s) for s in specs])
    occ = sched.occupancy
    assert occ.pages_shared is not None and occ.pages_shared > 0
    assert occ.pages_owned is not None and occ.pages_owned > 0
    np.testing.assert_allclose(occ.pages_owned + occ.pages_shared, occ.pages,
                               rtol=1e-12)
    assert 0.0 < occ.pages <= 1.0


def test_per_request_top_k_top_p_reach_sampler(monkeypatch):
    """The scheduler must forward each request's top_k/top_p into
    serving.sampler.sample for both the batched and per-slot paths."""
    import repro.serving.sampler as sampler_mod

    seen = []
    real = sampler_mod.sample

    def spy(logits, temperature=0.0, rng=None, top_k=0, top_p=1.0):
        seen.append((temperature, top_k, top_p))
        return real(logits, temperature, rng, top_k=top_k, top_p=top_p)

    monkeypatch.setattr(sampler_mod, "sample", spy)
    sched = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL)
    uniform = [Request(prompt=_req(81, 4, 4).prompt, max_new_tokens=4,
                       temperature=0.8, top_k=7, top_p=0.9)
               for _ in range(2)]
    for r in uniform:
        sched.submit(r)
    sched.run()
    assert all(k == (0.8, 7, 0.9) for k in seen)
    batched = [k for k in seen]
    assert len(batched) > 0
    # mixed knobs force the per-slot fallback; both settings must appear
    seen.clear()
    sched2 = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL)
    a = Request(prompt=_req(82, 4, 4).prompt, max_new_tokens=4,
                temperature=0.8, top_k=3)
    b = Request(prompt=_req(83, 6, 4).prompt, max_new_tokens=4,
                temperature=0.8, top_p=0.5)
    sched2.submit(a); sched2.submit(b)
    sched2.run()
    assert (0.8, 3, 1.0) in seen and (0.8, 0, 0.5) in seen


def test_sampler_top_p_truncates():
    """Nucleus sampling keeps exactly the smallest head set reaching p."""
    from repro.serving.sampler import sample
    logits = jnp.log(jnp.asarray([[0.5, 0.3, 0.15, 0.05]]))
    rng = jax.random.PRNGKey(0)
    # p=0.6: exclusive mass {0:0, 1:0.5, 2:0.8} -> tokens 0,1 survive
    draws = {int(sample(logits, 1.0, jax.random.fold_in(rng, i),
                        top_p=0.6)[0]) for i in range(64)}
    assert draws <= {0, 1} and len(draws) == 2
    # p=1.0 leaves the tail reachable
    draws_full = {int(sample(logits, 1.0, jax.random.fold_in(rng, i))[0])
                  for i in range(256)}
    assert 3 in draws_full
    # p=0 keeps ONLY the argmax — never an empty distribution
    draws_zero = {int(sample(logits, 1.0, jax.random.fold_in(rng, i),
                             top_p=0.0)[0]) for i in range(32)}
    assert draws_zero == {0}
    # ties at the cutoff must not leak: exclusive mass {0, 0.4, 0.7} at
    # p=0.5 keeps ranks 0,1 — token 2 ties token 1's value but is OUT
    tied = jnp.log(jnp.asarray([[0.4, 0.3, 0.3]]))
    draws_tied = {int(sample(tied, 1.0, jax.random.fold_in(rng, i),
                             top_p=0.5)[0]) for i in range(64)}
    assert draws_tied == {0, 1}


def test_aliased_block_tables_read_bit_equal():
    """Two rows aliasing one physical page must decode exactly like two
    rows owning private copies of it (reads through aliased tables are
    bit-identical — the property sharing stands on)."""
    from repro.core.sparse_format import gather_pages, mapped_page_counts

    r = np.random.default_rng(0)
    n_phys, Hkv, pt, k = 5, 2, TT, 8
    pool = jnp.asarray(r.normal(size=(n_phys, Hkv, pt, k)), jnp.float32)
    aliased = jnp.asarray([[0, 1, -1], [0, 2, -1]], jnp.int32)
    # private copies: duplicate page 0's content into page 3 for row 1
    pool_dup = pool.at[3].set(pool[0])
    private = jnp.asarray([[0, 1, -1], [3, 2, -1]], jnp.int32)
    np.testing.assert_array_equal(np.asarray(gather_pages(pool, aliased)),
                                  np.asarray(gather_pages(pool_dup, private)))
    uniq, total = mapped_page_counts(aliased)
    assert (uniq, total) == (3, 4)               # page 0 counted once
    # the kernel-side validator accepts aliased READ rows...
    validate_block_table(np.asarray(aliased), n_phys)
    # ...but rejects a WRITE into a shared page
    with pytest.raises(AssertionError, match="refcount"):
        validate_block_table(
            np.asarray(aliased), n_phys, page_tokens=pt,
            n_compressed=np.asarray([pt // 2, pt]),
            refcounts=[2, 1, 1, 0, 0], will_compact=[True, False])
