"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py forces 512."""
import os

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def import_hypothesis():
    """Import hypothesis for property tests.

    Locally the property variants skip when hypothesis isn't installed
    (runtime needs only jax + numpy). In CI the skip would silently shrink
    coverage, so the workflow sets ``CI_REQUIRE_HYPOTHESIS=1`` and a missing
    install becomes a hard FAILURE instead of an importorskip."""
    if os.environ.get("CI_REQUIRE_HYPOTHESIS"):
        import hypothesis
        return hypothesis
    return pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis "
               "(pip install -r requirements-dev.txt)")
