"""Hypothesis property tests on system invariants (pruning, cache manager,
serving counters) — beyond the per-kernel sweeps in test_kernels.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from conftest import import_hypothesis

import_hypothesis()   # hard requirement in CI (CI_REQUIRE_HYPOTHESIS=1)
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import pruning
from repro.core.sparse_format import pack_fixedk, topk_mask, unpack_fixedk
from repro.models import init_params
from repro.serving.cache import plan_pools, prefill_split
from repro.serving.engine import decode_step, prefill

CFG = get_config("starcoder2-3b").reduced()
PARAMS = init_params(jax.random.PRNGKey(0), CFG)


# ----------------------------------------------------------------------
# pruning invariants

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.01, 100.0),
       k=st.sampled_from([8, 24, 64, 120]))
def test_topk_mask_scale_invariant(seed, scale, k):
    """Per-token magnitude selection is invariant to positive row scaling
    (the formal core of 'per-token magnitude is output-aware for V')."""
    g = np.random.default_rng(seed)
    x = jnp.asarray(g.normal(size=(4, 128)).astype(np.float32))
    m1 = topk_mask(x, k)
    m2 = topk_mask(x * scale, k)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.sampled_from([8, 40, 64]))
def test_pack_unpack_idempotent(seed, k):
    """Compressing an already-pruned tensor is lossless (compaction of a
    prefill-compressed tile never drifts)."""
    g = np.random.default_rng(seed)
    x = jnp.asarray(g.normal(size=(3, 8, 128)).astype(np.float32))
    m = topk_mask(x, k)
    v1, b1 = pack_fixedk(x, m, k)
    d1 = unpack_fixedk(v1, b1, 128)
    v2, b2 = pack_fixedk(d1, topk_mask(d1, k), k)
    d2 = unpack_fixedk(v2, b2, 128)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       strategy=st.sampled_from(["per_token_magnitude",
                                 "semi_structured_2_4"]))
def test_prune_is_projection(seed, strategy):
    """prune(prune(x)) == prune(x) — pruning is a projection operator."""
    g = np.random.default_rng(seed)
    x = jnp.asarray(g.normal(size=(2, 2, 16, 128)).astype(np.float32))
    p1 = pruning.prune(x, 0.5, strategy)
    p2 = pruning.prune(p1, 0.5, strategy)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), rtol=1e-6)


# ----------------------------------------------------------------------
# cache-manager invariants

@settings(max_examples=40, deadline=None)
@given(T=st.integers(1, 4096))
def test_prefill_split_partition(T):
    """compressible + window == T; compressible tile-aligned; window bounded."""
    comp, win = prefill_split(CFG, T)
    m = CFG.mustafar
    assert comp + win == T
    assert comp % m.tile_tokens == 0
    assert comp >= 0 and win >= 0
    if T >= m.local_window:
        assert win >= m.local_window           # dense window never starved
    assert win < m.local_window + 2 * m.tile_tokens


@settings(max_examples=40, deadline=None)
@given(total=st.integers(1, 1 << 20), B=st.sampled_from([1, 8, 128]))
def test_plan_pools_capacity(total, B):
    """Pools always hold the max context; alignment divides evenly."""
    Tc, Wbuf = plan_pools(CFG, total, batch=B)
    m = CFG.mustafar
    assert Tc >= total
    assert Tc % m.tile_tokens == 0
    assert Wbuf == m.local_window + m.tile_tokens
    if B == 1 and total >= 4096 * 16:
        assert Tc % (4096 * 16) == 0           # context-shard alignment


# ----------------------------------------------------------------------
# serving counter invariants (end-to-end, small but real model)

@settings(max_examples=6, deadline=None)
@given(T=st.integers(9, 40), n_dec=st.integers(1, 24),
       seed=st.integers(0, 1000))
def test_serving_counters(T, n_dec, seed):
    """After prefill(T) + n decode steps:
       position == T + n;
       n_compressed ≡ 0 (mod tile_tokens);
       n_compressed + w_len == position;
       w_len stays inside the buffer; logits finite."""
    m = CFG.mustafar
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (2, T + n_dec), 0, CFG.vocab_size)
    lg, cache = prefill(PARAMS, toks[:, :T], CFG,
                        max_total_tokens=T + n_dec + 8)
    step = jax.jit(lambda p, t, c: decode_step(p, t, c, CFG))
    for t in range(T, T + n_dec):
        lg, cache = step(PARAMS, toks[:, t], cache)
    # state vectors are per-sequence [B]; the invariants hold per slot
    pos = np.asarray(cache["position"])
    nc = np.asarray(cache["n_compressed"])
    wl = np.asarray(cache["w_len"])
    assert pos.shape == nc.shape == wl.shape == (2,)
    np.testing.assert_array_equal(pos, T + n_dec)
    assert (nc % m.tile_tokens == 0).all()
    np.testing.assert_array_equal(nc + wl, T + n_dec)
    assert (0 <= wl).all() and (wl <= m.local_window + m.tile_tokens).all()
    assert np.isfinite(np.asarray(lg, np.float32)).all()
