"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU), with
shape/dtype/sparsity sweeps per the deliverable spec."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.bitmap_compress import mustafar_compress
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.sparse_decode import (decode_attention_fused, sparse_av,
                                         sparse_qk)


def _mk(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("d,k", [(128, 40), (128, 64), (64, 24), (80, 32)])
def test_compress_kernel(rng, dtype, d, k):
    x = _mk(rng, (3, 32, d), dtype)
    v_ref, b_ref = ref.mustafar_compress_ref(x, k)
    v_pl, b_pl = mustafar_compress(x, k, interpret=True)
    np.testing.assert_array_equal(np.asarray(b_ref), np.asarray(b_pl))
    np.testing.assert_allclose(np.asarray(v_ref, np.float32),
                               np.asarray(v_pl, np.float32), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,tile", [(64, 32), (128, 128), (256, 64)])
@pytest.mark.parametrize("d,k,G", [(128, 40, 4), (64, 24, 1)])
def test_sparse_qk_kernel(rng, dtype, T, tile, d, k, G):
    BH = 3
    q = _mk(rng, (BH, G, d), dtype)
    x = _mk(rng, (BH, T, d), dtype)
    vals, bm = ref.mustafar_compress_ref(x, k)
    s_ref = ref.sparse_qk_ref(q, vals, bm, d, 0.1)
    s_pl = sparse_qk(q, vals, bm, scale=0.1, interpret=True, tile_t=tile)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s_pl),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,tile,d,k,G", [(128, 64, 128, 40, 4),
                                          (64, 32, 64, 24, 2)])
def test_sparse_av_kernel(rng, dtype, T, tile, d, k, G):
    BH = 2
    x = _mk(rng, (BH, T, d), dtype)
    vals, bm = ref.mustafar_compress_ref(x, k)
    p = jax.nn.softmax(_mk(rng, (BH, G, T), jnp.float32), axis=-1)
    o_ref = ref.sparse_av_ref(p, vals, bm, d)
    o_pl = sparse_av(p, vals, bm, interpret=True, tile_t=tile)[..., :d]
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pl),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("nv", [[64, 40, 17], [128, 128, 1]])
def test_fused_decode_kernel(rng, nv):
    BH, G, d, T, k = 3, 4, 128, 128, 40
    q = _mk(rng, (BH, G, d), jnp.float32)
    kx = _mk(rng, (BH, T, d), jnp.float32)
    vx = _mk(rng, (BH, T, d), jnp.float32)
    kv_, kb_ = ref.mustafar_compress_ref(kx, k)
    vv_, vb_ = ref.mustafar_compress_ref(vx, k)
    n_valid = jnp.asarray(nv, jnp.int32)
    o_ref = ref.decode_attention_fused_ref(q, kv_, kb_, vv_, vb_, n_valid, d,
                                           scale=d ** -0.5)
    o_pl = decode_attention_fused(q, kv_, kb_, vv_, vb_, n_valid, d=d,
                                  scale=d ** -0.5, interpret=True, tile_t=32)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pl),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("Hq,Hkv,T,d,bq,bk", [(4, 2, 128, 64, 64, 64),
                                              (2, 2, 256, 128, 128, 64)])
def test_flash_prefill_kernel(rng, Hq, Hkv, T, d, bq, bk):
    B = 2
    q = _mk(rng, (B, Hq, T, d), jnp.float32)
    k = _mk(rng, (B, Hkv, T, d), jnp.float32)
    v = _mk(rng, (B, Hkv, T, d), jnp.float32)
    o_pl = flash_prefill(q, k, v, scale=d ** -0.5, interpret=True,
                         block_q=bq, block_k=bk)
    rep = Hq // Hkv
    o_ref = ref.flash_prefill_ref(q, jnp.repeat(k, rep, 1),
                                  jnp.repeat(v, rep, 1))
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-4)


def test_ops_dispatch_cpu(rng):
    """Public wrappers use the jnp path on CPU and agree with Pallas."""
    from repro.kernels import ops
    B, Hkv, Hq, T, d, k = 2, 2, 4, 64, 128, 40
    x = _mk(rng, (B, Hkv, T, d), jnp.float32)
    v1, b1 = ops.compress(x, k)                       # jnp path
    v2, b2 = ops.compress(x, k, use_pallas=True)      # interpret path
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    q = _mk(rng, (B, Hq, d), jnp.float32)
    s1 = ops.sparse_qk(q, v1, b1, scale=0.1)
    s2 = ops.sparse_qk(q, v1, b1, scale=0.1, use_pallas=True)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5,
                               atol=1e-5)
