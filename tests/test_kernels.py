"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU), with
shape/dtype/sparsity sweeps per the deliverable spec, plus PR-2 equivalence
sweeps: the gather-based decompress/compress formulations must match the
legacy one-hot / rank-cube formulations bit-for-bit in fp32 (bf16 within
tolerance) across head dims, sparsities, and the ragged n_valid edges."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import legacy, ref
from repro.kernels.bitmap_compress import mustafar_compress
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.sparse_decode import (_decompress, decode_attention_fused,
                                         sparse_av, sparse_qk)


def _mk(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x).astype(dtype)


def _keep_k(d, sparsity, align=8):
    k = int(round(d * (1.0 - sparsity)))
    return max(align, (k + align - 1) // align * align)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("d,k", [(128, 40), (128, 64), (64, 24), (80, 32)])
def test_compress_kernel(rng, dtype, d, k):
    x = _mk(rng, (3, 32, d), dtype)
    v_ref, b_ref = ref.mustafar_compress_ref(x, k)
    v_pl, b_pl = mustafar_compress(x, k, interpret=True)
    np.testing.assert_array_equal(np.asarray(b_ref), np.asarray(b_pl))
    np.testing.assert_allclose(np.asarray(v_ref, np.float32),
                               np.asarray(v_pl, np.float32), rtol=1e-6)


@pytest.mark.parametrize("tile_t", [8, 32, 64, 128])
def test_compress_kernel_tile_t(rng, tile_t):
    """tile_t is a free parameter now (the [T,d,d] rank cube is gone):
    results are identical at every tile size, including >= 64."""
    x = _mk(rng, (2, 128, 128), jnp.float32)
    v_ref, b_ref = ref.mustafar_compress_ref(x, 40)
    v_pl, b_pl = mustafar_compress(x, 40, interpret=True, tile_t=tile_t)
    np.testing.assert_array_equal(np.asarray(b_ref), np.asarray(b_pl))
    np.testing.assert_array_equal(np.asarray(v_ref), np.asarray(v_pl))


def test_compress_kernel_bad_tile_t(rng):
    x = _mk(rng, (1, 48, 64), jnp.float32)
    with pytest.raises(ValueError, match="multiple of tile_t"):
        mustafar_compress(x, 16, interpret=True, tile_t=32)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sparsity", [0.5, 0.7])
@pytest.mark.parametrize("d", [64, 80, 128])
def test_compress_matches_legacy_rankcube(rng, dtype, sparsity, d):
    """Threshold-search top-k + gather compaction == the legacy all-pairs
    rank cube + one-hot compaction, bit-for-bit (both dtypes: selection is
    exact and values pass through ungathered)."""
    from repro.core.sparse_format import pack_fixedk, pad_to_words
    k = _keep_k(d, sparsity)
    x = _mk(rng, (2, 64, d), dtype)
    d_pad = pad_to_words(d)
    xp = jnp.pad(x, ((0, 0), (0, 0), (0, d_pad - d)))
    keep = jax.vmap(lambda r: legacy.topk_mask_rankcube(r, k, d))(xp)
    v_leg = jax.vmap(lambda r, m: legacy.compact_onehot(r, m, k))(
        xp.astype(jnp.float32), keep)
    v_pl, b_pl = mustafar_compress(x, k, interpret=True)
    np.testing.assert_array_equal(np.asarray(v_leg, np.float32),
                                  np.asarray(v_pl, np.float32))
    # and the bitmap agrees with the legacy keep mask
    _, b_leg = pack_fixedk(x, keep[..., :d], k)
    np.testing.assert_array_equal(np.asarray(b_leg), np.asarray(b_pl))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sparsity", [0.5, 0.7])
@pytest.mark.parametrize("d", [64, 80, 128])
def test_decompress_matches_legacy_onehot(rng, dtype, sparsity, d):
    """Gather expansion == legacy one-hot contraction: bit-for-bit in fp32,
    and (up to the fp32 cast) exact for bf16 values too — both reproduce the
    stored value or 0, so only dtype width differs."""
    k = _keep_k(d, sparsity)
    x = _mk(rng, (3, 32, d), dtype)
    vals, bm = ref.mustafar_compress_ref(x, k)
    for r in range(vals.shape[0]):
        new = _decompress(vals[r], bm[r], d, k)           # vals.dtype
        old = legacy.decompress_onehot(vals[r], bm[r], k)  # fp32
        np.testing.assert_array_equal(
            np.asarray(new, np.float32), np.asarray(old, np.float32))
    # and both match the dense reference (pruned x) on the true channels
    dense = np.asarray(
        jax.vmap(lambda v, b: _decompress(v, b, d, k))(vals, bm))[..., :d]
    from repro.core.sparse_format import topk_mask
    pruned = np.where(np.asarray(topk_mask(x, k)), np.asarray(x, np.float32), 0.0)
    np.testing.assert_array_equal(dense.astype(np.float32), pruned)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,tile", [(64, 32), (128, 128), (256, 64)])
@pytest.mark.parametrize("d,k,G", [(128, 40, 4), (64, 24, 1)])
def test_sparse_qk_kernel(rng, dtype, T, tile, d, k, G):
    BH = 3
    q = _mk(rng, (BH, G, d), dtype)
    x = _mk(rng, (BH, T, d), dtype)
    vals, bm = ref.mustafar_compress_ref(x, k)
    s_ref = ref.sparse_qk_ref(q, vals, bm, d, 0.1)
    s_pl = sparse_qk(q, vals, bm, scale=0.1, interpret=True, tile_t=tile)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s_pl),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,tile,d,k,G", [(128, 64, 128, 40, 4),
                                          (64, 32, 64, 24, 2),
                                          (64, 32, 80, 32, 2)])
def test_sparse_av_kernel(rng, dtype, T, tile, d, k, G):
    BH = 2
    x = _mk(rng, (BH, T, d), dtype)
    vals, bm = ref.mustafar_compress_ref(x, k)
    p = jax.nn.softmax(_mk(rng, (BH, G, T), jnp.float32), axis=-1)
    o_ref = ref.sparse_av_ref(p, vals, bm, d)
    o_pl = sparse_av(p, vals, bm, d=d, interpret=True, tile_t=tile)
    assert o_pl.shape == (BH, G, d)        # sliced to true d internally
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pl),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("nv", [[64, 40, 17], [128, 128, 1]])
def test_fused_decode_kernel(rng, nv):
    BH, G, d, T, k = 3, 4, 128, 128, 40
    q = _mk(rng, (BH, G, d), jnp.float32)
    kx = _mk(rng, (BH, T, d), jnp.float32)
    vx = _mk(rng, (BH, T, d), jnp.float32)
    kv_, kb_ = ref.mustafar_compress_ref(kx, k)
    vv_, vb_ = ref.mustafar_compress_ref(vx, k)
    n_valid = jnp.asarray(nv, jnp.int32)
    o_ref = ref.decode_attention_fused_ref(q, kv_, kb_, vv_, vb_, n_valid, d,
                                           scale=d ** -0.5)
    o_pl = decode_attention_fused(q, kv_, kb_, vv_, vb_, n_valid, d=d,
                                  scale=d ** -0.5, interpret=True, tile_t=32)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pl),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("d", [64, 80, 128])
def test_fused_decode_ragged_edges(rng, dtype, d):
    """n_valid ∈ {0, 1, tile_t, T} per row: the DMA-skipping grid clamps
    past-depth tiles, empty rows finalize to a zero vector, and partial
    tiles mask correctly — all against the jnp oracle."""
    BH, G, T, tile_t = 4, 2, 64, 16
    k = _keep_k(d, 0.7)
    q = _mk(rng, (BH, G, d), dtype)
    kx = _mk(rng, (BH, T, d), dtype)
    vx = _mk(rng, (BH, T, d), dtype)
    kv_, kb_ = ref.mustafar_compress_ref(kx, k)
    vv_, vb_ = ref.mustafar_compress_ref(vx, k)
    n_valid = jnp.asarray([0, 1, tile_t, T], jnp.int32)
    o_ref = ref.decode_attention_fused_ref(q, kv_, kb_, vv_, vb_, n_valid, d,
                                           scale=d ** -0.5)
    o_pl = decode_attention_fused(q, kv_, kb_, vv_, vb_, n_valid, d=d,
                                  scale=d ** -0.5, interpret=True,
                                  tile_t=tile_t)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_pl),
                               rtol=tol, atol=tol)
    assert np.all(np.asarray(o_pl)[0] == 0.0)  # empty row -> zero vector


def test_fused_decode_state(rng):
    """return_state hands back (acc, m, l) consistent with the normalized
    output and the jnp state oracle."""
    BH, G, d, T, k = 3, 4, 128, 128, 40
    q = _mk(rng, (BH, G, d), jnp.float32)
    kx = _mk(rng, (BH, T, d), jnp.float32)
    vx = _mk(rng, (BH, T, d), jnp.float32)
    kv_, kb_ = ref.mustafar_compress_ref(kx, k)
    vv_, vb_ = ref.mustafar_compress_ref(vx, k)
    n_valid = jnp.asarray([128, 40, 0], jnp.int32)
    o, acc, m, l = decode_attention_fused(
        q, kv_, kb_, vv_, vb_, n_valid, d=d, scale=d ** -0.5,
        interpret=True, tile_t=32, return_state=True)
    o_ref, acc_ref, m_ref, l_ref = ref.decode_attention_fused_state_ref(
        q, kv_, kb_, vv_, vb_, n_valid, d, scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(l), np.asarray(l_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(acc), np.asarray(o) * np.maximum(np.asarray(l), 1e-30),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("Hq,Hkv,T,d,bq,bk", [(4, 2, 128, 64, 64, 64),
                                              (2, 2, 256, 128, 128, 64)])
def test_flash_prefill_kernel(rng, Hq, Hkv, T, d, bq, bk):
    B = 2
    q = _mk(rng, (B, Hq, T, d), jnp.float32)
    k = _mk(rng, (B, Hkv, T, d), jnp.float32)
    v = _mk(rng, (B, Hkv, T, d), jnp.float32)
    o_pl = flash_prefill(q, k, v, scale=d ** -0.5, interpret=True,
                         block_q=bq, block_k=bk)
    rep = Hq // Hkv
    o_ref = ref.flash_prefill_ref(q, jnp.repeat(k, rep, 1),
                                  jnp.repeat(v, rep, 1))
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-4)


def test_ops_dispatch_cpu(rng):
    """Public wrappers use the jnp path on CPU and agree with Pallas."""
    from repro.kernels import ops
    B, Hkv, Hq, T, d, k = 2, 2, 4, 64, 128, 40
    x = _mk(rng, (B, Hkv, T, d), jnp.float32)
    v1, b1 = ops.compress(x, k)                       # jnp path
    v2, b2 = ops.compress(x, k, use_pallas=True)      # interpret path
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    q = _mk(rng, (B, Hq, d), jnp.float32)
    s1 = ops.sparse_qk(q, v1, b1, scale=0.1)
    s2 = ops.sparse_qk(q, v1, b1, scale=0.1, use_pallas=True)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5,
                               atol=1e-5)
    p = jax.nn.softmax(_mk(rng, (B, Hq, T), jnp.float32), axis=-1)
    o1 = ops.sparse_av(p, v1, b1, d=d)
    o2 = ops.sparse_av(p, v1, b1, d=d, use_pallas=True)
    assert o1.shape == o2.shape == (B, Hq, d)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5,
                               atol=1e-5)


def test_ops_compress_auto_tile(rng):
    """ops.compress tiles ragged token counts automatically: T=80 (a
    tile_tokens=16 prefill) is not a multiple of the default tile_t=64, so
    the dispatch picks the largest divisor (40) instead of raising."""
    from repro.kernels import ops
    x = _mk(rng, (2, 2, 80, 64), jnp.float32)
    v1, b1 = ops.compress(x, 24)                      # jnp path
    v2, b2 = ops.compress(x, 24, use_pallas=True)     # interpret path
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_kernelized_decode_matches_chunked(rng):
    """decode_attention_mustafar_kernelized (fused kernel + window merge)
    == the chunked jnp formulation on the same view."""
    from repro.core.attention import (MustafarCacheView,
                                      decode_attention_mustafar_chunked,
                                      decode_attention_mustafar_kernelized)
    B, Hkv, Hq, Tc, W, d, k = 2, 2, 4, 128, 16, 128, 40
    kx = _mk(rng, (B, Hkv, Tc, d), jnp.float32)
    vx = _mk(rng, (B, Hkv, Tc, d), jnp.float32)
    ckv, ckb = ref.mustafar_compress_ref(kx, k)
    cvv, cvb = ref.mustafar_compress_ref(vx, k)
    view = MustafarCacheView(
        ckv, ckb, cvv, cvb, jnp.asarray([128, 40], jnp.int32),
        _mk(rng, (B, Hkv, W, d), jnp.float32),
        _mk(rng, (B, Hkv, W, d), jnp.float32),
        jnp.asarray([16, 9], jnp.int32))
    q = _mk(rng, (B, Hq, d), jnp.float32)
    o_kern = decode_attention_mustafar_kernelized(q, view)
    o_chnk = decode_attention_mustafar_chunked(q, view, chunk=32)
    np.testing.assert_allclose(np.asarray(o_kern), np.asarray(o_chnk),
                               rtol=1e-4, atol=1e-4)
