"""repro.obs unit + integration tests: histogram/percentile math, lazy
metrics, registry snapshots and aggregation, Chrome-trace validation, the
roofline drift auditor on a live Scheduler run, Router.stats() fleet
aggregation, and the artifact validator the CI obs-smoke job runs."""
import json
import math

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.obs import (Counter, EventTracer, Gauge, Histogram,
                       MetricsRegistry, NullRegistry, TIME_BUCKETS_S,
                       format_stats_line, validate_chrome_trace)

KEY = jax.random.PRNGKey(0)
CFG = get_config("starcoder2-3b").reduced().with_sparsity(0.5, 0.5)
PARAMS = init_params(KEY, CFG)
MAX_TOTAL = 96


# ---------------------------------------------------------------- histogram

def test_histogram_empty():
    h = Histogram("t")
    assert h.count == 0
    assert h.percentile(50) is None
    assert h.min is None and h.max is None and h.mean is None
    assert h.summary()["p99"] is None
    assert h.summary()["buckets"] == []


def test_histogram_one_sample_exact():
    h = Histogram("t")
    h.observe(3.7e-3)
    # the [min, max] clamp collapses every percentile onto the sample
    for q in (0, 50, 90, 99, 100):
        assert h.percentile(q) == pytest.approx(3.7e-3)
    assert h.mean == pytest.approx(3.7e-3)


def test_histogram_known_distribution():
    h = Histogram("t")
    vals = [1e-6 * (i + 1) for i in range(100)]       # 1..100 µs uniform
    for v in vals:
        h.observe(v)
    assert h.count == 100
    assert h.total == pytest.approx(sum(vals))
    # p50's bucket upper bound must sit within a quarter-decade of the
    # true median, and every estimate stays inside the observed range
    for q in (50, 90, 99):
        est = h.percentile(q)
        true = float(np.percentile(vals, q))
        assert h.min <= est <= h.max
        assert est >= true * 0.99                      # upper-bound estimate
        assert est <= true * 10 ** 0.25 * 1.01
    assert h.percentile(100) == h.max


def test_histogram_overflow_bucket():
    h = Histogram("t")
    h.observe(1e9)                                     # above every bound
    assert h.counts[-1] == 1
    assert h.percentile(99) == pytest.approx(1e9)      # clamped to max


def test_histogram_bad_bounds_and_q():
    with pytest.raises(ValueError, match="ascending"):
        Histogram("t", bounds=(1.0, 1.0, 2.0))
    h = Histogram("t")
    h.observe(1.0)
    with pytest.raises(ValueError, match="outside"):
        h.percentile(101)


def test_histogram_merge_exact():
    a, b = Histogram("t"), Histogram("t")
    for v in (1e-5, 2e-4, 3e-3):
        a.observe(v)
    for v in (5e-6, 7e-2):
        b.observe(v)
    a.merge(b)
    assert a.count == 5
    assert a.total == pytest.approx(1e-5 + 2e-4 + 3e-3 + 5e-6 + 7e-2)
    assert a.min == pytest.approx(5e-6)
    assert a.max == pytest.approx(7e-2)
    # merged counts equal a histogram fed the union stream
    u = Histogram("t")
    for v in (1e-5, 2e-4, 3e-3, 5e-6, 7e-2):
        u.observe(v)
    assert a.counts == u.counts
    assert a.percentile(50) == u.percentile(50)


def test_histogram_merge_mismatched_bounds_raises():
    a = Histogram("t")
    b = Histogram("t", bounds=(1.0, 2.0))
    with pytest.raises(ValueError, match="bounds differ"):
        a.merge(b)


def test_time_buckets_cover_serving_range():
    assert TIME_BUCKETS_S[0] == pytest.approx(1e-6)
    assert TIME_BUCKETS_S[-1] == pytest.approx(100.0)
    assert all(b < c for b, c in zip(TIME_BUCKETS_S, TIME_BUCKETS_S[1:]))


# ----------------------------------------------------- counters and gauges

def test_lazy_counter_reads_callback_and_rejects_inc():
    box = {"n": 3}
    c = Counter("c", fn=lambda: box["n"])
    assert c.value == 3
    box["n"] = 9
    assert c.value == 9                    # live view, not a copy
    with pytest.raises(RuntimeError, match="lazy"):
        c.inc()
    d = Counter("d")
    d.inc()
    d.inc(4)
    assert d.value == 5


def test_callback_gauge_rejects_set():
    g = Gauge("g", fn=lambda: 7)
    assert g.value == 7
    with pytest.raises(RuntimeError):
        g.set(1)


# ----------------------------------------------------------------- registry

def test_registry_get_or_create_and_snapshot_json():
    reg = MetricsRegistry()
    assert reg.counter("a") is reg.counter("a")
    assert reg.histogram("h") is reg.histogram("h")
    reg.counter("a").inc(2)
    reg.gauge("g").set(1.5)
    reg.histogram("h").observe(1e-3)
    snap = reg.snapshot()
    json.dumps(snap)                       # JSON-serializable end to end
    assert snap["counters"]["a"] == 2
    assert snap["gauges"]["g"] == 1.5
    assert snap["histograms"]["h"]["count"] == 1


def test_registry_aggregate_sums_and_merges():
    regs = []
    for k in range(3):
        r = MetricsRegistry()
        r.counter("c").inc(k + 1)
        r.gauge("g", fn=lambda k=k: k)     # callback gauges sum by value
        r.histogram("h").observe(1e-4 * (k + 1))
        regs.append(r)
    regs.append(NullRegistry())            # skipped, not an error
    agg = MetricsRegistry.aggregate(regs)
    assert agg.counter("c").value == 6
    assert agg.gauge("g").value == 0 + 1 + 2
    assert agg.histogram("h").count == 3


def test_null_registry_is_inert():
    reg = NullRegistry()
    c = reg.counter("x")
    c.inc(100)
    reg.histogram("h").observe(1.0)
    assert c.value == 0
    assert reg.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}


def test_format_stats_line():
    reg = MetricsRegistry()
    reg.counter("engine.steps").inc(12)
    reg.counter("engine.tokens_sampled").inc(30)
    reg.gauge("engine.slots_active").set(2)
    reg.histogram("step/step_s").observe(2e-3)
    line = format_stats_line(reg.snapshot(), prefix="#")
    assert line.startswith("# step=12 tok=30 active=2")
    assert "step_p50=" in line


# ------------------------------------------------------------------- tracer

def test_tracer_spans_instants_async_validate():
    tr = EventTracer()
    with tr.span("step", tid=1):
        with tr.span("decode", tid=1):
            tr.instant("first_token", tid=1, uid=0)
    tr.async_begin("req", 0, prompt_tokens=4)
    tr.async_end("req", 0)
    counts = validate_chrome_trace(tr.events)
    assert counts == {"events": 7, "spans": 2, "instants": 1, "async": 1}


@pytest.mark.parametrize("events,msg", [
    ([{"ph": "B", "ts": 0, "pid": 0, "tid": 0}], "missing 'name'"),
    ([{"name": "x", "ph": "Q", "ts": 0, "pid": 0, "tid": 0}], "unknown ph"),
    ([{"name": "x", "ph": "i", "ts": -1, "pid": 0, "tid": 0}], "bad ts"),
    ([{"name": "x", "ph": "i", "ts": 5, "pid": 0, "tid": 0},
      {"name": "y", "ph": "i", "ts": 2, "pid": 0, "tid": 0}], "decreases"),
    ([{"name": "x", "ph": "E", "ts": 0, "pid": 0, "tid": 0}], "no open B"),
    ([{"name": "x", "ph": "B", "ts": 0, "pid": 0, "tid": 0},
      {"name": "y", "ph": "E", "ts": 1, "pid": 0, "tid": 0}], "closes B"),
    ([{"name": "x", "ph": "B", "ts": 0, "pid": 0, "tid": 0}], "unclosed B"),
    ([{"name": "x", "ph": "b", "ts": 0, "pid": 0, "tid": 0}], "missing id"),
    ([{"name": "x", "ph": "e", "ts": 0, "pid": 0, "tid": 0, "id": 1}],
     "no open begin"),
    ([{"name": "x", "ph": "b", "ts": 0, "pid": 0, "tid": 0, "id": 1}],
     "unclosed async"),
])
def test_validate_chrome_trace_rejects(events, msg):
    with pytest.raises(ValueError, match=msg):
        validate_chrome_trace(events)


def test_tracer_export_round_trip(tmp_path):
    from repro.obs.trace import load_trace
    tr = EventTracer()
    with tr.span("step"):
        pass
    path = str(tmp_path / "trace.json")
    assert tr.export(path) == 2
    events = load_trace(path)
    assert validate_chrome_trace(events)["spans"] == 1
    # bare-array form also loads
    with open(path, "w") as f:
        json.dump(events, f)
    assert load_trace(path) == events


# ------------------------------------------- live scheduler: stats + drift

def _serve(n_requests=3, **kw):
    from repro.serving.engine import Request, Scheduler
    rng = np.random.default_rng(0)
    sched = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL,
                      page_tokens=CFG.mustafar.tile_tokens, **kw)
    for _ in range(n_requests):
        sched.submit(Request(
            prompt=rng.integers(0, CFG.vocab_size, size=9),
            max_new_tokens=4))
    sched.run(max_steps=2000)
    return sched


def test_scheduler_stats_and_drift():
    tr = EventTracer()
    sched = _serve(tracer=tr)
    st = sched.stats()
    json.dumps(st)
    assert st["counters"]["engine.finished"] == 3
    assert st["counters"]["engine.tokens_sampled"] \
        == sum(len(r.output_tokens) for r in sched.finished)
    assert st["histograms"]["step/step_s"]["count"] == sched.step_count
    assert st["gauges"]["pool.pages_in_use"] == 0       # drained
    assert isinstance(st["occupancy"], dict) and "slots" in st["occupancy"]
    validate_chrome_trace(tr.events)

    from repro.obs.drift import roofline_drift
    drift = roofline_drift(sched)
    json.dumps(drift)
    dec = drift["decode_step"]
    assert dec["decode_steps"] > 0
    assert math.isfinite(dec["drift_ratio"]) and dec["drift_ratio"] > 0
    assert dec["modeled_bytes"] > dec["modeled_metadata_bytes"] > 0
    # no swap traffic moved: exact agreement, not inf/NaN
    assert drift["swap_bytes_out"]["ratio"] == 1.0
    assert drift["swap_bytes_in"]["ratio"] == 1.0


def test_decode_step_model_dense_vs_mustafar():
    from repro.obs.drift import decode_step_model
    sparse = decode_step_model(CFG, 2, MAX_TOTAL)
    import dataclasses
    dense_cfg = dataclasses.replace(
        CFG, mustafar=dataclasses.replace(CFG.mustafar, enabled=False))
    dense = decode_step_model(dense_cfg, 2, MAX_TOTAL)
    assert sparse["cache_bytes"] < dense["cache_bytes"]
    assert sparse["seconds"] > 0


def test_validate_metrics_artifact(tmp_path):
    from repro.obs.drift import roofline_drift
    from repro.obs.validate import main, validate_metrics
    tr = EventTracer()
    sched = _serve(tracer=tr)
    trace_path = str(tmp_path / "trace.json")
    tr.export(trace_path)
    blob = {"stats": sched.stats(), "roofline_drift": roofline_drift(sched)}
    mpath = str(tmp_path / "metrics.json")
    with open(mpath, "w") as f:
        json.dump(blob, f)
    assert main([trace_path, "--metrics", mpath,
                 "--max-decode-drift", "1e12"]) == 0
    # a broken swap ratio must be caught
    bad = json.loads(json.dumps(blob))
    bad["roofline_drift"]["swap_bytes_out"]["ratio"] = 1.5
    with pytest.raises(ValueError, match="swap_bytes_out"):
        validate_metrics(bad, 1e-3, 1e12)
    bad2 = json.loads(json.dumps(blob))
    del bad2["stats"]["histograms"]["step/decode_s"]
    with pytest.raises(ValueError, match="decode_s"):
        validate_metrics(bad2, 1e-3, 1e12)


def test_router_stats_aggregates_fleet():
    from repro.serving.engine import Request
    from repro.serving.router import Router
    rng = np.random.default_rng(1)
    router = Router(CFG, PARAMS, n_engines=2, n_slots=4,
                    max_total_tokens=MAX_TOTAL,
                    page_tokens=CFG.mustafar.tile_tokens)
    for _ in range(4):
        router.submit(Request(
            prompt=rng.integers(0, CFG.vocab_size, size=9),
            max_new_tokens=3))
    router.run()
    st = router.stats()
    json.dumps(st)
    assert st["counters"]["engine.finished"] == 4
    assert st["counters"]["engine.finished"] \
        == sum(len(e.finished) for e in router.engines)
    # merged histogram count == sum over replicas (exact merge)
    assert st["histograms"]["step/step_s"]["count"] \
        == sum(e.obs.histogram("step/step_s").count for e in router.engines)
    assert len(st["per_engine"]) == 2
    with pytest.raises(ValueError, match="registry"):
        Router(CFG, PARAMS, n_engines=2, n_slots=4,
               max_total_tokens=MAX_TOTAL, registry=MetricsRegistry())
