"""H2O eviction + KIVI quantization joint-application invariants (paper §4.2)
plus the symmetric-quantization storage model and its oracle contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.eviction import accumulate_attention, h2o_keep_mask
from repro.core.quantization import (kivi_quantize_key, kivi_quantize_value,
                                     quant_bytes_per_token,
                                     symmetric_fake_quant)
from repro.core.sparse_format import (dequantize_fixedk, prune_and_pack,
                                      quantize_fixedk)
from repro.core import pruning


def test_h2o_budget_respected(rng):
    T = 256
    acc = jnp.asarray(np.abs(rng.normal(size=(2, 4, T))).astype(np.float32))
    keep = h2o_keep_mask(acc, T, heavy_budget=20, recent_budget=30)
    counts = np.asarray(keep).sum(-1)
    assert (counts == 50).all()
    # recent tokens always kept
    assert np.asarray(keep)[..., -30:].all()


def test_h2o_keeps_heavy_hitters(rng):
    T = 128
    acc = jnp.zeros((1, 1, T)).at[0, 0, 7].set(100.0).at[0, 0, 40].set(50.0)
    keep = np.asarray(h2o_keep_mask(acc, T, heavy_budget=2, recent_budget=8))
    assert keep[0, 0, 7] and keep[0, 0, 40]


def test_accumulate_attention_shape(rng):
    probs = jax.nn.softmax(jnp.asarray(
        rng.normal(size=(2, 4, 8, 64)).astype(np.float32)), axis=-1)
    acc = accumulate_attention(probs)
    assert acc.shape == (2, 4, 64)
    np.testing.assert_allclose(np.asarray(acc.sum(-1)), 8.0, rtol=1e-5)


@pytest.mark.parametrize("bits,tol", [(4, 0.12), (2, 0.5)])
def test_kivi_quant_error_bounded(rng, bits, tol):
    x = jnp.asarray(rng.normal(size=(2, 4, 64, 128)).astype(np.float32))
    for fn in (kivi_quantize_key, kivi_quantize_value):
        q = fn(x, bits)
        rel = float(jnp.linalg.norm(q - x) / jnp.linalg.norm(x))
        assert rel < tol, (fn.__name__, rel)


def test_kivi_prune_then_quantize_preserves_zeros(rng):
    """Harma et al. ordering: quantizing a pruned cache must not resurrect
    pruned positions with large values (group min/max includes 0)."""
    x = jnp.asarray(rng.normal(size=(2, 4, 64, 128)).astype(np.float32))
    xp = pruning.prune(x, 0.7, "per_token_magnitude")
    q = kivi_quantize_value(xp, 4)
    # pruned positions may carry small quantization residue only
    pruned_pos = np.asarray(xp) == 0
    resurrect = np.abs(np.asarray(q))[pruned_pos]
    assert resurrect.max() < 0.5 * np.abs(np.asarray(x)).max()


def test_quant_storage_model():
    """The model describes the SHIPPED layout: packed symmetric ints plus
    ONE fp32 absmax scale per tile_tokens tile (amortized per token) — not
    the seed's per-group-of-32 asymmetric fp16 scale+zero, which nothing
    ever stored."""
    assert quant_bytes_per_token(128, 4) < 128 * 2 * 0.35
    assert quant_bytes_per_token(128, 2) < quant_bytes_per_token(128, 4)
    # exact: d·bits/8 value bytes + 4-byte scale amortized over the tile
    assert quant_bytes_per_token(128, 8, tile_tokens=64) == \
        pytest.approx(128 + 4.0 / 64)
    # coarser tiles amortize the scale further
    assert quant_bytes_per_token(128, 8, tile_tokens=128) < \
        quant_bytes_per_token(128, 8, tile_tokens=32)


def test_symmetric_quant_roundtrip_matches_oracle(rng):
    """The storage round-trip (quantize_fixedk -> dequantize_fixedk) must
    reproduce the fake-quant oracle BIT-FOR-BIT: both use the same jnp ops
    (fp32, round-half-to-even, reciprocal-multiply scale), which is the
    contract the real int8 pools are held to."""
    x = jnp.asarray(rng.normal(size=(2, 3, 64, 32)).astype(np.float32))
    vals, _ = prune_and_pack(x, 8)
    for tile in (16, 32, 64):
        q, s = quantize_fixedk(vals, tile)
        assert q.dtype == jnp.int8
        assert s.shape == (2, 3, 64 // tile, 1) and s.dtype == jnp.float32
        np.testing.assert_array_equal(
            np.asarray(dequantize_fixedk(q, s)),
            np.asarray(symmetric_fake_quant(vals, tile)))


def test_symmetric_quant_zero_blocks_stay_zero():
    vals = jnp.zeros((1, 1, 32, 8), jnp.float32)
    q, s = quantize_fixedk(vals, 16)
    assert (np.asarray(q) == 0).all()
    assert (np.asarray(s) == 1.0).all()       # zero-guard scale
    assert (np.asarray(dequantize_fixedk(q, s)) == 0.0).all()


def test_symmetric_quant_error_bounded(rng):
    """Per-tile absmax int8: max error <= scale/2 per element."""
    x = jnp.asarray(rng.normal(size=(4, 64, 16)).astype(np.float32))
    q, s = quantize_fixedk(x, 16)
    deq = np.asarray(dequantize_fixedk(q, s))
    err = np.abs(deq - np.asarray(x))
    bound = np.repeat(np.asarray(s), 16, axis=-2)[..., 0] / 2 + 1e-7
    assert (err <= bound[..., None]).all()
