"""H2O eviction + KIVI quantization joint-application invariants (paper §4.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.eviction import accumulate_attention, h2o_keep_mask
from repro.core.quantization import (kivi_quantize_key, kivi_quantize_value,
                                     quant_bytes_per_token)
from repro.core import pruning


def test_h2o_budget_respected(rng):
    T = 256
    acc = jnp.asarray(np.abs(rng.normal(size=(2, 4, T))).astype(np.float32))
    keep = h2o_keep_mask(acc, T, heavy_budget=20, recent_budget=30)
    counts = np.asarray(keep).sum(-1)
    assert (counts == 50).all()
    # recent tokens always kept
    assert np.asarray(keep)[..., -30:].all()


def test_h2o_keeps_heavy_hitters(rng):
    T = 128
    acc = jnp.zeros((1, 1, T)).at[0, 0, 7].set(100.0).at[0, 0, 40].set(50.0)
    keep = np.asarray(h2o_keep_mask(acc, T, heavy_budget=2, recent_budget=8))
    assert keep[0, 0, 7] and keep[0, 0, 40]


def test_accumulate_attention_shape(rng):
    probs = jax.nn.softmax(jnp.asarray(
        rng.normal(size=(2, 4, 8, 64)).astype(np.float32)), axis=-1)
    acc = accumulate_attention(probs)
    assert acc.shape == (2, 4, 64)
    np.testing.assert_allclose(np.asarray(acc.sum(-1)), 8.0, rtol=1e-5)


@pytest.mark.parametrize("bits,tol", [(4, 0.12), (2, 0.5)])
def test_kivi_quant_error_bounded(rng, bits, tol):
    x = jnp.asarray(rng.normal(size=(2, 4, 64, 128)).astype(np.float32))
    for fn in (kivi_quantize_key, kivi_quantize_value):
        q = fn(x, bits)
        rel = float(jnp.linalg.norm(q - x) / jnp.linalg.norm(x))
        assert rel < tol, (fn.__name__, rel)


def test_kivi_prune_then_quantize_preserves_zeros(rng):
    """Harma et al. ordering: quantizing a pruned cache must not resurrect
    pruned positions with large values (group min/max includes 0)."""
    x = jnp.asarray(rng.normal(size=(2, 4, 64, 128)).astype(np.float32))
    xp = pruning.prune(x, 0.7, "per_token_magnitude")
    q = kivi_quantize_value(xp, 4)
    # pruned positions may carry small quantization residue only
    pruned_pos = np.asarray(xp) == 0
    resurrect = np.abs(np.asarray(q))[pruned_pos]
    assert resurrect.max() < 0.5 * np.abs(np.asarray(x)).max()


def test_quant_storage_model():
    assert quant_bytes_per_token(128, 4) < 128 * 2 * 0.35
    assert quant_bytes_per_token(128, 2) < quant_bytes_per_token(128, 4)
