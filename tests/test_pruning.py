"""Pruning strategies (paper §2): sparsity accounting, accuracy orderings.

The paper's accuracy claims (unstructured > 2:4 > structured at fixed
sparsity) are validated here at the attention-output level: relative error
of pruned decode attention vs dense, on caches with the distributions the
paper describes (Key: outlier channels; Value: uniform).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pruning
from repro.core.attention import decode_attention_dense


def _key_cache(rng, B=2, H=4, T=128, d=128):
    """Key-like cache: a few high-magnitude outlier channels (KIVI/Fig 2a)."""
    x = rng.normal(size=(B, H, T, d)).astype(np.float32)
    outliers = rng.choice(d, size=8, replace=False)
    x[..., outliers] *= 8.0
    return jnp.asarray(x)


def _value_cache(rng, B=2, H=4, T=128, d=128):
    """Value-like cache: uniform magnitude distribution (Fig 2b)."""
    return jnp.asarray(rng.normal(size=(B, H, T, d)).astype(np.float32))


def _attn_err(k_cache, k_pruned, v_cache, v_pruned, rng, n_q: int = 16):
    """Mean relative decode-attention output error over n_q query draws."""
    B, H, T, d = k_cache.shape
    L = jnp.full((B,), T)
    errs = []
    for _ in range(n_q):
        q = jnp.asarray(rng.normal(size=(B, H, d)).astype(np.float32))
        ref = decode_attention_dense(q, k_cache, v_cache, L)
        out = decode_attention_dense(q, k_pruned, v_pruned, L)
        errs.append(float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref)))
    return float(np.mean(errs))


@pytest.mark.parametrize("strategy", ["per_token_magnitude",
                                      "per_channel_magnitude",
                                      "semi_structured_2_4"])
def test_sparsity_exact(rng, strategy):
    x = _value_cache(rng)
    s = 0.5
    mask = pruning.prune_mask(x, s, strategy)
    frac = float(mask.mean())
    assert abs(frac - 0.5) < 0.02


def test_key_unstructured_beats_structured(rng):
    """Paper Table 1: at K_s=0.7, unstructured magnitude ≪ ThinK error."""
    k = _key_cache(rng)
    v = _value_cache(rng)
    q_acc = jnp.asarray(np.abs(rng.normal(size=k.shape[:2] + (128,))
                               ).astype(np.float32))
    e_unstr = _attn_err(k, pruning.prune(k, 0.7, "per_token_magnitude"), v, v, rng)
    e_think = _attn_err(k, pruning.prune(k, 0.7, "think", q_acc=q_acc), v, v, rng)
    e_24 = _attn_err(k, pruning.prune(k, 0.5, "semi_structured_2_4"), v, v, rng)
    e_unstr_50 = _attn_err(k, pruning.prune(k, 0.5, "per_token_magnitude"), v, v, rng)
    assert e_unstr < e_think, (e_unstr, e_think)
    assert e_unstr_50 < e_24, (e_unstr_50, e_24)            # paper Appx. B


def test_value_per_token_beats_per_channel_magnitude(rng):
    """Paper Table 2/8: per-token magnitude is the best value strategy."""
    k = _key_cache(rng)
    v = _value_cache(rng)
    e_tok = _attn_err(k, k, v, pruning.prune(v, 0.7, "per_token_magnitude"), rng)
    e_ch = _attn_err(k, k, v, pruning.prune(v, 0.7, "per_channel_magnitude"), rng)
    assert e_tok < e_ch, (e_tok, e_ch)


def test_output_aware_key_scores_shape(rng):
    k = _key_cache(rng)
    qw = jnp.asarray(rng.normal(size=(2, 8, 32, 128)).astype(np.float32))
    q_acc = pruning.gqa_query_accumulate(qw, n_kv_heads=4)
    assert q_acc.shape == (2, 4, 128)
    s = pruning.key_output_aware_scores(k, q_acc)
    assert s.shape == k.shape
    assert float(s.min()) >= 0.0
    mask = pruning.prune_mask(k, 0.5, "per_token_output_aware", q_acc=q_acc)
    assert int(mask.sum(-1).std()) == 0                     # fixed-k per token


def test_value_output_aware_is_per_token_equivalent(rng):
    """§2.2: per-token magnitude IS output-aware for Value (α multiplies whole
    rows — scaling a token's row by its α never changes within-row ranking)."""
    v = _value_cache(rng)
    alpha = jnp.asarray(np.abs(rng.normal(size=v.shape[:3])).astype(np.float32))
    scores = pruning.value_output_aware_scores(v, alpha)
    m1 = pruning.per_token_score_mask(scores, 64)
    m2 = pruning.prune_mask(v, 0.5, "per_token_magnitude", keep_k=64)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


def test_2to4_pattern(rng):
    x = _value_cache(rng)
    mask = np.asarray(pruning.prune_mask(x, 0.5, "semi_structured_2_4"))
    groups = mask.reshape(*mask.shape[:-1], -1, 4)
    assert (groups.sum(-1) == 2).all()


def test_think_removes_whole_channels(rng):
    k = _key_cache(rng)
    q_acc = jnp.asarray(np.abs(rng.normal(size=(2, 4, 128))).astype(np.float32))
    mask = np.asarray(pruning.prune_mask(k, 0.5, "think", q_acc=q_acc))
    # per (B, H): each channel fully kept or fully dropped across tokens
    per_channel = mask.all(axis=2) | (~mask).all(axis=2)
    assert per_channel.all()


def test_per_channel_group_structure(rng):
    v = _value_cache(rng, T=128)
    mask = np.asarray(pruning.prune_mask(v, 0.5, "per_channel_magnitude",
                                         group=32))
    g = mask.reshape(2, 4, 4, 32, 128)                      # [B,H,G,32,d]
    counts = g.sum(axis=3)
    assert (counts == 16).all()                             # 50% per group-col
