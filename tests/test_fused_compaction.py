"""Fused-epilogue compaction (PR 6, compress-as-you-evict) vs the
two-dispatch oracle.

``ops.compress_scatter`` compresses a retiring window tile group and lands
the values/bitmaps in their destination page in ONE dispatch (Pallas
scalar-prefetched output index maps over aliased pools on TPU; reference
compress + one vectorized scatter off-TPU). The oracle is the legacy
``compact_layer_paged`` path: a separate ``compress`` launch followed by a
scan of per-slot dynamic-update-slices. Contract: bit-identical pools on
every NON-scratch page (masked rows write the write-discard scratch page,
where duplicate writes may land in any order — it is never read).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.sparse_format import pad_to_words
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.serving import cache as cache_mod

POOL_DTYPE = cache_mod.POOL_DTYPE


def _rand_pools(rng, n_phys, Hkv, pt, kk, kv, n_words):
    return (
        jnp.asarray(rng.normal(size=(n_phys, Hkv, pt, kk)), POOL_DTYPE),
        jnp.asarray(rng.integers(0, 2 ** 31, size=(n_phys, Hkv, pt, n_words)),
                    jnp.uint32),
        jnp.asarray(rng.normal(size=(n_phys, Hkv, pt, kv)), POOL_DTYPE),
        jnp.asarray(rng.integers(0, 2 ** 31, size=(n_phys, Hkv, pt, n_words)),
                    jnp.uint32),
    )


def _oracle_scatter(pools, k_tile, v_tile, kk, kv, phys, off, scratch):
    """Independent numpy formulation: ref compress + per-row python loop."""
    ck_v, ck_b = ref.mustafar_compress_ref(k_tile, kk)
    cv_v, cv_b = ref.mustafar_compress_ref(v_tile, kv)
    outs = [np.asarray(p).copy() for p in pools]
    tt = k_tile.shape[2]
    for b in range(k_tile.shape[0]):
        if phys[b] == scratch:
            continue
        for pool, tiles in zip(outs, (ck_v, ck_b, cv_v, cv_b)):
            pool[phys[b], :, off[b]:off[b] + tt] = \
                np.asarray(tiles[b]).astype(pool.dtype)
    return outs


@pytest.mark.parametrize("d", [64, 80, 128])
def test_compress_scatter_matches_oracle(d):
    """Both backends of ``compress_scatter`` (vectorized jnp fallback AND
    the Pallas interpret kernel) against the loop oracle, for head dims
    covering the word-aligned (64, 128) and padded (80 -> 96 lanes) cases,
    with destinations at page start, page END (boundary fill), and the
    scratch page."""
    rng = np.random.default_rng(d)
    B, Hkv, tt, pt = 4, 2, 16, 32
    kk, kv = 24, 20
    n_phys = 5                               # pages 0..3 + scratch 4
    n_words = pad_to_words(d) // 32
    pools = _rand_pools(rng, n_phys, Hkv, pt, kk, kv, n_words)
    k_tile = jnp.asarray(rng.normal(size=(B, Hkv, tt, d)), jnp.float32)
    v_tile = jnp.asarray(rng.normal(size=(B, Hkv, tt, d)), jnp.float32)
    phys = np.asarray([2, 0, n_phys - 1, 3])     # row 2 masked -> scratch
    off = np.asarray([0, pt - tt, 0, tt])        # start / boundary / mid

    want = _oracle_scatter(pools, k_tile, v_tile, kk, kv, phys, off,
                           scratch=n_phys - 1)
    for use_pallas in (False, True):
        got = kops.compress_scatter(k_tile, v_tile, *pools,
                                    jnp.asarray(phys, jnp.int32),
                                    jnp.asarray(off, jnp.int32),
                                    use_pallas=use_pallas)
        for name, g, w in zip(("ck_vals", "ck_bm", "cv_vals", "cv_bm"),
                              got, want):
            g = np.asarray(g.astype(jnp.float32))[:n_phys - 1]
            w = w.astype(np.float32)[:n_phys - 1]
            assert np.array_equal(g, w), \
                f"{name} diverged (pallas={use_pallas}, d={d})"


def test_fused_layer_compaction_matches_two_dispatch_oracle():
    """``compact_layer_paged_fused`` (whole period stack, one fused
    scatter) vs vmapped ``compact_layer_paged`` (two dispatches) on mixed
    need/unmapped rows, including a slot whose fill CROSSES a page
    boundary — pools bit-identical on all non-scratch pages, windows
    identically rolled."""
    cfg = get_config("starcoder2-3b").reduced().with_sparsity(0.6, 0.6)
    m = cfg.mustafar
    tt = m.tile_tokens
    pt = 2 * tt
    d, Hkv = cfg.d_head, cfg.n_kv_heads
    kk = m.keep_k(d, m.key_sparsity)
    kv = m.keep_k(d, m.value_sparsity)
    n_words = pad_to_words(d) // 32
    Wbuf = m.local_window + tt
    P, B, n_pages = 2, 3, 6
    n_phys = n_pages + 1
    rng = np.random.default_rng(0)
    lc = {}
    for name, c, dt in (("ck_vals", kk, POOL_DTYPE),
                        ("ck_bm", n_words, jnp.uint32),
                        ("cv_vals", kv, POOL_DTYPE),
                        ("cv_bm", n_words, jnp.uint32)):
        raw = (rng.integers(0, 2 ** 31, size=(P, n_phys, Hkv, pt, c))
               if dt == jnp.uint32 else
               rng.normal(size=(P, n_phys, Hkv, pt, c)))
        lc[name] = jnp.asarray(raw, dt)
    lc["k_win"] = jnp.asarray(rng.normal(size=(P, B, Hkv, Wbuf, d)),
                              jnp.float32)
    lc["v_win"] = jnp.asarray(rng.normal(size=(P, B, Hkv, Wbuf, d)),
                              jnp.float32)
    # slot 0 fills page 0 from its start; slot 1 is mid-window (no
    # compaction); slot 2 has filled page 2 completely -> this tile group
    # crosses into its SECOND page (lp=1 -> page 3)
    n_comp = jnp.asarray([0, tt, pt], jnp.int32)
    bt = jnp.asarray([[0, -1, -1], [1, -1, -1], [2, 3, -1]], jnp.int32)
    need = jnp.asarray([True, False, True])

    oracle = jax.vmap(lambda one: cache_mod.compact_layer_paged(
        cfg, one, n_comp, bt, need))(lc)
    fused = cache_mod.compact_layer_paged_fused(cfg, lc, n_comp, bt, need)
    for name in ("ck_vals", "ck_bm", "cv_vals", "cv_bm"):
        a = np.asarray(oracle[name][:, :n_pages].astype(jnp.float32))
        b = np.asarray(fused[name][:, :n_pages].astype(jnp.float32))
        assert np.array_equal(a, b), f"{name} non-scratch pages diverged"
    for name in ("k_win", "v_win"):
        assert np.array_equal(np.asarray(oracle[name]),
                              np.asarray(fused[name])), name


def test_fused_scheduler_run_bit_exact_vs_legacy():
    """End-to-end: a decode-heavy paged trace served with
    ``fused_compaction=True`` emits exactly the tokens of the legacy
    two-dispatch run (every compaction in the trace goes through the fused
    epilogue instead)."""
    from repro.models import init_params
    from repro.serving.engine import Request, Scheduler

    cfg = get_config("starcoder2-3b").reduced().with_sparsity(0.5, 0.5)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    prompts = [[int(t) for t in rng.integers(0, cfg.vocab_size, size=T)]
               for T in (26, 9, 31)]

    def serve(fused):
        sched = Scheduler(cfg, params, n_slots=3, max_total_tokens=96,
                          page_tokens=cfg.mustafar.tile_tokens,
                          fused_compaction=fused, debug_invariants=True)
        for i, p in enumerate(prompts):
            sched.submit(Request(prompt=np.asarray(p), max_new_tokens=30,
                                 uid=i))
        sched.run()
        return {r.uid: r.output_tokens for r in sched.finished}

    assert serve(False) == serve(True)
