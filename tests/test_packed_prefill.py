"""Packed multi-admission chunked prefill (PR 6): chunks from several
in-flight admissions run as batch lanes of ONE ``prefill_chunk_step`` call.

The bit-exactness contract: every op in the chunk step is row-independent
(batched einsums + per-lane dynamic_update_slice + exact-zero masking in
``prefix_causal_attention``), so a lane's logits and K/V carry are
bit-identical to the same chunk run solo — and therefore to the one-shot
prefill, via the already-tested solo-chunk == one-shot equivalence. The
tests here pin BOTH links of that chain across ragged segment boundaries
(prompt lengths that are not chunk multiples, lanes at different offsets,
dummy lanes riding along) and at the scheduler level.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving.engine import (Request, Scheduler, init_chunk_carry,
                                  prefill, prefill_chunk_step)

KEY = jax.random.PRNGKey(0)
CFG = get_config("starcoder2-3b").reduced().with_sparsity(0.5, 0.5)
PARAMS = init_params(KEY, CFG)
MAX_TOTAL = 96
TT = CFG.mustafar.tile_tokens
C = 8                                    # chunk size used throughout


def _chunks(T):
    return -(-T // C)


def _solo_chunked(prompt):
    """Solo-chunked reference: batch-1 carry, scalar offsets. Returns the
    per-chunk logits list and the final carry sliced to the true T."""
    T = len(prompt)
    T_buf = _chunks(T) * C
    carry = init_chunk_carry(CFG, T_buf)
    step = jax.jit(lambda p, t, c, o: prefill_chunk_step(p, t, c, o, CFG))
    logits = []
    for i in range(_chunks(T)):
        off = i * C
        tok = prompt[off:off + C] + [0] * max(0, off + C - T)
        lg, carry = step(PARAMS, jnp.asarray([tok], jnp.int32), carry,
                         jnp.asarray(off, jnp.int32))
        logits.append(lg[0])
    sliced = jax.tree_util.tree_map(lambda a: a[:, 0, :T], carry)
    return logits, sliced


def test_packed_lanes_bit_exact_vs_solo_chunks():
    """Three live lanes at DIFFERENT ragged offsets plus one dummy lane in
    every packed call: each lane's per-chunk logits and final carry must be
    bit-identical to its solo-chunked run."""
    rng = np.random.default_rng(0)
    prompts = [[int(t) for t in rng.integers(0, CFG.vocab_size, size=T)]
               for T in (20, 13, 29)]           # none a chunk multiple
    n_lanes = 4                                  # lane 3 is always dummy
    T_buf = _chunks(MAX_TOTAL) * C
    carry = init_chunk_carry(CFG, T_buf, batch=n_lanes)
    step = jax.jit(lambda p, t, c, o: prefill_chunk_step(p, t, c, o, CFG))

    solo = [_solo_chunked(p) for p in prompts]
    done = [0] * len(prompts)
    for _ in range(max(_chunks(len(p)) for p in prompts)):
        toks = [[0] * C for _ in range(n_lanes)]
        offs = [T_buf - C] * n_lanes             # dummy lanes park at tail
        live = []
        for lane, p in enumerate(prompts):
            if done[lane] >= len(p):
                continue
            off = done[lane]
            tok = p[off:off + C] + [0] * max(0, off + C - len(p))
            toks[lane], offs[lane] = tok, off
            live.append(lane)
        lg, carry = step(PARAMS, jnp.asarray(toks, jnp.int32), carry,
                         jnp.asarray(offs, jnp.int32))
        for lane in live:
            i = done[lane] // C
            want = solo[lane][0][i]
            assert np.array_equal(np.asarray(lg[lane]), np.asarray(want)), \
                f"lane {lane} chunk {i} logits diverged from solo"
            done[lane] += C
    for lane, p in enumerate(prompts):
        got = jax.tree_util.tree_map(lambda a: a[:, lane, :len(p)], carry)
        flat_g, _ = jax.tree_util.tree_flatten(got)
        flat_w, _ = jax.tree_util.tree_flatten(solo[lane][1])
        for g, w in zip(flat_g, flat_w):
            assert np.array_equal(np.asarray(g), np.asarray(w)), \
                f"lane {lane} carry diverged from solo"


def test_packed_lanes_bit_exact_vs_one_shot_prefill():
    """Lane logits at the prompt's last position must equal the one-shot
    ``prefill`` logits bit-for-bit (the first sampled token comes from
    there), including for a prompt whose last chunk is ragged."""
    rng = np.random.default_rng(1)
    prompts = [[int(t) for t in rng.integers(0, CFG.vocab_size, size=T)]
               for T in (11, 24)]
    T_buf = _chunks(MAX_TOTAL) * C
    carry = init_chunk_carry(CFG, T_buf, batch=len(prompts))
    step = jax.jit(lambda p, t, c, o: prefill_chunk_step(p, t, c, o, CFG))
    last = {}
    done = [0] * len(prompts)
    for _ in range(max(_chunks(len(p)) for p in prompts)):
        toks = [[0] * C for _ in prompts]
        offs = [T_buf - C] * len(prompts)
        for lane, p in enumerate(prompts):
            if done[lane] >= len(p):
                continue
            off = done[lane]
            toks[lane] = p[off:off + C] + [0] * max(0, off + C - len(p))
            offs[lane] = off
        lg, carry = step(PARAMS, jnp.asarray(toks, jnp.int32), carry,
                         jnp.asarray(offs, jnp.int32))
        for lane, p in enumerate(prompts):
            if done[lane] < len(p):
                if done[lane] + C >= len(p):     # this was the last chunk
                    last[lane] = lg[lane, (len(p) - 1) - done[lane]]
                done[lane] += C
    for lane, p in enumerate(prompts):
        want, _ = prefill(PARAMS, jnp.asarray([p], jnp.int32), CFG,
                          max_total_tokens=MAX_TOTAL)
        assert np.array_equal(np.asarray(last[lane]), np.asarray(want[0])), \
            f"lane {lane} last-position logits != one-shot prefill"


def test_scheduler_packed_matches_solo_and_oneshot():
    """End-to-end: the same burst trace served three ways — one-shot
    prefill, serial chunking, packed chunking — must emit identical
    tokens, and packing must strictly reduce drain time on the burst."""
    rng = np.random.default_rng(2)
    prompts = [[int(t) for t in rng.integers(0, CFG.vocab_size, size=T)]
               for T in (20, 13, 29, 17)]

    def serve(prefill_chunk=None, prefill_budget=None, pack=False):
        sched = Scheduler(CFG, PARAMS, n_slots=4, max_total_tokens=MAX_TOTAL,
                          page_tokens=TT, prefill_chunk=prefill_chunk,
                          prefill_budget=prefill_budget, pack_prefill=pack,
                          debug_invariants=True)
        for i, p in enumerate(prompts):          # burst arrival at step 0
            sched.submit(Request(prompt=np.asarray(p), max_new_tokens=6,
                                 uid=i))
        sched.run()
        return ({r.uid: r.output_tokens for r in sched.finished},
                sched.step_count, sched.max_prefill_step_tokens)

    oneshot, _, _ = serve()
    solo, steps_solo, stall_solo = serve(prefill_chunk=C)
    packed, steps_packed, stall_packed = serve(prefill_chunk=C,
                                               prefill_budget=4 * C,
                                               pack=True)
    assert oneshot == solo == packed
    assert stall_solo <= C
    assert stall_packed <= 4 * C
    assert steps_packed < steps_solo, \
        "packing did not shorten the burst drain"
