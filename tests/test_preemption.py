"""Page-aware preemption + the hierarchical KV cache tier (HBM -> host
spool -> restart persistence).

The claims this module pins down:

  * PREEMPT/RESTORE BIT-EXACTNESS — a request whose pages were swapped to
    the host ``PageSpool`` mid-decode and later spliced back produces
    output tokens IDENTICAL to running it uninterrupted (compressed pages
    are immutable once retired, so the device->host->device round-trip is
    byte-exact; the decode state — window, counters, next token — rides
    along). Also asserted under a sharded ``mesh=`` scheduler at model=1.
  * VICTIM POLICY — only STRICTLY lower-priority decoders are swapped out
    (equal-priority traffic never self-preempts), and every preemption is
    matched by a restore before the drain completes.
  * SPILL TIER — prefix-index chains demoted to the spool promote back
    byte-exactly on the next admission that walks their path, and
    ``save()``/``load()`` persist them across a scheduler restart (with a
    config fingerprint guarding against stale caches).
  * ROUTER FIXES — prefix affinity only wins when the holding replica can
    actually admit (a flood spills to siblings instead of queueing), and
    ``_free_now`` counts page headroom, not just slots.
  * ZERO LEAKS — after every drain: nothing reserved, nothing drawn,
    nothing left in the spool.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving import cache as cache_mod
from repro.serving.engine import Request, Scheduler, decode_step, prefill
from repro.serving.router import Router

KEY = jax.random.PRNGKey(0)
CFG = get_config("starcoder2-3b").reduced().with_sparsity(0.5, 0.5)
PARAMS = init_params(KEY, CFG)
MAX_TOTAL = 96
TT = CFG.mustafar.tile_tokens          # 16 in the reduced cfg
_PREFIX_RNG = np.random.default_rng(300)
PREFIX = [int(t) for t in _PREFIX_RNG.integers(0, CFG.vocab_size, size=56)]


def _req(seed, n_prompt, gen, priority=0, prefix=()):
    r = np.random.default_rng(seed)
    prompt = list(prefix) + [int(t) for t in
                             r.integers(0, CFG.vocab_size, size=n_prompt)]
    return Request(prompt=prompt, max_new_tokens=gen, priority=priority)


def _solo_greedy(prompt, n_new):
    """Contiguous lockstep reference run (tokens only)."""
    lg, cache = prefill(PARAMS, jnp.asarray(prompt, jnp.int32)[None], CFG,
                        max_total_tokens=MAX_TOTAL)
    toks = [int(jnp.argmax(lg[0]))]
    step = jax.jit(lambda p, t, c: decode_step(p, t, c, CFG))
    while len(toks) < n_new:
        lg, cache = step(PARAMS, jnp.asarray([toks[-1]], jnp.int32), cache)
        toks.append(int(jnp.argmax(lg[0])))
    return toks


def _assert_drained_clean(sched):
    """Nothing drawn, nothing reserved, nothing stranded in the spool."""
    if sched.share_prefix:
        sched.prefix.clear(sched.allocator)
    assert sched.allocator.in_use == 0
    assert sched.allocator.n_reserved == 0
    assert sched.spool.n_entries == 0, "host spool leaked entries"


def _preempt_scenario(mesh=None):
    """One low-priority background decoder whose worst case fills the pool
    (total 80 -> 4 of 5 pages), then a high-priority arrival needing 2
    pages: admission MUST swap the background out and splice it back."""
    sched = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT, n_pages=5,
                      admission_policy="preempt", mesh=mesh,
                      debug_invariants=True)
    bg = _req(101, 24, 56, priority=0)
    hi = _req(102, 24, 24, priority=1)
    sched.submit(bg)
    for _ in range(6):                       # bg decodes mid-flight first
        sched.step()
    assert bg.num_generated >= 4
    sched.submit(hi)
    sched.run()
    return sched, bg, hi


def test_preempt_restore_bit_exact():
    sched, bg, hi = _preempt_scenario()
    assert sched.preempt_count >= 1, "pool pressure never preempted"
    assert sched.restore_count == sched.preempt_count
    assert bg.preempt_count >= 1 and hi.preempt_count == 0
    assert sched.swapped_pages >= 1
    # the whole point: a preempted/restored request is BIT-IDENTICAL to an
    # uninterrupted run — no recompute, no drift
    assert bg.output_tokens == _solo_greedy(bg.prompt, bg.max_new_tokens)
    assert hi.output_tokens == _solo_greedy(hi.prompt, hi.max_new_tokens)
    # swap traffic round-tripped: bytes out came back in
    assert sched.spool.bytes_in > 0
    _assert_drained_clean(sched)


def test_preempt_restore_bit_exact_sharded():
    """Same scenario under a shard_map mesh (model=1 runs anywhere): the
    gather/scatter swap path must be mesh-transparent."""
    from repro.serving.sharded import make_serving_mesh

    sched, bg, hi = _preempt_scenario(mesh=make_serving_mesh(1))
    assert sched.preempt_count >= 1
    assert bg.output_tokens == _solo_greedy(bg.prompt, bg.max_new_tokens)
    assert hi.output_tokens == _solo_greedy(hi.prompt, hi.max_new_tokens)
    _assert_drained_clean(sched)


def test_equal_priority_never_preempts():
    """Victims are STRICTLY lower priority: two equal-priority requests on
    the same overcommitted pool must fall back to waiting, not thrash."""
    sched = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT, n_pages=5,
                      admission_policy="preempt", debug_invariants=True)
    a = _req(111, 24, 56, priority=0)
    b = _req(112, 24, 24, priority=0)
    sched.submit(a)
    for _ in range(4):
        sched.step()
    sched.submit(b)
    sched.run()
    assert sched.preempt_count == 0
    assert a.output_tokens == _solo_greedy(a.prompt, a.max_new_tokens)
    assert b.output_tokens == _solo_greedy(b.prompt, b.max_new_tokens)
    _assert_drained_clean(sched)


def test_preempt_requires_paged_pools():
    with pytest.raises(ValueError, match="preempt"):
        Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL,
                  admission_policy="preempt")
    with pytest.raises(ValueError, match="admission_policy"):
        Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL,
                  admission_policy="shed")


def test_reject_policy_sheds_instead_of_queueing():
    """Under ``reject`` a page-starved admission is dropped immediately
    (the baseline BENCH_preemption compares preemption against)."""
    sched = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT, n_pages=5,
                      admission_policy="reject", debug_invariants=True)
    keep = _req(121, 24, 56)                 # 4 of 5 pages worst-case
    shed = _req(122, 24, 24)                 # needs 2 -> must be dropped
    sched.submit(keep)
    for _ in range(4):
        sched.step()
    sched.submit(shed)
    sched.run()
    assert keep.done and not shed.done
    assert shed.rejected and sched.rejected == [shed]
    assert keep.output_tokens == _solo_greedy(keep.prompt,
                                              keep.max_new_tokens)
    _assert_drained_clean(sched)


# ----------------------------------------------------------------------
# spill tier: demote -> promote, save -> load

def test_prefix_spill_promotes_back_bit_exact():
    """Demote EVERY cached chain to the host spool, then admit a request
    sharing that prefix: admission must promote the chain back onto device
    pages and the output must match solo exactly (byte-exact round-trip)."""
    sched = Scheduler(CFG, PARAMS, n_slots=1, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT, share_prefix=True,
                      debug_invariants=True)
    first = _req(131, 4, 8, prefix=PREFIX)
    sched.submit(first)
    sched.run()
    assert len(sched.prefix.held_pages) > 0
    # force-demote everything the index holds (what pool pressure does)
    sched.prefix.evict_until(sched.allocator, sched.n_pages,
                             spool=True, cache=sched.cache)
    assert sched.prefix.spooled_entries > 0
    assert sched.prefix.held_pages == []
    spooled_before = sched.prefix.spooled_entries
    second = _req(132, 6, 8, prefix=PREFIX)
    sched.submit(second)
    sched.run()
    assert second.shared_prefix_tokens > 0, "spool hit never promoted"
    assert sched.prefix.spooled_entries < spooled_before
    assert second.output_tokens == _solo_greedy(second.prompt,
                                                second.max_new_tokens)
    _assert_drained_clean(sched)


def test_prefix_save_load_round_trip():
    """Restart persistence: save the index, load it into a FRESH scheduler,
    and the warm start must (a) report identical potential coverage for
    the saved prompts, (b) alias pages on the first same-prefix admission,
    (c) reproduce solo outputs exactly."""
    donor = Scheduler(CFG, PARAMS, n_slots=1, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT, share_prefix=True,
                      debug_invariants=True)
    seed_req = _req(141, 4, 8, prefix=PREFIX)
    donor.submit(seed_req)
    donor.run()
    path = os.path.join(tempfile.mkdtemp(), "prefix_cache.pkl")
    n_saved = donor.save_prefix_cache(path)
    assert n_saved == len(donor.prefix._nodes) + len(donor.prefix._partials)
    assert n_saved >= 1

    fresh = Scheduler(CFG, PARAMS, n_slots=1, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT, share_prefix=True,
                      debug_invariants=True)
    assert fresh.load_prefix_cache(path) == n_saved
    # identical match potential for the persisted prompt (probe counts
    # spooled entries; loaded entries all start spooled)
    comp, _ = cache_mod.prefill_split(CFG, len(seed_req.prompt))
    assert fresh.prefix.probe(seed_req.prompt, comp) \
        == donor.prefix.probe(seed_req.prompt, comp)
    assert fresh.prefix.spooled_entries == n_saved
    warm = _req(142, 6, 8, prefix=PREFIX)
    fresh.submit(warm)
    fresh.run()
    assert warm.shared_prefix_tokens > 0, "persisted chains never hit"
    assert warm.output_tokens == _solo_greedy(warm.prompt,
                                              warm.max_new_tokens)
    _assert_drained_clean(fresh)
    _assert_drained_clean(donor)


def test_prefix_load_rejects_stale_fingerprint():
    """A persisted cache from a DIFFERENT config (here: other sparsity,
    i.e. another pruning operating point) must be refused, not silently
    reinterpreted — the compressed bytes would be wrong."""
    donor = Scheduler(CFG, PARAMS, n_slots=1, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT, share_prefix=True)
    donor.submit(_req(151, 4, 8, prefix=PREFIX))
    donor.run()
    path = os.path.join(tempfile.mkdtemp(), "prefix_cache.pkl")
    donor.save_prefix_cache(path)
    other_cfg = get_config("starcoder2-3b").reduced().with_sparsity(0.7, 0.7)
    other = Scheduler(other_cfg, init_params(KEY, other_cfg), n_slots=1,
                      max_total_tokens=MAX_TOTAL, page_tokens=TT,
                      share_prefix=True)
    with pytest.raises(ValueError, match="fingerprint"):
        other.load_prefix_cache(path)
    _assert_drained_clean(donor)


# ----------------------------------------------------------------------
# router fixes

def test_router_affinity_spills_when_holder_saturated():
    """Regression: prefix affinity used to win UNCONDITIONALLY, so a flood
    of same-prefix requests all queued on the one replica holding the
    chain while its sibling sat idle. Affinity must be gated on
    admissibility: the first request lands on the holder, the overflow
    spills to the sibling."""
    router = Router(CFG, PARAMS, n_engines=2, n_slots=2,
                    max_total_tokens=MAX_TOTAL, page_tokens=TT,
                    share_prefix=True)
    seed_req = _req(201, 4, 6, prefix=PREFIX)
    router.submit(seed_req)
    router.run()
    holder = router.engine_of[seed_req.uid]
    burst = [_req(210 + i, 4, 6, prefix=PREFIX) for i in range(3)]
    for r in burst:
        router.submit(r)
    owners = [router.engine_of[r.uid] for r in burst]
    assert owners[0] == holder, "affinity ignored an admissible holder"
    assert len(set(owners)) == 2, \
        f"flood never spilled off the prefix holder: {owners}"
    router.run()
    assert all(r.done for r in burst)


def test_router_free_now_counts_page_headroom():
    """Regression: ``_free_now`` used to check slots only, so pack routing
    sent requests to the busiest replica even when its page pool was
    pinned by a live decoder — the request then queued for no reason
    while the sibling had free pages."""
    router = Router(CFG, PARAMS, n_engines=2, n_slots=4,
                    max_total_tokens=MAX_TOTAL, page_tokens=TT, n_pages=10)
    big = _req(221, 40, 56)                  # 96 total -> all 5 of e0's pages
    router.submit(big)
    assert router.engine_of[big.uid] == 0
    for _ in range(3):                       # let e0 admit + reserve
        router.step()
    small = _req(222, 24, 24)                # needs 2 pages
    router.submit(small)
    assert router.engine_of[small.uid] == 1, \
        "pack routed into a page-starved replica"
    router.run()
    assert big.done and small.done
    for e in router.engines:
        _assert_drained_clean(e)
