"""Differential oracle tests: PAGED compressed pools vs CONTIGUOUS pools.

Every read path must be bit-exact fp32 between the two layouts — the gather
view, the two-pass and chunked jnp formulations, the fused Pallas kernel
(interpret mode), and the full decode_step over a paged cache — across head
dims, sparsities, page sizes, and ragged fills sitting exactly on/around
page boundaries. ``repro.kernels.legacy`` is reused as the ground-truth
decompression oracle the same way tests/test_kernels.py does: paging only
relocates fixed-k rows, so the legacy one-hot expansion of the contiguous
pool is the authority both layouts must reproduce.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MustafarConfig
from repro.core.attention import (MustafarCacheView, PagedMustafarCacheView,
                                  decode_attention_mustafar,
                                  decode_attention_mustafar_chunked)
from repro.core.sparse_format import gather_pages, unpack_fixedk
from repro.kernels import legacy, ref
from repro.kernels.sparse_decode import (decode_attention_fused,
                                         decode_attention_fused_paged)

TILE_T = 16           # kernel token tile for these tests


def _mk(rng, shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


def _keep_k(d, sparsity):
    """The production k formula — the same one the serving stack packs with."""
    return MustafarConfig().keep_k(d, sparsity)


def _page_layout(rng, arrs, B, Hkv, pt):
    """Scatter contiguous [B*Hkv, T, c] leaves into shuffled paged pools.

    Returns (pools, block_table): pools [n_phys, Hkv, pt, c] with physical
    page ids drawn from a random permutation (so logical adjacency never
    accidentally survives in physical order), block_table [B, MP] int32,
    plus one trailing scratch page left zeroed."""
    T = arrs[0].shape[1]
    assert T % pt == 0
    MP = T // pt
    n_phys = B * MP + 1
    perm = rng.permutation(B * MP)
    bt = np.full((B, MP), -1, np.int32)
    pools = []
    for arr in arrs:
        a = np.asarray(arr).reshape(B, Hkv, T, arr.shape[-1])
        pool = np.zeros((n_phys, Hkv, pt) + a.shape[3:], a.dtype)
        for b in range(B):
            for lp in range(MP):
                bt[b, lp] = perm[b * MP + lp]
                pool[bt[b, lp]] = a[b, :, lp * pt:(lp + 1) * pt]
        pools.append(jnp.asarray(pool))
    return pools, jnp.asarray(bt)


def _ragged_nv(pt, T):
    """The ISSUE's page-boundary fills: 0, 1, boundary, boundary ± 1."""
    return [0, 1, min(pt, T), max(pt - 1, 0), min(pt + 1, T)]


def _compressed(rng, B, Hkv, T, d, k):
    kx = _mk(rng, (B * Hkv, T, d))
    vx = _mk(rng, (B * Hkv, T, d))
    ckv, ckb = ref.mustafar_compress_ref(kx, k)
    cvv, cvb = ref.mustafar_compress_ref(vx, k)
    return ckv, ckb, cvv, cvb


@pytest.mark.parametrize("sparsity", [0.5, 0.7])
@pytest.mark.parametrize("d", [64, 80, 128])
@pytest.mark.parametrize("pt_mult", [1, 2])
def test_gather_view_matches_legacy_oracle(rng, d, sparsity, pt_mult):
    """The paged gather view must reproduce the contiguous pool bit-for-bit,
    and its decompression must equal the LEGACY one-hot expansion of the
    contiguous pool (the pre-overhaul ground truth) exactly in fp32."""
    B, Hkv, T = 3, 2, 64
    pt = pt_mult * TILE_T
    k = _keep_k(d, sparsity)
    ckv, ckb, cvv, cvb = _compressed(np.random.default_rng(0), B, Hkv, T, d, k)
    pools, bt = _page_layout(np.random.default_rng(7), (ckv, ckb, cvv, cvb),
                             B, Hkv, pt)
    for contig, pool in zip((ckv, ckb, cvv, cvb), pools):
        view = gather_pages(pool, bt).reshape(B * Hkv, T, -1)
        np.testing.assert_array_equal(np.asarray(view), np.asarray(contig))
    # legacy one-hot decompression of the contiguous pool == unpack of the
    # gathered paged pool (fp32 bit-exact)
    gk = gather_pages(pools[0], bt).reshape(B * Hkv, T, -1)
    gb = gather_pages(pools[1], bt).reshape(B * Hkv, T, -1)
    dense_paged = unpack_fixedk(gk, gb, d)
    for r in range(B * Hkv):
        dense_legacy = legacy.decompress_onehot(ckv[r], ckb[r], k)[:, :d]
        np.testing.assert_array_equal(
            np.asarray(dense_paged[r], np.float32),
            np.asarray(dense_legacy, np.float32))


@pytest.mark.parametrize("sparsity", [0.5, 0.7])
@pytest.mark.parametrize("d", [64, 80, 128])
@pytest.mark.parametrize("pt_mult", [1, 2])
def test_paged_fused_kernel_bitexact(rng, d, sparsity, pt_mult):
    """Paged fused kernel == contiguous fused kernel, bit-for-bit fp32, for
    ragged fills on and around every page boundary (tile→page translation
    in the scalar-prefetch grid changes residency, never math)."""
    Hkv, G, T = 1, 2, 64
    pt = pt_mult * TILE_T
    k = _keep_k(d, sparsity)
    nv_list = _ragged_nv(pt, T) + [T]
    B = len(nv_list)
    ckv, ckb, cvv, cvb = _compressed(np.random.default_rng(1), B, Hkv, T, d, k)
    q = _mk(np.random.default_rng(2), (B * Hkv, G, d))
    nv = jnp.asarray(nv_list, jnp.int32)
    o_contig = decode_attention_fused(
        q, ckv, ckb, cvv, cvb, nv, d=d, scale=d ** -0.5,
        interpret=True, tile_t=TILE_T)
    pools, bt = _page_layout(np.random.default_rng(8), (ckv, ckb, cvv, cvb),
                             B, Hkv, pt)
    o_paged = decode_attention_fused_paged(
        q, *pools, bt, nv, d=d, scale=d ** -0.5,
        interpret=True, tile_t=TILE_T)
    np.testing.assert_array_equal(np.asarray(o_contig), np.asarray(o_paged))
    assert np.all(np.asarray(o_paged)[0] == 0.0)   # nv=0 row -> zero vector
    # and both agree with the jnp oracle
    o_ref = ref.decode_attention_fused_ref(q, ckv, ckb, cvv, cvb, nv, d,
                                           scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(o_paged), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("sparsity", [0.5, 0.7])
@pytest.mark.parametrize("d", [64, 80, 128])
@pytest.mark.parametrize("pt_mult", [1, 2])
def test_paged_view_two_pass_and_chunked_bitexact(rng, d, sparsity, pt_mult):
    """The jnp decode formulations (two-pass joint softmax and chunked
    online softmax) read the paged cache through the gather view — outputs
    must be bit-identical fp32 to the contiguous view, page-boundary fills
    included. This is the CPU serving path's equivalence guarantee."""
    Hkv, Hq, T, W = 2, 4, 64, 8
    pt = pt_mult * TILE_T
    k = _keep_k(d, sparsity)
    nv_list = _ragged_nv(pt, T)
    B = len(nv_list)
    r = np.random.default_rng(3)
    ckv, ckb, cvv, cvb = _compressed(r, B, Hkv, T, d, k)

    def shp(x):
        return x.reshape(B, Hkv, T, x.shape[-1])

    kw = _mk(r, (B, Hkv, W, d))
    vw = _mk(r, (B, Hkv, W, d))
    n_win = jnp.asarray(r.integers(1, W + 1, size=B), jnp.int32)
    n_comp = jnp.asarray(nv_list, jnp.int32)
    contig = MustafarCacheView(shp(ckv), shp(ckb), shp(cvv), shp(cvb),
                               n_comp, kw, vw, n_win)
    pools, bt = _page_layout(np.random.default_rng(9), (ckv, ckb, cvv, cvb),
                             B, Hkv, pt)
    paged = PagedMustafarCacheView(*pools, bt, n_comp, kw, vw, n_win)
    q = _mk(r, (B, Hq, d))

    via_gather = paged.to_contiguous()
    o_two = decode_attention_mustafar(q, contig)
    o_two_p = decode_attention_mustafar(q, via_gather)
    np.testing.assert_array_equal(np.asarray(o_two), np.asarray(o_two_p))
    o_chnk = decode_attention_mustafar_chunked(q, contig, chunk=TILE_T)
    o_chnk_p = decode_attention_mustafar_chunked(q, via_gather, chunk=TILE_T)
    np.testing.assert_array_equal(np.asarray(o_chnk), np.asarray(o_chnk_p))


# ----------------------------------------------------------------------
# full-stack: decode_step over a paged cache vs a contiguous cache

def test_decode_step_paged_cache_bitexact():
    """The whole serving step — append, per-slot compaction across page
    boundaries, paged attention view — produces logits bit-identical to the
    contiguous cache, for both page sizes, over enough steps that every
    slot retires tile groups into first and subsequent pages."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import cache as cache_mod
    from repro.serving.engine import decode_step, prefill_into_slot

    cfg = get_config("starcoder2-3b").reduced().with_sparsity(0.5, 0.5)
    params = init_params(jax.random.PRNGKey(0), cfg)
    max_total = 96
    tt = cfg.mustafar.tile_tokens
    rng = np.random.default_rng(4)
    prompts = [jnp.asarray(rng.integers(0, cfg.vocab_size, size=n), jnp.int32)
               for n in (23, 9)]          # slot 0 compacts first

    for pt in (tt, 2 * tt):
        max_pages = cache_mod.plan_pages(cfg, max_total, pt, batch=2)
        contig = cache_mod.init_cache(cfg, 2, max_total)
        paged = cache_mod.init_cache(cfg, 2, max_total, page_tokens=pt)
        # pre-map each slot's full logical range (identity-per-slot pages;
        # the scheduler normally draws these lazily from the allocator)
        slot_pages = [list(range(max_pages)),
                      list(range(max_pages, 2 * max_pages))]
        for slot, prompt in enumerate(prompts):
            _, contig = prefill_into_slot(params, prompt[None], contig, slot,
                                          cfg, max_total)
            _, paged = prefill_into_slot(params, prompt[None], paged, slot,
                                         cfg, max_total,
                                         pages=slot_pages[slot],
                                         page_tokens=pt)
        np.testing.assert_array_equal(np.asarray(paged["w_len"]),
                                      np.asarray(contig["w_len"]))
        step = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))
        tok = jnp.zeros((2,), jnp.int32)
        for i in range(2 * tt + 4):       # spans >= 2 compactions on slot 0
            lg_c, contig = step(params, tok, contig)
            lg_p, paged = step(params, tok, paged)
            np.testing.assert_array_equal(
                np.asarray(lg_c, np.float32), np.asarray(lg_p, np.float32),
                err_msg=f"pt={pt} step={i}")
            tok = jnp.argmax(lg_c, axis=-1).astype(jnp.int32)
        for key in ("position", "w_len", "n_compressed"):
            np.testing.assert_array_equal(np.asarray(contig[key]),
                                          np.asarray(paged[key]))
        assert int(paged["n_compressed"].max()) > pt   # crossed a boundary
