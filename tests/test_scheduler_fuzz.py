"""Scheduler trace fuzz: seeded random arrival/length/EOS traces through the
PAGED continuous-batching scheduler, asserting the three allocator-level
invariants the paged pools stand on:

  * per-request SOLO-LOCKSTEP EQUIVALENCE — every request's output tokens
    match running it alone through the contiguous lockstep path (on CPU the
    paged read path is a gather view, so this is exact);
  * NO PAGE LEAKS — after all retirements the free list holds every page
    again and no reservations remain; under PREFIX SHARING the only
    post-drain holders are the prefix index's cache entries, and clearing
    the index restores the full free list (zero refcount leaks);
  * NO ILLEGAL ALIASING — at every step, a physical page mapped by two
    live slots must be a SHARED page with a refcount covering every
    holder (with sharing off: no aliasing at all), host mirrors track the
    device counters exactly, and no compaction ever writes a refcount>1
    page (``debug_invariants=True`` asserts the write-target rule inside
    ``Scheduler._provision_pages`` right before every decode).

Chunked-prefill traces additionally assert the decode-stall budget: no
engine step ever ran more than ``prefill_chunk`` prefill tokens.

A hypothesis variant fuzzes the trace parameters; locally it skips without
hypothesis, in CI it is a hard requirement (CI_REQUIRE_HYPOTHESIS=1 — see
conftest.import_hypothesis). The numpy-seeded traces below always run.
"""
import collections
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import import_hypothesis
from repro.configs import get_config
from repro.kernels.sparse_decode import validate_block_table
from repro.models import init_params
from repro.serving import cache as cache_mod
from repro.serving.engine import Request, Scheduler, decode_step, prefill

KEY = jax.random.PRNGKey(0)
CFG = get_config("starcoder2-3b").reduced().with_sparsity(0.5, 0.5)
PARAMS = init_params(KEY, CFG)
MAX_TOTAL = 96
TT = CFG.mustafar.tile_tokens

# bucketed prompt lengths so prefill executables amortize across cases
PROMPT_LENS = (7, 9, 14, 21)
GEN_LENS = (3, 5, 9, 14)

_SOLO_CACHE = {}


def _solo_tokens(prompt_key, n_new, eos):
    """Contiguous lockstep reference run (memoised across traces)."""
    key = (prompt_key, n_new, eos)
    if key in _SOLO_CACHE:
        return _SOLO_CACHE[key]
    prompt = jnp.asarray(prompt_key, jnp.int32)
    lg, cache = prefill(PARAMS, prompt[None], CFG, max_total_tokens=MAX_TOTAL)
    toks = [int(jnp.argmax(lg[0]))]
    step = jax.jit(lambda p, t, c: decode_step(p, t, c, CFG))
    while len(toks) < n_new and toks[-1] != eos:
        lg, cache = step(PARAMS, jnp.asarray([toks[-1]], jnp.int32), cache)
        toks.append(int(jnp.argmax(lg[0])))
    _SOLO_CACHE[key] = toks
    return toks


def _make_trace(seed, n_requests, prefix_len=0):
    """``prefix_len > 0`` prepends one seeded common prefix to every prompt
    (the system-prompt pattern prefix sharing exists for)."""
    rng = np.random.default_rng(seed)
    prefix = tuple(int(t) for t in rng.integers(0, CFG.vocab_size,
                                                size=prefix_len))
    arrivals = np.cumsum(rng.poisson(1.2, size=n_requests)).astype(int)
    reqs = []
    for i in range(n_requests):
        # request 0 is always deep enough to compact (window fills at
        # local_window + tile = 24 tokens), so every trace exercises a
        # lazy page draw; the rest are random
        plen = PROMPT_LENS[-1] if i == 0 \
            else int(rng.choice(PROMPT_LENS))
        prompt = prefix + tuple(int(t) for t in rng.integers(
            0, CFG.vocab_size, size=plen))
        gen = GEN_LENS[-1] if i == 0 else int(rng.choice(GEN_LENS))
        # an in-vocab EOS that random prompts are unlikely to hit, except
        # for a third of requests where we plant the solo run's 2nd token
        # so EOS retirement genuinely fires mid-generation
        eos = CFG.vocab_size - 1
        if i % 3 == 2 and gen >= 3:      # never the deep request 0
            solo = _solo_tokens(prompt, gen, CFG.vocab_size - 1)
            if len(solo) >= 2:
                eos = solo[1]
        reqs.append(Request(prompt=np.asarray(prompt, np.int64),
                            max_new_tokens=gen, eos_token_id=eos))
    return arrivals, reqs


def _assert_no_aliasing(sched):
    """With sharing OFF: no physical page mapped twice. With sharing ON:
    any page aliased by several holders must carry a refcount covering all
    of them (live-slot mappings + one possible prefix-index entry), and
    every mapped page must be live (refcount >= 1)."""
    live = [s for s, r in enumerate(sched.slots) if r is not None]
    pend = list(getattr(sched, "_pending", ()))
    # host-side page lists across live AND pending (chunked) slots
    counts = collections.Counter(
        p for s in live + pend for p in sched._slot_pages[s])
    bt = np.asarray(sched.cache["block_table"])
    bt_counts = collections.Counter(int(p) for s in live for p in bt[s]
                                    if p >= 0)
    for src, cnt in (("host", counts), ("block-table", bt_counts)):
        for p, n in cnt.items():
            if n > 1:
                assert sched.share_prefix, f"{src} aliasing w/o sharing: {p}"
                assert sched.allocator.refcount(p) >= n, \
                    f"{src} page {p}: {n} holders, refcount " \
                    f"{sched.allocator.refcount(p)}"
            assert sched.allocator.refcount(p) >= 1, f"{src} maps dead {p}"
    # the kernels' read-side contract: mapped entries are real pages and
    # every live row covers its compressed depth
    nc_rows = np.asarray([sched._n_comp[s] if s in live else 0
                          for s in range(sched.n_slots)])
    validate_block_table(bt, sched.n_pages + 1,
                         page_tokens=sched.page_tokens, n_compressed=nc_rows)
    # host mirrors track the device counters exactly
    w = np.asarray(sched.cache["w_len"])
    nc = np.asarray(sched.cache["n_compressed"])
    for s in live:
        assert sched._w_len[s] == int(w[s]), (s, sched._w_len[s], int(w[s]))
        assert sched._n_comp[s] == int(nc[s])


def _run_trace(seed, n_requests, page_tokens, n_slots=2, n_pages=None,
               share_prefix=False, prefill_chunk=None, prefix_len=0,
               prefill_budget=None, pack_prefill=False):
    arrivals, reqs = _make_trace(seed, n_requests, prefix_len=prefix_len)
    sched = Scheduler(CFG, PARAMS, n_slots=n_slots,
                      max_total_tokens=MAX_TOTAL,
                      page_tokens=page_tokens, n_pages=n_pages,
                      share_prefix=share_prefix, prefill_chunk=prefill_chunk,
                      prefill_budget=prefill_budget,
                      pack_prefill=pack_prefill,
                      debug_invariants=True)
    i = 0
    guard = 0
    while i < n_requests or sched.has_work:
        while i < n_requests and arrivals[i] <= sched.step_count:
            sched.submit(reqs[i])
            i += 1
        sched.step()
        _assert_no_aliasing(sched)
        guard += 1
        assert guard < 2000, "trace did not drain (deadlock?)"
    return sched, reqs


def _check_drained(sched, reqs):
    assert all(r.done for r in reqs)
    assert sched.slots == [None] * sched.n_slots
    # no page leaked: nothing reserved; under sharing the prefix index may
    # hold cached pages (exactly its entries, counted uniquely) and must
    # give the whole free list back when cleared — zero refcount leaks
    assert sched.allocator.n_reserved == 0
    if sched.share_prefix:
        held = sched.prefix.held_pages
        assert sched.allocator.in_use == len(set(held)), \
            (sched.allocator.in_use, held)
        sched.prefix.clear(sched.allocator)
    assert sched.allocator.in_use == 0
    assert sorted(sched.allocator._free) == list(range(sched.n_pages))
    bt = np.asarray(sched.cache["block_table"])
    assert (bt < 0).all(), "retired slots left mapped block-table rows"
    # solo-lockstep equivalence per request
    for r in reqs:
        want = _solo_tokens(tuple(int(t) for t in r.prompt),
                            r.max_new_tokens, r.eos_token_id)
        assert r.output_tokens == want, (r.uid, r.output_tokens, want)


@pytest.mark.parametrize("seed,page_mult", [(0, 1), (1, 2)])
def test_fuzz_trace_paged_invariants(seed, page_mult):
    sched, reqs = _run_trace(seed, n_requests=5,
                             page_tokens=page_mult * TT)
    _check_drained(sched, reqs)
    assert sched.allocator.peak_in_use > 0     # pages actually cycled


def test_fuzz_overcommitted_pool_still_drains():
    """A page pool far below contiguous capacity (n_pages=3 vs the full
    n_slots·max_pages) forces admission to wait on page budget — the trace
    must still drain leak-free with solo-equivalent outputs, just slower."""
    sched, reqs = _run_trace(seed=2, n_requests=5, page_tokens=TT, n_pages=3)
    _check_drained(sched, reqs)


def test_fuzz_shared_prefix_trace():
    """Common-prefix trace with sharing on: solo-equivalent outputs, later
    arrivals actually alias prefix pages, refcount leaks zero after the
    drain (and ``debug_invariants`` asserts every decode's write target has
    refcount 1 — the CoW rule — throughout)."""
    sched, reqs = _run_trace(seed=3, n_requests=5, page_tokens=TT,
                             share_prefix=True, prefix_len=40)
    _check_drained(sched, reqs)
    assert sched.prefix.hits > 0, "no prefix page was ever shared"
    assert sched.shared_admissions >= 1
    assert any(r.shared_prefix_tokens > 0 for r in reqs)


def test_fuzz_shared_prefix_cow_fires():
    """With page_tokens=2·tile the shared prefix ends in a partially-filled
    boundary page, so compactions past it MUST copy-on-write (the
    write-target assert inside the scheduler would trip otherwise)."""
    sched, reqs = _run_trace(seed=7, n_requests=4, page_tokens=2 * TT,
                             share_prefix=True, prefix_len=40)
    _check_drained(sched, reqs)
    assert sched.cow_count >= 1, "boundary page was never copied-on-write"


def test_fuzz_chunked_prefill_trace():
    """Chunked admissions interleaved with decode: same invariants, plus
    the decode-stall budget — no engine step ran more than prefill_chunk
    prefill tokens."""
    sched, reqs = _run_trace(seed=4, n_requests=5, page_tokens=TT,
                             prefill_chunk=8)
    _check_drained(sched, reqs)
    assert 0 < sched.max_prefill_step_tokens <= 8


def test_fuzz_packed_prefill_trace():
    """Packed multi-admission chunks: same invariants and solo-equivalent
    outputs, and the per-step executed-prefill-token bound still holds —
    now against the aggregate ``prefill_budget``, not one chunk."""
    budget = 24
    sched, reqs = _run_trace(seed=9, n_requests=6, page_tokens=TT,
                             n_slots=3, prefill_chunk=8,
                             prefill_budget=budget, pack_prefill=True)
    _check_drained(sched, reqs)
    assert 0 < sched.max_prefill_step_tokens <= budget
    # the trace's burst phase actually packed >1 admission into one step
    assert sched.max_prefill_step_tokens > 8, \
        "no step ever packed more than one chunk — trace too sparse"


def test_fuzz_packed_shared_prefix_trace():
    """Packing composed with prefix sharing on a common-prefix trace."""
    sched, reqs = _run_trace(seed=10, n_requests=5, page_tokens=TT,
                             n_slots=3, share_prefix=True, prefix_len=40,
                             prefill_chunk=8, prefill_budget=16,
                             pack_prefill=True)
    _check_drained(sched, reqs)
    assert sched.prefix.hits > 0
    assert 0 < sched.max_prefill_step_tokens <= 16


def test_fuzz_shared_and_chunked_trace():
    """Sharing and chunked prefill composed on one trace."""
    sched, reqs = _run_trace(seed=8, n_requests=5, page_tokens=TT,
                             share_prefix=True, prefill_chunk=8,
                             prefix_len=40)
    _check_drained(sched, reqs)
    assert sched.prefix.hits > 0
    assert 0 < sched.max_prefill_step_tokens <= 8


def test_fuzz_hypothesis_variant():
    """Property-based trace fuzz over page size, arrival pattern, sharing
    and chunking (locally skipped without hypothesis; in CI a hard
    requirement via CI_REQUIRE_HYPOTHESIS=1)."""
    import_hypothesis()
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(min_value=10, max_value=10 ** 6),
           page_mult=st.sampled_from([1, 2]),
           n_requests=st.integers(min_value=2, max_value=4),
           share=st.booleans(),
           chunk=st.sampled_from([None, 8]))
    def prop(seed, page_mult, n_requests, share, chunk):
        sched, reqs = _run_trace(seed, n_requests,
                                 page_tokens=page_mult * TT,
                                 share_prefix=share, prefill_chunk=chunk,
                                 prefix_len=40 if share else 0)
        _check_drained(sched, reqs)
        if chunk is not None:
            assert sched.max_prefill_step_tokens <= chunk

    prop()


@pytest.mark.parametrize("seed", [31, 33])
def test_fuzz_preemption_trace(seed):
    """Random preempt/restore cycles: mixed-priority arrivals on an
    overcommitted pool under ``admission_policy='preempt'``. Every
    preempted request must still match its solo lockstep run bit-exactly
    (the swap round-trip through the host spool is byte-preserving), every
    preemption must be matched by a restore, and after the drain NOTHING
    leaks — pages, reservations, or spool entries."""
    rng = np.random.default_rng(seed)
    n_requests = 5
    arrivals = np.cumsum(rng.poisson(4.0, size=n_requests)).astype(int)
    eos = CFG.vocab_size - 1
    reqs = []
    for i in range(n_requests):
        # totals of 54/61 tokens -> 2-3 page worst cases, so a 4-page pool
        # cannot hold two concurrent decoders: every higher-priority
        # arrival against a busy pool must preempt
        plen = PROMPT_LENS[-1] if i == 0 else int(rng.choice((14, 21)))
        gen = 40
        prompt = rng.integers(0, CFG.vocab_size, size=plen)
        reqs.append(Request(prompt=np.asarray(prompt, np.int64),
                            max_new_tokens=gen, eos_token_id=eos,
                            priority=i % 2))
    sched = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT, n_pages=4,
                      admission_policy="preempt", debug_invariants=True)
    i = 0
    guard = 0
    while i < n_requests or sched.has_work:
        while i < n_requests and arrivals[i] <= sched.step_count:
            sched.submit(reqs[i])
            i += 1
        sched.step()
        _assert_no_aliasing(sched)
        guard += 1
        assert guard < 2000, "preemption trace did not drain (thrash?)"
    assert all(r.done for r in reqs)
    assert sched.preempt_count >= 1, "trace never preempted — resize it"
    assert sched.restore_count == sched.preempt_count
    assert sched.spool.n_entries == 0, "host spool leaked swap entries"
    assert sched.allocator.n_reserved == 0
    assert sched.allocator.in_use == 0
    assert sorted(sched.allocator._free) == list(range(sched.n_pages))
    for r in reqs:
        want = _solo_tokens(tuple(int(t) for t in r.prompt),
                            r.max_new_tokens, r.eos_token_id)
        assert r.output_tokens == want, (r.uid, r.preempt_count)


def test_fuzz_prefix_save_load_round_trip():
    """After a shared-prefix fuzz trace, ``save()``/``load()`` must round-
    trip the whole index: a freshly loaded index reports IDENTICAL
    potential prefix coverage for every prompt in the trace, and loading
    under the wrong fingerprint raises."""
    sched, reqs = _run_trace(seed=12, n_requests=4, page_tokens=TT,
                             share_prefix=True, prefix_len=40, n_pages=6)
    path = os.path.join(tempfile.mkdtemp(), "prefix_cache.pkl")
    n_saved = sched.save_prefix_cache(path)
    assert n_saved >= 1
    fp = cache_mod.prefix_cache_fingerprint(CFG, sched.page_tokens)
    loaded = cache_mod.PrefixIndex(sched.page_tokens)
    assert loaded.load(path, fp) == n_saved
    for r in reqs:
        comp, _ = cache_mod.prefill_split(CFG, len(r.prompt))
        assert loaded.probe(r.prompt, comp) \
            == sched.prefix.probe(r.prompt, comp)
    with pytest.raises(ValueError, match="fingerprint"):
        cache_mod.PrefixIndex(sched.page_tokens).load(
            path, dict(fp, key_sparsity=0.123))
    _check_drained(sched, reqs)
    assert sched.spool.n_entries == 0    # clear() dropped spooled bytes too


def test_zero_max_new_tokens_budget_covers_prefill():
    """max_new_tokens=0 still emits the prefill token, and a long prompt's
    prefill can compress multiple pages — the admission budget must cover
    that fill rather than under-reserving via ``prompt + 0`` (regression:
    the second draw() used to steal another request's promise)."""
    rng = np.random.default_rng(6)
    # prompt = local_window + 2·tile -> prefill compresses 2 pages (pt=16)
    big = Request(prompt=rng.integers(0, CFG.vocab_size, size=8 + 2 * TT),
                  max_new_tokens=0)
    other = Request(prompt=rng.integers(0, CFG.vocab_size, size=9),
                    max_new_tokens=4)
    sched = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT)
    sched.submit(big)
    sched.submit(other)
    sched.run()
    assert big.done and len(big.output_tokens) == 1
    assert other.done and len(other.output_tokens) == 4
    assert sched.allocator.in_use == 0
    assert sched.allocator.n_reserved == 0


@pytest.mark.parametrize("seed,share,chunk", [(21, False, None),
                                              (22, True, 8)])
def test_fuzz_instrumentation_changes_nothing(seed, share, chunk):
    """Default-on telemetry is OBSERVATION only: the same seeded trace
    served with the full instrumentation stack (metrics registry + event
    tracer) and with it disabled (``NullRegistry``, no tracer) must
    produce bit-identical output tokens AND identical allocator end
    state — page accounting, peak, free list. The tracer's timeline must
    also validate as Chrome trace-event JSON with every request closed."""
    from repro.obs import EventTracer, NullRegistry, validate_chrome_trace

    def run(registry, tracer):
        arrivals, reqs = _make_trace(seed, 5,
                                     prefix_len=40 if share else 0)
        sched = Scheduler(CFG, PARAMS, n_slots=2,
                          max_total_tokens=MAX_TOTAL, page_tokens=TT,
                          share_prefix=share, prefill_chunk=chunk,
                          registry=registry, tracer=tracer)
        i = 0
        while i < 5 or sched.has_work:
            while i < 5 and arrivals[i] <= sched.step_count:
                sched.submit(reqs[i])
                i += 1
            sched.step()
            assert sched.step_count < 2000
        return sched, reqs

    tracer = EventTracer()
    s_on, r_on = run(None, tracer)          # default registry, traced
    s_off, r_off = run(NullRegistry(), None)
    assert [r.output_tokens for r in r_on] \
        == [r.output_tokens for r in r_off], "instrumentation moved tokens"
    assert s_on.allocator.peak_in_use == s_off.allocator.peak_in_use
    assert sorted(s_on.allocator._free) == sorted(s_off.allocator._free)
    assert s_on.allocator.in_use == s_off.allocator.in_use
    assert s_on.step_count == s_off.step_count
    # the null path really recorded nothing; the live path really did
    assert s_off.obs.snapshot() == {"counters": {}, "gauges": {},
                                    "histograms": {}}
    snap = s_on.obs.snapshot()
    assert snap["counters"]["engine.finished"] == 5
    assert snap["histograms"]["step/step_s"]["count"] == s_on.step_count
    counts = validate_chrome_trace(tracer.events)
    assert counts["async"] == 5              # every request span closed


def test_heterogeneous_trace_page_bytes_beat_contiguous():
    """The paging payoff, asserted: on a heterogeneous-length trace the
    peak drawn-page bytes stay >= 20% below the contiguous per-slot pool
    allocation (the BENCH_paging.json acceptance bar, in-miniature)."""
    from repro.serving.cache import page_bytes, plan_pools

    rng = np.random.default_rng(5)
    # one long request, several short ones — contiguous sizing pays the
    # long request's pool for every slot
    reqs = [Request(prompt=rng.integers(0, CFG.vocab_size, size=30),
                    max_new_tokens=60)]
    reqs += [Request(prompt=rng.integers(0, CFG.vocab_size, size=9),
                     max_new_tokens=4) for _ in range(5)]
    sched = Scheduler(CFG, PARAMS, n_slots=3, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT)
    for r in reqs:
        sched.submit(r)
    sched.run()
    assert all(r.done for r in reqs)
    pb = page_bytes(CFG, TT)
    Tc_max, _ = plan_pools(CFG, MAX_TOTAL, batch=3)
    contig_bytes = 3 * (Tc_max // TT) * pb
    paged_bytes = sched.allocator.peak_in_use * pb \
        + 4 * 3 * sched.max_pages
    saving = 1.0 - paged_bytes / contig_bytes
    assert saving >= 0.2, f"paging saved only {saving*100:.1f}%"
