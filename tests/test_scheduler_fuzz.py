"""Scheduler trace fuzz: seeded random arrival/length/EOS traces through the
PAGED continuous-batching scheduler, asserting the three allocator-level
invariants the paged pools stand on:

  * per-request SOLO-LOCKSTEP EQUIVALENCE — every request's output tokens
    match running it alone through the contiguous lockstep path (on CPU the
    paged read path is a gather view, so this is exact);
  * NO PAGE LEAKS — after all retirements the free list holds every page
    again and no reservations remain;
  * NO BLOCK-TABLE ALIASING — at every step, no physical page is mapped by
    two live slots (in the device block table or the host mirrors), and
    host mirrors track the device counters exactly.

A hypothesis variant fuzzes the trace parameters behind the repo's usual
importorskip; the numpy-seeded traces below always run.
"""
import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.serving.engine import Request, Scheduler, decode_step, prefill

KEY = jax.random.PRNGKey(0)
CFG = get_config("starcoder2-3b").reduced().with_sparsity(0.5, 0.5)
PARAMS = init_params(KEY, CFG)
MAX_TOTAL = 96
TT = CFG.mustafar.tile_tokens

# bucketed prompt lengths so prefill executables amortize across cases
PROMPT_LENS = (7, 9, 14, 21)
GEN_LENS = (3, 5, 9, 14)

_SOLO_CACHE = {}


def _solo_tokens(prompt_key, n_new, eos):
    """Contiguous lockstep reference run (memoised across traces)."""
    key = (prompt_key, n_new, eos)
    if key in _SOLO_CACHE:
        return _SOLO_CACHE[key]
    prompt = jnp.asarray(prompt_key, jnp.int32)
    lg, cache = prefill(PARAMS, prompt[None], CFG, max_total_tokens=MAX_TOTAL)
    toks = [int(jnp.argmax(lg[0]))]
    step = jax.jit(lambda p, t, c: decode_step(p, t, c, CFG))
    while len(toks) < n_new and toks[-1] != eos:
        lg, cache = step(PARAMS, jnp.asarray([toks[-1]], jnp.int32), cache)
        toks.append(int(jnp.argmax(lg[0])))
    _SOLO_CACHE[key] = toks
    return toks


def _make_trace(seed, n_requests):
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.poisson(1.2, size=n_requests)).astype(int)
    reqs = []
    for i in range(n_requests):
        # request 0 is always deep enough to compact (window fills at
        # local_window + tile = 24 tokens), so every trace exercises a
        # lazy page draw; the rest are random
        plen = PROMPT_LENS[-1] if i == 0 \
            else int(rng.choice(PROMPT_LENS))
        prompt = tuple(int(t) for t in rng.integers(
            0, CFG.vocab_size, size=plen))
        gen = GEN_LENS[-1] if i == 0 else int(rng.choice(GEN_LENS))
        # an in-vocab EOS that random prompts are unlikely to hit, except
        # for a third of requests where we plant the solo run's 2nd token
        # so EOS retirement genuinely fires mid-generation
        eos = CFG.vocab_size - 1
        if i % 3 == 2 and gen >= 3:      # never the deep request 0
            solo = _solo_tokens(prompt, gen, CFG.vocab_size - 1)
            if len(solo) >= 2:
                eos = solo[1]
        reqs.append(Request(prompt=np.asarray(prompt, np.int64),
                            max_new_tokens=gen, eos_token_id=eos))
    return arrivals, reqs


def _assert_no_aliasing(sched):
    live = [s for s, r in enumerate(sched.slots) if r is not None]
    # host-side drawn pages must be disjoint across live slots
    drawn = [p for s in live for p in sched._slot_pages[s]]
    assert len(drawn) == len(set(drawn)), f"host page aliasing: {drawn}"
    # device block-table rows of live slots must not share mapped entries
    bt = np.asarray(sched.cache["block_table"])
    mapped = [p for s in live for p in bt[s] if p >= 0]
    assert len(mapped) == len(set(mapped)), f"block-table aliasing: {mapped}"
    # host mirrors track the device counters exactly
    w = np.asarray(sched.cache["w_len"])
    nc = np.asarray(sched.cache["n_compressed"])
    for s in live:
        assert sched._w_len[s] == int(w[s]), (s, sched._w_len[s], int(w[s]))
        assert sched._n_comp[s] == int(nc[s])


def _run_trace(seed, n_requests, page_tokens, n_slots=2, n_pages=None):
    arrivals, reqs = _make_trace(seed, n_requests)
    sched = Scheduler(CFG, PARAMS, n_slots=n_slots,
                      max_total_tokens=MAX_TOTAL,
                      page_tokens=page_tokens, n_pages=n_pages)
    i = 0
    guard = 0
    while i < n_requests or sched.has_work:
        while i < n_requests and arrivals[i] <= sched.step_count:
            sched.submit(reqs[i])
            i += 1
        sched.step()
        _assert_no_aliasing(sched)
        guard += 1
        assert guard < 2000, "trace did not drain (deadlock?)"
    return sched, reqs


def _check_drained(sched, reqs):
    assert all(r.done for r in reqs)
    assert sched.slots == [None] * sched.n_slots
    # no page leaked: free-list cardinality restored, nothing reserved
    assert sched.allocator.in_use == 0
    assert sched.allocator.n_reserved == 0
    assert sorted(sched.allocator._free) == list(range(sched.n_pages))
    bt = np.asarray(sched.cache["block_table"])
    assert (bt < 0).all(), "retired slots left mapped block-table rows"
    # solo-lockstep equivalence per request
    for r in reqs:
        want = _solo_tokens(tuple(int(t) for t in r.prompt),
                            r.max_new_tokens, r.eos_token_id)
        assert r.output_tokens == want, (r.uid, r.output_tokens, want)


@pytest.mark.parametrize("seed,page_mult", [(0, 1), (1, 2)])
def test_fuzz_trace_paged_invariants(seed, page_mult):
    sched, reqs = _run_trace(seed, n_requests=5,
                             page_tokens=page_mult * TT)
    _check_drained(sched, reqs)
    assert sched.allocator.peak_in_use > 0     # pages actually cycled


def test_fuzz_overcommitted_pool_still_drains():
    """A page pool far below contiguous capacity (n_pages=3 vs the full
    n_slots·max_pages) forces admission to wait on page budget — the trace
    must still drain leak-free with solo-equivalent outputs, just slower."""
    sched, reqs = _run_trace(seed=2, n_requests=5, page_tokens=TT, n_pages=3)
    _check_drained(sched, reqs)


def test_fuzz_hypothesis_variant():
    """Property-based trace fuzz (skipped without hypothesis, like
    tests/test_property_system.py)."""
    pytest.importorskip("hypothesis",
                        reason="property fuzz needs hypothesis "
                               "(pip install -r requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=3, deadline=None)
    @given(seed=st.integers(min_value=10, max_value=10 ** 6),
           page_mult=st.sampled_from([1, 2]),
           n_requests=st.integers(min_value=2, max_value=4))
    def prop(seed, page_mult, n_requests):
        sched, reqs = _run_trace(seed, n_requests,
                                 page_tokens=page_mult * TT)
        _check_drained(sched, reqs)

    prop()


def test_zero_max_new_tokens_budget_covers_prefill():
    """max_new_tokens=0 still emits the prefill token, and a long prompt's
    prefill can compress multiple pages — the admission budget must cover
    that fill rather than under-reserving via ``prompt + 0`` (regression:
    the second draw() used to steal another request's promise)."""
    rng = np.random.default_rng(6)
    # prompt = local_window + 2·tile -> prefill compresses 2 pages (pt=16)
    big = Request(prompt=rng.integers(0, CFG.vocab_size, size=8 + 2 * TT),
                  max_new_tokens=0)
    other = Request(prompt=rng.integers(0, CFG.vocab_size, size=9),
                    max_new_tokens=4)
    sched = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT)
    sched.submit(big)
    sched.submit(other)
    sched.run()
    assert big.done and len(big.output_tokens) == 1
    assert other.done and len(other.output_tokens) == 4
    assert sched.allocator.in_use == 0
    assert sched.allocator.n_reserved == 0


def test_heterogeneous_trace_page_bytes_beat_contiguous():
    """The paging payoff, asserted: on a heterogeneous-length trace the
    peak drawn-page bytes stay >= 20% below the contiguous per-slot pool
    allocation (the BENCH_paging.json acceptance bar, in-miniature)."""
    from repro.serving.cache import page_bytes, plan_pools

    rng = np.random.default_rng(5)
    # one long request, several short ones — contiguous sizing pays the
    # long request's pool for every slot
    reqs = [Request(prompt=rng.integers(0, CFG.vocab_size, size=30),
                    max_new_tokens=60)]
    reqs += [Request(prompt=rng.integers(0, CFG.vocab_size, size=9),
                     max_new_tokens=4) for _ in range(5)]
    sched = Scheduler(CFG, PARAMS, n_slots=3, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT)
    for r in reqs:
        sched.submit(r)
    sched.run()
    assert all(r.done for r in reqs)
    pb = page_bytes(CFG, TT)
    Tc_max, _ = plan_pools(CFG, MAX_TOTAL, batch=3)
    contig_bytes = 3 * (Tc_max // TT) * pb
    paged_bytes = sched.allocator.peak_in_use * pb \
        + 4 * 3 * sched.max_pages
    saving = 1.0 - paged_bytes / contig_bytes
    assert saving >= 0.2, f"paging saved only {saving*100:.1f}%"
