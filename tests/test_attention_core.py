"""Mustafar decode attention: oracle equivalence, chunked == two-pass,
window masking, GQA grouping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import (MustafarCacheView, decode_attention_dense,
                                  decode_attention_mustafar,
                                  decode_attention_mustafar_chunked)
from repro.core.sparse_format import pack_fixedk, topk_mask
from repro.models.attention import chunked_attention, causal_attention
from repro.configs import get_config


def _cache(rng, B=2, Hkv=2, Tc=128, W=16, d=128, k=64):
    kc = jnp.asarray(rng.normal(size=(B, Hkv, Tc, d)).astype(np.float32))
    vc = jnp.asarray(rng.normal(size=(B, Hkv, Tc, d)).astype(np.float32))
    km, vm = topk_mask(kc, k), topk_mask(vc, k)
    kv_, kb_ = pack_fixedk(kc, km, k)
    vv_, vb_ = pack_fixedk(vc, vm, k)
    kw = jnp.asarray(rng.normal(size=(B, Hkv, W, d)).astype(np.float32))
    vw = jnp.asarray(rng.normal(size=(B, Hkv, W, d)).astype(np.float32))
    view = MustafarCacheView(kv_, kb_, vv_, vb_,
                             jnp.array([Tc, Tc // 2]), kw, vw,
                             jnp.array([W, 3]))
    pruned = (jnp.where(km, kc, 0), jnp.where(vm, vc, 0), kw, vw)
    return view, pruned


def test_mustafar_equals_dense_on_pruned(rng):
    """Two-part attention over (compressed ⊕ window) == dense attention over
    the concatenated pruned cache (per-sequence lengths respected)."""
    view, (kp, vp, kw, vw) = _cache(rng)
    B, Hkv, Tc, d = kp.shape
    q = jnp.asarray(rng.normal(size=(B, 4, d)).astype(np.float32))
    out = decode_attention_mustafar(q, view)
    for b in range(B):
        n_c = int(view.n_compressed[b])
        n_w = int(view.n_window[b])
        kk = jnp.concatenate([kp[b:b+1, :, :n_c], kw[b:b+1, :, :n_w]], axis=2)
        vv = jnp.concatenate([vp[b:b+1, :, :n_c], vw[b:b+1, :, :n_w]], axis=2)
        ref = decode_attention_dense(q[b:b+1], kk, vv,
                                     jnp.array([n_c + n_w]))
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(ref[0]),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("chunk", [32, 64, 128])
def test_chunked_equals_two_pass(rng, chunk):
    view, _ = _cache(rng, Tc=128)
    q = jnp.asarray(rng.normal(size=(2, 4, 128)).astype(np.float32))
    o1 = decode_attention_mustafar(q, view)
    o2 = decode_attention_mustafar_chunked(q, view, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)


def test_chunked_causal_attention_matches_full(rng):
    cfg = get_config("starcoder2-3b").reduced()
    B, T, Hq, Hkv, dh = 2, 256, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = jnp.asarray(rng.normal(size=(B, T, Hq, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, T, Hkv, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, T, Hkv, dh)).astype(np.float32))
    full = causal_attention(q, k, v, cfg)          # T<1024: direct path
    chk = chunked_attention(q, k, v, cfg, causal=True, chunk=64)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chk),
                               rtol=2e-5, atol=2e-5)


def test_chunked_attention_grad_finite(rng):
    cfg = get_config("starcoder2-3b").reduced()
    B, T = 1, 128
    q = jnp.asarray(rng.normal(size=(B, T, 4, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, T, 4, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, T, 4, 32)).astype(np.float32))
    g = jax.grad(lambda q: jnp.sum(
        chunked_attention(q, k, v, cfg, causal=True, chunk=32)))(q)
    assert np.isfinite(np.asarray(g)).all()
