"""Per-arch smoke tests (reduced configs): forward/train step on CPU,
output shapes, no NaNs — one per assigned architecture, as required."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, TrainConfig, get_config
from repro.models import forward_train, init_params, lm_loss
from repro.training import init_train_state, make_train_step
from repro.training.data import synthetic_batch


def _batch(cfg, key, B=2, T=32):
    batch = synthetic_batch(0, 0, B, T, cfg)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    state = init_train_state(key, cfg)
    batch = _batch(cfg, key)
    # forward: shape + finiteness
    extra = {k: batch[k] for k in ("frames", "patches") if k in batch}
    logits, aux = forward_train(state.params, batch["tokens"], cfg, extra=extra)
    T_total = batch["tokens"].shape[1] + (
        cfg.n_vision_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, T_total, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # one full train step
    step = jax.jit(make_train_step(cfg, TrainConfig()))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    l0 = jax.tree.leaves(state.params)[0]
    l1 = jax.tree.leaves(state2.params)[0]
    assert not np.allclose(np.asarray(l0, np.float32),
                           np.asarray(l1, np.float32))


def test_grad_flows_to_all_params():
    cfg = get_config("jamba-1.5-large-398b").reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = _batch(cfg, key)
    grads = jax.grad(lambda p: lm_loss(p, batch, cfg)[0])(params)
    flat = jax.tree_util.tree_flatten_with_path(grads)[0]
    dead = [jax.tree_util.keystr(p) for p, g in flat
            if float(jnp.max(jnp.abs(g.astype(jnp.float32)))) == 0.0]
    # router aux paths may be zero-grad on tiny batches; core weights must not
    assert not any(("wq" in d or "up" in d or "tokens" in d) for d in dead), dead


def test_rwkv_decay_in_range():
    """Finch data-dependent decay stays in (0,1) — recurrence stability."""
    cfg = get_config("rwkv6-7b").reduced()
    from repro.models import rwkv as rwkv_mod
    key = jax.random.PRNGKey(0)
    p = rwkv_mod.init_rwkv_time_mix(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32) * 3
    st = rwkv_mod.rwkv_state_shapes(cfg, 2)
    out, (shift, wkv) = rwkv_mod.rwkv_time_mix(
        p, x.astype(jnp.bfloat16), cfg,
        jnp.zeros(st["tm_shift"], jnp.bfloat16),
        jnp.zeros(st["wkv"], jnp.float32))
    assert np.isfinite(np.asarray(out, np.float32)).all()
    assert np.isfinite(np.asarray(wkv)).all()


def test_jamba_layer_pattern():
    cfg = get_config("jamba-1.5-large-398b")
    kinds = [cfg.layer_kind(i) for i in range(16)]
    assert kinds.count("attn") == 2                       # 1:7 ratio
    assert kinds[4] == "attn" and kinds[12] == "attn"
    ffns = [cfg.ffn_kind(i) for i in range(16)]
    assert ffns.count("moe") == 8                         # every other layer
