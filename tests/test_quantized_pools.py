"""Int8 quantized sparse page pools (PR 10).

What this module pins down:

  * FORMAT TRANSPARENCY — switching ``pool_dtype`` from bf16 to int8
    changes the VALUE pools only: bitmap planes and block tables are
    BIT-IDENTICAL between the two (pruning decides what survives, the
    pool dtype only decides how survivors are stored).
  * ORACLE CONTRACT — dequantizing a real int8 pool reproduces the
    ``symmetric_fake_quant`` accuracy oracle bit-for-bit on the packed
    fp32 values (the KIVI-module contract from the paper's §4.2.2
    joint-application experiments).
  * SPOOL ROUND-TRIP — preempt -> restore and prefix demote -> promote
    move the int8 leaves AND their sibling fp32 scale leaves through the
    host spool byte-exactly (outputs identical to an uninterrupted int8
    run).
  * FINGERPRINT REFUSAL — a prefix cache persisted under one pool dtype
    is refused by a scheduler running the other (the compressed bytes
    would be reinterpreted wrongly).
"""
import os
import tempfile
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.quantization import symmetric_fake_quant
from repro.core.sparse_format import dequantize_fixedk, prune_and_pack
from repro.models import init_params
from repro.serving import cache as cache_mod
from repro.serving.cache import (build_layer_cache_from_prefill,
                                 gather_page_arrays, init_cache,
                                 pool_value_bytes, prefill_split,
                                 scatter_page_arrays)
from repro.serving.engine import Request, Scheduler, decode_step, prefill

KEY = jax.random.PRNGKey(0)
CFG = get_config("starcoder2-3b").reduced().with_sparsity(0.5, 0.5)
CFG_Q = replace(CFG, mustafar=replace(CFG.mustafar, pool_dtype="int8"))
PARAMS = init_params(KEY, CFG)          # weights don't depend on pool dtype
MAX_TOTAL = 96
TT = CFG.mustafar.tile_tokens           # 16 in the reduced cfg
_PREFIX_RNG = np.random.default_rng(300)
PREFIX = [int(t) for t in _PREFIX_RNG.integers(0, CFG.vocab_size, size=56)]


def _req(seed, n_prompt, gen, priority=0, prefix=()):
    r = np.random.default_rng(seed)
    prompt = list(prefix) + [int(t) for t in
                             r.integers(0, CFG.vocab_size, size=n_prompt)]
    return Request(prompt=prompt, max_new_tokens=gen, priority=priority)


def _solo_greedy(cfg, prompt, n_new):
    """Contiguous lockstep reference run under ``cfg`` (tokens only)."""
    lg, cache = prefill(PARAMS, jnp.asarray(prompt, jnp.int32)[None], cfg,
                        max_total_tokens=MAX_TOTAL)
    toks = [int(jnp.argmax(lg[0]))]
    step = jax.jit(lambda p, t, c: decode_step(p, t, c, cfg))
    while len(toks) < n_new:
        lg, cache = step(PARAMS, jnp.asarray([toks[-1]], jnp.int32), cache)
        toks.append(int(jnp.argmax(lg[0])))
    return toks


def _assert_drained_clean(sched):
    if sched.share_prefix:
        sched.prefix.clear(sched.allocator)
    assert sched.allocator.in_use == 0
    assert sched.allocator.n_reserved == 0
    assert sched.spool.n_entries == 0, "host spool leaked entries"


# ----------------------------------------------------------------------
# format transparency + oracle contract (cache level)

def test_int8_pools_bitmaps_identical_and_match_oracle(rng):
    """Build one layer's cache from the SAME dense prefill under bf16 and
    int8 pools: bitmaps must be bit-identical, and dequantizing the int8
    pool must reproduce the fake-quant oracle on the packed fp32 values."""
    B, T, Hkv, d = 2, 80, CFG.n_kv_heads, CFG.d_head
    k = jnp.asarray(rng.normal(size=(B, T, Hkv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, T, Hkv, d)).astype(np.float32))
    lc_b = build_layer_cache_from_prefill(CFG, k, v, MAX_TOTAL)
    lc_q = build_layer_cache_from_prefill(CFG_Q, k, v, MAX_TOTAL)
    comp, _ = prefill_split(CFG, T)
    assert comp > 0 and comp % TT == 0
    np.testing.assert_array_equal(np.asarray(lc_b["ck_bm"]),
                                  np.asarray(lc_q["ck_bm"]))
    np.testing.assert_array_equal(np.asarray(lc_b["cv_bm"]),
                                  np.asarray(lc_q["cv_bm"]))
    assert lc_q["ck_vals"].dtype == jnp.int8
    assert lc_q["ck_scale"].dtype == jnp.float32
    assert "ck_scale" not in lc_b and "cv_scale" not in lc_b

    m = CFG.mustafar
    for src, vals_key, sc_key, kk in (
            (jnp.swapaxes(k, 1, 2), "ck_vals", "ck_scale",
             m.keep_k(d, m.key_sparsity)),
            (jnp.swapaxes(v, 1, 2), "cv_vals", "cv_scale",
             m.keep_k(d, m.value_sparsity))):
        packed, _ = prune_and_pack(src[:, :, :comp], kk)
        oracle = np.asarray(symmetric_fake_quant(packed, TT))
        deq = np.asarray(dequantize_fixedk(
            lc_q[vals_key][:, :, :comp],
            lc_q[sc_key][:, :, :comp // TT]))
        np.testing.assert_array_equal(deq, oracle)


def test_int8_paged_engine_matches_bf16_metadata():
    """Same workload through a bf16 and an int8 paged scheduler: sampled
    outputs, block tables, and bitmap planes all bit-identical (the int8
    error at this operating point never flips a greedy argmax, and the
    paging machinery never looks inside the value pools)."""
    scheds = {}
    for name in ("bf16", "int8"):
        sched = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL,
                          page_tokens=TT, debug_invariants=True,
                          pool_dtype=name)
        for seed in (401, 402):
            sched.submit(_req(seed, 24, 12))
        for _ in range(8):                 # prefill + a few decode steps
            sched.step()
        scheds[name] = sched
    sb, sq = scheds["bf16"], scheds["int8"]
    assert sq.cfg.mustafar.pool_dtype == "int8"
    np.testing.assert_array_equal(np.asarray(sb.cache["block_table"]),
                                  np.asarray(sq.cache["block_table"]))
    for blk_b, blk_q in zip(sb.cache["blocks"], sq.cache["blocks"]):
        for key in ("ck_bm", "cv_bm"):
            if key in blk_b:
                np.testing.assert_array_equal(np.asarray(blk_b[key]),
                                              np.asarray(blk_q[key]))
        if "ck_vals" in blk_q:
            assert blk_q["ck_vals"].dtype == jnp.int8
            assert blk_b["ck_vals"].dtype == jnp.bfloat16
    sb.run()
    sq.run()
    done_b = {tuple(r.prompt): r.output_tokens for r in sb.finished}
    done_q = {tuple(r.prompt): r.output_tokens for r in sq.finished}
    assert done_b == done_q, "int8 flipped a greedy sample"
    _assert_drained_clean(sb)
    _assert_drained_clean(sq)


def test_int8_pool_bytes_halved():
    assert pool_value_bytes(CFG_Q, 64) <= 0.55 * pool_value_bytes(CFG, 64)


# ----------------------------------------------------------------------
# spool round-trips (preempt/restore, demote/promote) under int8

def test_int8_preempt_restore_bit_exact():
    """The PR 8 preemption scenario with int8 pools: the swapped-out pages
    now include int8 value leaves AND fp32 scale leaves, and the splice
    back must still be byte-exact vs an uninterrupted int8 run."""
    sched = Scheduler(CFG, PARAMS, n_slots=2, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT, n_pages=5,
                      admission_policy="preempt", debug_invariants=True,
                      pool_dtype="int8")
    bg = _req(101, 24, 56, priority=0)
    hi = _req(102, 24, 24, priority=1)
    sched.submit(bg)
    for _ in range(6):
        sched.step()
    assert bg.num_generated >= 4
    sched.submit(hi)
    sched.run()
    assert sched.preempt_count >= 1, "pool pressure never preempted"
    assert sched.restore_count == sched.preempt_count
    assert bg.output_tokens == _solo_greedy(CFG_Q, bg.prompt,
                                            bg.max_new_tokens)
    assert hi.output_tokens == _solo_greedy(CFG_Q, hi.prompt,
                                            hi.max_new_tokens)
    assert sched.spool.bytes_in > 0
    _assert_drained_clean(sched)


def test_int8_prefix_spill_promotes_back_bit_exact():
    sched = Scheduler(CFG, PARAMS, n_slots=1, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT, share_prefix=True,
                      debug_invariants=True, pool_dtype="int8")
    first = _req(131, 4, 8, prefix=PREFIX)
    sched.submit(first)
    sched.run()
    assert len(sched.prefix.held_pages) > 0
    sched.prefix.evict_until(sched.allocator, sched.n_pages,
                             spool=True, cache=sched.cache)
    assert sched.prefix.spooled_entries > 0
    second = _req(132, 6, 8, prefix=PREFIX)
    sched.submit(second)
    sched.run()
    assert second.shared_prefix_tokens > 0, "spool hit never promoted"
    assert second.output_tokens == _solo_greedy(CFG_Q, second.prompt,
                                                second.max_new_tokens)
    _assert_drained_clean(sched)


def test_page_gather_scatter_round_trips_scale_leaves(rng):
    """The spool payload for an int8 cache carries SIX pool leaves per
    layer (values + bitmaps + scales); gather -> zero -> scatter must put
    every byte back, scales included."""
    cache = init_cache(CFG_Q, 2, MAX_TOTAL, page_tokens=TT)
    bi = next(i for i, b in enumerate(cache["blocks"]) if "ck_vals" in b)
    blk = dict(cache["blocks"][bi])
    for key, leaf in blk.items():
        if key in cache_mod._POOL_KEYS:
            if leaf.dtype == jnp.int8:
                fill = rng.integers(-127, 128, size=leaf.shape)
            elif leaf.dtype == jnp.uint32:
                fill = rng.integers(0, 2**32, size=leaf.shape)
            else:
                fill = rng.normal(size=leaf.shape)
            blk[key] = jnp.asarray(fill).astype(leaf.dtype)
    blocks = list(cache["blocks"])
    blocks[bi] = blk
    cache["blocks"] = tuple(blocks)
    pages = [1, 3]
    data = gather_page_arrays(cache, pages)
    assert any(layer is not None and "ck_scale" in layer
               and "cv_scale" in layer for layer in data), \
        "scale leaves missing from spool payload"
    wiped = dict(cache)
    wiped["blocks"] = tuple(
        {k: jnp.zeros_like(v) for k, v in b.items()}
        for b in cache["blocks"])
    restored = scatter_page_arrays(wiped, data, pages)
    for key in cache_mod._POOL_KEYS:
        if key not in cache["blocks"][bi]:
            continue
        np.testing.assert_array_equal(
            np.asarray(restored["blocks"][bi][key][:, pages]),
            np.asarray(cache["blocks"][bi][key][:, pages]), err_msg=key)


# ----------------------------------------------------------------------
# fingerprint refusal

def test_prefix_load_rejects_pool_dtype_mismatch():
    donor = Scheduler(CFG, PARAMS, n_slots=1, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT, share_prefix=True)
    donor.submit(_req(151, 4, 8, prefix=PREFIX))
    donor.run()
    path = os.path.join(tempfile.mkdtemp(), "prefix_cache.pkl")
    donor.save_prefix_cache(path)
    other = Scheduler(CFG, PARAMS, n_slots=1, max_total_tokens=MAX_TOTAL,
                      page_tokens=TT, share_prefix=True, pool_dtype="int8")
    with pytest.raises(ValueError, match="fingerprint"):
        other.load_prefix_cache(path)
    _assert_drained_clean(donor)


def test_scheduler_rejects_unknown_pool_dtype():
    with pytest.raises(ValueError, match="pool_dtype"):
        Scheduler(CFG, PARAMS, n_slots=1, max_total_tokens=MAX_TOTAL,
                  pool_dtype="fp4")
