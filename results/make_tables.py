"""Render the §Roofline markdown table from results/dryrun_*.json."""
from __future__ import annotations

import glob
import json
import sys


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def lever(arch: str, shape: str, bottleneck: str, r) -> str:
    """One sentence: what would move the dominant term down."""
    if "moe" in arch or "jamba" in arch:
        moe = True
    else:
        moe = False
    if bottleneck == "memory":
        if "decode" in shape or "long" in shape:
            return ("already Mustafar-compressed; next: fuse decompress+MV "
                    "(Pallas kernel on TPU) and quantize packed values (KIVI)")
        if "train" in shape:
            return ("reduce remat recompute (dot-only save policy) and "
                    "narrow fp32 cotangents at norm/softmax boundaries")
        return "flash prefill kernel avoids K/V re-reads per query chunk"
    if bottleneck == "collective":
        if moe:
            return "overlap expert all-to-all with shared compute"
        if "prefill" in shape or "train" in shape:
            return ("overlap TP all-reduces with matmuls (latency-hiding "
                    "scheduler) and keep activation collectives bf16")
        return "shard_map compaction: owner-shard writes, no gather"
    return "increase per-device batch/seq to raise arithmetic intensity"


def main(pattern="results/dryrun_single_*.json"):
    rows = []
    for path in sorted(glob.glob(pattern)):
        for r in json.load(open(path)):
            rows.append(r)
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    print("| arch | shape | status | mem/dev | t_comp | t_mem | t_coll | "
          "bottleneck | 6ND/HLO | dominant-term lever |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        arch, shape = r["arch"], r["shape"]
        if "skipped" in r:
            print(f"| {arch} | {shape} | SKIP | - | - | - | - | - | - | "
                  f"{r['skipped'][:50]} |")
            continue
        if "error" in r:
            print(f"| {arch} | {shape} | FAIL | - | - | - | - | - | - | "
                  f"{r['error'][:60]} |")
            continue
        m = r["memory"]["per_device_total"] / 2**30
        rf = r["roofline"]
        uf = r.get("useful_flops_frac")
        lv = lever(arch, shape, rf["bottleneck"], r)
        print(f"| {arch} | {shape} | ok | {m:.1f}GiB "
              f"| {fmt_s(rf['t_compute_s'])} | {fmt_s(rf['t_memory_s'])} "
              f"| {fmt_s(rf['t_collective_s'])} | {rf['bottleneck']} "
              f"| {uf:.3f} | {lv} |"
              if uf is not None else
              f"| {arch} | {shape} | ok | {m:.1f}GiB | - | - | - | - | - | |")


if __name__ == "__main__":
    main(*sys.argv[1:])
