"""Roofline drift auditing: measured telemetry vs. the analytic cost models.

The ``repro.roofline`` module models what serving *should* cost; the
``repro.obs`` registry measures what it *did* cost. ``roofline_drift``
divides the two so a cost-model-vs-reality gap is a number in every
metrics snapshot instead of a benchmark surprise. Two audits:

**Swap traffic (exact).** Spool byte counters are host-side accounting of
whole-page/whole-window transfers, and ``roofline.swap_bytes`` charges
exactly those quanta — so ``ratio`` must be **1.0 whenever any traffic
moved** (the BENCH_preemption gate, generalized here to also cover prefix
demote/promote traffic). Any other value means the byte accounting broke.

**Decode step time (approximate).** Measured decode-phase wall time
(p50 of the ``step/decode_s`` histogram) vs. the memory-bound model:
``(param bytes + MUSTAFAR compressed-cache bytes + paged block-table
metadata) / HBM_BW``. Interpretation of ``drift_ratio`` =
measured / modeled:

- ≈ 1 on TPU: decode is memory-bound at roofline bandwidth, as the paper
  claims (PAPER.md §5) — the bitmap kernel is paying for pruning.
- ≫ 1: dispatch/host overhead or kernel inefficiency dominates; on the
  CPU interpret-mode reference path this is expected to be orders of
  magnitude (the number quantifies the reference-path gap, and its TREND
  across PRs is the regression signal CI's sanity band watches).
- The model charges worst-case fill (``max_compressed_tokens`` at
  ``max_total_tokens``), so early-trace ratios read low.

Without ``--trace-sync`` the decode timer measures *dispatch* (JAX async
dispatch returns before the device finishes); the device time then drains
into whichever later phase blocks. Sync mode gives per-phase device
attribution at the cost of one ``block_until_ready`` per step.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

from repro.roofline import HBM_BW, paged_metadata_bytes, swap_bytes


def _ratio(measured: float, modeled: float) -> float:
    """measured/modeled with 0/0 defined as exact agreement (1.0)."""
    if modeled:
        return measured / modeled
    return 1.0 if not measured else math.inf


def decode_step_model(cfg, n_slots: int, max_total_tokens: int,
                      page_tokens: Optional[int] = None) -> Dict[str, Any]:
    """Modeled HBM bytes (and roofline seconds) for ONE batched decode step
    at worst-case cache fill: parameter reads + per-row MUSTAFAR cache
    traffic (``core.attention.hbm_bytes_mustafar`` — dense model when
    pruning is disabled) + paged block-table metadata."""
    import numpy as np
    from repro.core.attention import hbm_bytes_dense, hbm_bytes_mustafar
    from repro.serving.cache import (max_compressed_tokens, pool_dtype,
                                     pool_quantized)

    m = cfg.mustafar
    d = cfg.d_head
    itemsize = int(np.dtype(cfg.dtype).itemsize)
    n_attn = len(cfg.attention_layers())
    if m.enabled:
        k_k = m.keep_k(d, m.key_sparsity)
        k_v = m.keep_k(d, m.value_sparsity)
        tc = max_compressed_tokens(cfg, max_total_tokens)
        # cache term streams at the POOL width (int8 pools read half the
        # value bytes plus per-tile fp32 scales); params and the dense
        # window stay in the model dtype
        per_row = hbm_bytes_mustafar(
            tc, m.local_window + m.tile_tokens, d, k_k, k_v,
            itemsize=itemsize,
            pool_itemsize=int(np.dtype(pool_dtype(cfg)).itemsize),
            quant_tile=m.tile_tokens if pool_quantized(cfg) else None)
    else:
        per_row = hbm_bytes_dense(max_total_tokens, d, itemsize=itemsize)
    cache_bytes = n_attn * n_slots * cfg.n_kv_heads * per_row
    param_bytes = cfg.active_param_count() * itemsize
    meta_bytes = (paged_metadata_bytes(cfg, n_slots, max_total_tokens,
                                       page_tokens)
                  if page_tokens else 0)
    total = param_bytes + cache_bytes + meta_bytes
    return {
        "param_bytes": int(param_bytes),
        "cache_bytes": int(cache_bytes),
        "metadata_bytes": int(meta_bytes),
        "bytes": int(total),
        "seconds": total / HBM_BW,
    }


def roofline_drift(sched) -> Dict[str, Any]:
    """Drift report for one :class:`~repro.serving.engine.Scheduler`.

    Returns ``{"decode_step": {...}, "swap_bytes_out": {...},
    "swap_bytes_in": {...}}`` (swap sections only for paged schedulers).
    Ratios are measured/modeled; see module docstring for interpretation.
    """
    cfg = sched.cfg
    report: Dict[str, Any] = {}

    h = sched.obs.histogram("step/decode_s")
    model = decode_step_model(cfg, sched.n_slots, sched.max_total,
                              sched.page_tokens if sched.paged else None)
    p50 = h.percentile(50)
    report["decode_step"] = {
        "measured_p50_s": p50,
        "measured_mean_s": h.mean,
        "decode_steps": int(h.count),
        "modeled_s": model["seconds"],
        "modeled_bytes": model["bytes"],
        "modeled_metadata_bytes": model["metadata_bytes"],
        "drift_ratio": (p50 / model["seconds"]
                        if p50 is not None and model["seconds"] > 0
                        else None),
    }

    if sched.paged:
        pt = sched.page_tokens
        per_page = swap_bytes(cfg, pt, 1) - swap_bytes(cfg, pt, 0)
        per_event = swap_bytes(cfg, pt, 0)     # window rows + 12 counter B
        demoted = promoted = 0
        if sched.share_prefix:
            demoted = sched.prefix.demotions
            promoted = sched.prefix.promotions
        # spool byte counters exclude the 3 int32 per-slot counters (host
        # ints are 0 numpy bytes) that swap_bytes charges — add them back
        # per event, exactly as the BENCH_preemption gate does.
        measured_out = sched.spool.bytes_out + 12 * sched.preempt_count
        # a demotion spools ONE page with no window/counters: it is charged
        # page_bytes (== per_page) per demoted entry, nothing else
        modeled_out = (per_page * (sched.swapped_pages + demoted)
                       + per_event * sched.preempt_count)
        measured_in = sched.spool.bytes_in + 12 * sched.restore_count
        modeled_in = (per_page * (sched.restored_pages + promoted)
                      + per_event * sched.restore_count)
        report["swap_bytes_out"] = {
            "measured": int(measured_out),
            "modeled": int(modeled_out),
            "events": int(sched.preempt_count),
            "demotions": int(demoted),
            "ratio": _ratio(measured_out, modeled_out),
        }
        report["swap_bytes_in"] = {
            "measured": int(measured_in),
            "modeled": int(modeled_in),
            "events": int(sched.restore_count),
            "promotions": int(promoted),
            "ratio": _ratio(measured_in, modeled_in),
        }
    return report
