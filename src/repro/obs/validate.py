"""Validate an exported trace + metrics snapshot (CI ``obs-smoke`` gate).

Usage::

    python -m repro.obs.validate TRACE.json [--metrics METRICS.json]
        [--max-decode-drift 1e9] [--min-decode-drift 1e-3]

Checks:

- the trace is schema-valid Chrome trace-event JSON (well-formed, known
  ``ph`` codes, per-track monotonic timestamps, matched B/E span pairs,
  matched async b/e request spans) — ``trace.validate_chrome_trace``;
- the trace actually contains the serving vocabulary: ``step`` spans and
  request lifecycle instants;
- the metrics snapshot (``--metrics``) has per-phase step histograms with
  samples, and every roofline drift ratio is finite and inside a loose
  sanity band: swap ratios must be ~exactly 1.0 (byte accounting is
  exact), the decode-time drift ratio inside
  ``[--min-decode-drift, --max-decode-drift]`` (wide by default — the CPU
  reference path runs far off the TPU roofline; the band only catches
  NaN/inf/zero accounting breakage, see ``repro.obs.drift``).

Exits 0 and prints a summary on success; raises (exit != 0) on the first
violation.
"""

from __future__ import annotations

import argparse
import json
import math
import sys

from repro.obs.trace import load_trace, validate_chrome_trace


def validate_metrics(blob: dict, min_decode_drift: float,
                     max_decode_drift: float) -> None:
    stats = blob.get("stats", blob)
    hists = stats.get("histograms", {})
    for name in ("step/step_s", "step/decode_s"):
        h = hists.get(name)
        if not h or not h.get("count"):
            raise ValueError(f"metrics: histogram {name!r} missing or empty")
        for q in ("p50", "p90", "p99"):
            v = h.get(q)
            if v is None or not math.isfinite(v) or v < 0:
                raise ValueError(f"metrics: {name}.{q} = {v!r} not finite")
    if not stats.get("gauges"):
        raise ValueError("metrics: no gauges recorded")
    drift = blob.get("roofline_drift")
    if drift is None:
        raise ValueError("metrics: no roofline_drift section")
    dec = drift.get("decode_step", {})
    ratio = dec.get("drift_ratio")
    if ratio is None or not math.isfinite(ratio):
        raise ValueError(f"drift: decode drift_ratio = {ratio!r} not finite")
    if not min_decode_drift <= ratio <= max_decode_drift:
        raise ValueError(
            f"drift: decode drift_ratio {ratio:.3g} outside sanity band "
            f"[{min_decode_drift:g}, {max_decode_drift:g}]")
    for key in ("swap_bytes_out", "swap_bytes_in"):
        sec = drift.get(key)
        if sec is None:
            continue                       # contiguous run: no swap audit
        r = sec.get("ratio")
        if r is None or not math.isfinite(r):
            raise ValueError(f"drift: {key}.ratio = {r!r} not finite")
        if abs(r - 1.0) > 1e-9:
            raise ValueError(
                f"drift: {key}.ratio = {r!r} != 1.0 — spool byte "
                f"accounting no longer matches roofline.swap_bytes "
                f"(measured {sec.get('measured')}, "
                f"modeled {sec.get('modeled')})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Validate repro.obs trace/metrics artifacts")
    ap.add_argument("trace", help="Chrome trace-event JSON path")
    ap.add_argument("--metrics", default=None,
                    help="metrics JSON path (from --metrics-json)")
    ap.add_argument("--min-decode-drift", type=float, default=1e-3)
    ap.add_argument("--max-decode-drift", type=float, default=1e9)
    args = ap.parse_args(argv)

    events = load_trace(args.trace)
    counts = validate_chrome_trace(events)
    names = {ev["name"] for ev in events}
    required = {"step", "decode", "submit", "admit", "finish"}
    missing = required - names
    if missing:
        raise ValueError(f"trace: missing expected event names {missing!r}")
    print(f"trace OK: {counts['events']} events, {counts['spans']} spans, "
          f"{counts['instants']} instants, {counts['async']} request spans")

    if args.metrics:
        with open(args.metrics) as f:
            blob = json.load(f)
        validate_metrics(blob, args.min_decode_drift, args.max_decode_drift)
        drift = blob.get("roofline_drift", {})
        dec = drift.get("decode_step", {})
        print(f"metrics OK: decode drift {dec.get('drift_ratio'):.3g} "
              f"over {dec.get('decode_steps')} steps"
              + (f", swap ratio out/in = "
                 f"{drift['swap_bytes_out']['ratio']:.6f}/"
                 f"{drift['swap_bytes_in']['ratio']:.6f}"
                 if "swap_bytes_out" in drift else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
