"""Structured event tracer exporting Chrome trace-event JSON.

The exported file loads directly in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``. Event vocabulary (trace-event ``ph`` codes):

- ``B``/``E`` duration spans — scheduler phases (``step``, ``admit``,
  ``prefill``, ``provision``, ``compaction``, ``decode``, ``sample``,
  ``preempt_out``, ``restore_in``). Strict stack discipline per
  (pid, tid): every ``E`` closes the most recent open ``B``.
- ``i`` instant events — request lifecycle markers (``submit``,
  ``admit``, ``first_token``, ``finish``, ``reject``, ``preempt``,
  ``restore``) and prefill ``chunk`` boundaries, each carrying the
  request uid in ``args``.
- ``b``/``e`` async spans (cat ``request``, id = request uid) — the
  submit→finish lifetime of each request, rendered by Perfetto as one
  horizontal track segment per request.

Timestamps are ``time.perf_counter`` microseconds relative to tracer
construction — monotonic by construction. Recording an event is one dict
append; there is deliberately no flushing, file IO, or locking on the hot
path (export happens once, after the run). Engines in a multi-engine
``Router`` share one tracer with distinct ``tid``s so their timelines
render as separate rows.
"""

from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Any, Dict, List


class EventTracer:
    """Append-only trace-event recorder (see module docstring)."""

    def __init__(self):
        self._t0 = time.perf_counter()
        self.events: List[Dict[str, Any]] = []

    def _ts(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6   # µs

    def begin(self, name: str, tid: int = 0, **args) -> None:
        ev = {"name": name, "ph": "B", "ts": self._ts(), "pid": 0, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def end(self, name: str, tid: int = 0) -> None:
        self.events.append(
            {"name": name, "ph": "E", "ts": self._ts(), "pid": 0, "tid": tid})

    def instant(self, name: str, tid: int = 0, **args) -> None:
        ev = {"name": name, "ph": "i", "s": "t", "ts": self._ts(),
              "pid": 0, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def async_begin(self, name: str, id: int, tid: int = 0, **args) -> None:
        ev = {"name": name, "ph": "b", "cat": "request", "id": int(id),
              "ts": self._ts(), "pid": 0, "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def async_end(self, name: str, id: int, tid: int = 0) -> None:
        self.events.append(
            {"name": name, "ph": "e", "cat": "request", "id": int(id),
             "ts": self._ts(), "pid": 0, "tid": tid})

    @contextmanager
    def span(self, name: str, tid: int = 0, **args):
        self.begin(name, tid=tid, **args)
        try:
            yield
        finally:
            self.end(name, tid=tid)

    def export(self, path: str) -> int:
        """Write ``{"traceEvents": [...]}`` JSON; returns event count."""
        with open(path, "w") as f:
            json.dump({"traceEvents": self.events,
                       "displayTimeUnit": "ms"}, f)
        return len(self.events)


def validate_chrome_trace(events: List[Dict[str, Any]]) -> Dict[str, int]:
    """Assert ``events`` is schema-valid Chrome trace-event JSON content.

    Checks (raising ``ValueError`` with the first violation):

    - every event carries ``name``/``ph``/``ts``/``pid``/``tid`` and a
      known ``ph`` code; async events also carry ``id``;
    - timestamps are finite, non-negative, and non-decreasing in record
      order per (pid, tid) track (the tracer appends in time order);
    - ``B``/``E`` pairs balance as a stack per (pid, tid), names matching
      on pop, with nothing left open at the end;
    - async ``b``/``e`` pairs balance per (cat, id, name).

    Returns summary counts for reporting.
    """
    open_spans: Dict[Any, List[str]] = {}
    open_async: Dict[Any, int] = {}
    last_ts: Dict[Any, float] = {}
    counts = {"events": 0, "spans": 0, "instants": 0, "async": 0}
    for i, ev in enumerate(events):
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"event {i} missing {key!r}: {ev!r}")
        ph = ev["ph"]
        if ph not in ("B", "E", "i", "b", "e"):
            raise ValueError(f"event {i} has unknown ph {ph!r}")
        ts = ev["ts"]
        if not isinstance(ts, (int, float)) or not ts >= 0.0 \
                or ts != ts or ts == float("inf"):
            raise ValueError(f"event {i} has bad ts {ts!r}")
        track = (ev["pid"], ev["tid"])
        if ts < last_ts.get(track, 0.0):
            raise ValueError(
                f"event {i} ts {ts} decreases on track {track} "
                f"(prev {last_ts[track]})")
        last_ts[track] = ts
        counts["events"] += 1
        if ph == "B":
            open_spans.setdefault(track, []).append(ev["name"])
        elif ph == "E":
            stack = open_spans.get(track)
            if not stack:
                raise ValueError(
                    f"event {i}: E {ev['name']!r} with no open B on "
                    f"track {track}")
            top = stack.pop()
            if top != ev["name"]:
                raise ValueError(
                    f"event {i}: E {ev['name']!r} closes B {top!r} on "
                    f"track {track}")
            counts["spans"] += 1
        elif ph == "i":
            counts["instants"] += 1
        else:                                   # async b/e
            if "id" not in ev:
                raise ValueError(f"event {i}: async {ph!r} missing id")
            akey = (ev.get("cat"), ev["id"], ev["name"])
            if ph == "b":
                open_async[akey] = open_async.get(akey, 0) + 1
            else:
                if open_async.get(akey, 0) <= 0:
                    raise ValueError(
                        f"event {i}: async end {akey!r} with no open begin")
                open_async[akey] -= 1
                counts["async"] += 1
    leftovers = {t: s for t, s in open_spans.items() if s}
    if leftovers:
        raise ValueError(f"unclosed B spans at end of trace: {leftovers!r}")
    dangling = {k: n for k, n in open_async.items() if n}
    if dangling:
        raise ValueError(f"unclosed async spans: {dangling!r}")
    return counts


def load_trace(path: str) -> List[Dict[str, Any]]:
    """Load a trace file; accepts the object form ``{"traceEvents": [...]}``
    or a bare JSON array (both valid Chrome trace inputs)."""
    with open(path) as f:
        blob = json.load(f)
    events = blob["traceEvents"] if isinstance(blob, dict) else blob
    if not isinstance(events, list):
        raise ValueError(f"{path}: traceEvents is not a list")
    return events
