"""repro.obs — dependency-free serving telemetry.

Three pieces, stdlib-only so the serving stack can depend on them
unconditionally:

- ``metrics``: Prometheus-flavoured :class:`Counter` / :class:`Gauge` /
  :class:`Histogram` primitives behind a :class:`MetricsRegistry`
  (plus :class:`NullRegistry` for the instrumentation-off A/B in the
  fuzz suite). Histograms use fixed log-spaced buckets so per-replica
  instances merge exactly in ``Router.stats()``.
- ``trace``: :class:`EventTracer`, a low-overhead structured event
  recorder that exports Chrome trace-event JSON loadable in Perfetto
  (https://ui.perfetto.dev) — scoped B/E spans for scheduler phases,
  instant events for request lifecycle, async b/e spans per request.
- ``drift`` (import the submodule explicitly): the ``roofline_drift``
  auditor comparing measured step timings / spool byte counters against
  the ``repro.roofline`` cost models.

``python -m repro.obs.validate`` checks an exported trace against the
Chrome trace-event schema and a metrics snapshot for sane drift ratios
(the CI ``obs-smoke`` job).
"""

from repro.obs.metrics import (          # noqa: F401
    Counter, Gauge, Histogram, MetricsRegistry, NullRegistry,
    TIME_BUCKETS_S, format_stats_line,
)
from repro.obs.trace import EventTracer, validate_chrome_trace  # noqa: F401
