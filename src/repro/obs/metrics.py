"""Dependency-free metrics primitives for the serving stack.

Design constraints (ISSUE 9):

- ZERO hot-path cost beyond a dict append / int add: ``Histogram.observe``
  is one ``bisect`` + two adds; counters are one add. No locks (the
  scheduler is single-threaded per engine), no background threads, no
  wall-clock reads here — timestamps belong to the tracer.
- Existing engine statistics (``preempt_count``, ``cow_count``, prefix
  ``hits``/``misses``, ...) stay authoritative as plain ints so none of
  the code that mutates them changes; the registry mirrors them through
  LAZY counters/gauges (a ``fn`` callback read at snapshot time). That is
  what makes the fuzz "instrumentation changes nothing" property trivially
  true for those paths.
- Histograms use FIXED log-spaced bucket bounds so two histograms of the
  same metric always merge exactly — ``Router.stats()`` merges per-replica
  registries into fleet totals with no resampling error beyond the shared
  bucket resolution.
"""

from __future__ import annotations

import math
from bisect import bisect_left
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

# Default bounds for time-valued histograms: 1 µs .. 100 s in quarter-decade
# steps (4 buckets per decade => ~78% worst-case relative bucket error,
# tightened by the [min, max] clamp in percentile()). 33 finite upper bounds
# + 1 overflow bucket.
TIME_BUCKETS_S: Tuple[float, ...] = tuple(
    10.0 ** (e / 4.0) * 1e-6 for e in range(33))

# Bounds for count-valued histograms (tokens, pages): powers of two.
COUNT_BUCKETS: Tuple[float, ...] = tuple(float(2 ** e) for e in range(21))


class Counter:
    """Monotonic counter. Either incremented directly (``inc``) or LAZY —
    constructed with ``fn`` reading an existing plain-int statistic at
    snapshot time, so legacy bookkeeping stays the single source of truth."""

    __slots__ = ("name", "_value", "_fn")

    def __init__(self, name: str, fn: Optional[Callable[[], float]] = None):
        self.name = name
        self._value = 0
        self._fn = fn

    @property
    def value(self):
        return self._fn() if self._fn is not None else self._value

    def inc(self, n=1) -> None:
        if self._fn is not None:
            raise RuntimeError(
                f"counter {self.name!r} is lazy (callback-backed); "
                f"mutate the underlying statistic instead")
        self._value += n


class Gauge:
    """Point-in-time value. ``set()`` for pushed values, or ``fn`` for
    callback gauges evaluated at snapshot time (pool occupancy etc.)."""

    __slots__ = ("name", "_value", "_fn")

    def __init__(self, name: str, fn: Optional[Callable[[], float]] = None):
        self.name = name
        self._value = 0.0
        self._fn = fn

    @property
    def value(self):
        return self._fn() if self._fn is not None else self._value

    def set(self, v) -> None:
        if self._fn is not None:
            raise RuntimeError(f"gauge {self.name!r} is callback-backed")
        self._value = v


class Histogram:
    """Fixed-bucket histogram with percentile estimation.

    ``bounds`` are ascending finite upper bounds; samples above the last
    bound land in an implicit overflow bucket. ``percentile(q)`` walks the
    cumulative counts to the bucket containing rank ``ceil(q/100 * count)``
    and returns that bucket's upper bound CLAMPED to the observed
    ``[min, max]`` — so an empty histogram reports ``None``, a one-sample
    histogram reports the sample exactly, and estimates never leave the
    observed range. ``merge`` requires identical bounds (all histograms
    built through :class:`MetricsRegistry` defaults satisfy this)."""

    __slots__ = ("name", "bounds", "counts", "count", "total", "_min", "_max")

    def __init__(self, name: str,
                 bounds: Optional[Sequence[float]] = None):
        self.name = name
        b = tuple(float(x) for x in (bounds if bounds is not None
                                     else TIME_BUCKETS_S))
        if len(b) < 1 or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(f"bounds must be strictly ascending: {b!r}")
        self.bounds = b
        self.counts = [0] * (len(b) + 1)       # +1 overflow bucket
        self.count = 0
        self.total = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v) -> None:
        v = float(v)
        self.counts[bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.total += v
        if v < self._min:
            self._min = v
        if v > self._max:
            self._max = v

    @property
    def min(self) -> Optional[float]:
        return self._min if self.count else None

    @property
    def max(self) -> Optional[float]:
        return self._max if self.count else None

    @property
    def mean(self) -> Optional[float]:
        return self.total / self.count if self.count else None

    def percentile(self, q) -> Optional[float]:
        if self.count == 0:
            return None
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"q={q} outside [0, 100]")
        rank = max(1, math.ceil(q / 100.0 * self.count))
        cum = 0
        est = self._max                        # overflow bucket estimate
        for i, n in enumerate(self.counts):
            cum += n
            if cum >= rank:
                est = self.bounds[i] if i < len(self.bounds) else self._max
                break
        return float(min(max(est, self._min), self._max))

    def merge(self, other: "Histogram") -> None:
        if self.bounds != other.bounds:
            raise ValueError(
                f"cannot merge histogram {self.name!r}: bucket bounds differ "
                f"({len(self.bounds)} vs {len(other.bounds)} buckets)")
        for i, n in enumerate(other.counts):
            self.counts[i] += n
        self.count += other.count
        self.total += other.total
        self._min = min(self._min, other._min)
        self._max = max(self._max, other._max)

    def summary(self) -> Dict[str, Any]:
        """Plain-python snapshot (JSON-ready); nonzero buckets only."""
        return {
            "count": int(self.count),
            "sum": float(self.total),
            "mean": self.mean,
            "min": self.min,
            "max": self.max,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
            "buckets": [
                [self.bounds[i] if i < len(self.bounds) else None, int(n)]
                for i, n in enumerate(self.counts) if n],
        }


class MetricsRegistry:
    """Flat namespace of counters/gauges/histograms, get-or-create by name.

    ``snapshot()`` renders everything to plain python (JSON-serializable);
    ``aggregate()`` folds several registries into one — counters/gauges sum,
    histograms merge — which is how ``Router.stats()`` builds fleet totals
    from per-replica registries (replicas must NOT share one registry:
    callback gauges bind to a single engine's pool)."""

    null = False

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str,
                fn: Optional[Callable[[], float]] = None) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name, fn)
        return c

    def gauge(self, name: str,
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name, fn)
        return g

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, bounds)
        return h

    def snapshot(self) -> Dict[str, Any]:
        def _num(v):
            v = v.item() if hasattr(v, "item") else v
            return float(v) if isinstance(v, float) else int(v) \
                if isinstance(v, int) else v
        return {
            "counters": {n: _num(c.value)
                         for n, c in sorted(self._counters.items())},
            "gauges": {n: _num(g.value)
                       for n, g in sorted(self._gauges.items())},
            "histograms": {n: h.summary()
                           for n, h in sorted(self._histograms.items())},
        }

    @classmethod
    def aggregate(cls, registries: Iterable["MetricsRegistry"]
                  ) -> "MetricsRegistry":
        out = cls()
        for reg in registries:
            if getattr(reg, "null", False):
                continue
            for name, c in reg._counters.items():
                tgt = out.counter(name)
                tgt._value += c.value
            for name, g in reg._gauges.items():
                tgt = out.gauge(name)
                tgt._value += g.value
            for name, h in reg._histograms.items():
                out.histogram(name, h.bounds).merge(h)
        return out


class _NullMetric:
    """Accepts every metric operation and records nothing."""

    __slots__ = ()
    name = "null"
    value = 0
    count = 0
    total = 0.0
    bounds: Tuple[float, ...] = ()
    min = None
    max = None
    mean = None

    def inc(self, n=1):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass

    def percentile(self, q):
        return None

    def merge(self, other):
        pass

    def summary(self):
        return {}


_NULL_METRIC = _NullMetric()


class NullRegistry:
    """Instrumentation OFF: every metric is a shared no-op. Used by the
    fuzz A/B test proving metrics collection never changes tokens or page
    accounting, and available to callers who want the last few ns back."""

    null = True

    def counter(self, name, fn=None):
        return _NULL_METRIC

    def gauge(self, name, fn=None):
        return _NULL_METRIC

    def histogram(self, name, bounds=None):
        return _NULL_METRIC

    def snapshot(self) -> Dict[str, Any]:
        return {"counters": {}, "gauges": {}, "histograms": {}}


def format_stats_line(snap: Dict[str, Any], prefix: str = "stats") -> str:
    """One-line periodic log from a ``Scheduler.stats()`` snapshot."""
    c = snap.get("counters", {})
    g = snap.get("gauges", {})
    h = snap.get("histograms", {})
    parts: List[str] = [prefix]
    if "engine.steps" in c:
        parts.append(f"step={c['engine.steps']}")
    if "engine.tokens_sampled" in c:
        parts.append(f"tok={c['engine.tokens_sampled']}")
    if "engine.slots_active" in g:
        parts.append(f"active={g['engine.slots_active']:g}")
    if "pool.pages_in_use" in g:
        parts.append(f"pages={g['pool.pages_in_use']:g}")
    if "spool.held_bytes" in g and g["spool.held_bytes"]:
        parts.append(f"spool={g['spool.held_bytes'] / 1e6:.1f}MB")
    if "prefix.hits" in c or "prefix.misses" in c:
        parts.append(f"prefix={c.get('prefix.hits', 0)}h/"
                     f"{c.get('prefix.misses', 0)}m")
    step_h = h.get("step/step_s") or {}
    if step_h.get("p50") is not None:
        parts.append(f"step_p50={step_h['p50'] * 1e3:.2f}ms")
    dec_h = h.get("step/decode_s") or {}
    if dec_h.get("p50") is not None:
        parts.append(f"decode_p50={dec_h['p50'] * 1e3:.2f}ms")
    return " ".join(parts)
