"""Training substrate: AdamW, train loop, checkpointing, synthetic data."""
from repro.training.checkpoint import Checkpointer
from repro.training.optimizer import (OptState, adamw_update, init_opt_state)
from repro.training.train_loop import (TrainState, init_train_state,
                                       make_train_step, train)
