"""Stateless-seeded synthetic data pipeline.

Every batch is a pure function of (seed, step) — any host can deterministically
recompute any shard after a failure or an elastic re-partition, so the data
pipeline needs no coordination or state checkpointing (DESIGN.md §5).

The stream is a random bigram Markov chain over the vocab: learnable structure
(a transformer quickly drops below the unigram entropy) while requiring no
external corpus.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

_N_STATES = 256  # bigram table is over vocab % _N_STATES for O(1) memory


def _transition_logits(seed: int) -> jax.Array:
    key = jax.random.PRNGKey(seed ^ 0x5EED)
    return jax.random.normal(key, (_N_STATES, _N_STATES), jnp.float32) * 2.0


def synthetic_batch(seed: int, step, B: int, T: int, cfg: ModelConfig,
                    extras: bool = True) -> Dict[str, jax.Array]:
    """Deterministic batch for (seed, step). tokens/labels [B, T]."""
    table = _transition_logits(seed)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k0, k1, k2, k3 = jax.random.split(key, 4)
    first = jax.random.randint(k0, (B,), 0, cfg.vocab_size)

    def gen(carry, k):
        prev = carry
        logits = table[prev % _N_STATES]
        nxt = jax.random.categorical(k, logits, axis=-1)
        # lift back to full vocab deterministically
        nxt = (nxt + (prev // _N_STATES) * 131) % cfg.vocab_size
        return nxt, nxt

    keys = jax.random.split(k1, T - 1)
    _, rest = jax.lax.scan(gen, first, keys)
    tokens = jnp.concatenate([first[:, None], rest.T], axis=1)
    labels = jnp.concatenate([tokens[:, 1:], -jnp.ones((B, 1), jnp.int32)], axis=1)
    batch = {"tokens": tokens, "labels": labels.astype(jnp.int32)}
    if extras and cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            k2, (B, cfg.encoder_ctx, cfg.d_model), jnp.float32)
    if extras and cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            k3, (B, cfg.n_vision_tokens, cfg.d_model), jnp.float32)
    return batch
