"""Train-step factory: grad accumulation, remat, mixed precision, metrics.

``make_train_step(cfg, tc)`` returns a pure ``(state, batch) -> (state,
metrics)`` suitable for jit/pjit; ``train`` drives it with checkpointing and
crash-resume (used by launch/train.py and the examples).
"""
from __future__ import annotations

import time
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models.model import init_params, lm_loss
from repro.training import data as data_mod
from repro.training.checkpoint import Checkpointer
from repro.training.optimizer import OptState, adamw_update, init_opt_state


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def init_train_state(key, cfg: ModelConfig) -> TrainState:
    params = init_params(key, cfg)
    return TrainState(params, init_opt_state(params))


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics).

    With tc.microbatch > 0 the global batch is split into microbatches and
    gradients are accumulated in a lax.scan (memory ∝ one microbatch)."""

    def loss_fn(params, batch):
        return lm_loss(params, batch, cfg, z_loss=tc.z_loss,
                       moe_aux=tc.moe_aux_loss, remat=tc.remat)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if tc.microbatch <= 0:
            (loss, aux), grads = grad_fn(params, batch)
            return loss, aux, grads
        B = batch["tokens"].shape[0]
        n_micro = B // tc.microbatch
        assert B % tc.microbatch == 0, (B, tc.microbatch)
        micro = jax.tree.map(
            lambda x: x.reshape(n_micro, tc.microbatch, *x.shape[1:]), batch)

        def body(acc, mb):
            loss_a, grads_a, aux_a = acc
            (loss, aux), grads = grad_fn(params, mb)
            grads = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / n_micro,
                grads_a, grads)
            aux = jax.tree.map(lambda a, b: a + b / n_micro, aux_a, aux)
            return (loss_a + loss / n_micro, grads, aux), None

        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        zero_aux = {"nll": jnp.zeros(()), "z_loss": jnp.zeros(()),
                    "moe_aux": jnp.zeros(())}
        (loss, grads, aux), _ = jax.lax.scan(
            body, (jnp.zeros(()), zero_g, zero_aux), micro)
        return loss, aux, grads

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        loss, aux, grads = compute_grads(state.params, batch)
        params, opt, om = adamw_update(grads, state.opt, state.params, tc)
        metrics = {"loss": loss, **aux, **om}
        return TrainState(params, opt), metrics

    return train_step


def train(cfg: ModelConfig, tc: TrainConfig, *, batch_size: int, seq_len: int,
          resume: bool = True, log_every: int = 10,
          step_fn=None, state: Optional[TrainState] = None,
          on_metrics=None) -> TrainState:
    """Single-host training driver with checkpoint/restart fault tolerance."""
    ckpt = Checkpointer(tc.checkpoint_dir, keep=tc.keep_checkpoints)
    if state is None:
        state = init_train_state(jax.random.PRNGKey(tc.seed), cfg)
    start_step = 0
    if resume:
        got, restored = ckpt.restore_latest(state)
        if got is not None:
            state, start_step = restored, got
            print(f"[train] resumed from step {got}")
    step_fn = step_fn or jax.jit(make_train_step(cfg, tc))
    t0 = time.time()
    for step in range(start_step, tc.total_steps):
        batch = data_mod.synthetic_batch(tc.seed, step, batch_size, seq_len, cfg)
        state, metrics = step_fn(state, batch)
        if (step + 1) % log_every == 0 or step == start_step:
            m = {k: float(v) for k, v in metrics.items()}
            dt = (time.time() - t0) / max(step - start_step + 1, 1)
            print(f"[train] step={step+1} loss={m['loss']:.4f} "
                  f"nll={m['nll']:.4f} gnorm={m['grad_norm']:.3f} "
                  f"lr={m['lr']:.2e} {dt*1e3:.0f}ms/step")
            if on_metrics:
                on_metrics(step + 1, m)
        if (step + 1) % tc.checkpoint_every == 0:
            ckpt.save(step + 1, state)
    ckpt.wait()
    return state
