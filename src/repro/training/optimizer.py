"""AdamW + cosine schedule + global-norm clipping (no optax dependency).

Moments are fp32 regardless of param dtype (bf16 params keep an fp32 master
copy in the optimizer state — standard mixed-precision training).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class OptState(NamedTuple):
    step: jax.Array          # int32
    mu: Any                  # first moment (fp32 pytree)
    nu: Any                  # second moment (fp32 pytree)
    master: Any              # fp32 master params


def cosine_schedule(tc: TrainConfig):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = tc.learning_rate * step / max(tc.warmup_steps, 1)
        t = jnp.clip((step - tc.warmup_steps)
                     / max(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * tc.learning_rate * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < tc.warmup_steps, warm, cos)
    return lr


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree.map(jnp.copy, zeros), master)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def _decay_mask(path) -> bool:
    """Weight decay on matrices only (no norms/biases/1-d params)."""
    name = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
    return not any(s in name for s in ("scale", "bias", "norm", "mix_",
                                       "w0", "dt_bias", "u", "D", "A_log"))


def adamw_update(grads, opt: OptState, params, tc: TrainConfig
                 ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    grads, gn = clip_by_global_norm(grads, tc.grad_clip)
    step = opt.step + 1
    lr = cosine_schedule(tc)(step)
    b1, b2 = tc.beta1, tc.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(path, g, m, v, master):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        update = (m / c1) / (jnp.sqrt(v / c2) + tc.eps)
        if _decay_mask(path):
            update = update + tc.weight_decay * master
        new_master = master - lr * update
        return m, v, new_master

    flat = jax.tree_util.tree_flatten_with_path(grads)[0]
    treedef = jax.tree.structure(grads)
    mus = jax.tree.leaves(opt.mu)
    nus = jax.tree.leaves(opt.nu)
    masters = jax.tree.leaves(opt.master)
    new_m, new_v, new_master = [], [], []
    for (path, g), m, v, ma in zip(flat, mus, nus, masters):
        a, b, c = upd(path, g, m, v, ma)
        new_m.append(a)
        new_v.append(b)
        new_master.append(c)
    mu = jax.tree.unflatten(treedef, new_m)
    nu = jax.tree.unflatten(treedef, new_v)
    master = jax.tree.unflatten(treedef, new_master)
    new_params = jax.tree.map(lambda ma, p: ma.astype(p.dtype), master, params)
    metrics = {"grad_norm": gn, "lr": lr}
    return new_params, OptState(step, mu, nu, master), metrics
