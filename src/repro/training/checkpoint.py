"""Fault-tolerant checkpointing: atomic, content-hashed, async, elastic.

Layout:   <dir>/step_<N>/
             shard_<proc>.npz     flattened leaves owned by this process
             meta.json            step, leaf treedef, shapes, sha256 per file
          <dir>/LATEST            text file with the newest complete step

Atomicity: write to ``step_<N>.tmp-<pid>`` then ``os.rename`` (POSIX-atomic)
after all shards land; a crash mid-write leaves only tmp dirs that restore
ignores. ``restore_latest`` verifies hashes and falls back to the previous
complete checkpoint on corruption — node failure during save never loses the
run. Saves can run on a background thread (``async_save=True``); the train
loop only blocks on the *previous* save (one outstanding write, bounded host
memory).

Elasticity: leaves are stored unsharded (gathered); ``restore`` reshards onto
whatever mesh the restarted job built, so a 512-chip run can resume on 256.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_names(tree) -> list:
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = []
    for path, _ in paths:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        names.append("/".join(parts))
    return names


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any) -> None:
        """Snapshot ``tree`` at ``step``. Returns immediately if async."""
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()
        if self.async_save:
            self._pending = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._pending.start()
        else:
            self._write(step, host)

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _write(self, step: int, host_tree: Any) -> None:
        names = _leaf_names(host_tree)
        leaves = jax.tree.leaves(host_tree)
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp-", dir=self.dir)
        try:
            shard = os.path.join(tmp, "shard_0.npz")
            # npz can't store ml_dtypes (bfloat16 etc.) — save a uint16/uint8
            # byte view and record the true dtype in meta.
            to_save = {}
            for i, l in enumerate(leaves):
                if l.dtype.kind == "V" or str(l.dtype) == "bfloat16":
                    l = l.view(np.uint16 if l.dtype.itemsize == 2 else np.uint8)
                to_save[f"leaf_{i}"] = l
            np.savez(shard, **to_save)
            meta = {
                "step": step,
                "names": names,
                "shapes": [list(l.shape) for l in leaves],
                "dtypes": [str(l.dtype) for l in leaves],
                "sha256": {"shard_0.npz": _sha256(shard)},
            }
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)                      # atomic publish
            with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
                f.write(str(step))
            os.rename(os.path.join(self.dir, "LATEST.tmp"),
                      os.path.join(self.dir, "LATEST"))
            self._gc()
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise

    def _gc(self) -> None:
        steps = self.complete_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def complete_steps(self) -> list:
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_") and ".tmp" not in name:
                if os.path.exists(os.path.join(self.dir, name, "meta.json")):
                    out.append(int(name.split("_")[1]))
        return out

    def _verify(self, path: str) -> bool:
        try:
            with open(os.path.join(path, "meta.json")) as f:
                meta = json.load(f)
            for fname, want in meta["sha256"].items():
                if _sha256(os.path.join(path, fname)) != want:
                    return False
            return True
        except (OSError, json.JSONDecodeError, KeyError):
            return False

    def restore(self, step: int, template: Any,
                shardings: Optional[Any] = None) -> Any:
        """Load step into the structure of ``template``; reshard if given
        (device placement derived from the *current* mesh — elastic)."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, "shard_0.npz"))
        leaves = [data[f"leaf_{i}"] for i in range(len(data.files))]
        treedef = jax.tree.structure(template)
        tleaves = jax.tree.leaves(template)
        assert len(leaves) == len(tleaves), "checkpoint/template mismatch"
        out = []
        for l, t in zip(leaves, tleaves):
            if l.dtype != t.dtype and l.dtype.kind == "u":
                l = l.view(jnp.dtype(t.dtype))      # byte view (bfloat16 path)
            out.append(jnp.asarray(l, dtype=t.dtype))
        tree = jax.tree.unflatten(treedef, out)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree

    def restore_latest(self, template: Any,
                       shardings: Optional[Any] = None
                       ) -> Tuple[Optional[int], Any]:
        """Newest *verified* checkpoint (corrupt ones skipped). (None, template)
        if nothing usable exists — the fault-tolerant cold-start path."""
        for step in reversed(self.complete_steps()):
            path = os.path.join(self.dir, f"step_{step:08d}")
            if self._verify(path):
                return step, self.restore(step, template, shardings)
        return None, template
