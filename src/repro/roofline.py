"""Roofline-term derivation from compiled dry-run artifacts.

Per (arch × shape × mesh):
    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

``cost_analysis()`` numbers are *per device* on the post-SPMD module, so no
chip division is applied to them; collective bytes are parsed per-device from
``compiled.as_text()``.

Scan correction: XLA counts a while-loop body once, not trip_count times.
The layer scan is unrolled at dry-run (REPRO_UNROLL_LAYERS), but the *time*
scans (RWKV WKV, Mamba SSM, chunked attention) stay loops — their remaining
(trip-1)·body cost is added analytically below and reported separately so
the raw and corrected numbers are both visible.
"""
from __future__ import annotations

import os
import re
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig

# TPU v5e-class constants (per assignment)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*(?P<result>[^=]*?)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")
_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")

# bytes moved per device ≈ weight × result bytes (ring algorithms):
# all-reduce moves ~2× the tensor (reduce-scatter + all-gather phases).
_KIND_WEIGHT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str,
                              cond_amortize: float = 1.0) -> Dict[str, int]:
    """Per-device collective traffic parsed from the post-SPMD module.

    HLO call operands are bare ``%names`` (no types), so the RESULT shape of
    each collective is used (= operand shape for all-reduce/all-to-all/
    permute; = gathered shape for all-gather; ring all-reduce weighted 2x).
    Async pairs (-start/-done) are counted once.

    ``cond_amortize`` down-weights collectives inside conditional branches
    (op_name contains "/cond/"): XLA cost analysis sums both branches every
    step, but e.g. the decode compaction branch fires once per tile_tokens
    steps. Amortized bytes are also reported under a ``*_cond`` key.
    """
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        if m.group("suffix") == "-done":
            continue
        kind = m.group("kind").lower()
        total = sum(_shape_bytes(t, d)
                    for t, d in _SHAPE_RE.findall(m.group("result")))
        total = int(total * _KIND_WEIGHT.get(kind, 1.0))
        if cond_amortize != 1.0 and "/cond/" in line:
            out[kind + "_cond"] = out.get(kind + "_cond", 0) + total
            total = int(total * cond_amortize)
        out[kind] = out.get(kind, 0) + total
    return out


@dataclass
class RooflineTerms:
    flops: float                   # per-device
    bytes_hbm: float               # per-device
    bytes_collective: float        # per-device
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    correction_flops: float = 0.0
    correction_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        return (self.flops + self.correction_flops) / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return (self.bytes_hbm + self.correction_bytes) / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.bytes_collective / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict:
        return {
            "flops_per_dev": self.flops,
            "bytes_per_dev": self.bytes_hbm,
            "coll_bytes_per_dev": self.bytes_collective,
            "coll_breakdown": self.coll_breakdown,
            "corr_flops": self.correction_flops,
            "corr_bytes": self.correction_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


def terms_from_compiled(compiled, n_chips: int,
                        corr_flops: float = 0.0,
                        corr_bytes: float = 0.0,
                        cond_amortize: float = 1.0) -> RooflineTerms:
    ca = compiled.cost_analysis()
    flops = float(ca.get("flops", 0.0))
    bytes_hbm = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes_from_hlo(compiled.as_text(), cond_amortize)
    total = sum(v for k, v in coll.items() if not k.endswith("_cond"))
    return RooflineTerms(flops, bytes_hbm, float(total), coll,
                         corr_flops / n_chips, corr_bytes / n_chips)


# ----------------------------------------------------------------------
# analytic corrections for loops left as scans (global numbers; divided by
# chips by the caller)

def paged_metadata_bytes(cfg: ModelConfig, B: int, max_total_tokens: int,
                         page_tokens: int) -> int:
    """Per-decode-step HBM bytes the PAGED pool layout adds on top of the
    contiguous cost model: every attention layer reads the int32 block
    table (tile→page translation — SMEM-prefetched by the fused kernel,
    gathered by the jnp paths) and the scratch page costs one page of pool
    bytes once. Per step:

        n_attn · 4 · B · max_pages          (block-table words)

    The compressed-token bytes themselves are unchanged — pages hold the
    same fixed-k rows, just at translated addresses — so this term is the
    entire steady-state paging overhead (the scratch page is capacity, not
    traffic)."""
    from repro.serving.cache import plan_pages
    max_pages = plan_pages(cfg, max_total_tokens, page_tokens, batch=B)
    n_attn = len(cfg.attention_layers())
    return n_attn * 4 * B * max_pages


# Per-grid-step fixed cost of the paged decode kernel expressed in
# HBM-byte equivalents (DMA issue + scalar-prefetch index math per tile).
# Calibrated coarsely from the BENCH_paging trace: one extra tile costs
# about as much as streaming 2 KiB at HBM bandwidth on a v5e-class part.
TILE_OVERHEAD_BYTES = 2048


def _tile_overhead_bytes(override: "int | None" = None) -> int:
    """Resolve the per-tile overhead constant: explicit argument >
    ``REPRO_TILE_OVERHEAD_BYTES`` env var > module default. The env hook
    lets a deployment re-fit ``auto_page_tokens`` from a measured
    dispatch latency (overhead_bytes = latency_s · HBM_GBps · 1e9)
    without editing source."""
    if override is not None:
        return int(override)
    env = os.environ.get("REPRO_TILE_OVERHEAD_BYTES")
    if env:
        return int(env)
    return TILE_OVERHEAD_BYTES


def auto_page_tokens(cfg: ModelConfig, n_slots: int,
                     max_total_tokens: int,
                     tile_overhead_bytes: "int | None" = None) -> int:
    """Pick ``page_tokens`` for ``Scheduler(page_tokens="auto")``.

    PAGE-SIZE TUNING GUIDE — the two costs that move with ``page_tokens``:

    1. **Block-table metadata** (favors LARGE pages). Every attention layer
       reads ``4 · B · max_pages`` bytes of int32 block table per decode
       step (``paged_metadata_bytes``); halving the page count halves this
       term. It also shrinks the allocator's per-step event list and the
       single block-table splice.

    2. **Tile shrink** (favors LARGE pages, saturating at ``TILE_T``). The
       paged decode kernel tiles the compressed stream at
       ``min(page_tokens, TILE_T)`` tokens — a page cannot span two tiles —
       so small pages multiply the grid steps per row and each step pays a
       fixed DMA-issue + index-translation cost (``TILE_OVERHEAD_BYTES``
       byte-equivalents). Past ``TILE_T`` (128) larger pages buy nothing
       here.

    Pulling the other way, **fragmentation** (favors SMALL pages): a live
    request strands ``~(page_tokens - 1) / 2`` compressed-token rows in its
    partially-filled last page, and copy-on-write of a shared boundary page
    copies a whole page. This is capacity, not steady-state traffic, so it
    enters as a tiebreak: the smallest candidate within 2% of the best
    modeled per-step cost wins.

    Candidates are multiples of ``mustafar.tile_tokens`` (the pool layout
    requires ``page_tokens % tile_tokens == 0``) up to
    ``min(max_total_tokens, 2·TILE_T)``. Typical result: pages of one-to-a
    few ``TILE_T`` — e.g. 128 for deep caches, smaller only when
    ``max_total_tokens`` is itself small.

    ``tile_overhead_bytes`` overrides the ``TILE_OVERHEAD_BYTES``
    calibration point (falling back to the ``REPRO_TILE_OVERHEAD_BYTES``
    env var, then the module constant — see ``_tile_overhead_bytes``);
    ``Scheduler(page_tokens="auto", tile_overhead_bytes=...)`` plumbs it
    through."""
    from repro.kernels.sparse_decode import TILE_T
    overhead = _tile_overhead_bytes(tile_overhead_bytes)
    tt = cfg.mustafar.tile_tokens
    n_attn = max(1, len(cfg.attention_layers()))
    cands = []
    pt = tt
    while pt <= max(tt, min(max_total_tokens, 2 * TILE_T)):
        cands.append(pt)
        pt *= 2
    costs = []
    for pt in cands:
        meta = paged_metadata_bytes(cfg, n_slots, max_total_tokens, pt)
        tile_t = min(pt, TILE_T)
        n_tiles = -(-max_total_tokens // tile_t)
        tile = n_attn * n_slots * cfg.n_kv_heads * n_tiles * overhead
        costs.append(meta + tile)
    best = min(costs)
    for pt, c in zip(cands, costs):        # smallest page within 2% of best
        if c <= 1.02 * best:
            return pt
    return cands[-1]


def prefix_shared_pool_bytes_saved(cfg: ModelConfig, page_tokens: int,
                                   prefix_tokens: int, n_sharers: int) -> int:
    """Modeled pool-byte saving from prefix sharing (BENCH_prefix term).

    ``n_sharers`` live requests whose prompts agree on ``prefix_tokens``
    leading tokens alias the prefix's fully-retired compressed pages
    instead of each owning a copy: the pool holds those pages ONCE, so the
    saving is ``(n_sharers - 1) · floor(prefix_tokens / page_tokens) ·
    page_bytes``. (The partially-filled boundary page is shared too until
    a sharer's first compaction copies-on-write, so this is the
    steady-state lower bound; block-table metadata is unchanged — aliasing
    costs no extra entries.)"""
    from repro.serving.cache import page_bytes
    full_pages = prefix_tokens // page_tokens
    return max(0, n_sharers - 1) * full_pages * page_bytes(cfg, page_tokens)


def swap_bytes(cfg: ModelConfig, page_tokens: int, n_pages: int,
               include_window: bool = True) -> int:
    """Modeled bytes ONE preemption swap event moves device→host (a
    restore moves the same bytes back host→device — double it for the
    round trip). A swap spools the victim's ``n_pages`` drawn compressed
    pages plus, with ``include_window``, its dense local-window K/V rows
    and the three per-slot int32 counters — the complete slot state
    ``Scheduler._preempt_slot`` gathers (``gather_page_arrays`` +
    ``gather_slot_state``). BENCH_preemption.json reports this model next
    to the spool's measured ``bytes_out``/``bytes_in`` so the accounting
    can be cross-checked: measured page traffic quantizes to WHOLE pages
    and whole window buffers (a half-filled page still ships
    ``page_bytes``), which is exactly what this model charges."""
    from repro.serving.cache import page_bytes
    total = n_pages * page_bytes(cfg, page_tokens)
    if include_window:
        m = cfg.mustafar
        wbuf = m.local_window + m.tile_tokens
        n_attn = len(cfg.attention_layers())
        itemsize = np.dtype(cfg.dtype).itemsize
        total += n_attn * cfg.n_kv_heads * 2 * wbuf * cfg.d_head * itemsize
    return total + 3 * 4       # position/w_len/n_compressed counters


def chunked_prefill_stall_model(prompt_tokens: int, prefill_chunk: int,
                                t_token_s: float) -> Dict[str, float]:
    """Decode-stall model for chunked admissions: a solo prefill stalls the
    running batch for ``prompt_tokens`` token-equivalents at once; chunked
    admission bounds the per-step stall to ``prefill_chunk`` tokens and
    spreads the prefill over ``ceil(T / chunk)`` engine steps. Returns both
    stalls in seconds plus the added first-token latency in steps.

    The per-step stall is the FULL chunk even for prompts shorter than it:
    the engine pads every chunk to ``prefill_chunk`` tokens and charges the
    padded size (``Scheduler._run_prefill_chunks``), so that is the
    wall-clock a decode step actually loses."""
    import math
    steps = math.ceil(prompt_tokens / max(1, prefill_chunk))
    return {
        "solo_stall_s": prompt_tokens * t_token_s,
        "chunked_stall_per_step_s": prefill_chunk * t_token_s,
        "first_token_extra_steps": float(steps - 1),
    }


def scan_corrections(cfg: ModelConfig, shape: ShapeConfig,
                     mode: str, train_factor: float = 3.0,
                     page_tokens: Optional[int] = None) -> Dict[str, float]:
    """(flops, bytes) NOT counted by cost_analysis because they sit inside a
    while-loop body that executes trip>1 times. ``train_factor`` accounts for
    fwd+bwd (~3x) on those bodies in training mode. ``page_tokens`` adds the
    paged-pool metadata traffic (block-table reads) to decode mode."""
    B, T = shape.global_batch, shape.seq_len
    fl = 0.0
    by = 0.0
    if mode == "train":
        # chunked cross-entropy scan: vocab matmul counted once, runs
        # T/CE_CHUNK times (fwd+bwd)
        from repro.models.model import CE_CHUNK
        from repro.models.attention import pick_chunk as _pc
        ce_chunk = _pc(T, CE_CHUNK)
        n_ce = T // ce_chunk
        if n_ce > 1:
            body_fl = 2.0 * B * ce_chunk * cfg.d_model * cfg.vocab_size
            body_by = cfg.d_model * cfg.vocab_size * 2     # W_vocab reread
            fl += (n_ce - 1) * body_fl * train_factor
            by += (n_ce - 1) * body_by * train_factor
        # chunked causal attention scan: counted once, runs n_chunks times
        from repro.models.attention import (CHUNKED_ATTN_THRESHOLD,
                                            pick_chunk)
        if T >= CHUNKED_ATTN_THRESHOLD and not cfg.is_attention_free:
            c = pick_chunk(T)
            n_chunks = T // c
            n_attn = len(cfg.attention_layers())
            body_fl = 4.0 * B * cfg.n_heads * c * T * cfg.d_head
            body_by = 2.0 * B * cfg.n_kv_heads * T * cfg.d_head * 2  # K,V reread
            fl += (n_chunks - 1) * n_attn * body_fl * train_factor
            by += (n_chunks - 1) * n_attn * body_by * train_factor
        if cfg.family == "ssm":
            H = cfg.d_model // cfg.rwkv_head_size
            hs = cfg.rwkv_head_size
            body = 6.0 * B * H * hs * hs            # wkv update+readout
            fl += (T - 1) * cfg.n_layers * body * train_factor
        if cfg.family == "hybrid":
            n_mamba = cfg.n_layers - len(cfg.attention_layers())
            din = cfg.mamba_expand * cfg.d_model
            body = 6.0 * B * din * cfg.mamba_d_state
            fl += (T - 1) * n_mamba * body * train_factor
    elif mode == "prefill":
        from repro.models.attention import (CHUNKED_ATTN_THRESHOLD,
                                            pick_chunk)
        if T >= CHUNKED_ATTN_THRESHOLD and not cfg.is_attention_free:
            c = pick_chunk(T)
            n_chunks = T // c
            n_attn = len(cfg.attention_layers())
            body_fl = 4.0 * B * cfg.n_heads * c * T * cfg.d_head
            body_by = 2.0 * B * cfg.n_kv_heads * T * cfg.d_head * 2
            fl += (n_chunks - 1) * n_attn * body_fl
            by += (n_chunks - 1) * n_attn * body_by
        if cfg.family == "ssm":
            H = cfg.d_model // cfg.rwkv_head_size
            hs = cfg.rwkv_head_size
            fl += (T - 1) * cfg.n_layers * 6.0 * B * H * hs * hs
        if cfg.family == "hybrid":
            n_mamba = cfg.n_layers - len(cfg.attention_layers())
            din = cfg.mamba_expand * cfg.d_model
            fl += (T - 1) * n_mamba * 6.0 * B * din * cfg.mamba_d_state
    elif mode == "decode" and cfg.mustafar.enabled and not cfg.is_attention_free:
        # chunked online-softmax decode scan over the compressed pools:
        # body counted once, runs n_chunks times
        from repro.core.attention import DECODE_CHUNK
        from repro.serving.cache import plan_pools
        Tc, _ = plan_pools(cfg, T + cfg.mustafar.tile_tokens * 2, batch=B)
        chunk = min(DECODE_CHUNK, Tc)
        n_chunks = Tc // chunk
        if n_chunks > 1:
            m = cfg.mustafar
            d = cfg.d_head
            kk = m.keep_k(d, m.key_sparsity)
            kv = m.keep_k(d, m.value_sparsity)
            n_attn = len(cfg.attention_layers())
            from repro.core.sparse_format import pad_to_words
            from repro.serving.cache import pool_dtype, pool_quantized
            # packed values stream at the configured pool width (bf16=2,
            # int8=1 + per-tile fp32 scales riding beside the values)
            itemsize = int(np.dtype(pool_dtype(cfg)).itemsize)
            # per-chunk: read compressed K+V chunk, decompress, 2 matvecs
            # (bitmap stored as whole uint32 words: pad_to_words(d)/8 bytes)
            body_by = B * cfg.n_kv_heads * chunk * (
                (kk + kv) * itemsize + 2 * (pad_to_words(d) // 8))
            if pool_quantized(cfg):
                body_by += B * cfg.n_kv_heads * 2 * \
                    (chunk // m.tile_tokens) * 4
            # gather decompression is O(d) per row for K and for V (bit
            # expand + cumsum + gather — the old one-hot formulation charged
            # an extra O(d·k) MXU contraction here)
            body_fl = 4.0 * B * cfg.n_heads * chunk * d \
                + 2.0 * B * cfg.n_kv_heads * chunk * d
            fl += (n_chunks - 1) * n_attn * body_fl
            by += (n_chunks - 1) * n_attn * body_by
        if page_tokens is not None:
            by += paged_metadata_bytes(cfg, B, T, page_tokens)
    return {"flops": fl, "bytes": by}


# ----------------------------------------------------------------------
def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for training;
    2·N_active·D per generated token batch for decode; 2·N·D for prefill."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: one token each
