"""Serving launcher: batched generation with the Mustafar cache.

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-3b --smoke \
        --batch 4 --prompt-len 128 --gen 64 [--dense] [--mesh data=2,model=2]
"""
from __future__ import annotations

import argparse
import time
from dataclasses import replace

import jax

from repro.configs import get_config
from repro.models import init_params
from repro.serving.cache import cache_hbm_bytes
from repro.serving.engine import Engine
from repro.launch.train import build_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--dense", action="store_true")
    ap.add_argument("--sparsity", type=float, default=-1.0)
    ap.add_argument("--mesh", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    if args.dense:
        cfg = replace(cfg, mustafar=replace(cfg.mustafar, enabled=False))
    elif args.sparsity >= 0:
        cfg = cfg.with_sparsity(args.sparsity, args.sparsity)

    params = init_params(jax.random.PRNGKey(0), cfg)
    max_total = args.prompt_len + args.gen + 64
    eng = Engine(cfg, params, max_total_tokens=max_total)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    _ = eng.generate(prompts, n_new=2)          # compile
    t0 = time.perf_counter()
    out = jax.block_until_ready(eng.generate(prompts, n_new=args.gen,
                                             temperature=0.7))
    dt = time.perf_counter() - t0
    acct = cache_hbm_bytes(cfg, args.batch, max_total)
    print(f"[serve] {args.arch} batch={args.batch} gen={args.gen} "
          f"{args.batch*args.gen/dt:.1f} tok/s; cache ratio "
          f"{acct['ratio']*100:.1f}% of dense")
    print("sample:", out[0, :12].tolist())


if __name__ == "__main__":
    main()
