"""Production mesh builders (functions, not constants — importing this module
never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model); the 'pod' axis carries
    only cross-pod data parallelism (DCN-friendly)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests)."""
    n = n_devices or len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
