"""Distributed training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
        --steps 200 --batch 8 --seq 256 [--smoke] [--mesh data=4,model=2] \
        [--resume auto] [--ckpt-dir /tmp/run1]

On a real cluster this is invoked once per host (jax.distributed.initialize
picks up the coordinator from env); in this container it runs single-process.
``--smoke`` uses the reduced config. Fault tolerance: any crash/restart with
``--resume auto`` continues from the newest verified checkpoint; if the
device count changed (elastic), params are resharded onto the new mesh.
"""
from __future__ import annotations

import argparse
import os

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import TrainConfig, get_config
from repro.sharding import specs as sh
from repro.training import init_train_state, make_train_step, train
from repro.training.optimizer import OptState
from repro.training.train_loop import TrainState


def build_mesh(spec: str):
    axes = []
    sizes = []
    for part in spec.split(","):
        name, size = part.split("=")
        axes.append(name)
        sizes.append(int(size))
    return jax.make_mesh(tuple(sizes), tuple(axes))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--distributed", action="store_true",
                    help="multi-host: call jax.distributed.initialize()")
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    tc = TrainConfig(total_steps=args.steps, learning_rate=args.lr,
                     warmup_steps=max(args.steps // 10, 1),
                     microbatch=args.microbatch,
                     checkpoint_every=args.ckpt_every,
                     checkpoint_dir=args.ckpt_dir)

    step_fn = None
    state = None
    if args.mesh:
        mesh = build_mesh(args.mesh)
        state = init_train_state(jax.random.PRNGKey(tc.seed), cfg)
        pspecs = sh.param_specs(state.params, mesh, fsdp=True, cfg=cfg)
        ospecs = OptState(P(), pspecs, pspecs, pspecs)
        sspec = TrainState(sh.to_named(pspecs, mesh),
                           sh.to_named(ospecs, mesh))
        state = jax.device_put(state, sspec)
        bspec = sh.to_named(sh.train_batch_specs(cfg, args.batch, mesh), mesh)
        step_fn = jax.jit(make_train_step(cfg, tc),
                          in_shardings=(sspec, bspec), donate_argnums=(0,))
        print(f"[launch] mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    train(cfg, tc, batch_size=args.batch, seq_len=args.seq,
          resume=args.resume == "auto", step_fn=step_fn, state=state)


if __name__ == "__main__":
    main()
