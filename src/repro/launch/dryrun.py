"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation) for params / optimizer state / batch / serving
cache, lowers the appropriate step under the production mesh, compiles it,
and records memory_analysis + cost_analysis + parsed collective bytes.

    PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-3b \
        --shape train_4k --multi-pod both --out results/dryrun.json

Shapes: train_4k lowers train_step; prefill_32k lowers prefill;
decode_32k / long_500k lower serve_step (decode with a seq_len KV cache).
long_500k runs for SSM/hybrid archs per the assignment and additionally for
the GQA archs with the Mustafar-compressed cache (bonus — see DESIGN.md §4);
whisper is excluded from long_500k.
"""
from __future__ import annotations

# The two env lines below MUST run before any other jax-touching import —
# jax locks the device count on first init (assignment step 0).
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
os.environ.setdefault("REPRO_UNROLL_LAYERS", "256")

import argparse
import json
import time
import traceback
from dataclasses import replace
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import roofline
from repro.configs import (ASSIGNED_ARCHS, LM_SHAPES, TrainConfig, get_config,
                           get_shape)
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.models.model import param_shapes
from repro.serving import cache as cache_mod
from repro.serving.engine import decode_step, prefill
from repro.sharding import specs as sh
from repro.sharding.constraints import constraint_mesh
from repro.training.optimizer import OptState
from repro.training.train_loop import make_train_step, TrainState


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """None if runnable, else a skip reason (recorded, per assignment)."""
    if shape.name == "long_500k":
        if cfg.family == "audio":
            return "whisper decoder max position 448; 500k not meaningful"
        # ssm/hybrid required; GQA archs run as Mustafar bonus
    return None


# ----------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input

def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> Dict:
    """Sharded ShapeDtypeStructs for the batch of one cell."""
    B, T = shape.global_batch, shape.seq_len
    bspec = sh.batch_spec(B, mesh)
    mk = lambda shp, dt, spec: jax.ShapeDtypeStruct(
        shp, dt, sharding=NamedSharding(mesh, spec))
    if shape.kind == "train":
        out = {"tokens": mk((B, T), jnp.int32, bspec),
               "labels": mk((B, T), jnp.int32, bspec)}
    elif shape.kind == "prefill":
        out = {"tokens": mk((B, T), jnp.int32, bspec)}
    else:
        out = {"tokens": mk((B,), jnp.int32, P(bspec[0]))}
    if cfg.family == "audio" and shape.kind != "decode":
        out["frames"] = mk((B, cfg.encoder_ctx, cfg.d_model), jnp.float32,
                           sh.batch_spec(B, mesh, extra_dims=2))
    if cfg.family == "vlm" and shape.kind != "decode":
        out["patches"] = mk((B, cfg.n_vision_tokens, cfg.d_model), jnp.float32,
                            sh.batch_spec(B, mesh, extra_dims=2))
    return out


def _effective_cfg(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    # whisper: seq_len is decoder-side for lowering; cap learned positions
    if cfg.family == "audio":
        cfg = replace(cfg, max_position=max(shape.seq_len + 64, 4096))
    return cfg


# ----------------------------------------------------------------------
def lower_cell(arch: str, shape_name: str, mesh, *, fsdp: bool = True,
               mustafar: Optional[bool] = None,
               microbatch: int = 0, compile_: bool = True) -> Dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    skip = shape_applicable(cfg, shape)
    if skip:
        return {"arch": arch, "shape": shape_name, "skipped": skip}
    cfg = _effective_cfg(cfg, shape)
    if mustafar is not None:
        cfg = replace(cfg, mustafar=replace(cfg.mustafar, enabled=mustafar))
    n_chips = mesh.devices.size
    t0 = time.time()
    _ctx = constraint_mesh(mesh)
    _ctx.__enter__()

    pshapes = param_shapes(cfg)
    pspecs = sh.param_specs(pshapes, mesh, fsdp=fsdp, cfg=cfg)
    params_in = sh.shaped(pshapes, pspecs, mesh)
    batch_in = input_specs(cfg, shape, mesh)

    if shape.kind == "train":
        tc = TrainConfig(microbatch=microbatch)
        step = make_train_step(cfg, tc)
        opt_shapes = jax.eval_shape(
            lambda p: __import__("repro.training.optimizer",
                                 fromlist=["init_opt_state"]).init_opt_state(p),
            pshapes)
        ospecs = OptState(P(), pspecs, pspecs, pspecs)
        state_in = TrainState(params_in, sh.shaped(opt_shapes, ospecs, mesh))
        fn = jax.jit(step,
                     in_shardings=(TrainState(sh.to_named(pspecs, mesh),
                                              sh.to_named(ospecs, mesh)),
                                   sh.to_named(sh.train_batch_specs(
                                       cfg, shape.global_batch, mesh), mesh)),
                     donate_argnums=(0,))
        lowered = fn.lower(state_in, batch_in)
        mode = "train"
    elif shape.kind == "prefill":
        max_total = shape.seq_len + 128
        extra_keys = {k: v for k, v in batch_in.items() if k != "tokens"}
        f = partial(prefill, cfg=cfg, max_total_tokens=max_total)
        fn = jax.jit(lambda p, t, e: f(p, t, extra=e or None))
        lowered = fn.lower(params_in, batch_in["tokens"], extra_keys)
        mode = "prefill"
    else:
        max_total = shape.seq_len + cfg.mustafar.tile_tokens * 2
        B = shape.global_batch
        enc_ctx = cfg.encoder_ctx if cfg.family == "audio" else 0
        cache_shapes = jax.eval_shape(
            lambda: cache_mod.init_cache(cfg, B, max_total, enc_ctx))
        cspecs = sh.cache_specs(cache_shapes, cfg, mesh)
        cache_in = sh.shaped(cache_shapes, cspecs, mesh)
        fn = jax.jit(partial(decode_step, cfg=cfg), donate_argnums=(2,))
        lowered = fn.lower(params_in, batch_in["tokens"], cache_in)
        mode = "decode"

    _ctx.__exit__(None, None, None)
    res = {"arch": arch, "shape": shape_name, "mode": mode,
           "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
           "n_chips": int(n_chips), "lower_s": round(time.time() - t0, 1)}
    if not compile_:
        return res
    t1 = time.time()
    compiled = lowered.compile()
    res["compile_s"] = round(time.time() - t1, 1)
    mem = compiled.memory_analysis()
    res["memory"] = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "per_device_total": (mem.argument_size_in_bytes
                             + mem.output_size_in_bytes
                             + mem.temp_size_in_bytes
                             - mem.alias_size_in_bytes),
    }
    corr = roofline.scan_corrections(cfg, shape, mode)
    # decode: per-slot compaction sits behind an any-slot lax.cond that, in
    # lockstep, takes the compress branch once per tile_tokens steps (ragged
    # slots can fire more often, up to once per step at full stagger);
    # amortize its collectives by the lockstep factor (raw numbers kept
    # under the *_cond keys of the breakdown).
    amort = (1.0 / cfg.mustafar.tile_tokens
             if mode == "decode" and cfg.mustafar.enabled else 1.0)
    terms = roofline.terms_from_compiled(compiled, n_chips,
                                         corr["flops"], corr["bytes"],
                                         cond_amortize=amort)
    # layer-scale correction: with REPRO_UNROLL_LAYERS < n_periods the layer
    # scan is a while loop whose body XLA counts ONCE; scale
    # flops/bytes/collectives by the trip count. Overcounts the non-layer
    # fixed parts (embed/CE) by <~10% — validated against a full-unroll
    # measurement (EXPERIMENTS §Roofline).
    from repro.models.model import structural_period
    n_periods = cfg.n_layers // structural_period(cfg)
    unroll = int(os.environ.get("REPRO_UNROLL_LAYERS", "1"))
    if unroll < n_periods:
        scale = n_periods / max(1, unroll)
        terms.flops *= scale
        terms.bytes_hbm *= scale
        terms.bytes_collective *= scale
        terms.coll_breakdown = {k: int(v * scale)
                                for k, v in terms.coll_breakdown.items()}
        res["layer_scale"] = scale
    res["roofline"] = terms.as_dict()
    res["model_flops_global"] = roofline.model_flops(cfg, shape)
    res["model_flops_per_dev"] = res["model_flops_global"] / n_chips
    hlo_fl = terms.flops + terms.correction_flops
    res["useful_flops_frac"] = (res["model_flops_per_dev"] / hlo_fl
                                if hlo_fl else None)
    return res


# ----------------------------------------------------------------------
def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--mustafar", default=None,
                    help="force mustafar on/off (default: config)")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--lower-only", action="store_true")
    args = ap.parse_args()

    archs = list(ASSIGNED_ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = [s.name for s in LM_SHAPES] if args.shape == "all" \
        else args.shape.split(",")
    meshes = []
    if args.multi_pod in ("single", "both"):
        meshes.append(("single_pod", make_production_mesh(multi_pod=False)))
    if args.multi_pod in ("multi", "both"):
        meshes.append(("multi_pod", make_production_mesh(multi_pod=True)))
    mustafar = None if args.mustafar is None else args.mustafar == "on"

    results = []
    for mesh_name, mesh in meshes:
        for arch in archs:
            for shape_name in shapes:
                tag = f"{mesh_name}/{arch}/{shape_name}"
                try:
                    r = lower_cell(arch, shape_name, mesh,
                                   fsdp=not args.no_fsdp,
                                   mustafar=mustafar,
                                   compile_=not args.lower_only)
                    r["mesh_name"] = mesh_name
                    status = r.get("skipped") and f"SKIP ({r['skipped']})" or \
                        (f"ok lower={r.get('lower_s')}s "
                         f"compile={r.get('compile_s')}s "
                         f"mem={r.get('memory', {}).get('per_device_total', 0)/2**30:.2f}GiB "
                         f"bottleneck={r.get('roofline', {}).get('bottleneck')}")
                    print(f"[dryrun] {tag}: {status}", flush=True)
                except Exception as e:  # noqa: BLE001 — record and continue
                    r = {"arch": arch, "shape": shape_name,
                         "mesh_name": mesh_name, "error": str(e)[:2000],
                         "traceback": traceback.format_exc()[-4000:]}
                    print(f"[dryrun] {tag}: FAIL {str(e)[:300]}", flush=True)
                results.append(r)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1, default=str)
    n_ok = sum(1 for r in results if "roofline" in r or "skipped" in r
               or (args.lower_only and "mode" in r))
    print(f"[dryrun] {n_ok}/{len(results)} cells ok -> {args.out}")
    return results


class _nullctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
