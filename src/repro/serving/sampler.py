"""Token sampling: greedy / temperature / top-k / top-p (nucleus)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def sample(logits: jax.Array, temperature: float = 0.0, rng=None,
           top_k: int = 0, top_p: float = 1.0) -> jax.Array:
    """logits [B, V] -> tokens [B].

    ``temperature <= 0`` is greedy (top_k/top_p are no-ops — argmax already
    picks the nucleus head). ``top_k > 0`` keeps the k highest logits;
    ``top_p < 1`` keeps the smallest set of tokens whose cumulative
    probability reaches ``top_p`` (the argmax token always survives, so the
    distribution can never empty — ``top_p <= 0`` keeps ONLY the argmax
    rather than silently disabling truncation). Both truncations compose: top-k first,
    then the nucleus over what remains — the scheduler plumbs them through
    per request (``Request.top_k`` / ``Request.top_p``).
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, NEG_INF, logits)
    if top_p < 1.0:
        # mask in RANK space and scatter back through the inverse sort:
        # a value-threshold cutoff would leak every token TIED with the
        # last nucleus member, sampling a larger set than specified
        sort_idx = jnp.argsort(-logits, axis=-1)       # stable: ties by id
        desc = jnp.take_along_axis(logits, sort_idx, axis=-1)
        probs = jax.nn.softmax(desc, axis=-1)
        # exclusive cumulative mass BEFORE each token: a token stays while
        # the mass above it is still < top_p; rank 0 is pinned so top_p <= 0
        # degrades to keep-argmax-only rather than an empty distribution
        cum = jnp.cumsum(probs, axis=-1) - probs
        kept_sorted = (cum < top_p).at[..., 0].set(True)
        inv = jnp.argsort(sort_idx, axis=-1)
        kept = jnp.take_along_axis(kept_sorted, inv, axis=-1)
        logits = jnp.where(kept, logits, NEG_INF)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
