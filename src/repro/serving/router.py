"""Data-parallel multi-engine router: N Scheduler replicas behind one
``submit()``/``step()`` API.

Tensor parallelism (``serving.sharded``) splits each step's work across
devices; the router is the orthogonal axis — it splits the REQUEST STREAM
across engine replicas, each with its own slot budget, page pool and
(optionally) mesh. One router step steps only the engines that currently
have work, which is where the throughput comes from: a continuous-batching
engine pays for ALL its slots every decode step (inactive rows ride along —
static shapes), so one 4-slot replica serving 3 requests costs ~4 slot-rows
per step while a single 16-slot engine serving the same 3 costs ~16. At
moderate concurrency the idle replicas simply don't step.

ROUTING. Three signals, in priority order:

1. **Prefix affinity** — each engine keeps its own ``PrefixIndex`` (page
   ids are engine-local, so the index cannot physically be shared), but
   the router treats the UNION of those indexes as one shared prefix
   cache: ``submit`` probes every engine's trie (read-only — no LRU
   touch) and routes to the engine holding the longest matched prefix, so
   a prompt family concentrates where its pages already live instead of
   recompressing per replica.
2. **Pack** (default policy): among engines with a free slot, prefer the
   BUSIEST — concentrating load keeps sibling replicas idle and therefore
   free to skip steps entirely (see above; the opposite of classic
   load-balancing, and the right call for throughput under static-shape
   batches — ``policy="spread"`` flips it for latency-sensitive traffic).
3. **Backlog** — when nobody can admit immediately, queue on the engine
   with the shortest waiting line.

Slot and page budgets partition evenly across replicas (remainders go to
the earliest engines); per-engine admission gating (slot capacity, page
budget, CoW headroom) is untouched Scheduler logic.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.serving import cache as cache_mod
from repro.serving.engine import Occupancy, Request, Scheduler


def _split_evenly(total: int, n: int) -> List[int]:
    base, rem = divmod(total, n)
    return [base + (1 if i < rem else 0) for i in range(n)]


class Router:
    """N engine replicas behind one submit()/step() API.

    ``n_slots`` / ``n_pages`` are TOTALS, partitioned across the
    ``n_engines`` replicas; ``meshes`` optionally pins each replica to its
    own device mesh (e.g. one single-device mesh per replica to spread
    engines over a host's devices, or a multi-device mesh each for
    TP-within-replica — router data parallelism composes with shard_map
    tensor parallelism). Every other keyword is forwarded verbatim to each
    ``Scheduler``."""

    def __init__(self, cfg: ModelConfig, params, n_engines: int,
                 n_slots: int, max_total_tokens: int, seed: int = 0,
                 n_pages: Optional[int] = None,
                 meshes: Optional[List[Any]] = None,
                 policy: str = "pack",
                 tracer=None,
                 **sched_kwargs):
        if n_engines < 1:
            raise ValueError(f"n_engines={n_engines} must be >= 1")
        if n_slots < n_engines:
            raise ValueError(f"n_slots={n_slots} cannot cover "
                             f"{n_engines} engines")
        if policy not in ("pack", "spread"):
            raise ValueError(f"unknown routing policy {policy!r}")
        if meshes is not None and len(meshes) != n_engines:
            raise ValueError("meshes must list one mesh per engine")
        if "registry" in sched_kwargs:
            # one registry across replicas would collide: callback gauges
            # (pool.*, spool.*) bind to ONE engine's allocator/spool and
            # get-or-create would silently keep the first binding. Each
            # engine keeps its own registry; stats() aggregates them.
            raise ValueError(
                "Router does not accept a shared registry= — each engine "
                "owns one; read fleet totals via Router.stats()")
        self.cfg = cfg
        self.policy = policy
        self.n_engines = n_engines
        slot_split = _split_evenly(n_slots, n_engines)
        page_split = (_split_evenly(n_pages, n_engines)
                      if n_pages is not None else [None] * n_engines)
        self.engines: List[Scheduler] = [
            Scheduler(cfg, params, n_slots=slot_split[i],
                      max_total_tokens=max_total_tokens, seed=seed + i,
                      n_pages=page_split[i],
                      mesh=(meshes[i] if meshes is not None else None),
                      tracer=tracer, tracer_tid=i,
                      **sched_kwargs)
            for i in range(n_engines)]
        self.step_count = 0
        self._uid = 0
        self._owner: Dict[int, int] = {}          # uid -> engine index

    # ------------------------------------------------------------------
    # routing

    def _load(self, e: Scheduler) -> int:
        """In-flight work on one engine: queued + mid-prefill + decoding
        + swapped-out (a preempted request still owes decode steps)."""
        return (len(e.waiting) + len(e._pending) + len(e._preempted)
                + sum(s is not None for s in e.slots))

    def _free_now(self, e: Scheduler, req: Optional[Request] = None) -> bool:
        """Could the engine admit at its next step? Requires a free slot
        beyond the queued backlog AND — when ``req`` is given and the
        engine is paged — worst-case page headroom for it, counting
        reclaimable pages (idle prefix-index holds, and preemptible
        lower-priority victims under ``admission_policy='preempt'``).
        Ignoring pages here routed requests at engines whose pool was
        pinned by live decoders while a sibling had free pages — the
        request then sat in that engine's queue (or thrashed its swap)
        for no reason."""
        free = sum(1 for i, s in enumerate(e.slots)
                   if s is None and i not in e._pending)
        if free <= len(e.waiting) + len(e._preempted):
            return False
        if req is not None and e.paged:
            total = len(req.prompt) + max(req.max_new_tokens, 1)
            need = e._worst_case_pages(len(req.prompt), total)
            if e.allocator.available \
                    + e.reclaimable_pages(req.priority) < need:
                return False
        return True

    def _prefix_affinity(self, prompt) -> Optional[int]:
        """Engine index holding the longest indexed prefix of ``prompt``
        (read-only probe of every replica's trie — the router-level view
        of a shared prefix cache), or None when nothing matches. Probes
        POTENTIAL coverage: a chain demoted to an engine's host spool
        still counts — promotion is far cheaper than recompressing on a
        sibling."""
        best, best_tokens = None, 0
        for i, e in enumerate(self.engines):
            if not e.share_prefix:
                continue
            comp, _ = cache_mod.prefill_split(e.cfg, len(prompt))
            shared_tokens = e.prefix.probe(prompt, comp)
            if shared_tokens > best_tokens:
                best, best_tokens = i, shared_tokens
        return best

    def _route(self, req: Request) -> int:
        hit = self._prefix_affinity(req.prompt)
        if hit is not None and self._free_now(self.engines[hit], req):
            # affinity only wins when the holder can actually admit —
            # honoring it unconditionally let a saturated replica with a
            # stale hit absorb the flood while its siblings sat idle
            # (recompressing a prefix elsewhere beats queueing behind a
            # full pool)
            return hit
        order = list(range(self.n_engines))
        if self.policy == "pack":
            # busiest-first among immediately-admissible engines: fills
            # replicas one at a time so the rest stay idle (skippable)
            order.sort(key=lambda i: -self._load(self.engines[i]))
            for i in order:
                if self._free_now(self.engines[i], req):
                    return i
            # everyone is saturated: shortest backlog
            return min(order, key=lambda i: len(self.engines[i].waiting))
        # spread: least loaded
        return min(order, key=lambda i: self._load(self.engines[i]))

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> Request:
        """Validate, pick a replica, enqueue. Router-global uids keep the
        aggregated ``finished`` list unambiguous."""
        if req.uid < 0:
            req.uid = self._uid
        self._uid = max(self._uid, req.uid) + 1
        i = self._route(req)
        self._owner[req.uid] = i
        self.engines[i].submit(req)
        return req

    def step(self) -> None:
        """One router step: step every engine that has work. Idle engines
        are skipped outright — no admit scan, no frozen decode."""
        for e in self.engines:
            if e.has_work:
                e.step()
        self.step_count += 1

    @property
    def has_work(self) -> bool:
        return any(e.has_work for e in self.engines)

    def run(self, max_steps: int = 1 << 20) -> List[Request]:
        while self.has_work and self.step_count < max_steps:
            self.step()
        return self.finished

    # ------------------------------------------------------------------
    # aggregation

    @property
    def finished(self) -> List[Request]:
        out: List[Request] = []
        for e in self.engines:
            out.extend(e.finished)
        out.sort(key=lambda r: r.uid)
        return out

    @property
    def rejected(self) -> List[Request]:
        """Requests shed under ``admission_policy='reject'``, fleet-wide."""
        out: List[Request] = []
        for e in self.engines:
            out.extend(e.rejected)
        out.sort(key=lambda r: r.uid)
        return out

    @property
    def engine_of(self) -> Dict[int, int]:
        """uid -> engine index (for tests / debugging)."""
        return dict(self._owner)

    @property
    def occupancy(self) -> Occupancy:
        """Fleet-level utilization: busy-slot (and busy-page) fractions
        over the steps each engine ACTUALLY ran — idle skipped steps cost
        nothing, so they are not in the denominator."""
        slot_num = sum(e.busy_slot_steps for e in self.engines)
        slot_den = sum(e.decode_steps * e.n_slots for e in self.engines)
        pages = None
        if all(e.paged for e in self.engines):
            page_num = sum(e.busy_page_steps for e in self.engines)
            page_den = sum(e.decode_steps * e.n_pages for e in self.engines)
            pages = page_num / max(1, page_den)
        return Occupancy(slot_num / max(1, slot_den), pages)

    def stats(self) -> Dict[str, Any]:
        """Fleet-level registry snapshot: per-replica registries folded
        into one (counters/gauges sum, fixed-bucket histograms merge
        exactly — see ``MetricsRegistry.aggregate``), plus the fleet
        ``occupancy`` ratios and a compact per-engine summary. The same
        metric names as ``Scheduler.stats()``, so dashboards/BENCH JSONs
        read identically for one engine or sixteen."""
        from repro.obs.metrics import MetricsRegistry
        agg = MetricsRegistry.aggregate([e.obs for e in self.engines])
        snap = agg.snapshot()
        snap["occupancy"] = dict(self.occupancy._asdict())
        snap["per_engine"] = [
            {"steps": e.step_count, "decode_steps": e.decode_steps,
             "finished": len(e.finished), "waiting": len(e.waiting),
             "preempted": len(e._preempted)}
            for e in self.engines]
        return snap

    @property
    def pages_in_use(self) -> int:
        """Fleet-wide drawn pages (includes prefix-index-held cache)."""
        return sum(e.allocator.in_use for e in self.engines
                   if e.paged)

    @property
    def page_leaks(self) -> int:
        """Drawn pages NOT accounted for by live slots or the prefix
        index's deliberate cache holds. 0 after a clean drain — the
        router-level zero-leak invariant the tests assert."""
        leaks = 0
        for e in self.engines:
            if not e.paged:
                continue
            held = set()
            for sp in e._slot_pages:
                held.update(sp)
            if e.share_prefix:
                held.update(e.prefix.held_pages)
            in_use = {p for p in range(e.n_pages)
                      if e.allocator.refcount(p) > 0}
            leaks += len(in_use - held)
        return leaks
