"""KV-head-sharded serving: run the paged engine hot path under shard_map.

LAYOUT CONTRACT (one mesh axis, ``"model"``, per engine):

- **params** — Megatron-style tensor parallelism, serving posture
  (``sharding.specs.serving_param_specs``): wq/wk/wv column-shard their
  fused head dim (head-major reshape ⇒ contiguous whole heads per
  device), wo row-shards to match, bq/bk/bv ride with their heads, and
  ``bo`` is replicated but divided by the axis size at install (it sits
  before the psum point — see ``_rescale_o_bias``). Norms, FFN, embedding
  and LM head replicate: every device runs the identical non-attention
  compute, so logits emerge replicated without a dedicated collective.
- **cache** — the global page pool ``[n_phys, Hkv, page_tokens, k]``
  shards Hkv on "model"; physical-page ids stay device-agnostic (the page
  dim is NOT sharded), so the block table + per-slot counters are
  replicated int32 metadata the host-side allocator mutates exactly as in
  the single-device engine. Dense windows and contiguous solo pools shard
  Hkv the same way (``sharding.specs.cache_specs``).
- **step functions** — decode / one-shot prefill / packed chunk step /
  finalize each wrap the EXISTING ``serving.engine`` function in one
  ``shard_map`` whose body runs with a LOCAL config (head counts divided
  by the axis size, ``local_config``) and ``model_axis="model"``: every
  device executes the same kernels on its head shard, and the ONLY
  cross-device traffic in steady state is one ``lax.psum`` of the [B,1,D]
  residual per attention layer. ``collective_audit`` proves it from the
  compiled HLO: all-reduce only, no all-gather / all-to-all /
  collective-permute (no per-step resharding).

Per-device bytes: ``pool_bytes / model + window_bytes / model +
replicated_metadata`` — ``serving.cache.cache_hbm_bytes(mesh_model=...)``
models it, ``per_device_cache_bytes`` measures it from live shards.

``check_rep=False`` everywhere: the replication of psum-produced outputs
is not verifiable by shard_map's static rep-checker, and the counter
leaves are replicated by construction (identical compute per device).
"""
from __future__ import annotations

import dataclasses
import re
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.model import structural_period
from repro.serving import engine as engine_mod
from repro.sharding import specs as specs_mod

MODEL = specs_mod.MODEL


# ----------------------------------------------------------------------
# eligibility + mesh/config plumbing

def sharding_supported(cfg: ModelConfig, model: int) -> bool:
    """True iff the serving shard_map posture covers this config at the
    given model-axis size: a pure-attention decoder stack (the same gate
    as chunked prefill — recurrent mixers would need their own state
    sharding story) whose q AND kv head counts divide the axis (whole
    heads per device is what keeps every existing kernel reusable)."""
    period = structural_period(cfg)
    return (model >= 1
            and cfg.family not in ("audio", "vlm")
            and all(cfg.layer_kind(j) == "attn" for j in range(period))
            and cfg.n_heads % model == 0
            and cfg.n_kv_heads % model == 0)


def make_serving_mesh(model: int, devices=None) -> Mesh:
    """1-D ("model",) mesh over the first ``model`` devices. Data
    parallelism lives ABOVE the mesh in ``serving.router`` (engine
    replicas), so a serving mesh never carries a "data" axis — batch
    leaves replicate automatically under ``specs.data_axes``."""
    devices = list(jax.devices()) if devices is None else list(devices)
    if model > len(devices):
        raise ValueError(
            f"model={model} exceeds the {len(devices)} visible devices")
    return Mesh(np.asarray(devices[:model]), (MODEL,))


def local_config(cfg: ModelConfig, model: int) -> ModelConfig:
    """The per-device view of the model: head counts divided by the axis
    size, everything else (d_head, d_model, GQA ratio) unchanged — the
    shard_map bodies hand this to the unmodified engine functions."""
    if model == 1:
        return cfg
    return dataclasses.replace(cfg, n_heads=cfg.n_heads // model,
                               n_kv_heads=cfg.n_kv_heads // model)


def _norm_spec(s: P) -> P:
    # a 1-D model mesh has no data axes, so batch rules resolve to the
    # empty tuple; normalize to None for shard_map spec matching
    return P(*(None if e == () else e for e in s))


def _norm_tree(tree):
    return jax.tree.map(_norm_spec, tree,
                        is_leaf=lambda x: isinstance(x, P))


def _rescale_o_bias(params, model: int):
    """``o_proj`` adds ``bo`` BEFORE the per-layer psum, so an unscaled
    replicated bias would be summed ``model`` times. Dividing it once at
    install keeps the engine code untouched: psum(out_i @ wo_i + bo/M)
    == (sum_i out_i @ wo_i) + bo."""
    if model == 1:
        return params

    def fix(path, leaf):
        name = str(getattr(path[-1], "key", ""))
        return (leaf / model).astype(leaf.dtype) if name == "bo" else leaf

    return jax.tree_util.tree_map_with_path(fix, params)


# ----------------------------------------------------------------------
# the four step functions, shard_map-wrapped

class ShardedServingOps:
    """Sharded placements + step functions for one Scheduler.

    Construction computes every PartitionSpec tree the engine needs
    (params, shared cache, solo prefill cache, chunk carry) and builds
    jitted shard_map wrappers with call signatures IDENTICAL to the
    single-device jits they replace — ``install_sharded_ops`` just swaps
    them onto the Scheduler."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh, params, cache,
                 n_slots: int, max_total_tokens: int,
                 fused_compaction: bool = False):
        M = int(mesh.shape[MODEL])
        if not sharding_supported(cfg, M):
            raise ValueError(
                f"config not shardable over model={M}: serving TP needs a "
                f"pure-attention decoder stack with n_heads={cfg.n_heads} "
                f"and n_kv_heads={cfg.n_kv_heads} divisible by the axis")
        self.cfg, self.mesh, self.M = cfg, mesh, M
        self.cfg_local = local_config(cfg, M)
        self.n_slots, self.max_total = n_slots, max_total_tokens
        self.pspecs = _norm_tree(
            specs_mod.serving_param_specs(params, cfg, mesh))
        self.cache_specs = _norm_tree(specs_mod.cache_specs(cache, cfg, mesh))
        period = structural_period(cfg)
        cspec = P(None, None, None, MODEL, None)   # [Pd,B,T_buf,Hkv,d]
        self.carry_specs = tuple({"k": cspec, "v": cspec}
                                 for _ in range(period))
        # the solo (B=1) prefill cache tree: structure is prompt-length
        # independent, so one eval_shape fixes the out_specs for every T
        m = cfg.mustafar
        T0 = (m.local_window + m.tile_tokens) if m.enabled else 8
        _, solo_shapes = jax.eval_shape(
            lambda p, t: engine_mod.prefill(p, t, cfg, max_total_tokens,
                                            plan_batch=n_slots),
            params, jax.ShapeDtypeStruct((1, T0), jnp.int32))
        self.solo_specs = _norm_tree(
            specs_mod.cache_specs(solo_shapes, cfg, mesh, paged=False))

        cfg_l = self.cfg_local

        def decode_body(p, token, cache, active):
            return engine_mod.decode_step(
                p, token, cache, cfg_l, active=active,
                fused_compaction=fused_compaction, model_axis=MODEL)

        dec = shard_map(decode_body, mesh=mesh,
                        in_specs=(self.pspecs, P(), self.cache_specs, P()),
                        out_specs=(P(), self.cache_specs),
                        check_rep=False)

        def _decode(p, token, cache, active=None):
            if active is None:
                active = jnp.ones(token.shape, jnp.bool_)
            return dec(p, token, cache, active)

        self.decode = jax.jit(_decode)

        @partial(jax.jit, static_argnames=("shared_tokens",))
        def _prefill(p, tokens, shared_tokens=0):
            def body(pp, tt):
                return engine_mod.prefill(
                    pp, tt, cfg_l, max_total_tokens, plan_batch=n_slots,
                    shared_tokens=shared_tokens, model_axis=MODEL)
            return shard_map(body, mesh=mesh,
                             in_specs=(self.pspecs, P()),
                             out_specs=(P(), self.solo_specs),
                             check_rep=False)(p, tokens)

        self.prefill = _prefill

        def chunk_body(p, t, c, o):
            return engine_mod.prefill_chunk_step(p, t, c, o, cfg_l,
                                                 model_axis=MODEL)

        self.chunk_step = jax.jit(shard_map(
            chunk_body, mesh=mesh,
            in_specs=(self.pspecs, P(), self.carry_specs, P()),
            out_specs=(P(), self.carry_specs), check_rep=False))

        @partial(jax.jit, static_argnames=("T", "shared_tokens"))
        def _finalize(p, kv_carry, T, shared_tokens=0):
            def body(pp, cc):
                # no attention here — prune+compress of the carried K/V is
                # head-local, so the body needs no psum; counters come out
                # replicated because every device computes them identically
                return engine_mod.finalize_chunked_prefill(
                    pp, cc, cfg_l, T, max_total_tokens, plan_batch=n_slots,
                    shared_tokens=shared_tokens)
            return shard_map(body, mesh=mesh,
                             in_specs=(self.pspecs, self.carry_specs),
                             out_specs=self.solo_specs,
                             check_rep=False)(p, kv_carry)

        self.finalize = _finalize

    # ------------------------------------------------------------------
    def shard_params(self, params):
        return jax.device_put(_rescale_o_bias(params, self.M),
                              specs_mod.to_named(self.pspecs, self.mesh))

    def shard_cache(self, cache):
        return jax.device_put(cache,
                              specs_mod.to_named(self.cache_specs, self.mesh))

    def shard_carry(self, carry):
        """Lay a fresh chunk carry out over the mesh (Hkv sharded) so the
        first packed chunk step never resharding-copies it."""
        return jax.device_put(carry,
                              specs_mod.to_named(self.carry_specs, self.mesh))


def install_sharded_ops(sched, mesh: Mesh) -> ShardedServingOps:
    """Switch a freshly-constructed Scheduler onto the mesh: shard its
    params/cache in place and replace the four jitted step functions with
    the shard_map wrappers. Called from ``Scheduler.__init__(mesh=...)``;
    everything else in the scheduler (allocator, block-table splices,
    packed-lane bookkeeping, sampling) is host-side metadata work that
    runs unchanged — eager updates on replicated leaves stay replicated
    and sliced/DUS'd sharded leaves keep their sharding under GSPMD."""
    ops = ShardedServingOps(sched.cfg, mesh, sched.params, sched.cache,
                            sched.n_slots, sched.max_total,
                            fused_compaction=sched.fused_compaction)
    sched.params = ops.shard_params(sched.params)
    sched.cache = ops.shard_cache(sched.cache)
    sched.next_tokens = jax.device_put(sched.next_tokens,
                                       NamedSharding(mesh, P()))
    sched._decode = ops.decode
    sched._prefill = ops.prefill
    sched._chunk_step = ops.chunk_step
    sched._finalize = ops.finalize
    sched._shard_carry = ops.shard_carry
    sched._sharded = ops
    return ops


# ----------------------------------------------------------------------
# verification: sharding assertions + compiled-HLO collective audit

_RESHARD_OPS = ("all-gather", "all-to-all", "collective-permute")


def collective_audit(jitted_fn, *args, **kwargs):
    """Compile a wrapped step on the given arguments and count collectives
    in the optimized HLO. Returns {op_name: count} for all-reduce plus the
    three resharding ops."""
    txt = jitted_fn.lower(*args, **kwargs).compile().as_text()
    return {op: len(re.findall(re.escape(op) + r"[.(\s-]", txt))
            for op in _RESHARD_OPS + ("all-reduce",)}


def assert_no_resharding(counts) -> None:
    """The steady-state contract: per-layer all-reduce is the ONLY
    collective; any all-gather / all-to-all / collective-permute means an
    input's layout disagrees with what the body produces (a per-step
    reshard that would swamp the psum traffic at scale)."""
    bad = {k: v for k, v in counts.items() if k in _RESHARD_OPS and v}
    if bad:
        raise AssertionError(
            f"resharding collectives in steady-state HLO: {bad}")


def assert_cache_shardings(sched) -> None:
    """Post-step layout check (the jax.debug-style assertion of the
    tentpole): every live cache leaf is laid out EXACTLY as cache_specs
    prescribes — pool/window leaves Hkv-sharded on "model", block table
    and counters replicated. Catches eager host-side mutations (block-
    table splices, CoW page copies, slot writes) silently resharding a
    leaf between steps."""
    ops = sched._sharded
    leaves = jax.tree.leaves(sched.cache)
    specs = jax.tree.leaves(ops.cache_specs,
                            is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(specs)
    for leaf, spec in zip(leaves, specs):
        want = NamedSharding(ops.mesh, spec)
        if not leaf.sharding.is_equivalent_to(want, leaf.ndim):
            raise AssertionError(
                f"cache leaf {leaf.shape} drifted to {leaf.sharding}, "
                f"expected {want}")


def per_device_cache_bytes(cache) -> int:
    """Measured per-device bytes of a (possibly sharded) cache: one
    addressable shard per leaf — replicated leaves charge their full
    size (every device holds a copy), sharded leaves 1/axis of it."""
    total = 0
    for leaf in jax.tree.leaves(cache):
        shards = getattr(leaf, "addressable_shards", None)
        if shards:
            d = shards[0].data
            total += int(d.size) * d.dtype.itemsize
        else:
            total += int(getattr(leaf, "nbytes", 0))
    return total
