"""Batched serving engine: dense/flash prefill + Mustafar decode + a
continuous-batching scheduler.

``prefill``  — full-sequence forward (FlashAttention-compatible, paper §3),
               then prune+compress everything older than the local window
               into the bitmap pools (tile groups of 64).
``decode_step`` — one token for the whole batch. ALL sequence-progress state
               is per-sequence ([B] int32 vectors): each slot appends at its
               own window offset, attends under its own validity masks, and
               retires a tile group when *its own* window fills (per-slot
               masked updates behind an any-slot work-skip cond — no global
               counter decides who compacts). An ``active`` mask
               freezes the counters of empty slots so a partially-filled
               batch decodes correctly.
``prefill_into_slot`` — ragged admission: prefill ONE sequence (any length)
               and splice its pools + right-padded window into a chosen slot
               of the shared cache via ``dynamic_update_slice``.
``Scheduler`` / ``Request`` — continuous batching on top: a request queue
               with slot-based admission, batched decode over whatever mix
               of sequences currently occupies the slots, and slot release/
               reuse on EOS or max-length.

All step functions are pure functions of (params, inputs, cache) so they
pjit cleanly; ``serve_step`` for the dry-run grid is ``decode_step`` under
the production mesh. The Engine class wraps them with jit and a lockstep
sampling loop (kept for benchmarks and equivalence tests).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Deque, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.attention import (MustafarCacheView, PagedMustafarCacheView,
                                  decode_attention_dense)
from repro.models import attention as attn
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models.layers import (cdtype, embed_tokens, lm_logits, mlp_apply,
                                 norm_apply)
from repro.models.model import (encode, layer_scan_unroll, structural_period)
from repro.serving import cache as cache_mod
from repro.sharding.constraints import DP, shard_activation


# ----------------------------------------------------------------------
# ffn dispatch shared by prefill/decode

def _ffn(bp, h, cfg: ModelConfig, kind: str, ffn_kind: str,
         cm_state: Optional[jax.Array] = None):
    if ffn_kind == "moe":
        out, _ = moe_mod.moe_apply(bp["ffn"], h, cfg)
        return out, None
    if kind == "rwkv":
        B = h.shape[0]
        st = cm_state if cm_state is not None else jnp.zeros(
            (B, cfg.d_model), h.dtype)
        out, new_st = rwkv_mod.rwkv_channel_mix(bp["ffn"], h, cfg, st)
        return out, new_st
    return mlp_apply(bp["ffn"], h, cfg), None


# ----------------------------------------------------------------------
# prefill

def prefill(params, tokens: jax.Array, cfg: ModelConfig,
            max_total_tokens: int,
            extra: Optional[Dict[str, jax.Array]] = None,
            plan_batch: Optional[int] = None):
    """tokens [B, T] -> (logits [B, V] at last position, cache).

    extra carries the stub modality inputs (frames / patches).
    ``plan_batch`` forces the compressed-pool planning batch so a solo (B=1)
    prefill produces pool shapes matching an n-slot shared cache.
    """
    extra = extra or {}
    B, T = tokens.shape
    x = embed_tokens(params["embed"], tokens, cfg)
    enc_out = None
    enc_ctx = 0
    if cfg.family == "vlm":
        vis = extra["patches"].astype(cdtype(cfg))
        vis = jnp.einsum("bvd,de->bve", vis,
                         params["vis_proj"].astype(cdtype(cfg)))
        x = jnp.concatenate([vis, x], axis=1)
    if cfg.family == "audio":
        enc_out = encode(params, extra["frames"], cfg, remat="none")
        enc_ctx = enc_out.shape[1]
        x = x + params["embed"]["positions"][:T].astype(cdtype(cfg))[None]
    T_total = x.shape[1]
    x = shard_activation(x, DP, None, None)
    positions = jnp.arange(T_total)[None, :]
    period = structural_period(cfg)

    def body(carry, bp_period):
        x = carry
        caches = []
        for j in range(period):
            bp = bp_period[j]
            kind = cfg.layer_kind(j)
            h = norm_apply(bp["norm1"], x, cfg.norm)
            if kind == "attn":
                q, k, v = attn.qkv_proj(bp["mixer"], h, cfg, positions)
                core = attn.causal_attention(q, k, v, cfg)
                x = x + attn.o_proj(bp["mixer"], core, cfg)
                cross_kv = None
                if cfg.family == "audio":
                    hc = norm_apply(bp["norm_cross"], x, cfg.norm)
                    cross_kv = attn.encoder_kv(bp["cross"], enc_out, cfg)
                    x = x + attn.cross_attention_block(bp["cross"], hc,
                                                       cross_kv, cfg)
                lc = cache_mod.build_layer_cache_from_prefill(
                    cfg, k, v, max_total_tokens, cross_kv, plan_batch)
            elif kind == "mamba":
                st = mamba_mod.mamba_state_shapes(cfg, B)
                mix, (conv_st, ssm_st) = mamba_mod.mamba_apply(
                    bp["mixer"], h, cfg, jnp.zeros(st["conv"], jnp.float32),
                    jnp.zeros(st["ssm"], jnp.float32))
                x = x + mix
                lc = {"conv": conv_st, "ssm": ssm_st}
            else:  # rwkv
                st = rwkv_mod.rwkv_state_shapes(cfg, B)
                mix, (tm_shift, wkv) = rwkv_mod.rwkv_time_mix(
                    bp["mixer"], h, cfg, jnp.zeros(st["tm_shift"], x.dtype),
                    jnp.zeros(st["wkv"], jnp.float32))
                x = x + mix
                lc = {"tm_shift": tm_shift, "wkv": wkv}
            h2 = norm_apply(bp["norm2"], x, cfg.norm)
            f, cm_state = _ffn(bp, h2, cfg, kind, cfg.ffn_kind(j))
            x = x + f
            if kind == "rwkv":
                lc["cm_shift"] = cm_state
            caches.append(lc)
        return x, tuple(caches)

    x, block_caches = jax.lax.scan(body, x, params["blocks"],
                                   unroll=layer_scan_unroll())
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params["embed"], x[:, -1:, :], cfg)[:, 0, :]

    comp, win = cache_mod.prefill_split(cfg, T_total)
    m = cfg.mustafar
    cache = {
        "blocks": block_caches,
        "position": jnp.full((B,), T_total, jnp.int32),
        "w_len": jnp.full((B,), win if m.enabled else 0, jnp.int32),
        "n_compressed": jnp.full((B,), comp if m.enabled else 0, jnp.int32),
    }
    return logits, cache


# ----------------------------------------------------------------------
# decode

def _attn_decode(bp, h, cfg: ModelConfig, lc, position, w_len, n_compressed,
                 block_table=None):
    """One attention layer, one token. h [B,1,D] -> (out [B,1,D], new lc).

    ``position``/``w_len``/``n_compressed`` are per-sequence [B] vectors —
    RoPE rotates each row at its own ragged offset and the validity masks
    differ per row, so slots at different depths coexist in one batch.
    ``block_table`` (paged caches) switches the compressed operands to the
    paged view; formulation choice still lives in decode_attention_auto."""
    B = h.shape[0]
    q, k, v = attn.qkv_proj(bp["mixer"], h, cfg, position[:, None])  # [B,1,H,dh]
    m = cfg.mustafar
    if m.enabled:
        lc = cache_mod.append_window(lc, jnp.swapaxes(k, 1, 2),
                                     jnp.swapaxes(v, 1, 2), w_len)
        if block_table is not None:
            view = PagedMustafarCacheView(
                ck_pool=lc["ck_vals"], ck_bitmap=lc["ck_bm"],
                cv_pool=lc["cv_vals"], cv_bitmap=lc["cv_bm"],
                block_table=block_table, n_compressed=n_compressed,
                k_window=lc["k_win"], v_window=lc["v_win"],
                n_window=w_len + 1)
        else:
            view = MustafarCacheView(
                ck_values=lc["ck_vals"], ck_bitmap=lc["ck_bm"],
                cv_values=lc["cv_vals"], cv_bitmap=lc["cv_bm"],
                n_compressed=n_compressed,
                k_window=lc["k_win"], v_window=lc["v_win"],
                n_window=w_len + 1)
        # formulation choice (two-pass / fused Pallas kernel / chunked scan)
        # lives in models.attention.decode_attention_auto: sharding-friendly
        # two-pass for B==1 and small pools, the DMA-skipping fused kernel
        # for multi-chunk batched decode on TPU, chunked online softmax
        # elsewhere.
        out = attn.decode_attention_auto(q[:, 0], view, cfg,
                                         scale=cfg.d_head ** -0.5)
    else:
        def upd(buf, tok, p):                          # per-sequence DUS
            return jax.lax.dynamic_update_slice(
                buf, tok.astype(buf.dtype), (0, p, 0))

        lc = dict(lc)
        lc["k"] = jax.vmap(upd)(lc["k"], jnp.swapaxes(k, 1, 2), position)
        lc["v"] = jax.vmap(upd)(lc["v"], jnp.swapaxes(v, 1, 2), position)
        out = decode_attention_dense(q[:, 0], lc["k"], lc["v"], position + 1,
                                     scale=cfg.d_head ** -0.5)
    y = attn.o_proj(bp["mixer"],
                    out[:, None, :, :].reshape(B, 1, cfg.n_heads, cfg.d_head),
                    cfg)
    return y, lc


def decode_step(params, token: jax.Array, cache, cfg: ModelConfig,
                active: Optional[jax.Array] = None):
    """token [B] -> (logits [B, V], new cache). One step for the batch.

    Every slot advances independently: per-sequence [B] counters, per-slot
    compaction, per-row RoPE/masks. ``active`` [B] bool (default all-True)
    freezes the counters of empty slots — their rows still flow through the
    network (static shapes) but their cache state does not advance, so a
    scheduler can decode a partially-occupied batch and later reuse the
    slot via ``prefill_into_slot``."""
    B = token.shape[0]
    m = cfg.mustafar
    period = structural_period(cfg)
    position = cache["position"]                   # [B]
    w_len = cache["w_len"]                         # [B]
    n_comp = cache["n_compressed"]                 # [B]
    block_table = cache.get("block_table")         # [B, MP] iff paged
    act = jnp.ones((B,), jnp.int32) if active is None \
        else active.astype(jnp.int32)
    blocks = cache["blocks"]

    # --- per-slot tile-group compaction: a slot retires its oldest tile
    # group exactly when its OWN window fills. The per-slot decision is a
    # masked select (jnp.where inside compact_layer — no global counter,
    # slots at different depths compact at different steps); an outer
    # any-slot cond skips the compress entirely on the ~(tile_tokens-1)/
    # tile_tokens of steps where no slot is due, restoring the amortized
    # cost of the old lockstep path without coupling the slots ---
    if m.enabled and any(cfg.layer_kind(j) == "attn" for j in range(period)):
        Wbuf = m.local_window + m.tile_tokens
        # per-slot trigger; inactive slots are frozen entirely (a request
        # can retire the very step its window fills — the dead slot must
        # not keep mutating its pools/counters)
        need = (w_len >= Wbuf) & (act > 0)         # [B]

        def do_compact(blocks):
            new_blocks = []
            for j in range(period):
                lc = blocks[j]
                if cfg.layer_kind(j) == "attn":
                    if block_table is not None:
                        lc = jax.vmap(lambda one: cache_mod.compact_layer_paged(
                            cfg, one, n_comp, block_table, need))(lc)
                    else:
                        lc = jax.vmap(lambda one: cache_mod.compact_layer(
                            cfg, one, n_comp, need))(lc)
                new_blocks.append(lc)
            return tuple(new_blocks)

        blocks = jax.lax.cond(jnp.any(need), do_compact, lambda b: b, blocks)
        w_len = jnp.where(need, w_len - m.tile_tokens, w_len)
        n_comp = jnp.where(need, n_comp + m.tile_tokens, n_comp)

    x = embed_tokens(params["embed"], token[:, None], cfg)     # [B,1,D]
    x = shard_activation(x, DP, None, None)
    if cfg.family == "audio":
        # per-sequence learned positions at each slot's own offset
        x = x + params["embed"]["positions"][position][:, None, :]

    def body(carry, xs):
        x = carry
        bp_period, lc_period = xs
        new_caches = []
        for j in range(period):
            bp, lc = bp_period[j], lc_period[j]
            kind = cfg.layer_kind(j)
            h = norm_apply(bp["norm1"], x, cfg.norm)
            if kind == "attn":
                y, lc = _attn_decode(bp, h, cfg, lc, position, w_len, n_comp,
                                     block_table)
                x = x + y
                if cfg.family == "audio":
                    hc = norm_apply(bp["norm_cross"], x, cfg.norm)
                    x = x + attn.cross_attention_block(
                        bp["cross"], hc, (lc["cross_k"], lc["cross_v"]), cfg)
            elif kind == "mamba":
                lc = dict(lc)
                mix, (lc["conv"], lc["ssm"]) = mamba_mod.mamba_apply(
                    bp["mixer"], h, cfg, lc["conv"], lc["ssm"])
                x = x + mix
            else:  # rwkv
                lc = dict(lc)
                mix, (lc["tm_shift"], lc["wkv"]) = rwkv_mod.rwkv_time_mix(
                    bp["mixer"], h, cfg, lc["tm_shift"], lc["wkv"])
                x = x + mix
            h2 = norm_apply(bp["norm2"], x, cfg.norm)
            f, cm_state = _ffn(bp, h2, cfg, kind, cfg.ffn_kind(j),
                               lc.get("cm_shift"))
            x = x + f
            if kind == "rwkv":
                lc["cm_shift"] = cm_state
            new_caches.append(lc)
        return x, tuple(new_caches)

    x, new_blocks = jax.lax.scan(body, x, (params["blocks"], blocks),
                                 unroll=layer_scan_unroll())
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params["embed"], x, cfg)[:, 0, :]
    new_cache = {
        "blocks": new_blocks,
        "position": position + act,                # frozen where inactive
        "w_len": w_len + act if m.enabled else jnp.zeros_like(w_len),
        "n_compressed": n_comp,
    }
    if block_table is not None:
        new_cache["block_table"] = block_table     # mappings change host-side
    return logits, new_cache


# ----------------------------------------------------------------------
# continuous batching: ragged admission + scheduler

def prefill_into_slot(params, tokens: jax.Array, cache, slot, cfg: ModelConfig,
                      max_total_tokens: int,
                      extra: Optional[Dict[str, jax.Array]] = None,
                      prefill_fn=None, pages=None,
                      page_tokens: Optional[int] = None):
    """Prefill ONE sequence (tokens [1, T], any T — requests stay ragged)
    and splice its compressed pools + right-padded window into batch slot
    ``slot`` of the shared cache via ``dynamic_update_slice``.

    Returns (last-position logits [V], new shared cache). The solo prefill
    plans its pools with the shared batch size so the leaf shapes line up.
    ``prefill_fn`` overrides the solo prefill callable — the Scheduler
    passes its jitted one; it must accept (params, tokens) and already
    bind cfg/max_total/plan_batch consistently with this cache.

    For a PAGED shared cache pass ``pages`` (physical page ids covering at
    least the prefill's compressed fill) and ``page_tokens``: the solo
    contiguous pools are then copied page-by-page and the slot's
    block-table row rewritten (``cache_mod.write_slot_paged``).
    """
    if prefill_fn is None:
        n_slots = cache["position"].shape[0]
        prefill_fn = lambda p, t: prefill(p, t, cfg, max_total_tokens,
                                          extra=extra, plan_batch=n_slots)
    logits, solo = prefill_fn(params, tokens)
    if pages is not None:
        return logits[0], cache_mod.write_slot_paged(cfg, cache, solo, slot,
                                                     pages, page_tokens)
    return logits[0], cache_mod.write_slot(cache, solo, slot)


@dataclass
class Request:
    """One generation request for the Scheduler."""
    prompt: Any                          # [T] int tokens (list/np/jnp)
    max_new_tokens: int
    eos_token_id: Optional[int] = None
    temperature: float = 0.0
    uid: int = -1
    # filled in by the scheduler:
    arrival_step: int = -1               # engine step when submitted
    prefill_step: int = -1               # engine step when admitted
    finish_step: int = -1                # engine step when retired
    output_tokens: List[int] = field(default_factory=list)
    logits: List[Any] = field(default_factory=list)  # per-token, if collected

    @property
    def num_generated(self) -> int:
        return len(self.output_tokens)

    @property
    def done(self) -> bool:
        return self.finish_step >= 0


class Occupancy(NamedTuple):
    """Scheduler utilization report.

    ``slots`` — mean fraction of batch slots doing useful work per decode
    step. ``pages`` — mean fraction of the physical page pool drawn per
    decode step (None when the cache is contiguous). Under page-budget
    admission the interesting regime is high ``slots`` at modest ``pages``:
    heterogeneous-length batches keep every slot busy without any slot
    reserving worst-case pool memory."""
    slots: float
    pages: Optional[float] = None


class Scheduler:
    """Continuous-batching serving loop over a shared ``n_slots`` cache.

    Each engine step: (1) admit waiting requests into free slots (ragged
    solo prefill spliced in via ``prefill_into_slot`` — the first output
    token comes from the prefill logits), (2) one batched ``decode_step``
    over whatever mix of sequences currently occupies the slots (empty
    slots ride along frozen under the ``active`` mask), (3) sample one
    token per active slot, retiring sequences on EOS or max-new-tokens and
    releasing their slots for immediate reuse.

    Per-request math matches running that request alone through the
    lockstep path: every decode op is row-independent and each slot's
    counters/compaction advance exactly as a solo run's would (asserted in
    tests/test_scheduler.py). With pools at or under one decode chunk
    (Tc <= DECODE_CHUNK) both take the two-pass attention and the match is
    bit-exact; larger pools decode batched via the chunked online softmax,
    whose fp reordering vs the solo two-pass path can differ in the last
    ulp (greedy ties may resolve differently at that scale).

    PAGED MODE (``page_tokens`` set): the compressed pools become one
    global page pool shared by all slots, and admission is gated on the
    PAGE budget, not just a free slot — a request is admitted only when the
    allocator can promise its worst-case page count
    (``cache.pages_for_request``), so decode can never run out of pool
    mid-request. Physical pages are drawn lazily: the prefill's fill at
    admission, then one page right before the decode step whose compaction
    first writes it (the scheduler mirrors each slot's ``w_len`` /
    ``n_compressed`` counters on the host to predict compactions — decode
    itself stays one jitted call). Retirement returns drawn pages and
    unused promises to the free list and severs the slot's block-table row.
    ``n_pages`` below ``n_slots · max_pages`` overcommits: all slots can be
    busy as long as their combined worst-case budgets fit, which is the
    whole payoff for heterogeneous-length traffic.
    """

    def __init__(self, cfg: ModelConfig, params, n_slots: int,
                 max_total_tokens: int, seed: int = 0,
                 collect_logits: bool = False,
                 page_tokens: Optional[int] = None,
                 n_pages: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_total = max_total_tokens
        self.page_tokens = page_tokens
        self.paged = page_tokens is not None
        if self.paged:
            self.max_pages = cache_mod.plan_pages(
                cfg, max_total_tokens, page_tokens, batch=n_slots)
            self.n_pages = (n_slots * self.max_pages if n_pages is None
                            else n_pages)
            self.allocator = cache_mod.PageAllocator(self.n_pages)
            self._slot_pages: List[List[int]] = [[] for _ in range(n_slots)]
            self._slot_reserved = [0] * n_slots   # undrawn promises per slot
            self._w_len = [0] * n_slots           # host mirrors of the
            self._n_comp = [0] * n_slots          # per-slot device counters
            self.busy_page_steps = 0
        self.cache = cache_mod.init_cache(cfg, n_slots, max_total_tokens,
                                          page_tokens=page_tokens,
                                          n_pages=n_pages)
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.waiting: Deque[Request] = collections.deque()
        self.next_tokens = jnp.zeros((n_slots,), jnp.int32)
        self.rng = jax.random.PRNGKey(seed)
        self.collect_logits = collect_logits
        self.finished: List[Request] = []
        self.step_count = 0
        self.decode_steps = 0
        self.busy_slot_steps = 0
        self._uid = 0
        self._decode = jax.jit(partial(decode_step, cfg=cfg))
        self._prefill = jax.jit(partial(prefill, cfg=cfg,
                                        max_total_tokens=max_total_tokens,
                                        plan_batch=n_slots))

    # ------------------------------------------------------------------
    def _check_admissible(self, req: Request) -> int:
        """Raise unless the request could EVER be served; return its total
        token need. Silent truncation (admit + rely on max-length
        retirement) is not an option: under page budgets an oversized
        request would sit at the queue head waiting for pages that can
        never materialise, deadlocking every request behind it."""
        n_prompt = len(req.prompt)
        # the prefill itself emits one output token, so a request always
        # generates >= 1 even with max_new_tokens=0 — budgeting with the
        # raw value would under-reserve the prefill's own page fill
        total = n_prompt + max(req.max_new_tokens, 1)
        if total > self.max_total:
            raise ValueError(
                f"request needs {n_prompt} prompt + {req.max_new_tokens} new "
                f"tokens = {total}; slot capacity is {self.max_total} "
                f"(max_total_tokens) — rejecting rather than truncating")
        if self.paged:
            need = cache_mod.pages_for_request(self.cfg, total,
                                               self.page_tokens)
            if need > self.n_pages:
                raise ValueError(
                    f"request needs {need} pages worst-case; the pool holds "
                    f"{self.n_pages} — it could never be admitted")
        return total

    def submit(self, req: Request) -> Request:
        """Queue a request (admitted at the next step with a free slot)."""
        self._check_admissible(req)
        if req.uid < 0:
            req.uid = self._uid
        self._uid = max(self._uid, req.uid) + 1
        req.arrival_step = self.step_count
        self.waiting.append(req)
        return req

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(s is not None for s in self.slots)

    @property
    def occupancy(self) -> Occupancy:
        """Slot AND page utilization (see ``Occupancy``)."""
        slots = self.busy_slot_steps / max(1, self.decode_steps * self.n_slots)
        pages = None
        if self.paged:
            pages = self.busy_page_steps / max(
                1, self.decode_steps * self.n_pages)
        return Occupancy(slots, pages)

    # ------------------------------------------------------------------
    def _sample_one(self, logits: jax.Array, req: Request) -> int:
        from repro.serving.sampler import sample
        self.rng, sub = jax.random.split(self.rng)
        return int(sample(logits[None], req.temperature, sub)[0])

    def _sample_batch(self, logits: jax.Array):
        """One batched sample call + ONE device->host transfer per decode
        step when every active request shares a temperature (the common
        case); returns None to fall back to per-slot sampling otherwise."""
        import numpy as np

        from repro.serving.sampler import sample
        temps = {r.temperature for r in self.slots if r is not None}
        if len(temps) != 1:
            return None
        self.rng, sub = jax.random.split(self.rng)
        return np.asarray(sample(logits, temps.pop(), sub))

    def _retire(self, req: Request) -> None:
        req.finish_step = self.step_count
        self.finished.append(req)

    def _record(self, req: Request, tok: int, logits: jax.Array) -> bool:
        """Append one sampled token; True if the request just finished."""
        req.output_tokens.append(tok)
        if self.collect_logits:
            import numpy as np
            req.logits.append(np.asarray(logits, np.float32))
        if ((req.eos_token_id is not None and tok == req.eos_token_id)
                or req.num_generated >= req.max_new_tokens):
            self._retire(req)
            return True
        return False

    def _release_pages(self, slot: int) -> None:
        """Return a retired (or never-occupied) slot's drawn pages and
        unused promises; sever its block-table row so a later tenant can
        never alias a freed page."""
        if not self.paged:
            return
        self.allocator.free(self._slot_pages[slot])
        self.allocator.unreserve(self._slot_reserved[slot])
        self._slot_pages[slot] = []
        self._slot_reserved[slot] = 0
        self._w_len[slot] = 0
        self._n_comp[slot] = 0
        self.cache["block_table"] = self.cache["block_table"].at[slot].set(
            cache_mod.PAGE_UNMAPPED)

    def _provision_pages(self, active_flags: List[bool]) -> None:
        """Host mirror of ``decode_step``'s per-slot counter logic: if the
        upcoming step will compact a slot into a not-yet-mapped logical
        page, draw one (from the reservation made at admission) and write
        the block-table entry BEFORE the jitted decode fires."""
        m = self.cfg.mustafar
        if not m.enabled:
            return
        tt = m.tile_tokens
        wbuf = m.local_window + tt
        for slot, act in enumerate(active_flags):
            if not act:
                continue
            if self._w_len[slot] >= wbuf:              # compaction this step
                lp = self._n_comp[slot] // self.page_tokens
                if lp >= len(self._slot_pages[slot]):
                    assert self._slot_reserved[slot] > 0, \
                        "page budget exhausted mid-request (planner bug)"
                    page = self.allocator.draw()
                    self._slot_reserved[slot] -= 1
                    self._slot_pages[slot].append(page)
                    self.cache["block_table"] = \
                        self.cache["block_table"].at[slot, lp].set(page)
                self._n_comp[slot] += tt
                self._w_len[slot] -= tt
            self._w_len[slot] += 1

    def _admit(self) -> None:
        free = [i for i, s in enumerate(self.slots) if s is None]
        while free and self.waiting:
            req = self.waiting[0]
            # re-validate at admission: requests can reach the queue without
            # submit() (or be mutated after it), and an inadmissible head
            # would deadlock the queue under page-budget gating
            total = self._check_admissible(req)
            pages_needed = 0
            if self.paged:
                pages_needed = cache_mod.pages_for_request(
                    self.cfg, total, self.page_tokens)
                if not self.allocator.can_reserve(pages_needed):
                    break            # wait for a retirement to free pages
            self.waiting.popleft()
            slot = free[0]
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            pages = None
            if self.paged:
                comp, win = cache_mod.prefill_split(self.cfg, len(req.prompt))
                n_prefill = -(-comp // self.page_tokens)
                assert n_prefill <= pages_needed, (n_prefill, pages_needed)
                self.allocator.reserve(pages_needed)
                pages = [self.allocator.draw() for _ in range(n_prefill)]
                self._slot_pages[slot] = pages
                self._slot_reserved[slot] = pages_needed - n_prefill
                self._w_len[slot] = win
                self._n_comp[slot] = comp
            # jit caches one prefill executable per distinct prompt length
            lg, self.cache = prefill_into_slot(
                self.params, toks, self.cache, slot, self.cfg, self.max_total,
                prefill_fn=self._prefill, pages=pages,
                page_tokens=self.page_tokens)
            req.prefill_step = self.step_count
            tok = self._sample_one(lg, req)
            if self._record(req, tok, lg):
                self._release_pages(slot)
                continue                 # finished on the prefill token;
                                         # slot stays free for the next one
            free.pop(0)
            self.slots[slot] = req
            self.next_tokens = self.next_tokens.at[slot].set(tok)

    # ------------------------------------------------------------------
    def step(self) -> None:
        """One engine iteration: admit → batched decode → sample/retire."""
        self._admit()
        active_flags = [s is not None for s in self.slots]
        if any(active_flags):
            if self.paged:
                self._provision_pages(active_flags)
            active = jnp.asarray(active_flags)
            logits, self.cache = self._decode(self.params, self.next_tokens,
                                              self.cache, active=active)
            self.decode_steps += 1
            self.busy_slot_steps += sum(active_flags)
            if self.paged:
                self.busy_page_steps += self.allocator.in_use
            batch_toks = self._sample_batch(logits)
            for slot, req in enumerate(self.slots):
                if req is None:
                    continue
                tok = (int(batch_toks[slot]) if batch_toks is not None
                       else self._sample_one(logits[slot], req))
                if self._record(req, tok, logits[slot]):
                    self.slots[slot] = None          # released for reuse
                    self._release_pages(slot)
                else:
                    self.next_tokens = self.next_tokens.at[slot].set(tok)
        self.step_count += 1

    def run(self, max_steps: int = 1 << 20) -> List[Request]:
        """Drive until the queue and all slots drain; returns finished."""
        while self.has_work and self.step_count < max_steps:
            self.step()
        return self.finished


# ----------------------------------------------------------------------
class Engine:
    """Jit-wrapped convenience driver for examples/benchmarks."""

    def __init__(self, cfg: ModelConfig, params, max_total_tokens: int):
        self.cfg = cfg
        self.params = params
        self.max_total = max_total_tokens
        self._prefill = jax.jit(partial(prefill, cfg=cfg,
                                        max_total_tokens=max_total_tokens))
        self._decode = jax.jit(partial(decode_step, cfg=cfg))

    def generate(self, tokens: jax.Array, n_new: int, *,
                 temperature: float = 0.0, rng=None,
                 extra: Optional[Dict[str, jax.Array]] = None) -> jax.Array:
        from repro.serving.sampler import sample
        logits, cache = self._prefill(self.params, tokens, extra=extra)
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        outs = []
        tok = sample(logits, temperature, rng)
        outs.append(tok)
        for i in range(n_new - 1):
            rng = jax.random.fold_in(rng, i)
            logits, cache = self._decode(self.params, tok, cache)
            tok = sample(logits, temperature, rng)
            outs.append(tok)
        return jnp.stack(outs, axis=1)                  # [B, n_new]
