"""Batched serving engine: dense/flash prefill + Mustafar decode + a
continuous-batching scheduler.

``prefill``  — full-sequence forward (FlashAttention-compatible, paper §3),
               then prune+compress everything older than the local window
               into the bitmap pools (tile groups of 64).
``decode_step`` — one token for the whole batch. ALL sequence-progress state
               is per-sequence ([B] int32 vectors): each slot appends at its
               own window offset, attends under its own validity masks, and
               retires a tile group when *its own* window fills (per-slot
               masked updates behind an any-slot work-skip cond — no global
               counter decides who compacts). An ``active`` mask
               freezes the counters of empty slots so a partially-filled
               batch decodes correctly.
``prefill_into_slot`` — ragged admission: prefill ONE sequence (any length)
               and splice its pools + right-padded window into a chosen slot
               of the shared cache via ``dynamic_update_slice``.
``Scheduler`` / ``Request`` — continuous batching on top: a request queue
               with slot-based admission, batched decode over whatever mix
               of sequences currently occupies the slots, and slot release/
               reuse on EOS or max-length.

All step functions are pure functions of (params, inputs, cache) so they
pjit cleanly; ``serve_step`` for the dry-run grid is ``decode_step`` under
the production mesh. The Engine class wraps them with jit and a lockstep
sampling loop (kept for benchmarks and equivalence tests).
"""
from __future__ import annotations

import collections
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Deque, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.attention import (MustafarCacheView, PagedMustafarCacheView,
                                  decode_attention_dense)
from repro.models import attention as attn
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models.layers import (cdtype, embed_tokens, lm_logits, mlp_apply,
                                 norm_apply)
from repro.models.model import (encode, layer_scan_unroll, structural_period)
from repro.serving import cache as cache_mod
from repro.sharding.constraints import DP, shard_activation


# ----------------------------------------------------------------------
# ffn dispatch shared by prefill/decode

def _ffn(bp, h, cfg: ModelConfig, kind: str, ffn_kind: str,
         cm_state: Optional[jax.Array] = None):
    if ffn_kind == "moe":
        out, _ = moe_mod.moe_apply(bp["ffn"], h, cfg)
        return out, None
    if kind == "rwkv":
        B = h.shape[0]
        st = cm_state if cm_state is not None else jnp.zeros(
            (B, cfg.d_model), h.dtype)
        out, new_st = rwkv_mod.rwkv_channel_mix(bp["ffn"], h, cfg, st)
        return out, new_st
    return mlp_apply(bp["ffn"], h, cfg), None


# ----------------------------------------------------------------------
# prefill

def prefill(params, tokens: jax.Array, cfg: ModelConfig,
            max_total_tokens: int,
            extra: Optional[Dict[str, jax.Array]] = None,
            plan_batch: Optional[int] = None,
            shared_tokens: int = 0,
            model_axis: Optional[str] = None):
    """tokens [B, T] -> (logits [B, V] at last position, cache).

    extra carries the stub modality inputs (frames / patches).
    ``plan_batch`` forces the compressed-pool planning batch so a solo (B=1)
    prefill produces pool shapes matching an n-slot shared cache.
    ``shared_tokens`` (static) skips compressing the first S tokens of the
    compressed region — they arrive via shared prefix pages at the paged
    splice; the forward pass itself still covers the whole prompt (exact
    attention over the dense K/V is what keeps a shared-prefix admission
    bit-identical to a solo run — the compressed pages only ever feed
    DECODE steps, so sharing is a storage-level dedup, not an approximation).

    ``model_axis`` (static) marks this call as the per-device body of a
    ``shard_map`` over that mesh axis: ``cfg`` then carries the LOCAL head
    counts (``serving.sharded`` divides them), every attention layer's
    output projection is partial over the local heads and is all-reduced
    with ``lax.psum`` — the Megatron-style tensor-parallel cut. Everything
    outside attention (norms, FFN, embed/lm_head) computes replicated.
    """
    extra = extra or {}
    B, T = tokens.shape
    x = embed_tokens(params["embed"], tokens, cfg)
    enc_out = None
    enc_ctx = 0
    if cfg.family == "vlm":
        vis = extra["patches"].astype(cdtype(cfg))
        vis = jnp.einsum("bvd,de->bve", vis,
                         params["vis_proj"].astype(cdtype(cfg)))
        x = jnp.concatenate([vis, x], axis=1)
    if cfg.family == "audio":
        enc_out = encode(params, extra["frames"], cfg, remat="none")
        enc_ctx = enc_out.shape[1]
        x = x + params["embed"]["positions"][:T].astype(cdtype(cfg))[None]
    T_total = x.shape[1]
    x = shard_activation(x, DP, None, None)
    positions = jnp.arange(T_total)[None, :]
    period = structural_period(cfg)

    def body(carry, bp_period):
        x = carry
        caches = []
        for j in range(period):
            bp = bp_period[j]
            kind = cfg.layer_kind(j)
            h = norm_apply(bp["norm1"], x, cfg.norm)
            if kind == "attn":
                q, k, v = attn.qkv_proj(bp["mixer"], h, cfg, positions)
                core = attn.causal_attention(q, k, v, cfg)
                y = attn.o_proj(bp["mixer"], core, cfg)
                if model_axis is not None:
                    y = jax.lax.psum(y, model_axis)
                x = x + y
                cross_kv = None
                if cfg.family == "audio":
                    hc = norm_apply(bp["norm_cross"], x, cfg.norm)
                    cross_kv = attn.encoder_kv(bp["cross"], enc_out, cfg)
                    x = x + attn.cross_attention_block(bp["cross"], hc,
                                                       cross_kv, cfg)
                lc = cache_mod.build_layer_cache_from_prefill(
                    cfg, k, v, max_total_tokens, cross_kv, plan_batch,
                    shared_tokens)
            elif kind == "mamba":
                st = mamba_mod.mamba_state_shapes(cfg, B)
                mix, (conv_st, ssm_st) = mamba_mod.mamba_apply(
                    bp["mixer"], h, cfg, jnp.zeros(st["conv"], jnp.float32),
                    jnp.zeros(st["ssm"], jnp.float32))
                x = x + mix
                lc = {"conv": conv_st, "ssm": ssm_st}
            else:  # rwkv
                st = rwkv_mod.rwkv_state_shapes(cfg, B)
                mix, (tm_shift, wkv) = rwkv_mod.rwkv_time_mix(
                    bp["mixer"], h, cfg, jnp.zeros(st["tm_shift"], x.dtype),
                    jnp.zeros(st["wkv"], jnp.float32))
                x = x + mix
                lc = {"tm_shift": tm_shift, "wkv": wkv}
            h2 = norm_apply(bp["norm2"], x, cfg.norm)
            f, cm_state = _ffn(bp, h2, cfg, kind, cfg.ffn_kind(j))
            x = x + f
            if kind == "rwkv":
                lc["cm_shift"] = cm_state
            caches.append(lc)
        return x, tuple(caches)

    x, block_caches = jax.lax.scan(body, x, params["blocks"],
                                   unroll=layer_scan_unroll())
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params["embed"], x[:, -1:, :], cfg)[:, 0, :]

    comp, win = cache_mod.prefill_split(cfg, T_total)
    m = cfg.mustafar
    cache = {
        "blocks": block_caches,
        "position": jnp.full((B,), T_total, jnp.int32),
        "w_len": jnp.full((B,), win if m.enabled else 0, jnp.int32),
        "n_compressed": jnp.full((B,), comp if m.enabled else 0, jnp.int32),
    }
    return logits, cache


# ----------------------------------------------------------------------
# decode

def _attn_decode(bp, h, cfg: ModelConfig, lc, position, w_len, n_compressed,
                 block_table=None, model_axis=None):
    """One attention layer, one token. h [B,1,D] -> (out [B,1,D], new lc).

    ``position``/``w_len``/``n_compressed`` are per-sequence [B] vectors —
    RoPE rotates each row at its own ragged offset and the validity masks
    differ per row, so slots at different depths coexist in one batch.
    ``block_table`` (paged caches) switches the compressed operands to the
    paged view; formulation choice still lives in decode_attention_auto.
    ``model_axis``: inside a shard_map body, cfg carries local head counts
    and the o_proj output (partial over the head shard) is psum-reduced."""
    B = h.shape[0]
    q, k, v = attn.qkv_proj(bp["mixer"], h, cfg, position[:, None])  # [B,1,H,dh]
    m = cfg.mustafar
    if m.enabled:
        lc = cache_mod.append_window(lc, jnp.swapaxes(k, 1, 2),
                                     jnp.swapaxes(v, 1, 2), w_len)
        if block_table is not None:
            view = PagedMustafarCacheView(
                ck_pool=lc["ck_vals"], ck_bitmap=lc["ck_bm"],
                cv_pool=lc["cv_vals"], cv_bitmap=lc["cv_bm"],
                block_table=block_table, n_compressed=n_compressed,
                k_window=lc["k_win"], v_window=lc["v_win"],
                n_window=w_len + 1,
                ck_scale=lc.get("ck_scale"), cv_scale=lc.get("cv_scale"))
        else:
            view = MustafarCacheView(
                ck_values=lc["ck_vals"], ck_bitmap=lc["ck_bm"],
                cv_values=lc["cv_vals"], cv_bitmap=lc["cv_bm"],
                n_compressed=n_compressed,
                k_window=lc["k_win"], v_window=lc["v_win"],
                n_window=w_len + 1,
                ck_scale=lc.get("ck_scale"), cv_scale=lc.get("cv_scale"))
        # formulation choice (two-pass / fused Pallas kernel / chunked scan)
        # lives in models.attention.decode_attention_auto: sharding-friendly
        # two-pass for B==1 and small pools, the DMA-skipping fused kernel
        # for multi-chunk batched decode on TPU, chunked online softmax
        # elsewhere.
        out = attn.decode_attention_auto(q[:, 0], view, cfg,
                                         scale=cfg.d_head ** -0.5)
    else:
        def upd(buf, tok, p):                          # per-sequence DUS
            return jax.lax.dynamic_update_slice(
                buf, tok.astype(buf.dtype), (0, p, 0))

        lc = dict(lc)
        lc["k"] = jax.vmap(upd)(lc["k"], jnp.swapaxes(k, 1, 2), position)
        lc["v"] = jax.vmap(upd)(lc["v"], jnp.swapaxes(v, 1, 2), position)
        out = decode_attention_dense(q[:, 0], lc["k"], lc["v"], position + 1,
                                     scale=cfg.d_head ** -0.5)
    y = attn.o_proj(bp["mixer"],
                    out[:, None, :, :].reshape(B, 1, cfg.n_heads, cfg.d_head),
                    cfg)
    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)
    return y, lc


def decode_step(params, token: jax.Array, cache, cfg: ModelConfig,
                active: Optional[jax.Array] = None,
                fused_compaction: bool = False,
                model_axis: Optional[str] = None):
    """token [B] -> (logits [B, V], new cache). One step for the batch.

    Every slot advances independently: per-sequence [B] counters, per-slot
    compaction, per-row RoPE/masks. ``active`` [B] bool (default all-True)
    freezes the counters of empty slots — their rows still flow through the
    network (static shapes) but their cache state does not advance, so a
    scheduler can decode a partially-occupied batch and later reuse the
    slot via ``prefill_into_slot``.

    ``fused_compaction`` (static; paged caches only) switches tile-group
    retirement to the single-dispatch compress-and-scatter path
    (``cache.compact_layer_paged_fused``): the compressed tiles are
    emitted straight into their destination pool pages from the same
    kernel launch instead of a separate compress + scan-of-DUS pair. The
    two-dispatch path stays the bit-exactness oracle
    (tests/test_fused_compaction.py).

    ``model_axis`` (static): see ``prefill`` — marks this as the
    per-device body of a shard_map over that axis (cfg holds LOCAL head
    counts; each attention o_proj is psum-reduced). Compaction/window ops
    see only the local Hkv shard and need no collectives."""
    B = token.shape[0]
    m = cfg.mustafar
    period = structural_period(cfg)
    position = cache["position"]                   # [B]
    w_len = cache["w_len"]                         # [B]
    n_comp = cache["n_compressed"]                 # [B]
    block_table = cache.get("block_table")         # [B, MP] iff paged
    act = jnp.ones((B,), jnp.int32) if active is None \
        else active.astype(jnp.int32)
    blocks = cache["blocks"]

    # --- per-slot tile-group compaction: a slot retires its oldest tile
    # group exactly when its OWN window fills. The per-slot decision is a
    # masked select (jnp.where inside compact_layer — no global counter,
    # slots at different depths compact at different steps); an outer
    # any-slot cond skips the compress entirely on the ~(tile_tokens-1)/
    # tile_tokens of steps where no slot is due, restoring the amortized
    # cost of the old lockstep path without coupling the slots ---
    if m.enabled and any(cfg.layer_kind(j) == "attn" for j in range(period)):
        Wbuf = m.local_window + m.tile_tokens
        # per-slot trigger; inactive slots are frozen entirely (a request
        # can retire the very step its window fills — the dead slot must
        # not keep mutating its pools/counters)
        need = (w_len >= Wbuf) & (act > 0)         # [B]

        def do_compact(blocks):
            new_blocks = []
            for j in range(period):
                lc = blocks[j]
                if cfg.layer_kind(j) == "attn":
                    if block_table is not None and fused_compaction:
                        # one fused dispatch covers the WHOLE period stack:
                        # periods fold into the kernel batch instead of
                        # vmapping the two-dispatch pair per period
                        lc = cache_mod.compact_layer_paged_fused(
                            cfg, lc, n_comp, block_table, need)
                    elif block_table is not None:
                        lc = jax.vmap(lambda one: cache_mod.compact_layer_paged(
                            cfg, one, n_comp, block_table, need))(lc)
                    else:
                        lc = jax.vmap(lambda one: cache_mod.compact_layer(
                            cfg, one, n_comp, need))(lc)
                new_blocks.append(lc)
            return tuple(new_blocks)

        blocks = jax.lax.cond(jnp.any(need), do_compact, lambda b: b, blocks)
        w_len = jnp.where(need, w_len - m.tile_tokens, w_len)
        n_comp = jnp.where(need, n_comp + m.tile_tokens, n_comp)

    x = embed_tokens(params["embed"], token[:, None], cfg)     # [B,1,D]
    x = shard_activation(x, DP, None, None)
    if cfg.family == "audio":
        # per-sequence learned positions at each slot's own offset
        x = x + params["embed"]["positions"][position][:, None, :]

    def body(carry, xs):
        x = carry
        bp_period, lc_period = xs
        new_caches = []
        for j in range(period):
            bp, lc = bp_period[j], lc_period[j]
            kind = cfg.layer_kind(j)
            h = norm_apply(bp["norm1"], x, cfg.norm)
            if kind == "attn":
                y, lc = _attn_decode(bp, h, cfg, lc, position, w_len, n_comp,
                                     block_table, model_axis)
                x = x + y
                if cfg.family == "audio":
                    hc = norm_apply(bp["norm_cross"], x, cfg.norm)
                    x = x + attn.cross_attention_block(
                        bp["cross"], hc, (lc["cross_k"], lc["cross_v"]), cfg)
            elif kind == "mamba":
                lc = dict(lc)
                mix, (lc["conv"], lc["ssm"]) = mamba_mod.mamba_apply(
                    bp["mixer"], h, cfg, lc["conv"], lc["ssm"])
                x = x + mix
            else:  # rwkv
                lc = dict(lc)
                mix, (lc["tm_shift"], lc["wkv"]) = rwkv_mod.rwkv_time_mix(
                    bp["mixer"], h, cfg, lc["tm_shift"], lc["wkv"])
                x = x + mix
            h2 = norm_apply(bp["norm2"], x, cfg.norm)
            f, cm_state = _ffn(bp, h2, cfg, kind, cfg.ffn_kind(j),
                               lc.get("cm_shift"))
            x = x + f
            if kind == "rwkv":
                lc["cm_shift"] = cm_state
            new_caches.append(lc)
        return x, tuple(new_caches)

    x, new_blocks = jax.lax.scan(body, x, (params["blocks"], blocks),
                                 unroll=layer_scan_unroll())
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params["embed"], x, cfg)[:, 0, :]
    new_cache = {
        "blocks": new_blocks,
        "position": position + act,                # frozen where inactive
        "w_len": w_len + act if m.enabled else jnp.zeros_like(w_len),
        "n_compressed": n_comp,
    }
    if block_table is not None:
        new_cache["block_table"] = block_table     # mappings change host-side
    return logits, new_cache


# ----------------------------------------------------------------------
# chunked prefill: an admission prefill split into fixed-size chunks that
# interleave with decode steps, so admitting a long prompt never stalls the
# running batch for more than ``prefill_chunk`` tokens of prefill work per
# engine step. A transformer position's activations depend on earlier
# positions ONLY through their K/V, so each chunk's forward carries a dense
# per-layer K/V buffer and attends over it (prefix_causal_attention) —
# bit-identical to the one-shot prefill (masked tails underflow to exact
# zeros; asserted in tests/test_prefix_sharing.py).

def prefill_chunk_supported(cfg: ModelConfig) -> bool:
    """Chunked prefill covers pure-attention decoder stacks (any FFN kind).

    Recurrent kinds (mamba/rwkv) would need their own state carried between
    chunks and audio/vlm prefills splice encoder context — those families
    fall back to the one-shot solo prefill (the scheduler degrades the
    chunk size to the whole prompt)."""
    period = structural_period(cfg)
    return (cfg.family not in ("audio", "vlm")
            and all(cfg.layer_kind(j) == "attn" for j in range(period)))


def init_chunk_carry(cfg: ModelConfig, T_buf: int, batch: int = 1):
    """Zeroed per-layer dense K/V carry for chunked prefill: a tuple over
    period positions of {"k","v"} leaves [n_periods, batch, T_buf, Hkv, d]
    (qkv_proj layout — batch 1 for a solo admission, ``n_slots`` lanes for
    the packed multi-admission path). The buffer is TRANSIENT: it lives
    only until the prefill's last chunk, then the usual prune+compress
    splice runs and the buffer is dropped — it never counts against the
    compressed pool budget."""
    period = structural_period(cfg)
    n_periods = cfg.n_layers // period
    dt = cdtype(cfg)
    shp = (n_periods, batch, T_buf, cfg.n_kv_heads, cfg.d_head)
    return tuple({"k": jnp.zeros(shp, dt), "v": jnp.zeros(shp, dt)}
                 for _ in range(period))


def prefill_chunk_step(params, chunk_tokens: jax.Array, kv_carry,
                       offset: jax.Array, cfg: ModelConfig,
                       model_axis: Optional[str] = None):
    """One prefill chunk: tokens [B, C] at absolute positions
    ``offset + arange(C)`` -> (logits [B, C, V], updated kv_carry).

    ``offset`` is a scalar (solo admission, B == 1) or a [B] vector of
    PER-ROW offsets: the packed multi-admission path runs one chunk from
    each of several in-flight prefills as independent batch lanes of a
    single call (Sarathi-style packing — every op below is row-independent,
    so each lane's math is bit-identical to its solo-chunked run; asserted
    in tests/test_packed_prefill.py).

    Identical per-position math to ``prefill`` (same projections, RoPE at
    the same absolute offsets, same fp32 softmax) with the chunk's K/V
    appended into the carry before attention. The caller reads the logits
    row of the last VALID position (a ragged final chunk is padded; padded
    rows sit at positions >= T so no valid query ever attends to them)."""
    B, C = chunk_tokens.shape
    x = embed_tokens(params["embed"], chunk_tokens, cfg)
    x = shard_activation(x, DP, None, None)
    packed = getattr(offset, "ndim", 0) == 1       # per-lane offsets [B]
    if packed:
        positions = offset[:, None] + jnp.arange(C)[None, :]
    else:
        positions = offset + jnp.arange(C)[None, :]
    period = structural_period(cfg)

    def body(carry, xs):
        x = carry
        bp_period, kc_period = xs
        new_kc = []
        for j in range(period):
            bp, kc = bp_period[j], kc_period[j]
            h = norm_apply(bp["norm1"], x, cfg.norm)
            q, k, v = attn.qkv_proj(bp["mixer"], h, cfg, positions)
            if packed:
                # per-lane DUS: each lane appends its chunk at its own
                # ragged offset into its own carry rows
                upd = jax.vmap(lambda buf, kk, off: jax.lax.dynamic_update_slice(
                    buf, kk, (off, 0, 0)))
                k_buf = upd(kc["k"], k.astype(kc["k"].dtype), offset)
                v_buf = upd(kc["v"], v.astype(kc["v"].dtype), offset)
            else:
                k_buf = jax.lax.dynamic_update_slice(
                    kc["k"], k.astype(kc["k"].dtype), (0, offset, 0, 0))
                v_buf = jax.lax.dynamic_update_slice(
                    kc["v"], v.astype(kc["v"].dtype), (0, offset, 0, 0))
            core = attn.prefix_causal_attention(q, k_buf, v_buf, positions,
                                                cfg)
            y = attn.o_proj(bp["mixer"], core, cfg)
            if model_axis is not None:
                y = jax.lax.psum(y, model_axis)
            x = x + y
            h2 = norm_apply(bp["norm2"], x, cfg.norm)
            f, _ = _ffn(bp, h2, cfg, "attn", cfg.ffn_kind(j))
            x = x + f
            new_kc.append({"k": k_buf, "v": v_buf})
        return x, tuple(new_kc)

    x, new_carry = jax.lax.scan(body, x, (params["blocks"], kv_carry),
                                unroll=layer_scan_unroll())
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params["embed"], x, cfg)          # [1, C, V]
    return logits, new_carry


def finalize_chunked_prefill(params, kv_carry, cfg: ModelConfig, T: int,
                             max_total_tokens: int,
                             plan_batch: Optional[int] = None,
                             shared_tokens: int = 0):
    """Turn a completed chunk carry into the solo cache ``prefill`` builds.

    Slices each layer's dense K/V back to the true prompt length and runs
    the same prune+compress+window split (``build_layer_cache_from_prefill``
    with the same ``shared_tokens`` skip), so the resulting solo cache is
    leaf-for-leaf what the one-shot prefill would have produced."""
    period = structural_period(cfg)
    blocks = []
    for j in range(period):
        kc = kv_carry[j]

        def fin_body(_, kv_one):
            # carry leaves are [1, T_buf, Hkv, d] — the qkv_proj layout
            # build_layer_cache_from_prefill expects, sliced to the true T
            lc = cache_mod.build_layer_cache_from_prefill(
                cfg, kv_one["k"][:, :T], kv_one["v"][:, :T],
                max_total_tokens, None, plan_batch, shared_tokens)
            return None, lc

        _, lc_stack = jax.lax.scan(fin_body, None, kc)
        blocks.append(lc_stack)
    comp, win = cache_mod.prefill_split(cfg, T)
    m = cfg.mustafar
    return {
        "blocks": tuple(blocks),
        "position": jnp.full((1,), T, jnp.int32),
        "w_len": jnp.full((1,), win if m.enabled else 0, jnp.int32),
        "n_compressed": jnp.full((1,), comp if m.enabled else 0, jnp.int32),
    }


# ----------------------------------------------------------------------
# continuous batching: ragged admission + scheduler

def prefill_into_slot(params, tokens: jax.Array, cache, slot, cfg: ModelConfig,
                      max_total_tokens: int,
                      extra: Optional[Dict[str, jax.Array]] = None,
                      prefill_fn=None, pages=None,
                      page_tokens: Optional[int] = None,
                      shared_pages=(), shared_tokens: int = 0):
    """Prefill ONE sequence (tokens [1, T], any T — requests stay ragged)
    and splice its compressed pools + right-padded window into batch slot
    ``slot`` of the shared cache via ``dynamic_update_slice``.

    Returns (last-position logits [V], new shared cache). The solo prefill
    plans its pools with the shared batch size so the leaf shapes line up.
    ``prefill_fn`` overrides the solo prefill callable — the Scheduler
    passes its jitted one; it must accept (params, tokens, shared_tokens=)
    and already bind cfg/max_total/plan_batch consistently with this cache.

    For a PAGED shared cache pass ``pages`` (the slot's OWNED physical page
    ids) and ``page_tokens``: the solo contiguous pools are then copied
    page-by-page and the slot's block-table row rewritten
    (``cache_mod.write_slot_paged``). A SHARED-PREFIX admission additionally
    passes ``shared_pages`` (prefix pages mapped read-only ahead of the
    owned ones) and ``shared_tokens`` (the compressed tokens they cover, so
    the solo prefill skips re-compressing them — the splice starts its page
    copies at the first unmatched logical page).
    """
    if prefill_fn is None:
        n_slots = cache["position"].shape[0]
        prefill_fn = lambda p, t, shared_tokens=0: prefill(
            p, t, cfg, max_total_tokens, extra=extra, plan_batch=n_slots,
            shared_tokens=shared_tokens)
    logits, solo = prefill_fn(params, tokens, shared_tokens=shared_tokens)
    if pages is not None or shared_pages:
        return logits[0], cache_mod.write_slot_paged(
            cfg, cache, solo, slot, pages or [], page_tokens,
            shared_pages=shared_pages)
    return logits[0], cache_mod.write_slot(cache, solo, slot)


@dataclass
class Request:
    """One generation request for the Scheduler."""
    prompt: Any                          # [T] int tokens (list/np/jnp)
    max_new_tokens: int
    eos_token_id: Optional[int] = None
    temperature: float = 0.0
    top_k: int = 0                       # 0 = no top-k truncation
    top_p: float = 1.0                   # 1.0 = no nucleus truncation
    uid: int = -1
    priority: int = 0                    # higher preempts lower (see
                                         # Scheduler admission_policy)
    # filled in by the scheduler:
    preempt_count: int = 0               # times swapped out to the spool
    rejected: bool = False               # dropped under admission_policy=
                                         # "reject" (in Scheduler.rejected,
                                         # never in finished)
    arrival_step: int = -1               # engine step when submitted
    prefill_step: int = -1               # engine step when admission began
    first_token_step: int = -1           # engine step of the first sampled
                                         # token (== prefill_step unless the
                                         # prefill ran chunked)
    finish_step: int = -1                # engine step when retired
    shared_prefix_tokens: int = 0        # compressed tokens mapped from the
                                         # prefix index instead of recompressed
    output_tokens: List[int] = field(default_factory=list)
    logits: List[Any] = field(default_factory=list)  # per-token, if collected

    @property
    def num_generated(self) -> int:
        return len(self.output_tokens)

    @property
    def done(self) -> bool:
        return self.finish_step >= 0


class Occupancy(NamedTuple):
    """Scheduler utilization report.

    ``slots`` — mean fraction of batch slots doing useful work per decode
    step. ``pages`` — mean fraction of the physical page pool drawn per
    decode step (None when the cache is contiguous). Under page-budget
    admission the interesting regime is high ``slots`` at modest ``pages``:
    heterogeneous-length batches keep every slot busy without any slot
    reserving worst-case pool memory.

    Under PREFIX SHARING the drawn pages further split into
    ``pages_owned`` (exactly one holder) and ``pages_shared`` (refcount
    > 1 — a common prefix page or an index-cached one). Each physical page
    counts ONCE whichever split it lands in, so ``pages_owned +
    pages_shared == pages`` and utilization is never double-counted however
    many block-table rows alias a page.

    ``prefill_tokens_per_step`` is the mean prefill tokens EXECUTED per
    engine step when a ``prefill_chunk`` budget is set (None when it
    isn't). Chunk steps charge their full padded size, and a family that
    cannot chunk (``prefill_chunk_supported`` False) still reports its
    one-shot whole-prompt stalls here — the stat never claims a bound the
    engine didn't enforce. The per-step maximum is
    ``Scheduler.max_prefill_step_tokens``.

    ``ttft_p50``/``ttft_p99`` are percentiles (in engine steps) of
    time-to-first-token — ``first_token_step - arrival_step`` — over every
    request that has produced a token so far (finished or still decoding).
    ``prefill_stall_p50``/``prefill_stall_p99`` are percentiles of the
    per-step executed prefill tokens over all engine steps, the
    distribution whose max is ``max_prefill_step_tokens``; both are None
    until a sample exists (and the stall pair whenever chunking is off).
    Percentile tails, not just means, are what the packed-prefill path is
    judged on: packing collapses the TTFT tail under bursts while leaving
    the stall bound untouched."""
    slots: float
    pages: Optional[float] = None
    pages_owned: Optional[float] = None
    pages_shared: Optional[float] = None
    prefill_tokens_per_step: Optional[float] = None
    ttft_p50: Optional[float] = None
    ttft_p99: Optional[float] = None
    prefill_stall_p50: Optional[float] = None
    prefill_stall_p99: Optional[float] = None


@dataclass
class _PendingPrefill:
    """A chunked admission in flight: the prompt's processed prefix lives in
    the dense K/V carry; the slot is reserved (and its prefix-page refs
    held) but not yet active in decode."""
    req: Request
    tokens: Any                          # host int tokens [T]
    chunk: int                           # fixed chunk size C
    T_buf: int                           # carry capacity (T rounded up to C)
    carry: Any = None                    # per-layer dense K/V pytree
    done: int = 0                        # tokens processed so far
    last_logits: Any = None              # [1, C, V] of the latest chunk
    last_offset: int = 0                 # absolute offset of that chunk
    shared_pages: List[int] = field(default_factory=list)
    shared_tokens: int = 0


class Scheduler:
    """Continuous-batching serving loop over a shared ``n_slots`` cache.

    Each engine step: (1) admit waiting requests into free slots (ragged
    solo prefill spliced in via ``prefill_into_slot`` — the first output
    token comes from the prefill logits), (2) one batched ``decode_step``
    over whatever mix of sequences currently occupies the slots (empty
    slots ride along frozen under the ``active`` mask), (3) sample one
    token per active slot, retiring sequences on EOS or max-new-tokens and
    releasing their slots for immediate reuse.

    Per-request math matches running that request alone through the
    lockstep path: every decode op is row-independent and each slot's
    counters/compaction advance exactly as a solo run's would (asserted in
    tests/test_scheduler.py). With pools at or under one decode chunk
    (Tc <= DECODE_CHUNK) both take the two-pass attention and the match is
    bit-exact; larger pools decode batched via the chunked online softmax,
    whose fp reordering vs the solo two-pass path can differ in the last
    ulp (greedy ties may resolve differently at that scale).

    PAGED MODE (``page_tokens`` set): the compressed pools become one
    global page pool shared by all slots, and admission is gated on the
    PAGE budget, not just a free slot — a request is admitted only when the
    allocator can promise its worst-case page count
    (``cache.pages_for_request``), so decode can never run out of pool
    mid-request. Physical pages are drawn lazily: the prefill's fill at
    admission, then one page right before the decode step whose compaction
    first writes it (the scheduler mirrors each slot's ``w_len`` /
    ``n_compressed`` counters on the host to predict compactions — decode
    itself stays one jitted call). Retirement returns drawn pages and
    unused promises to the free list and severs the slot's block-table row.
    ``n_pages`` below ``n_slots · max_pages`` overcommits: all slots can be
    busy as long as their combined worst-case budgets fit, which is the
    whole payoff for heterogeneous-length traffic.

    PREFIX SHARING (``share_prefix=True``, requires paged mode): admissions
    consult a token-trie ``cache.PrefixIndex`` mapping prompt prefixes to
    retired compressed pages. Matched pages are refcount-``share()``d and
    MAPPED read-only into the new slot's block table instead of being
    recompressed and copied — per-token magnitude pruning is deterministic,
    so a shared page is bit-identical to the page the slot would have
    produced itself; the exact solo prefill forward still runs (the shared
    pages only feed decode reads), which keeps shared-prefix runs
    bit-identical to solo runs. Shared pages are IMMUTABLE: the one write
    path into prefill pages — tile-group compaction appending to the
    partially-filled boundary page — goes through a COPY-ON-WRITE in
    ``_provision_pages`` (fresh page drawn from the slot's own budget, page
    copied device-side, block-table entry remapped, shared ref released).
    The fuzz harness asserts no write ever targets a refcount>1 page and no
    reference leaks across a drain.

    CHUNKED PREFILL (``prefill_chunk=N``): every admission prefill runs as
    fixed-size chunks interleaved with decode steps (a short prompt is one
    padded chunk) — at most ``prefill_budget`` prefill tokens execute per
    engine step ACROSS all admissions (the decode-stall budget, defaulting
    to one chunk; observed max in ``max_prefill_step_tokens``, mean in
    ``occupancy.prefill_tokens_per_step``). Chunks carry the prompt's dense
    per-layer K/V (transient — dropped at the splice) and are bit-identical
    to the one-shot prefill; see ``prefill_chunk_step``.

    PACKED PREFILL (``pack_prefill=True``, requires chunking; the DEFAULT
    whenever ``prefill_chunk`` is set): instead of
    advancing one admission per step, chunks from up to
    ``prefill_budget // prefill_chunk`` in-flight admissions run as batch
    lanes of ONE ``prefill_chunk_step`` call per step (Sarathi-style
    packing over a shared [prefill_lanes, T_buf] K/V carry; lanes are
    leased per admission and returned at the splice, and ``prefill_lanes``
    caps the carry's lane count — default ``n_slots`` — so the persistent
    buffer stops scaling with slot count at thousands of slots). The
    per-step executed-token bound is unchanged in budget terms, but the
    admissions drain concurrently instead of serially, collapsing TTFT
    under bursts. Admissions are packed fewest-remaining-chunks first
    (ties FIFO) so short prompts — the TTFT-critical ones — finish
    earliest. Every lane's math is row-independent, so packed prefills
    stay bit-identical to solo-chunked ones.
    """

    def __init__(self, cfg: ModelConfig, params, n_slots: int,
                 max_total_tokens: int, seed: int = 0,
                 collect_logits: bool = False,
                 page_tokens=None,
                 n_pages: Optional[int] = None,
                 share_prefix: bool = False,
                 prefill_chunk: Optional[int] = None,
                 prefill_budget: Optional[int] = None,
                 pack_prefill: Optional[bool] = None,
                 fused_compaction: Optional[bool] = None,
                 prefill_lanes: Optional[int] = None,
                 tile_overhead_bytes: Optional[int] = None,
                 pool_dtype: Optional[str] = None,
                 mesh=None,
                 admission_policy: str = "wait",
                 debug_invariants: bool = False,
                 registry=None,
                 tracer=None,
                 trace_sync: bool = False,
                 tracer_tid: int = 0):
        # ``pool_dtype`` ("bf16"|"int8") overrides cfg.mustafar.pool_dtype:
        # the storage width of the compressed value pools (int8 adds
        # sibling per-tile fp32 scale leaves — see serving.cache). All
        # downstream consumers read the width off cfg, so overriding here
        # threads it everywhere (shapes, kernels, accounting, fingerprint).
        if pool_dtype is not None and pool_dtype != cfg.mustafar.pool_dtype:
            from dataclasses import replace as _dc_replace
            if pool_dtype not in ("bf16", "int8"):
                raise ValueError(f"unknown pool_dtype {pool_dtype!r} "
                                 "(expected 'bf16' or 'int8')")
            cfg = _dc_replace(cfg, mustafar=_dc_replace(
                cfg.mustafar, pool_dtype=pool_dtype))
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_total = max_total_tokens
        # telemetry is default-ON (a fresh MetricsRegistry): collection is
        # host-side arithmetic only and the fuzz suite proves it changes no
        # tokens/page accounting. Pass obs.NullRegistry() to opt out.
        # ``tracer`` (an obs.EventTracer) opts into the event timeline;
        # ``trace_sync`` adds one block_until_ready after decode dispatch
        # for accurate device attribution (NOT default: it serializes the
        # async dispatch pipeline). ``tracer_tid`` separates engines
        # sharing one tracer (Router replicas) into distinct trace rows.
        from repro.obs.metrics import MetricsRegistry
        self.obs = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer
        self.trace_sync = trace_sync
        self.tracer_tid = tracer_tid
        if page_tokens == "auto":
            from repro.roofline import auto_page_tokens
            page_tokens = auto_page_tokens(
                cfg, n_slots, max_total_tokens,
                tile_overhead_bytes=tile_overhead_bytes)
        self.page_tokens = page_tokens
        self.paged = page_tokens is not None
        # default-ON where applicable (both flags stay explicit opt-outs):
        # fused compaction needs paged pools; packing needs chunked prefill
        if fused_compaction is None:
            fused_compaction = self.paged
        if pack_prefill is None:
            pack_prefill = prefill_chunk is not None
        if share_prefix and not self.paged:
            raise ValueError("share_prefix=True requires paged pools "
                             "(pass page_tokens=...)")
        if prefill_chunk is not None and prefill_chunk <= 0:
            raise ValueError(f"prefill_chunk={prefill_chunk} must be positive")
        if prefill_budget is not None:
            if prefill_chunk is None:
                raise ValueError("prefill_budget requires prefill_chunk")
            if prefill_budget < prefill_chunk:
                raise ValueError(
                    f"prefill_budget={prefill_budget} below one chunk "
                    f"({prefill_chunk}) — no admission could ever advance")
        if pack_prefill and prefill_chunk is None:
            raise ValueError("pack_prefill=True requires prefill_chunk")
        if prefill_lanes is not None and prefill_lanes < 1:
            raise ValueError(f"prefill_lanes={prefill_lanes} must be >= 1")
        if admission_policy not in ("wait", "reject", "preempt"):
            raise ValueError(f"unknown admission_policy {admission_policy!r}"
                             " (expected 'wait', 'reject' or 'preempt')")
        if admission_policy == "preempt" and not self.paged:
            raise ValueError("admission_policy='preempt' requires paged "
                             "pools (pass page_tokens=...) — preemption "
                             "swaps pages, not contiguous slots")
        self.admission_policy = admission_policy
        self.share_prefix = share_prefix
        self.debug_invariants = debug_invariants
        if self.paged:
            self.max_pages = cache_mod.plan_pages(
                cfg, max_total_tokens, page_tokens, batch=n_slots)
            self.n_pages = (n_slots * self.max_pages if n_pages is None
                            else n_pages)
            self.allocator = cache_mod.PageAllocator(self.n_pages)
            self._slot_pages: List[List[int]] = [[] for _ in range(n_slots)]
            self._slot_reserved = [0] * n_slots   # undrawn promises per slot
            self._w_len = [0] * n_slots           # host mirrors of the
            self._n_comp = [0] * n_slots          # per-slot device counters
            self.busy_page_steps = 0
            self.busy_owned_page_steps = 0
            self.busy_shared_page_steps = 0
            # host tier shared by preemption swaps AND prefix-index
            # demotions, so swap-traffic accounting aggregates in one place
            # (byte counters live on the registry: satellite of ISSUE 9)
            self.spool = cache_mod.PageSpool(registry=self.obs)
        # preempted requests awaiting restore: uid -> spooled entry
        self._preempted: "collections.OrderedDict[int, Dict[str, Any]]" = \
            collections.OrderedDict()
        self.preempt_count = 0                    # swap-out events
        self.restore_count = 0                    # swap-in events
        self.swapped_pages = 0                    # pages spooled over all
                                                  # swap-outs (roofline
                                                  # swap_bytes cross-check)
        self.restored_pages = 0                   # pages scattered back over
                                                  # all swap-ins (drift audit)
        self.rejected: List[Request] = []         # admission_policy="reject"
        if share_prefix:
            self.prefix = cache_mod.PrefixIndex(page_tokens,
                                                spool=self.spool)
            self.shared_admissions = 0            # admissions that mapped
                                                  # at least one prefix page
        self.cow_count = 0                        # copy-on-write events
        self.prefill_chunk = prefill_chunk
        self.prefill_budget = (prefill_budget if prefill_budget is not None
                               else prefill_chunk)
        self.pack_prefill = pack_prefill
        self.fused_compaction = fused_compaction
        self._can_chunk = (prefill_chunk is not None
                           and prefill_chunk_supported(cfg))
        self._pending: "collections.OrderedDict[int, _PendingPrefill]" = \
            collections.OrderedDict()
        # packed-prefill lane carry, allocated on first use: one fixed
        # [prefill_lanes, T_buf] buffer keeps every packing step on a
        # single jit executable regardless of which lanes are live.
        # ``prefill_lanes`` caps the lane count below n_slots so the
        # persistent carry stops scaling with slot count at thousands of
        # slots — admissions beyond the cap simply wait for a free lane
        # (they'd have waited for packing bandwidth anyway: the per-step
        # budget admits at most prefill_budget // prefill_chunk lanes)
        self.prefill_lanes = (n_slots if prefill_lanes is None
                              else min(prefill_lanes, n_slots))
        self._free_lanes: Deque[int] = collections.deque(
            range(self.prefill_lanes))
        self._lane_of: Dict[int, int] = {}        # slot -> packed-carry lane
        self._packed_carry = None
        self._packed_T_buf = (-(-max_total_tokens // prefill_chunk)
                              * prefill_chunk if self._can_chunk else 0)
        self.prefill_token_total = 0              # prefill tokens executed
        self.max_prefill_step_tokens = 0          # worst per-step stall seen
        self._step_prefill_tokens = 0             # running count, this step
        self._stall_history: List[int] = []       # per-step executed prefill
                                                  # tokens (percentile source)
        self.cache = cache_mod.init_cache(cfg, n_slots, max_total_tokens,
                                          page_tokens=page_tokens,
                                          n_pages=n_pages)
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.waiting: Deque[Request] = collections.deque()
        self.next_tokens = jnp.zeros((n_slots,), jnp.int32)
        self.rng = jax.random.PRNGKey(seed)
        self.collect_logits = collect_logits
        self.finished: List[Request] = []
        self.step_count = 0
        self.decode_steps = 0
        self.busy_slot_steps = 0
        self._uid = 0
        self._decode = jax.jit(partial(decode_step, cfg=cfg,
                                       fused_compaction=fused_compaction))
        self._prefill = jax.jit(partial(prefill, cfg=cfg,
                                        max_total_tokens=max_total_tokens,
                                        plan_batch=n_slots),
                                static_argnames=("shared_tokens",))
        self._chunk_step = jax.jit(partial(prefill_chunk_step, cfg=cfg))
        self._finalize = jax.jit(partial(finalize_chunked_prefill, cfg=cfg,
                                         max_total_tokens=max_total_tokens,
                                         plan_batch=n_slots),
                                 static_argnames=("T", "shared_tokens"))
        # identity hook: the sharded install replaces it with a device_put
        # that lays fresh chunk carries out over the mesh (Hkv sharded)
        self._shard_carry = lambda c: c
        self.mesh = mesh
        if mesh is not None:
            # KV-head tensor parallelism over the mesh's "model" axis:
            # replaces params/cache with sharded copies and swaps the four
            # jitted step functions for shard_map-wrapped ones. See
            # serving.sharded for the layout contract.
            from repro.serving.sharded import install_sharded_ops
            install_sharded_ops(self, mesh)
        self._init_metrics()

    # ------------------------------------------------------------------
    # telemetry (repro.obs): per-phase step histograms, lifecycle counters,
    # pool/spool/prefix gauges. Existing plain-int stats stay authoritative
    # (nothing that mutates them changed); the registry mirrors them via
    # LAZY counters read at snapshot time, so instrumentation cannot perturb
    # scheduling state — the property the fuzz A/B test pins down.

    _PHASES = ("step", "admit", "prefill", "provision", "compaction",
               "decode", "sample", "preempt_out", "restore_in")

    def _init_metrics(self) -> None:
        reg = self.obs
        self._phase_h = {name: reg.histogram(f"step/{name}_s")
                         for name in self._PHASES}
        self._c_tokens = reg.counter("engine.tokens_sampled")
        self._c_submitted = reg.counter("engine.submitted")
        self._c_admitted = reg.counter("engine.admitted")
        self._c_compactions = reg.counter("engine.compactions")
        reg.counter("engine.steps", fn=lambda: self.step_count)
        reg.counter("engine.decode_steps", fn=lambda: self.decode_steps)
        reg.counter("engine.finished", fn=lambda: len(self.finished))
        reg.counter("engine.rejected", fn=lambda: len(self.rejected))
        reg.counter("engine.preempts", fn=lambda: self.preempt_count)
        reg.counter("engine.restores", fn=lambda: self.restore_count)
        reg.counter("engine.swapped_pages", fn=lambda: self.swapped_pages)
        reg.counter("engine.restored_pages", fn=lambda: self.restored_pages)
        reg.counter("engine.cow_events", fn=lambda: self.cow_count)
        reg.counter("engine.prefill_tokens",
                    fn=lambda: self.prefill_token_total)
        reg.gauge("engine.slots_active",
                  fn=lambda: sum(1 for s in self.slots if s is not None))
        reg.gauge("engine.waiting", fn=lambda: len(self.waiting))
        reg.gauge("engine.pending_prefills", fn=lambda: len(self._pending))
        reg.gauge("engine.preempted", fn=lambda: len(self._preempted))
        if self.paged:
            self.allocator.register_metrics(reg)
            reg.gauge("spool.held_bytes", fn=lambda: self.spool.held_bytes)
            reg.gauge("spool.entries", fn=lambda: self.spool.n_entries)
        if self.share_prefix:
            self.prefix.register_metrics(reg)
            reg.counter("engine.shared_admissions",
                        fn=lambda: self.shared_admissions)

    @contextmanager
    def _phase(self, name: str):
        """Time one host-side phase into its ``step/<name>_s`` histogram
        (and, with a tracer, a B/E span). Wraps EXISTING host boundaries
        only — no device syncs: without ``trace_sync`` the decode phase
        measures dispatch (JAX returns before the device finishes) and the
        device time drains into whichever later phase first blocks."""
        tr = self.tracer
        if tr is not None:
            tr.begin(name, tid=self.tracer_tid)
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self._phase_h[name].observe(time.perf_counter() - t0)
            if tr is not None:
                tr.end(name, tid=self.tracer_tid)

    def stats(self) -> Dict[str, Any]:
        """THE stats accessor: the registry snapshot (counters / gauges /
        per-phase histograms — see ROADMAP.md "Observability" for the
        metric-name catalog) plus the ``occupancy`` ratios under an
        ``"occupancy"`` key. Examples/benchmarks read this dict instead of
        poking ``occupancy`` NamedTuple fields; the property remains for
        programmatic use but new consumers should prefer ``stats()``."""
        snap = self.obs.snapshot()
        snap["occupancy"] = dict(self.occupancy._asdict())
        return snap

    # ------------------------------------------------------------------
    def _check_admissible(self, req: Request) -> int:
        """Raise unless the request could EVER be served; return its total
        token need. Silent truncation (admit + rely on max-length
        retirement) is not an option: under page budgets an oversized
        request would sit at the queue head waiting for pages that can
        never materialise, deadlocking every request behind it."""
        n_prompt = len(req.prompt)
        # the prefill itself emits one output token, so a request always
        # generates >= 1 even with max_new_tokens=0 — budgeting with the
        # raw value would under-reserve the prefill's own page fill
        total = n_prompt + max(req.max_new_tokens, 1)
        if total > self.max_total:
            raise ValueError(
                f"request needs {n_prompt} prompt + {req.max_new_tokens} new "
                f"tokens = {total}; slot capacity is {self.max_total} "
                f"(max_total_tokens) — rejecting rather than truncating")
        if self.paged:
            need = self._worst_case_pages(n_prompt, total)
            if need > self.n_pages:
                raise ValueError(
                    f"request needs {need} pages worst-case; the pool holds "
                    f"{self.n_pages} — it could never be admitted")
        return total

    def submit(self, req: Request) -> Request:
        """Queue a request (admitted at the next step with a free slot)."""
        self._check_admissible(req)
        if req.uid < 0:
            req.uid = self._uid
        self._uid = max(self._uid, req.uid) + 1
        req.arrival_step = self.step_count
        self._c_submitted.inc()
        if self.tracer is not None:
            self.tracer.instant("submit", tid=self.tracer_tid, uid=req.uid,
                                prompt_tokens=len(req.prompt))
            self.tracer.async_begin("req", req.uid, tid=self.tracer_tid)
        self.waiting.append(req)
        return req

    @property
    def has_work(self) -> bool:
        return (bool(self.waiting) or bool(self._pending)
                or bool(self._preempted)
                or any(s is not None for s in self.slots))

    @property
    def occupancy(self) -> Occupancy:
        """Slot AND page utilization (see ``Occupancy``), with drawn pages
        split owned/shared so prefix aliasing is never double-counted.

        Prefer ``stats()`` for reporting: it carries these same ratios
        under ``stats()["occupancy"]`` next to the full registry snapshot,
        so examples/benchmarks no longer poke NamedTuple fields."""
        slots = self.busy_slot_steps / max(1, self.decode_steps * self.n_slots)
        pages = owned = shared = None
        if self.paged:
            denom = max(1, self.decode_steps * self.n_pages)
            pages = self.busy_page_steps / denom
            owned = self.busy_owned_page_steps / denom
            shared = self.busy_shared_page_steps / denom
        stall = None
        if self.prefill_chunk is not None:
            stall = self.prefill_token_total / max(1, self.step_count)
        import numpy as np
        ttfts = [r.first_token_step - r.arrival_step
                 for r in self.finished if r.first_token_step >= 0]
        ttfts += [r.first_token_step - r.arrival_step
                  for r in self.slots
                  if r is not None and r.first_token_step >= 0]
        t50 = t99 = s50 = s99 = None
        if ttfts:
            t50 = float(np.percentile(ttfts, 50))
            t99 = float(np.percentile(ttfts, 99))
        if self.prefill_chunk is not None and self._stall_history:
            s50 = float(np.percentile(self._stall_history, 50))
            s99 = float(np.percentile(self._stall_history, 99))
        return Occupancy(slots, pages, owned, shared, stall,
                         t50, t99, s50, s99)

    # ------------------------------------------------------------------
    def _sample_one(self, logits: jax.Array, req: Request) -> int:
        from repro.serving.sampler import sample
        self.rng, sub = jax.random.split(self.rng)
        return int(sample(logits[None], req.temperature, sub,
                          top_k=req.top_k, top_p=req.top_p)[0])

    def _sample_batch(self, logits: jax.Array):
        """One batched sample call + ONE device->host transfer per decode
        step when every active request shares (temperature, top_k, top_p)
        — the common case; returns None to fall back to per-slot sampling
        otherwise."""
        import numpy as np

        from repro.serving.sampler import sample
        knobs = {(r.temperature, r.top_k, r.top_p)
                 for r in self.slots if r is not None}
        if len(knobs) != 1:
            return None
        temp, top_k, top_p = knobs.pop()
        self.rng, sub = jax.random.split(self.rng)
        return np.asarray(sample(logits, temp, sub, top_k=top_k, top_p=top_p))

    def _retire(self, req: Request) -> None:
        req.finish_step = self.step_count
        if self.tracer is not None:
            self.tracer.instant("finish", tid=self.tracer_tid, uid=req.uid,
                                tokens=len(req.output_tokens))
            self.tracer.async_end("req", req.uid, tid=self.tracer_tid)
        self.finished.append(req)

    def _record(self, req: Request, tok: int, logits: jax.Array) -> bool:
        """Append one sampled token; True if the request just finished."""
        req.output_tokens.append(tok)
        if self.collect_logits:
            import numpy as np
            req.logits.append(np.asarray(logits, np.float32))
        if ((req.eos_token_id is not None and tok == req.eos_token_id)
                or req.num_generated >= req.max_new_tokens):
            self._retire(req)
            return True
        return False

    def _release_pages(self, slot: int) -> None:
        """Drop a retired (or never-occupied) slot's page references and
        unused promises; sever its block-table row so a later tenant can
        never alias a freed page. Under sharing a reference drop only frees
        the physical page once the prefix index and every other slot have
        let go too."""
        if not self.paged:
            return
        self.allocator.free(self._slot_pages[slot])
        self.allocator.unreserve(self._slot_reserved[slot])
        self._slot_pages[slot] = []
        self._slot_reserved[slot] = 0
        self._w_len[slot] = 0
        self._n_comp[slot] = 0
        self.cache["block_table"] = self.cache["block_table"].at[slot].set(
            cache_mod.PAGE_UNMAPPED)

    # ------------------------------------------------------------------
    # page-aware preemption: swap a decoding slot's pages to the host
    # spool under pool pressure, splice them back later — NO recompute,
    # so a preempted request's outputs are bit-identical to an
    # uninterrupted run (compressed pages are immutable; the round-trip
    # is byte-exact)

    def _preempt_slot(self, slot: int) -> None:
        """Swap one DECODING slot out: device_get its drawn pages + dense
        window/state + counters into the spool, free the device pages,
        return its unused promises, sever the block-table row. The request
        parks in ``_preempted`` until ``_restore_preempted`` re-admits it.
        Mid-prefill (``_pending``) slots are never preempted — their state
        lives in the chunk carry, not in pages."""
        with self._phase("preempt_out"):
            self._preempt_slot_inner(slot)

    def _preempt_slot_inner(self, slot: int) -> None:
        req = self.slots[slot]
        assert req is not None and slot not in self._pending
        pages = list(self._slot_pages[slot])
        entry = {
            "req": req,
            "n_pages": len(pages),
            "reserved": self._slot_reserved[slot],
            "w_len": self._w_len[slot],
            "n_comp": self._n_comp[slot],
            "next_token": int(self.next_tokens[slot]),
            "key": self.spool.put({
                "pages": cache_mod.gather_page_arrays(self.cache, pages),
                "state": cache_mod.gather_slot_state(self.cache, slot),
            }),
        }
        self.allocator.free(pages)
        self.allocator.unreserve(self._slot_reserved[slot])
        self._slot_pages[slot] = []
        self._slot_reserved[slot] = 0
        self._w_len[slot] = 0
        self._n_comp[slot] = 0
        self.cache["block_table"] = self.cache["block_table"].at[slot].set(
            cache_mod.PAGE_UNMAPPED)
        self.slots[slot] = None
        req.preempt_count += 1
        self.preempt_count += 1
        self.swapped_pages += len(pages)
        if self.tracer is not None:
            self.tracer.instant("preempt", tid=self.tracer_tid,
                                uid=req.uid, pages=len(pages))
        self._preempted[req.uid] = entry

    def _restore_slot(self, slot: int, entry: Dict[str, Any]) -> None:
        """Splice a preempted request back into a free slot: reserve its
        full page need (drawn + promised — the original admission proved
        this fits the pool), draw fresh pages, scatter the spooled bytes
        back, rebuild the block-table row and host mirrors. Restored pages
        are refcount-1 (owned), so any CoW demand the original reservation
        covered can only have shrunk — the promises carried through the
        swap still suffice."""
        with self._phase("restore_in"):
            self._restore_slot_inner(slot, entry)

    def _restore_slot_inner(self, slot: int, entry: Dict[str, Any]) -> None:
        req = entry["req"]
        self.allocator.reserve(entry["n_pages"] + entry["reserved"])
        pages = self.allocator.draw_many(entry["n_pages"])
        data = self.spool.take(entry["key"])
        if pages:
            self.cache = cache_mod.scatter_page_arrays(
                self.cache, data["pages"], pages)
        self.cache = cache_mod.scatter_slot_state(
            self.cache, slot, data["state"])
        self._slot_pages[slot] = pages
        self._slot_reserved[slot] = entry["reserved"]
        self._w_len[slot] = entry["w_len"]
        self._n_comp[slot] = entry["n_comp"]
        row = pages + [cache_mod.PAGE_UNMAPPED] * (self.max_pages
                                                   - len(pages))
        self.cache["block_table"] = self.cache["block_table"].at[slot].set(
            jnp.asarray(row, jnp.int32))
        self.slots[slot] = req
        self.next_tokens = self.next_tokens.at[slot].set(
            jnp.int32(entry["next_token"]))
        self.restore_count += 1
        self.restored_pages += len(pages)
        if self.tracer is not None:
            self.tracer.instant("restore", tid=self.tracer_tid,
                                uid=req.uid, pages=len(pages))

    def _restore_preempted(self, free: List[int]) -> None:
        """Re-admit preempted requests into free slots, highest priority
        first (FIFO by uid within a priority). A waiting request of
        STRICTLY higher priority blocks lower-priority restores — without
        this guard a restore would grab the pages the pending admission is
        about to preempt for, thrashing the swap. Falls back to demoting
        prefix-index entries when the pool is short."""
        if not self._preempted or not free:
            return
        top_wait = max((r.priority for r in self.waiting), default=None)
        order = sorted(self._preempted,
                       key=lambda uid: (
                           -self._preempted[uid]["req"].priority, uid))
        for uid in order:
            if not free:
                return
            entry = self._preempted[uid]
            if top_wait is not None \
                    and top_wait > entry["req"].priority:
                continue
            need = entry["n_pages"] + entry["reserved"]
            if not self.allocator.can_reserve(need):
                if self.share_prefix:
                    self.prefix.evict_until(self.allocator, need,
                                            spool=True, cache=self.cache)
                if not self.allocator.can_reserve(need):
                    continue
            del self._preempted[uid]
            self._restore_slot(free.pop(0), entry)

    def _pick_victim(self, priority: int) -> Optional[int]:
        """Victim policy: among decoding slots of STRICTLY lower priority
        than the blocked admission, pick the lowest priority, then the
        fewest generated tokens (least sunk decode work), then oldest uid.
        None when no slot qualifies — equal-priority traffic never
        preempts itself (no churn under a homogeneous load)."""
        best = None
        for s, r in enumerate(self.slots):
            if r is None or s in self._pending or r.priority >= priority:
                continue
            key = (r.priority, r.num_generated, r.uid)
            if best is None or key < best[0]:
                best = (key, s)
        return None if best is None else best[1]

    def reclaimable_pages(self, priority: Optional[int] = None) -> int:
        """Pages an admission COULD free without waiting for retirements:
        prefix-index entries with no other holder (evictable/demotable)
        plus — under ``admission_policy='preempt'`` with a ``priority`` —
        the sole-held pages and unused promises of strictly-lower-priority
        victims. The router adds this to ``available`` when judging
        page-headroom admissibility."""
        if not self.paged:
            return 0
        n = 0
        if self.share_prefix:
            n += sum(1 for p in self.prefix.held_pages
                     if self.allocator.refcount(p) == 1)
        if priority is not None and self.admission_policy == "preempt":
            for s, r in enumerate(self.slots):
                if r is not None and s not in self._pending \
                        and r.priority < priority:
                    n += sum(1 for p in self._slot_pages[s]
                             if self.allocator.refcount(p) == 1)
                    n += self._slot_reserved[s]
        return n

    def save_prefix_cache(self, path: str) -> int:
        """Persist the prefix index (device + spooled chains) for a warm
        restart; see ``PrefixIndex.save``. Returns entries written."""
        if not self.share_prefix:
            raise ValueError("save_prefix_cache requires share_prefix=True")
        return self.prefix.save(
            path, cache=self.cache,
            fingerprint=cache_mod.prefix_cache_fingerprint(
                self.cfg, self.page_tokens))

    def load_prefix_cache(self, path: str) -> int:
        """Warm-start the (empty) prefix index from ``save_prefix_cache``
        output; entries arrive spooled and promote on first use. Raises
        ValueError when the persisted fingerprint mismatches this
        scheduler's config/pruning mode/page geometry."""
        if not self.share_prefix:
            raise ValueError("load_prefix_cache requires share_prefix=True")
        return self.prefix.load(
            path,
            fingerprint=cache_mod.prefix_cache_fingerprint(
                self.cfg, self.page_tokens))

    def _provision_pages(self, active_flags: List[bool]) -> None:
        """Host mirror of ``decode_step``'s per-slot counter logic: predict
        every compaction the upcoming step will run, draw ALL the pages it
        needs in one allocator transaction (``draw_many``), and write the
        block-table entries as ONE device splice BEFORE the jitted decode
        fires — the decode loop never round-trips per slot.

        COPY-ON-WRITE: when a compaction target is already mapped but
        SHARED (refcount > 1 — a prefix boundary page, or the slot's own
        boundary page the prefix index also caches), the page is immutable:
        a fresh page is drawn from the slot's own budget (the admission
        reservation deliberately keeps the boundary page's promise for
        exactly this), its contents copied device-side, the block-table
        entry remapped, and the shared reference released. After this no
        write in ``compact_layer_paged`` can ever land in a refcount>1
        page — under ``debug_invariants`` the full
        ``kernels.sparse_decode.validate_block_table`` contract (read- AND
        write-side) is asserted here before every decode, and the fuzz
        harness re-checks the read side after every step."""
        m = self.cfg.mustafar
        if not m.enabled:
            return
        tt = m.tile_tokens
        wbuf = m.local_window + tt
        will = [False] * len(active_flags)
        nc_pre = [0] * len(active_flags)       # pre-compaction depths: the
        events = []                            # (is_cow, slot, lp, old_page)
        for slot, act in enumerate(active_flags):   # write target is
            if not act:                             # nc_pre // page_tokens
                continue
            nc_pre[slot] = self._n_comp[slot]
            if self._w_len[slot] >= wbuf:              # compaction this step
                will[slot] = True
                lp = self._n_comp[slot] // self.page_tokens
                if lp >= len(self._slot_pages[slot]):
                    assert self._slot_reserved[slot] > 0, \
                        "page budget exhausted mid-request (planner bug)"
                    events.append((False, slot, lp, -1))
                elif self.allocator.refcount(self._slot_pages[slot][lp]) > 1:
                    assert self._slot_reserved[slot] > 0, \
                        "no budget left for copy-on-write (planner bug)"
                    events.append((True, slot, lp,
                                   self._slot_pages[slot][lp]))
                self._n_comp[slot] += tt
                self._w_len[slot] -= tt
            self._w_len[slot] += 1
        n_compacting = sum(will)
        if n_compacting:
            # tile-group compactions the upcoming decode will run (the
            # fused kernel executes them inside the jitted step; this host
            # prediction is the same one that sizes the page draws)
            self._c_compactions.inc(n_compacting)
        if events:
            # one free-list transaction for the whole step (page ids match
            # what per-slot draw() calls would have assigned), then one
            # block-table scatter. CoW events have refcount > 1, so the
            # released old pages can never re-enter this step's free pops.
            # The "compaction" phase times this host-side page-provisioning
            # work (CoW copies + the block-table splice); the compaction
            # arithmetic itself runs inside the jitted decode step.
            with self._phase("compaction"):
                pages = self.allocator.draw_many(len(events))
                rows, cols = [], []
                for (is_cow, slot, lp, old), page in zip(events, pages):
                    self._slot_reserved[slot] -= 1
                    if is_cow:
                        self.cache = cache_mod.copy_page(self.cache, old,
                                                         page)
                        self.allocator.release(old)
                        self._slot_pages[slot][lp] = page
                        self.cow_count += 1
                    else:
                        self._slot_pages[slot].append(page)
                    rows.append(slot)
                    cols.append(lp)
                self.cache["block_table"] = self.cache["block_table"].at[
                    jnp.asarray(rows, jnp.int32),
                    jnp.asarray(cols, jnp.int32)
                ].set(jnp.asarray(pages, jnp.int32))
        if self.debug_invariants:
            import numpy as np

            from repro.kernels.sparse_decode import validate_block_table
            validate_block_table(
                np.asarray(self.cache["block_table"]), self.n_pages + 1,
                page_tokens=self.page_tokens,
                n_compressed=np.asarray(nc_pre),
                refcounts=[self.allocator.refcount(p)
                           for p in range(self.n_pages)],
                will_compact=will)

    def _worst_case_pages(self, n_prompt: int, total: int) -> int:
        """A request's worst-case page reservation: the base budget for
        ``total`` tokens PLUS one CoW-headroom page when the prompt's
        compressed fill ends mid-page under sharing (whether the boundary
        page ends up shared-in or owned-but-index-cached, the slot's first
        compaction into it must copy into a fresh page). The ONLY place
        this rule lives — admissibility checks, eviction targets, and
        reservation sizing all call it, so they cannot disagree."""
        need = cache_mod.pages_for_request(self.cfg, total, self.page_tokens)
        if self.share_prefix:
            comp, _ = cache_mod.prefill_split(self.cfg, n_prompt)
            if comp % self.page_tokens:
                need += 1
        return need

    def _match_prefix(self, req: Request, total: int):
        """Prefix-index lookup + reservation sizing for one admission.

        Returns (shared_pages, shared_tokens, pages_needed): the physical
        pages to alias (full-prefix chain, possibly plus a boundary page),
        the compressed tokens they cover, and the reservation AFTER
        discounting the shared pages — each fully-shared page drops one
        promise, and a SHARED boundary page drops the CoW-headroom page
        from ``_worst_case_pages`` (its own logical page's promise is kept,
        never drawn at admission, consumed by the CoW); an OWNED partial
        boundary page keeps the headroom — the slot draws its whole worst
        case itself AND the prefix index will register that boundary page
        (refcount 2), so the slot's own first compaction into it must
        copy."""
        comp, _ = cache_mod.prefill_split(self.cfg, len(req.prompt))
        shared: List[int] = []
        shared_tokens = 0
        pages_needed = self._worst_case_pages(len(req.prompt), total)
        if self.share_prefix:
            full, boundary, shared_tokens = self.prefix.match(req.prompt,
                                                              comp)
            shared = list(full) + ([boundary] if boundary is not None else [])
            pages_needed -= len(full)
            if boundary is not None:
                pages_needed -= 1          # shared boundary: headroom page
                                           # not needed (see docstring)
        return shared, shared_tokens, pages_needed

    def _after_first_token(self, slot: int, req: Request,
                           lg: jax.Array) -> bool:
        """Sample the prefill's own output token; returns True if the slot
        is now actively decoding (False: finished on the prefill token)."""
        req.first_token_step = self.step_count
        if self.tracer is not None:
            self.tracer.instant("first_token", tid=self.tracer_tid,
                                uid=req.uid, slot=slot)
        tok = self._sample_one(lg, req)
        self._c_tokens.inc()
        if self._record(req, tok, lg):
            self._release_pages(slot)
            return False
        self.slots[slot] = req
        self.next_tokens = self.next_tokens.at[slot].set(tok)
        return True

    def _draw_prefill_pages(self, slot: int, T: int,
                            shared_pages) -> List[int]:
        """Draw the slot's OWNED prefill pages (the compressed fill minus
        the shared prefix) from its reservation and set the host mirrors.
        ``_slot_reserved[slot]`` must already hold the admission
        reservation. One copy shared by the one-shot and chunked admission
        paths, so their page/mirror bookkeeping — the invariant pair the
        fuzz harness checks — cannot desynchronize."""
        comp, win = cache_mod.prefill_split(self.cfg, T)
        n_owned = -(-comp // self.page_tokens) - len(shared_pages)
        assert 0 <= n_owned <= self._slot_reserved[slot]
        owned = [self.allocator.draw() for _ in range(n_owned)]
        self._slot_pages[slot] = list(shared_pages) + owned
        self._slot_reserved[slot] -= n_owned
        self._w_len[slot] = win
        self._n_comp[slot] = comp
        return owned

    def _register_prefix(self, slot: int, req: Request) -> None:
        """Index the slot's freshly-spliced prefill pages (prompt-derived
        pages only — decode-time compactions mix in generated tokens)."""
        if not self.share_prefix:
            return
        comp, _ = cache_mod.prefill_split(self.cfg, len(req.prompt))
        self.prefix.register(req.prompt, comp, self._slot_pages[slot],
                             self.allocator)

    def _admit(self) -> None:
        free = [i for i, s in enumerate(self.slots)
                if s is None and i not in self._pending]
        if self._preempted:
            self._restore_preempted(free)
        while free and self.waiting:
            if (self._can_chunk and self.pack_prefill
                    and not self._free_lanes):
                break        # all packed-prefill lanes busy: the admission
                             # would have no carry rows; wait for a lane
            req = self.waiting[0]
            # re-validate at admission: requests can reach the queue without
            # submit() (or be mutated after it), and an inadmissible head
            # would deadlock the queue under page-budget gating
            total = self._check_admissible(req)
            shared: List[int] = []
            shared_tokens = 0
            pages_needed = 0
            if self.paged:
                if self.share_prefix and self.prefix.spooled_entries:
                    # lift spooled chains on this prompt's path back onto
                    # device pages first, so _match_prefix can map them —
                    # the spool hit that makes demotion (and a persisted
                    # warm start) pay off. Partial promotion is fine: the
                    # admission shares whatever became resident
                    comp, _ = cache_mod.prefill_split(self.cfg,
                                                      len(req.prompt))
                    self.cache, _ = self.prefix.promote(
                        req.prompt, comp, self.allocator, self.cache)
                shared, shared_tokens, pages_needed = \
                    self._match_prefix(req, total)
                if not self.allocator.can_reserve(pages_needed):
                    # index-cached pages are reclaimable cache, not demand:
                    # LRU-DEMOTE to the spool until the reservation fits
                    # (pages still mapped by live slots only drop the
                    # index's ref; the chain stays promotable). Evict
                    # against the UNDISCOUNTED worst case (incl. CoW
                    # headroom) and re-match: demotion may have taken the
                    # very pages just matched
                    if self.share_prefix:
                        self.prefix.evict_until(
                            self.allocator,
                            self._worst_case_pages(len(req.prompt), total),
                            spool=True, cache=self.cache)
                        shared, shared_tokens, pages_needed = \
                            self._match_prefix(req, total)
                    if not self.allocator.can_reserve(pages_needed) \
                            and self.admission_policy == "preempt":
                        # swap out strictly-lower-priority decoders until
                        # the reservation fits (victims park in the spool
                        # and restore bit-exactly once pressure clears)
                        while not self.allocator.can_reserve(pages_needed):
                            victim = self._pick_victim(req.priority)
                            if victim is None:
                                break
                            self._preempt_slot(victim)
                            free.append(victim)
                    if not self.allocator.can_reserve(pages_needed):
                        if self.admission_policy == "reject":
                            # shed load instead of queueing: the caller
                            # sees the drop immediately (reject-mode
                            # baseline in BENCH_preemption.json)
                            self.waiting.popleft()
                            req.rejected = True
                            if self.tracer is not None:
                                self.tracer.instant("reject",
                                                    tid=self.tracer_tid,
                                                    uid=req.uid)
                                self.tracer.async_end("req", req.uid,
                                                      tid=self.tracer_tid)
                            self.rejected.append(req)
                            continue
                        break        # wait for a retirement to free pages
            self.waiting.popleft()
            slot = free.pop(0)
            if self.paged:
                self.allocator.reserve(pages_needed)
                for p in shared:     # slot-held refs: eviction/donor retire
                    self.allocator.share(p)   # can no longer free them
                if self.share_prefix:  # stats + LRU recency move only on
                    if shared:         # COMMITTED admissions (see
                        self.shared_admissions += 1      # PrefixIndex.match)
                        self.prefix.hits += len(shared)
                        comp, _ = cache_mod.prefill_split(self.cfg,
                                                          len(req.prompt))
                        self.prefix.match(req.prompt, comp, touch_lru=True)
                    else:
                        self.prefix.misses += 1
                req.shared_prefix_tokens = shared_tokens
            req.prefill_step = self.step_count
            self._c_admitted.inc()
            if self.tracer is not None:
                self.tracer.instant("admit", tid=self.tracer_tid,
                                    uid=req.uid, slot=slot)
            if self._can_chunk:
                # CHUNKED admission: reserve the slot + pages now, run the
                # forward in prefill_chunk-token slices between decode
                # steps. EVERY admission routes through the chunk queue —
                # a prompt shorter than the chunk is one (padded) chunk —
                # so the per-step stall budget in _run_prefill_chunks is a
                # real bound over concurrent admissions, not per-request
                C = self.prefill_chunk
                T = len(req.prompt)
                self._pending[slot] = _PendingPrefill(
                    req=req, tokens=[int(t) for t in req.prompt], chunk=C,
                    T_buf=-(-T // C) * C,
                    carry=(None if self.pack_prefill
                           else self._shard_carry(
                               init_chunk_carry(self.cfg, -(-T // C) * C))),
                    shared_pages=shared, shared_tokens=shared_tokens)
                if self.pack_prefill:
                    self._lane_of[slot] = self._free_lanes.popleft()
                if self.paged:
                    self._slot_pages[slot] = list(shared)
                    self._slot_reserved[slot] = pages_needed
                continue
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            pages = None
            if self.paged:
                self._slot_reserved[slot] = pages_needed
                pages = self._draw_prefill_pages(slot, len(req.prompt),
                                                 shared)
            if self.prefill_chunk is not None:
                # chunking requested but unsupported for this family: the
                # one-shot prefill stalls decode for the whole prompt —
                # report it honestly instead of claiming a zero stall
                self._step_prefill_tokens += len(req.prompt)
            # jit caches one prefill executable per distinct prompt length
            # (and, under sharing, per distinct shared-token offset)
            lg, self.cache = prefill_into_slot(
                self.params, toks, self.cache, slot, self.cfg, self.max_total,
                prefill_fn=self._prefill, pages=pages,
                page_tokens=self.page_tokens, shared_pages=shared,
                shared_tokens=shared_tokens)
            if self.paged:
                self._register_prefix(slot, req)
            if not self._after_first_token(slot, req, lg):
                free.insert(0, slot)     # finished on the prefill token;
                                         # slot stays free for the next one

    # ------------------------------------------------------------------
    def _run_prefill_chunks(self) -> None:
        """Advance pending chunked prefills by at most ``prefill_budget``
        prefill tokens of EXECUTED COMPUTE this engine step (the
        decode-stall budget); completed prefills splice in and go active
        for the decode that follows.

        The budget charges the full padded chunk each jitted step actually
        executes — a ragged final chunk of 3 real tokens still runs a
        ``prefill_chunk``-token forward — so the bound holds in wall-clock
        terms, not just in prompt-token bookkeeping.

        Unpacked (default): admissions advance oldest-first, one chunk per
        jitted call, until the budget is spent. Packed
        (``pack_prefill=True``): the chunks selected this step run as batch
        lanes of ONE call — see ``_run_prefill_chunks_packed``."""
        if self.pack_prefill and self._can_chunk:
            self._run_prefill_chunks_packed()
            return
        budget = self.prefill_budget
        while self._pending and budget > 0:
            slot, pend = next(iter(self._pending.items()))
            T = len(pend.tokens)
            off = pend.done
            if pend.chunk > budget:
                break
            n = min(pend.chunk, T - off)
            chunk = pend.tokens[off:off + n] + [0] * (pend.chunk - n)
            lg, pend.carry = self._chunk_step(
                self.params, jnp.asarray(chunk, jnp.int32)[None, :],
                pend.carry, jnp.int32(off))
            pend.last_logits = lg
            pend.last_offset = off
            pend.done += n
            budget -= pend.chunk
            self._step_prefill_tokens += pend.chunk
            if self.tracer is not None:
                self.tracer.instant("chunk", tid=self.tracer_tid,
                                    uid=pend.req.uid, done=pend.done,
                                    total=T)
            if pend.done >= T:
                del self._pending[slot]
                self._complete_prefill(slot, pend)

    def _run_prefill_chunks_packed(self) -> None:
        """Greedy budget fill: packed ``prefill_chunk_step`` calls until
        the step's ``prefill_budget`` is spent or no admission is pending.
        Each call advances up to ``budget_remaining // prefill_chunk``
        in-flight admissions by one chunk as batch lanes; when FEWER
        admissions are pending than the budget covers, the loop issues
        further calls so the same admissions advance additional chunks —
        a lone 64-token prompt under a 32-token budget prefills in 2
        steps, not 8. Lane = slot into a persistent [n_slots, T_buf] K/V
        carry, so every packing call reuses one jit executable.

        TTFT-aware order: admissions with the fewest remaining chunks pack
        first (ties FIFO by arrival then uid) — finishing short prompts
        early minimizes mean time-to-first-token without starving long
        ones (a long prompt keeps its lane and packs whenever fewer than
        ``k_max`` shorter admissions are in flight). Lanes come from a
        free-lane lease pool of size ``prefill_lanes`` (assigned at
        admission, returned at the splice) into a persistent
        [prefill_lanes, T_buf] K/V carry.

        Unselected lanes (idle, or pending-but-over-budget) run a dummy
        zero-token chunk aimed at the carry TAIL rows: any row at or above
        a pending admission's ``done`` watermark is rewritten by the chunk
        that owns it before any query ever attends to it, and a pending
        admission always has ``done <= T_buf - C``, so tail writes can
        never corrupt the packed prefix a live lane has already computed.
        The per-step token budget charges only REAL lanes — the dummy rows
        ride along inside the same fixed-shape call."""
        C = self.prefill_chunk
        budget = self.prefill_budget
        while budget >= C and self._pending:
            k_max = budget // C
            order = sorted(
                self._pending.items(),
                key=lambda kv: (-(-(len(kv[1].tokens) - kv[1].done) // C),
                                kv[1].req.arrival_step, kv[1].req.uid))
            batch = order[:k_max]
            if self._packed_carry is None:
                self._packed_carry = self._shard_carry(init_chunk_carry(
                    self.cfg, self._packed_T_buf, batch=self.prefill_lanes))
            toks = [[0] * C for _ in range(self.prefill_lanes)]
            offs = [self._packed_T_buf - C] * self.prefill_lanes  # dummy tail
            for slot, pend in batch:
                lane = self._lane_of[slot]
                off = pend.done
                n = min(C, len(pend.tokens) - off)
                toks[lane] = pend.tokens[off:off + n] + [0] * (C - n)
                offs[lane] = off
            lg, self._packed_carry = self._chunk_step(
                self.params, jnp.asarray(toks, jnp.int32),
                self._packed_carry, jnp.asarray(offs, jnp.int32))
            for slot, pend in batch:
                lane = self._lane_of[slot]
                off = pend.done
                n = min(C, len(pend.tokens) - off)
                pend.last_logits = lg[lane:lane + 1]
                pend.last_offset = off
                pend.done += n
                budget -= C
                self._step_prefill_tokens += C
                if self.tracer is not None:
                    self.tracer.instant("chunk", tid=self.tracer_tid,
                                        uid=pend.req.uid, done=pend.done,
                                        total=len(pend.tokens))
                if pend.done >= len(pend.tokens):
                    del self._pending[slot]
                    pend.carry = jax.tree_util.tree_map(
                        lambda a: a[:, lane:lane + 1], self._packed_carry)
                    self._free_lanes.append(self._lane_of.pop(slot))
                    self._complete_prefill(slot, pend)

    def _complete_prefill(self, slot: int, pend: _PendingPrefill) -> None:
        """Last chunk done: prune+compress the carried K/V (minus the shared
        prefix), draw the owned prefill pages, splice, and sample the
        request's first output token — exactly what the one-shot admission
        does, just spread over the preceding steps."""
        T = len(pend.tokens)
        solo = self._finalize(self.params, pend.carry, T=T,
                              shared_tokens=pend.shared_tokens)
        if self.paged:
            owned = self._draw_prefill_pages(slot, T, pend.shared_pages)
            self.cache = cache_mod.write_slot_paged(
                self.cfg, self.cache, solo, slot, owned, self.page_tokens,
                shared_pages=pend.shared_pages)
            self._register_prefix(slot, pend.req)
        else:
            self.cache = cache_mod.write_slot(self.cache, solo, slot)
        lg = pend.last_logits[0, (T - 1) - pend.last_offset]
        self._after_first_token(slot, pend.req, lg)

    # ------------------------------------------------------------------
    def step(self) -> None:
        """One engine iteration: admit → prefill chunks → batched decode →
        sample/retire. Each phase is timed into its ``step/<name>_s``
        histogram (and traced as a B/E span when a tracer is attached) at
        the host boundaries that already exist — no added device syncs;
        ``trace_sync=True`` blocks on the decode output for accurate
        per-phase device attribution."""
        with self._phase("step"):
            self._step_inner()
        self.step_count += 1

    def _step_inner(self) -> None:
        self._step_prefill_tokens = 0     # this step's prefill compute:
        with self._phase("admit"):        # one-shot fallbacks count too
            self._admit()
        if self._pending:
            with self._phase("prefill"):
                self._run_prefill_chunks()
        if self.prefill_chunk is not None:
            self.prefill_token_total += self._step_prefill_tokens
            self.max_prefill_step_tokens = max(self.max_prefill_step_tokens,
                                               self._step_prefill_tokens)
            self._stall_history.append(self._step_prefill_tokens)
        active_flags = [s is not None for s in self.slots]
        if any(active_flags):
            if self.paged:
                with self._phase("provision"):
                    self._provision_pages(active_flags)
            active = jnp.asarray(active_flags)
            with self._phase("decode"):
                logits, self.cache = self._decode(self.params,
                                                  self.next_tokens,
                                                  self.cache, active=active)
                if self.trace_sync:
                    jax.block_until_ready(logits)
            self.decode_steps += 1
            self.busy_slot_steps += sum(active_flags)
            if self.paged:
                self.busy_page_steps += self.allocator.in_use
                owned, shared = self.allocator.in_use_split
                self.busy_owned_page_steps += owned
                self.busy_shared_page_steps += shared
            with self._phase("sample"):
                batch_toks = self._sample_batch(logits)
                upd_slots, upd_toks = [], []
                for slot, req in enumerate(self.slots):
                    if req is None:
                        continue
                    tok = (int(batch_toks[slot]) if batch_toks is not None
                           else self._sample_one(logits[slot], req))
                    self._c_tokens.inc()
                    if self._record(req, tok, logits[slot]):
                        self.slots[slot] = None      # released for reuse
                        self._release_pages(slot)
                    else:
                        upd_slots.append(slot)
                        upd_toks.append(tok)
                if upd_slots:                        # one splice per step,
                    self.next_tokens = self.next_tokens.at[   # not per slot
                        jnp.asarray(upd_slots, jnp.int32)].set(
                        jnp.asarray(upd_toks, jnp.int32))

    def run(self, max_steps: int = 1 << 20) -> List[Request]:
        """Drive until the queue and all slots drain; returns finished."""
        while self.has_work and self.step_count < max_steps:
            self.step()
        return self.finished


# ----------------------------------------------------------------------
class Engine:
    """Jit-wrapped convenience driver for examples/benchmarks."""

    def __init__(self, cfg: ModelConfig, params, max_total_tokens: int):
        self.cfg = cfg
        self.params = params
        self.max_total = max_total_tokens
        self._prefill = jax.jit(partial(prefill, cfg=cfg,
                                        max_total_tokens=max_total_tokens))
        self._decode = jax.jit(partial(decode_step, cfg=cfg))

    def generate(self, tokens: jax.Array, n_new: int, *,
                 temperature: float = 0.0, rng=None,
                 extra: Optional[Dict[str, jax.Array]] = None) -> jax.Array:
        from repro.serving.sampler import sample
        logits, cache = self._prefill(self.params, tokens, extra=extra)
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        outs = []
        tok = sample(logits, temperature, rng)
        outs.append(tok)
        for i in range(n_new - 1):
            rng = jax.random.fold_in(rng, i)
            logits, cache = self._decode(self.params, tok, cache)
            tok = sample(logits, temperature, rng)
            outs.append(tok)
        return jnp.stack(outs, axis=1)                  # [B, n_new]
