"""Batched serving engine: dense/flash prefill + Mustafar decode.

``prefill``  — full-sequence forward (FlashAttention-compatible, paper §3),
               then prune+compress everything older than the local window
               into the bitmap pools (tile groups of 64).
``decode_step`` — one token for the whole batch: appends to the dense local
               window, runs the two-part (compressed ⊕ window) attention,
               and every ``tile_tokens`` steps retires the oldest tile group
               from the window into the pools (lax.cond — static shapes).

Both are pure functions of (params, inputs, cache) so they pjit cleanly;
``serve_step`` for the dry-run grid is ``decode_step`` under the production
mesh. The Engine class wraps them with jit and a sampling loop.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.attention import (MustafarCacheView, decode_attention_dense,
                                  decode_attention_mustafar,
                                  decode_attention_mustafar_chunked)
from repro.models import attention as attn
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models.layers import (cdtype, embed_tokens, lm_logits, mlp_apply,
                                 norm_apply)
from repro.models.model import (encode, layer_scan_unroll, structural_period)
from repro.serving import cache as cache_mod
from repro.sharding.constraints import DP, shard_activation


# ----------------------------------------------------------------------
# ffn dispatch shared by prefill/decode

def _ffn(bp, h, cfg: ModelConfig, kind: str, ffn_kind: str,
         cm_state: Optional[jax.Array] = None):
    if ffn_kind == "moe":
        out, _ = moe_mod.moe_apply(bp["ffn"], h, cfg)
        return out, None
    if kind == "rwkv":
        B = h.shape[0]
        st = cm_state if cm_state is not None else jnp.zeros(
            (B, cfg.d_model), h.dtype)
        out, new_st = rwkv_mod.rwkv_channel_mix(bp["ffn"], h, cfg, st)
        return out, new_st
    return mlp_apply(bp["ffn"], h, cfg), None


# ----------------------------------------------------------------------
# prefill

def prefill(params, tokens: jax.Array, cfg: ModelConfig,
            max_total_tokens: int,
            extra: Optional[Dict[str, jax.Array]] = None):
    """tokens [B, T] -> (logits [B, V] at last position, cache).

    extra carries the stub modality inputs (frames / patches).
    """
    extra = extra or {}
    B, T = tokens.shape
    x = embed_tokens(params["embed"], tokens, cfg)
    enc_out = None
    enc_ctx = 0
    if cfg.family == "vlm":
        vis = extra["patches"].astype(cdtype(cfg))
        vis = jnp.einsum("bvd,de->bve", vis,
                         params["vis_proj"].astype(cdtype(cfg)))
        x = jnp.concatenate([vis, x], axis=1)
    if cfg.family == "audio":
        enc_out = encode(params, extra["frames"], cfg, remat="none")
        enc_ctx = enc_out.shape[1]
        x = x + params["embed"]["positions"][:T].astype(cdtype(cfg))[None]
    T_total = x.shape[1]
    x = shard_activation(x, DP, None, None)
    positions = jnp.arange(T_total)[None, :]
    period = structural_period(cfg)

    def body(carry, bp_period):
        x = carry
        caches = []
        for j in range(period):
            bp = bp_period[j]
            kind = cfg.layer_kind(j)
            h = norm_apply(bp["norm1"], x, cfg.norm)
            if kind == "attn":
                q, k, v = attn.qkv_proj(bp["mixer"], h, cfg, positions)
                core = attn.causal_attention(q, k, v, cfg)
                x = x + attn.o_proj(bp["mixer"], core, cfg)
                cross_kv = None
                if cfg.family == "audio":
                    hc = norm_apply(bp["norm_cross"], x, cfg.norm)
                    cross_kv = attn.encoder_kv(bp["cross"], enc_out, cfg)
                    x = x + attn.cross_attention_block(bp["cross"], hc,
                                                       cross_kv, cfg)
                lc = cache_mod.build_layer_cache_from_prefill(
                    cfg, k, v, max_total_tokens, cross_kv)
            elif kind == "mamba":
                st = mamba_mod.mamba_state_shapes(cfg, B)
                mix, (conv_st, ssm_st) = mamba_mod.mamba_apply(
                    bp["mixer"], h, cfg, jnp.zeros(st["conv"], jnp.float32),
                    jnp.zeros(st["ssm"], jnp.float32))
                x = x + mix
                lc = {"conv": conv_st, "ssm": ssm_st}
            else:  # rwkv
                st = rwkv_mod.rwkv_state_shapes(cfg, B)
                mix, (tm_shift, wkv) = rwkv_mod.rwkv_time_mix(
                    bp["mixer"], h, cfg, jnp.zeros(st["tm_shift"], x.dtype),
                    jnp.zeros(st["wkv"], jnp.float32))
                x = x + mix
                lc = {"tm_shift": tm_shift, "wkv": wkv}
            h2 = norm_apply(bp["norm2"], x, cfg.norm)
            f, cm_state = _ffn(bp, h2, cfg, kind, cfg.ffn_kind(j))
            x = x + f
            if kind == "rwkv":
                lc["cm_shift"] = cm_state
            caches.append(lc)
        return x, tuple(caches)

    x, block_caches = jax.lax.scan(body, x, params["blocks"],
                                   unroll=layer_scan_unroll())
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params["embed"], x[:, -1:, :], cfg)[:, 0, :]

    comp, win = cache_mod.prefill_split(cfg, T_total)
    m = cfg.mustafar
    cache = {
        "blocks": block_caches,
        "position": jnp.asarray(T_total, jnp.int32),
        "w_len": jnp.asarray(win if m.enabled else 0, jnp.int32),
        "n_compressed": jnp.asarray(comp if m.enabled else 0, jnp.int32),
    }
    return logits, cache


# ----------------------------------------------------------------------
# decode

def _attn_decode(bp, h, cfg: ModelConfig, lc, position, w_len, n_compressed):
    """One attention layer, one token. h [B,1,D] -> (out [B,1,D], new lc)."""
    B = h.shape[0]
    pos = jnp.broadcast_to(position, (B, 1))
    q, k, v = attn.qkv_proj(bp["mixer"], h, cfg, pos)         # [B,1,H,dh]
    m = cfg.mustafar
    if m.enabled:
        lc = cache_mod.append_window(lc, jnp.swapaxes(k, 1, 2),
                                     jnp.swapaxes(v, 1, 2), w_len)
        view = MustafarCacheView(
            ck_values=lc["ck_vals"], ck_bitmap=lc["ck_bm"],
            cv_values=lc["cv_vals"], cv_bitmap=lc["cv_bm"],
            n_compressed=jnp.broadcast_to(n_compressed, (B,)),
            k_window=lc["k_win"], v_window=lc["v_win"],
            n_window=jnp.broadcast_to(w_len + 1, (B,)))
        # path choice: the chunked scan bounds temp memory, but its reshape
        # of the (possibly context-sharded) Tc dim defeats GSPMD propagation
        # — measured 70 GiB/step of pool all-gathers at B=1/524k. Small
        # decompressed sizes use the two-pass formulation (partial softmax
        # over the Tc-sharded dim lowers to tiny all-reduces); big batches
        # use the chunked scan (whole-pool decompression would be ~10 GiB).
        if B == 1:
            out = decode_attention_mustafar(q[:, 0], view,
                                            scale=cfg.d_head ** -0.5)
        else:
            out = decode_attention_mustafar_chunked(q[:, 0], view,
                                                    scale=cfg.d_head ** -0.5)
    else:
        lc = dict(lc)
        lc["k"] = jax.lax.dynamic_update_slice(
            lc["k"], jnp.swapaxes(k, 1, 2).astype(lc["k"].dtype),
            (0, 0, position, 0))
        lc["v"] = jax.lax.dynamic_update_slice(
            lc["v"], jnp.swapaxes(v, 1, 2).astype(lc["v"].dtype),
            (0, 0, position, 0))
        out = decode_attention_dense(q[:, 0], lc["k"], lc["v"],
                                     jnp.broadcast_to(position + 1, (B,)),
                                     scale=cfg.d_head ** -0.5)
    y = attn.o_proj(bp["mixer"],
                    out[:, None, :, :].reshape(B, 1, cfg.n_heads, cfg.d_head),
                    cfg)
    return y, lc


def decode_step(params, token: jax.Array, cache, cfg: ModelConfig):
    """token [B] -> (logits [B, V], new cache). One step for the batch."""
    B = token.shape[0]
    m = cfg.mustafar
    period = structural_period(cfg)

    # --- tile-group compaction when the window buffer is full ---
    if m.enabled and any(cfg.layer_kind(j) == "attn" for j in range(period)):
        Wbuf = m.local_window + m.tile_tokens

        def do_compact(c):
            new_blocks = []
            for j in range(period):
                lc = c["blocks"][j]
                if cfg.layer_kind(j) == "attn":
                    lc = jax.vmap(lambda one: cache_mod.compact_layer(
                        cfg, one, c["n_compressed"]))(lc)
                new_blocks.append(lc)
            out = dict(c)
            out["blocks"] = tuple(new_blocks)
            out["w_len"] = c["w_len"] - m.tile_tokens
            out["n_compressed"] = c["n_compressed"] + m.tile_tokens
            return out

        cache = jax.lax.cond(cache["w_len"] >= Wbuf,
                             do_compact, lambda c: c, cache)

    x = embed_tokens(params["embed"], token[:, None], cfg)     # [B,1,D]
    x = shard_activation(x, DP, None, None)
    if cfg.family == "audio":
        x = x + params["embed"]["positions"][cache["position"]][None, None]
    position = cache["position"]
    w_len = cache["w_len"]
    n_comp = cache["n_compressed"]

    def body(carry, xs):
        x = carry
        bp_period, lc_period = xs
        new_caches = []
        for j in range(period):
            bp, lc = bp_period[j], lc_period[j]
            kind = cfg.layer_kind(j)
            h = norm_apply(bp["norm1"], x, cfg.norm)
            if kind == "attn":
                y, lc = _attn_decode(bp, h, cfg, lc, position, w_len, n_comp)
                x = x + y
                if cfg.family == "audio":
                    hc = norm_apply(bp["norm_cross"], x, cfg.norm)
                    x = x + attn.cross_attention_block(
                        bp["cross"], hc, (lc["cross_k"], lc["cross_v"]), cfg)
            elif kind == "mamba":
                lc = dict(lc)
                mix, (lc["conv"], lc["ssm"]) = mamba_mod.mamba_apply(
                    bp["mixer"], h, cfg, lc["conv"], lc["ssm"])
                x = x + mix
            else:  # rwkv
                lc = dict(lc)
                mix, (lc["tm_shift"], lc["wkv"]) = rwkv_mod.rwkv_time_mix(
                    bp["mixer"], h, cfg, lc["tm_shift"], lc["wkv"])
                x = x + mix
            h2 = norm_apply(bp["norm2"], x, cfg.norm)
            f, cm_state = _ffn(bp, h2, cfg, kind, cfg.ffn_kind(j),
                               lc.get("cm_shift"))
            x = x + f
            if kind == "rwkv":
                lc["cm_shift"] = cm_state
            new_caches.append(lc)
        return x, tuple(new_caches)

    x, new_blocks = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]),
                                 unroll=layer_scan_unroll())
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = lm_logits(params["embed"], x, cfg)[:, 0, :]
    new_cache = {
        "blocks": new_blocks,
        "position": position + 1,
        "w_len": w_len + 1 if m.enabled else jnp.asarray(0, jnp.int32),
        "n_compressed": n_comp,
    }
    return logits, new_cache


# ----------------------------------------------------------------------
class Engine:
    """Jit-wrapped convenience driver for examples/benchmarks."""

    def __init__(self, cfg: ModelConfig, params, max_total_tokens: int):
        self.cfg = cfg
        self.params = params
        self.max_total = max_total_tokens
        self._prefill = jax.jit(partial(prefill, cfg=cfg,
                                        max_total_tokens=max_total_tokens))
        self._decode = jax.jit(partial(decode_step, cfg=cfg))

    def generate(self, tokens: jax.Array, n_new: int, *,
                 temperature: float = 0.0, rng=None,
                 extra: Optional[Dict[str, jax.Array]] = None) -> jax.Array:
        from repro.serving.sampler import sample
        logits, cache = self._prefill(self.params, tokens, extra=extra)
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        outs = []
        tok = sample(logits, temperature, rng)
        outs.append(tok)
        for i in range(n_new - 1):
            rng = jax.random.fold_in(rng, i)
            logits, cache = self._decode(self.params, tok, cache)
            tok = sample(logits, temperature, rng)
            outs.append(tok)
        return jnp.stack(outs, axis=1)                  # [B, n_new]
