"""Serving: Mustafar KV-cache manager, prefill/decode engine, sampler,
continuous-batching scheduler."""
from repro.serving.cache import (PageAllocator, cache_hbm_bytes, init_cache,
                                 pages_for_request, plan_pages, plan_pools,
                                 write_slot, write_slot_paged)
from repro.serving.engine import (Engine, Occupancy, Request, Scheduler,
                                  decode_step, prefill, prefill_into_slot)
