"""Serving: Mustafar KV-cache manager, prefill/decode engine, sampler,
continuous-batching scheduler."""
from repro.serving.cache import (cache_hbm_bytes, init_cache, plan_pools,
                                 write_slot)
from repro.serving.engine import (Engine, Request, Scheduler, decode_step,
                                  prefill, prefill_into_slot)
