"""Serving: Mustafar KV-cache manager, prefill/decode engine, sampler."""
from repro.serving.cache import cache_hbm_bytes, init_cache, plan_pools
from repro.serving.engine import Engine, decode_step, prefill
