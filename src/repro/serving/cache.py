"""Mustafar KV-cache manager (paper §3 + Appendix C, TPU static-shape form).

Per attention layer the cache is split into
  * compressed pools — fixed-k bitmap format, preallocated to the max
    context: values [P, B, Hkv, Tc_max, k] + bitmap [P, B, Hkv, Tc_max, W32]
    for K and V (P = stacked periods for lax.scan);
  * a dense local window buffer [P, B, Hkv, Wbuf, d] with
    Wbuf = local_window + tile_tokens. Tokens append densely; every time the
    buffer fills, the oldest ``tile_tokens`` (a tile group, paper Appx. C)
    are pruned+compressed into the pools and the window rolls left.

All updates are pure-functional ``dynamic_update_slice``s under jit —
the XLA/pjit analogue of the paper's CUDA-side cache pointer management.
Mamba layers carry (conv, ssm) state, RWKV layers carry (shift, wkv) state,
Whisper decoder layers additionally hold static cross-attention K/V.

Sequence-progress state (``position``, ``w_len``, ``n_compressed``) is
PER-SEQUENCE: ``[B]`` int32 vectors, one entry per batch slot. Slots advance
independently — each slot appends at its own window offset and retires a
tile group when *its own* window fills (per-slot masked updates; the engine
wraps them in an any-slot work-skip cond) — which is what lets the
continuous-batching scheduler in ``serving.engine`` admit/release ragged
requests without forcing the batch into lockstep.

PAGED POOLS (``init_cache(page_tokens=...)``) decouple slot capacity from
pool allocation: instead of ``[B, Hkv, Tc_max, k]`` per-slot compressed
pools (every slot pays worst-case context), one global page pool
``[n_pages + 1, Hkv, page_tokens, k]`` is shared by all slots through a
per-slot int32 block table — vLLM-style indirection over the fixed-k bitmap
format. ``PageAllocator`` manages the free list (reserve at admission, draw
lazily at compaction, free at retire); ``compact_layer_paged`` scatters tile
retirements through the table; reads gather pages back into the contiguous
view (bit-exact on CPU) or translate inside the fused kernel's
scalar-prefetch grid (TPU).
"""
from __future__ import annotations

import collections
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sparse_format import pad_to_words
from repro.kernels import ops as kops
from repro.models import mamba as mamba_mod
from repro.models import rwkv as rwkv_mod
from repro.models.model import structural_period


CONTEXT_SHARDS = 16  # production mesh "data" size; batch-1 pools shard Tc

# Compressed pools store packed values NARROWER than the compute dtype —
# decode is bandwidth-bound on pool bytes, so pool width is a knob
# (``MustafarConfig.pool_dtype``), never the compute dtype:
#   "bf16" (default) — kernels load bf16 and feed the MXU at native width
#     (fp32 only in the accumulators); a wider pool would double
#     compressed-cache HBM bytes for no accuracy the softmax can see.
#   "int8" — symmetric absmax quantization per (head, tile_tokens-token
#     tile) at compression time; one fp32 scale per tile rides in a sibling
#     ``ck_scale``/``cv_scale`` pool leaf and readers dequantize in-register
#     before the MXU product. Bitmap planes / block tables are unchanged.
# The dense window always keeps the compute dtype (read-modified every step).
POOL_DTYPE = jnp.bfloat16         # the "bf16" mapping (back-compat alias)
SCALE_DTYPE = jnp.float32         # per-tile absmax scales (int8 pools only)

_POOL_DTYPES = {"bf16": jnp.bfloat16, "int8": jnp.int8}


def pool_dtype(cfg: ModelConfig):
    """jnp dtype of the packed value pools for ``cfg`` (bf16 | int8)."""
    try:
        return _POOL_DTYPES[cfg.mustafar.pool_dtype]
    except KeyError:
        raise ValueError(
            f"unknown pool_dtype={cfg.mustafar.pool_dtype!r}; "
            f"expected one of {sorted(_POOL_DTYPES)}") from None


def pool_quantized(cfg: ModelConfig) -> bool:
    """True when value pools store int8 + sibling per-tile scale leaves."""
    pool_dtype(cfg)  # validate the knob even on the bf16 path
    return cfg.mustafar.pool_dtype == "int8"


def plan_pools(cfg: ModelConfig, max_total_tokens: int,
               batch: int = 0) -> Tuple[int, int]:
    """(Tc_max, Wbuf): compressed-pool capacity and window buffer size.

    Tc_max rounds up to the decode-attention chunk (4096) so the online-
    softmax scan divides evenly; below one chunk it rounds to tile_tokens.
    For batch-1 long-context serving the pools are context-parallel (Tc
    sharded over "data"), so Tc additionally aligns to chunk×shards —
    otherwise the chunk reshape crosses shard boundaries and GSPMD
    all-gathers the whole pool (measured: 62 GiB/step at 524k)."""
    from repro.core.attention import DECODE_CHUNK
    m = cfg.mustafar
    Wbuf = m.local_window + m.tile_tokens
    unit = DECODE_CHUNK if max_total_tokens >= DECODE_CHUNK else m.tile_tokens
    if batch == 1 and max_total_tokens >= DECODE_CHUNK * CONTEXT_SHARDS:
        unit = DECODE_CHUNK * CONTEXT_SHARDS
    Tc_max = (max_total_tokens + unit - 1) // unit * unit
    return Tc_max, Wbuf


# ----------------------------------------------------------------------
# paged pools: a global page pool [n_pages, Hkv, page_tokens, ·] shared by
# every batch slot, indexed through a per-slot int32 block table — slot
# capacity (max_total_tokens) no longer dictates pool allocation, so short
# requests stop reserving long-request memory (vLLM-style paging over the
# fixed-k bitmap format).

PAGE_UNMAPPED = -1      # block-table entry for a logical page with no backing


def plan_pages(cfg: ModelConfig, max_total_tokens: int, page_tokens: int,
               batch: int = 0) -> int:
    """max_pages: block-table width so the paged view covers Tc_max.

    ``page_tokens`` must be a positive multiple of ``tile_tokens`` — a tile
    group is the compaction write granule and must never straddle a page
    boundary (one dynamic_update_slice per retirement, one page per tile)."""
    m = cfg.mustafar
    if page_tokens <= 0 or page_tokens % m.tile_tokens:
        raise ValueError(
            f"page_tokens={page_tokens} must be a positive multiple of "
            f"tile_tokens={m.tile_tokens}")
    Tc_max, _ = plan_pools(cfg, max_total_tokens, batch=batch)
    return (Tc_max + page_tokens - 1) // page_tokens


def max_compressed_tokens(cfg: ModelConfig, total_tokens: int) -> int:
    """Upper bound on a request's pool fill over its whole lifetime.

    A tile group retires only when the window holds Wbuf tokens, so at every
    compaction ``n_compressed = position − local_window``; position at a
    compacting step's entry is at most ``total − 1`` (the final token is
    appended after the last compaction can fire)."""
    m = cfg.mustafar
    return max(0, (total_tokens - 1 - m.local_window) // m.tile_tokens) \
        * m.tile_tokens


def pages_for_request(cfg: ModelConfig, total_tokens: int,
                      page_tokens: int) -> int:
    """Worst-case page budget for ``prompt + max_new_tokens`` total tokens."""
    comp = max_compressed_tokens(cfg, total_tokens)
    return (comp + page_tokens - 1) // page_tokens


class PageAllocator:
    """Refcounted free-list allocator over the global compressed-page pool.

    Two-phase discipline so admission can never deadlock mid-decode:
    ``reserve(n)`` promises n pages to a request at admission (fails upfront
    if the budget isn't there), ``draw()`` converts one promised page into a
    physical page id lazily — the scheduler draws right before the decode
    step whose compaction writes it — and ``free``/``unreserve`` return a
    retired request's drawn pages and unused promises. ``peak_in_use``
    tracks the high-water mark of physically drawn pages (the byte number
    BENCH_paging.json / BENCH_prefix.json compare against contiguous
    allocation; a shared page counts ONCE however many slots map it).

    SHARING: every drawn page carries a refcount (1 at ``draw()``).
    ``share(page)`` adds a holder — a second slot mapping a common-prefix
    page read-only, or the scheduler's prefix index caching it past its
    donor's lifetime — and ``release(page)`` drops one holder, returning the
    page to the free list only when the last holder lets go. The write rule
    the whole design stands on: a page with ``refcount > 1`` is IMMUTABLE —
    any writer (tile-group compaction into a shared boundary page) must
    copy-on-write first (``Scheduler._provision_pages``), and the fuzz
    harness asserts no write ever targets a shared page.
    """

    def __init__(self, n_pages: int):
        if n_pages <= 0:
            raise ValueError(f"n_pages={n_pages} must be positive")
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, -1, -1))   # LIFO: low ids first
        self._ref = [0] * n_pages                        # holders per page
        self.n_reserved = 0
        self.peak_in_use = 0

    @property
    def in_use(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def in_use_split(self) -> Tuple[int, int]:
        """(owned, shared) physical pages: ``owned`` have exactly one holder,
        ``shared`` more than one. Each physical page counts once, so
        ``owned + shared == in_use`` — utilization is never double-counted
        however many block-table rows alias a page."""
        owned = sum(1 for r in self._ref if r == 1)
        return owned, self.in_use - owned

    @property
    def available(self) -> int:
        """Pages neither drawn nor promised to an admitted request."""
        return len(self._free) - self.n_reserved

    def can_reserve(self, n: int) -> bool:
        return self.available >= n

    def reserve(self, n: int) -> None:
        if not self.can_reserve(n):
            raise ValueError(
                f"cannot reserve {n} pages: {self.available} available "
                f"({self.in_use} in use, {self.n_reserved} reserved, "
                f"{self.n_pages} total)")
        self.n_reserved += n

    def unreserve(self, n: int) -> None:
        assert 0 <= n <= self.n_reserved, (n, self.n_reserved)
        self.n_reserved -= n

    def draw(self) -> int:
        """Convert one reserved promise into a physical page id (refcount 1)."""
        assert self.n_reserved > 0, "draw() without a reservation"
        self.n_reserved -= 1
        page = self._free.pop()
        self._ref[page] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return page

    def draw_many(self, n: int) -> List[int]:
        """Convert ``n`` reserved promises into physical page ids in ONE
        transaction — the batched-provisioning path: the scheduler predicts
        every compaction target for the upcoming step on the host, draws
        all of them here, and applies the block-table updates as a single
        device splice. Pages come off the free list in exactly the order
        ``n`` repeated ``draw()`` calls would return them."""
        assert 0 <= n <= self.n_reserved, (n, self.n_reserved)
        self.n_reserved -= n
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return pages

    def refcount(self, page: int) -> int:
        assert 0 <= page < self.n_pages, page
        return self._ref[page]

    def share(self, page: int) -> int:
        """Add a holder to a live page (maps it read-only somewhere else)."""
        assert 0 <= page < self.n_pages and self._ref[page] >= 1, \
            f"share() of page {page} with refcount {self._ref[page]}"
        self._ref[page] += 1
        return page

    def release(self, page: int) -> None:
        """Drop one holder; the page frees when the last holder lets go."""
        assert 0 <= page < self.n_pages and self._ref[page] >= 1, \
            f"release() of page {page} with refcount {self._ref[page]}"
        self._ref[page] -= 1
        if self._ref[page] == 0:
            self._free.append(page)

    def free(self, pages) -> None:
        """Drop one holder from each page (uniquely-owned pages free now)."""
        for p in pages:
            self.release(p)

    def register_metrics(self, registry) -> None:
        """Report pool state through ``registry`` as CALLBACK gauges —
        evaluated at snapshot time only, so allocator hot paths (draw /
        release on every provisioning step) stay untouched."""
        registry.gauge("pool.pages_total", fn=lambda: self.n_pages)
        registry.gauge("pool.pages_in_use", fn=lambda: self.in_use)
        registry.gauge("pool.pages_free", fn=lambda: self.available)
        registry.gauge("pool.pages_reserved", fn=lambda: self.n_reserved)
        registry.gauge("pool.pages_peak", fn=lambda: self.peak_in_use)
        registry.gauge("pool.pages_owned", fn=lambda: self.in_use_split[0])
        registry.gauge("pool.pages_shared", fn=lambda: self.in_use_split[1])


class PageSpool:
    """Host-memory tier for compressed KV pages — the middle rung of the
    HBM → host → disk hierarchy.

    Compressed pages are IMMUTABLE once retired (per-token magnitude
    pruning is deterministic and position-independent, the same property
    that makes prefix sharing bit-exact), so a page's bytes can round-trip
    through host memory and come back byte-identical: ``put()`` stores a
    host pytree (numpy leaves — typically ``gather_page_arrays`` /
    ``gather_slot_state`` output) under a fresh integer key, ``take()``
    pops it for restore, ``peek()`` reads without consuming (persistence),
    ``drop()`` discards. The spool holds NO allocator references — its
    entries are plain bytes; whoever spools a page releases the device
    page separately.

    BYTE ACCOUNTING: ``bytes_out`` accumulates device→host traffic (every
    ``put``), ``bytes_in`` host→device (every ``take``) — the measured
    swap-traffic numbers BENCH_preemption.json reports next to the
    ``roofline.swap_bytes`` model. ``held_bytes`` is the current host
    footprint (the oversubscription headroom in use).

    Both traffic totals live on ``repro.obs`` counters (named
    ``spool.bytes_out`` / ``spool.bytes_in`` in the registry passed at
    construction; standalone counters otherwise), so one metrics snapshot
    carries the same numbers the BENCH_preemption byte-exactness gate
    asserts. The ``bytes_out``/``bytes_in`` int properties keep every
    existing reader working unchanged."""

    def __init__(self, registry=None):
        from repro.obs.metrics import Counter
        self._entries: Dict[int, Any] = {}
        self._sizes: Dict[int, int] = {}
        self._next = 0
        if registry is not None and not getattr(registry, "null", False):
            self._bytes_out = registry.counter("spool.bytes_out")
            self._bytes_in = registry.counter("spool.bytes_in")
        else:
            self._bytes_out = Counter("spool.bytes_out")
            self._bytes_in = Counter("spool.bytes_in")

    @property
    def bytes_out(self) -> int:
        """Total device -> host bytes spilled (every counted ``put``)."""
        return self._bytes_out.value

    @property
    def bytes_in(self) -> int:
        """Total host -> device bytes restored (every ``take``)."""
        return self._bytes_in.value

    @property
    def n_entries(self) -> int:
        return len(self._entries)

    @property
    def held_bytes(self) -> int:
        return sum(self._sizes.values())

    def put(self, data, count: bool = True) -> int:
        """Store a host pytree, returning its key. ``count=False`` skips
        the ``bytes_out`` traffic accounting (disk→host loads are not
        device→host swaps)."""
        key = self._next
        self._next += 1
        size = host_nbytes(data)
        self._entries[key] = data
        self._sizes[key] = size
        if count:
            self._bytes_out.inc(size)
        return key

    def peek(self, key: int):
        return self._entries[key]

    def take(self, key: int):
        """Pop an entry for restore (counts toward ``bytes_in``)."""
        self._bytes_in.inc(self._sizes.pop(key))
        return self._entries.pop(key)

    def drop(self, key: int) -> None:
        """Discard an entry without restoring it (no traffic counted)."""
        self._entries.pop(key)
        self._sizes.pop(key)


def host_nbytes(tree) -> int:
    """Total numpy bytes in a host pytree (ints/None/strings cost 0)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        total += getattr(leaf, "nbytes", 0)
    return total


def gather_page_arrays(cache, pages):
    """Host copies of physical pages ``pages`` across every pool leaf.

    Returns a list over period positions: attention entries are
    ``{name: np.ndarray [n_periods, len(pages), Hkv, page_tokens, ·]}``
    over ``_POOL_KEYS``, non-attention entries are None. One gather +
    device_get per leaf — the device→host half of a page swap."""
    import numpy as np
    idx = np.asarray(list(pages), np.int32)
    out = []
    for lc in cache["blocks"]:
        if _is_pool_layer(lc):
            out.append({name: np.asarray(lc[name][:, idx])
                        for name in _pool_keys(lc)})
        else:
            out.append(None)
    return out


@partial(jax.jit, donate_argnums=0)
def _write_page_leaf(leaf: jax.Array, data: jax.Array,
                     dst: jax.Array) -> jax.Array:
    """Overwrite physical page ``dst`` of one pool leaf with host ``data``
    ([n_periods, Hkv, page_tokens, ·]). Donated like ``_copy_page_leaf``:
    in-place at O(page_bytes), one executable per leaf shape."""
    return leaf.at[:, dst].set(data)


def scatter_page_arrays(cache, data, pages):
    """Splice ``gather_page_arrays`` output back into freshly drawn pages
    (``pages[i]`` receives column ``i``) — the host→device half of a swap.
    The compressed content is restored byte-for-byte, so a restored
    request decodes bit-identically to one that was never swapped. Pool
    leaves are donated through ``_write_page_leaf``; callers must adopt
    the returned cache."""
    new_blocks = []
    for lc, entry in zip(cache["blocks"], data):
        if entry is None or not _is_pool_layer(lc):
            new_blocks.append(lc)
            continue
        nl = dict(lc)
        for name in _pool_keys(lc):
            leaf = nl[name]
            host = entry[name]
            for i, phys in enumerate(pages):
                leaf = _write_page_leaf(
                    leaf, jnp.asarray(host[:, i], leaf.dtype),
                    jnp.int32(phys))
            nl[name] = leaf
        new_blocks.append(nl)
    out = dict(cache)
    out["blocks"] = tuple(new_blocks)
    return out


@partial(jax.jit, donate_argnums=0)
def _write_slot_leaf(leaf: jax.Array, data: jax.Array,
                     slot: jax.Array) -> jax.Array:
    """Overwrite batch slot ``slot`` of one slot-major leaf with ``data``
    ([n_periods, ...], no batch dim). Donated, in-place."""
    return leaf.at[:, slot].set(data)


def gather_slot_state(cache, slot: int):
    """Host copy of ONE slot's non-pool cache state: every slot-major
    block leaf (dense windows, mamba/rwkv/cross state — pool leaves are
    page-major and travel via ``gather_page_arrays``) plus the three
    per-slot counters. Together with the slot's pages and block-table row
    this is the complete state a preemption must spool for a bit-exact
    restore (no recomputation)."""
    import numpy as np
    blocks = []
    for lc in cache["blocks"]:
        blocks.append({name: np.asarray(leaf[:, slot])
                       for name, leaf in lc.items()
                       if name not in _POOL_KEYS})
    return {
        "blocks": blocks,
        "position": int(cache["position"][slot]),
        "w_len": int(cache["w_len"][slot]),
        "n_compressed": int(cache["n_compressed"][slot]),
    }


def scatter_slot_state(cache, slot: int, state):
    """Restore ``gather_slot_state`` output into ``slot`` (leaves donated)."""
    new_blocks = []
    for lc, entry in zip(cache["blocks"], state["blocks"]):
        nl = dict(lc)
        for name, host in entry.items():
            nl[name] = _write_slot_leaf(
                nl[name], jnp.asarray(host, nl[name].dtype), jnp.int32(slot))
        new_blocks.append(nl)
    out = dict(cache)
    out["blocks"] = tuple(new_blocks)
    for key in ("position", "w_len", "n_compressed"):
        out[key] = cache[key].at[slot].set(jnp.int32(state[key]))
    return out


def prefix_cache_fingerprint(cfg: ModelConfig, page_tokens: int) -> Dict[str, Any]:
    """Identity of a persisted prefix cache's byte layout. Compressed page
    content is a pure function of (tokens, pruning config, page geometry);
    if ANY of these change between save and load the stored bytes are
    silently wrong for the new deployment, so ``PrefixIndex.load``
    hard-fails on mismatch — the invalidation rule."""
    m = cfg.mustafar
    return {
        "d_head": cfg.d_head,
        "n_kv_heads": cfg.n_kv_heads,
        "n_layers": cfg.n_layers,
        "tile_tokens": m.tile_tokens,
        "local_window": m.local_window,
        "key_sparsity": m.key_sparsity,
        "value_sparsity": m.value_sparsity,
        "page_tokens": page_tokens,
        "pool_dtype": str(jnp.dtype(pool_dtype(cfg))),
    }


class PrefixIndex:
    """Token-trie (radix) index from PROMPT prefixes to retired compressed
    pages, for cross-request sharing.

    Per-token magnitude pruning (paper §3) is deterministic and position-
    independent within the compressed region: two prompts that agree on
    their first ``(lp+1)·page_tokens`` tokens produce BIT-IDENTICAL
    compressed content for logical page ``lp`` once that page is fully
    retired. The index therefore keys physical pages on the exact token
    prefix they compress:

      * FULL pages — one trie node per retired page, its parent edge keyed
        on that page's own ``page_tokens``-token slice (a node at depth
        ``lp+1`` therefore identifies the whole prefix
        ``prompt[: (lp+1)·page_tokens]``; match walks edges outward from
        the root and stops at the first miss, so a hit is always a
        contiguous chain).
      * BOUNDARY pages — a partially-filled last page (``comp % page_tokens
        != 0``) is shareable too: rows past a sharer's own ``n_compressed``
        are masked by every consumer, so a sharer may alias a donor page
        whose fill is >= its own as long as the covered tokens agree. These
        hang off their full-page base node, keyed on the partial tokens.

    The index holds ONE allocator reference per entry (``register`` shares,
    eviction releases), so cached pages survive their donor's retirement.
    Matching hands refs to the caller per matched page; eviction is LRU and
    drops a chain's descendants with it (an orphaned descendant could never
    match again — match walks from the root).

    STORAGE is a real trie over ``page_tokens``-token chunks (integer node
    ids, each edge keyed by ONE page's token slice), so a cached L-token
    prefix costs O(L) key storage and match/register do O(L) hashing total
    — not the O(L^2) a flat whole-prefix-keyed map would pay.

    SPILL TIER: an entry's page is either DEVICE-resident (``page`` is a
    physical id the index holds a reference on) or SPOOLED (``page`` is
    None and ``spool`` keys its bytes in a host ``PageSpool``). Under pool
    pressure ``evict_until(spool=...)`` DEMOTES the least-recently-used
    entry to the spool instead of dropping it; ``promote()`` moves spooled
    entries on an admission's path back onto freshly drawn pages (the
    content round-trips byte-for-byte, so promoted hits stay bit-exact).
    ``match()`` itself never promotes — it walks device-resident chains
    only and a router may probe it read-only every step. ``save``/``load``
    persist every chain (token keys + page bytes + fill counts) across a
    restart; entries load SPOOLED and promote on first use.

    EVICTION is truly LRU across BOTH entry kinds: every full node and
    partial boundary entry carries a monotonic recency stamp (bumped at
    admission commit via ``match(touch_lru=True)`` and at ``register``),
    and ``_evict_one`` compares the oldest full chain against the oldest
    device-resident partial and takes the older stamp — a just-matched
    boundary page can no longer be outlived by a cold full chain (or vice
    versa), which the old two-separate-LRU-lists scheme allowed.
    """

    _ROOT = 0                              # virtual root node id

    def __init__(self, page_tokens: int,
                 spool: Optional[PageSpool] = None):
        self.page_tokens = page_tokens
        # node id -> {"page": phys|None, "spool": key|None, "parent": id,
        #             "chunk": edge tokens, "used": recency stamp}
        self._nodes: Dict[int, Dict[str, Any]] = {}
        # node id -> {edge chunk -> child node id}
        self._children: Dict[int, Dict[Tuple[int, ...], int]] = {
            self._ROOT: {}}
        self._next_id = self._ROOT + 1
        # DEVICE-resident full-page nodes in LRU order (oldest first);
        # spooled nodes leave this dict (they hold no device page)
        self._lru: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        # base node id -> {"toks": partial tuple, "page": phys|None,
        #                  "spool": key|None, "used": stamp}, LRU order
        self._partials: "collections.OrderedDict[int, Dict[str, Any]]" = \
            collections.OrderedDict()
        self.spool = spool if spool is not None else PageSpool()
        self._clock = 0                    # monotonic recency source
        # sharing stats, bumped by the SCHEDULER at admission commit (not
        # in match() — a blocked head-of-queue admission re-matches every
        # engine step and would inflate them arbitrarily)
        self.hits = 0      # pages mapped from the index, admitted matches
        self.misses = 0    # committed admissions that matched nothing
        # spill-tier traffic stats (entries == pages: one page per entry)
        self.demotions = 0   # entries demoted device -> spool
        self.promotions = 0  # entries promoted spool -> device
        self.evictions = 0   # entries dropped outright (storage released)

    def register_metrics(self, registry) -> None:
        """Report index state through ``registry``: LAZY counters mirror
        the plain-int stats (the scheduler mutates ``hits``/``misses``
        directly at admission commit; eviction paths bump the rest), plus
        callback gauges for residency."""
        registry.counter("prefix.hits", fn=lambda: self.hits)
        registry.counter("prefix.misses", fn=lambda: self.misses)
        registry.counter("prefix.demotions", fn=lambda: self.demotions)
        registry.counter("prefix.promotions", fn=lambda: self.promotions)
        registry.counter("prefix.evictions", fn=lambda: self.evictions)
        registry.gauge("prefix.device_entries",
                       fn=lambda: len(self.held_pages))
        registry.gauge("prefix.spooled_entries",
                       fn=lambda: self.spooled_entries)

    def _bump(self) -> int:
        self._clock += 1
        return self._clock

    @property
    def held_pages(self) -> List[int]:
        """DEVICE pages the index holds a reference on (one per resident
        entry; spooled entries hold host bytes, not pages)."""
        return [n["page"] for n in self._nodes.values()
                if n["page"] is not None] \
            + [e["page"] for e in self._partials.values()
               if e["page"] is not None]

    @property
    def spooled_entries(self) -> int:
        """Entries currently demoted to the host spool."""
        return sum(1 for n in self._nodes.values() if n["page"] is None) \
            + sum(1 for e in self._partials.values() if e["page"] is None)

    def match(self, prompt, comp: int, touch_lru: bool = False):
        """Longest shared prefix for ``prompt`` with compressed fill ``comp``.

        Returns ``(full_pages, boundary_page, shared_tokens)``:
        ``full_pages`` are physical ids for logical pages ``0..n-1``,
        ``boundary_page`` (or None) backs the partially-filled last page,
        and ``shared_tokens`` is the compressed-token count the caller can
        skip re-compressing (``n·page_tokens``, or ``comp`` when the
        boundary matched too). The caller must ``share()`` each returned
        page before relying on it.

        LRU recency moves only under ``touch_lru`` — the scheduler sets it
        at ADMISSION COMMIT, like the hit/miss stats: a blocked
        head-of-queue admission probes every engine step, and letting
        probes refresh recency would pin the never-admitted request's
        chain while chains that live requests re-use get evicted.

        SPOOLED entries stop the walk: only device-resident pages can be
        mapped into a block table. Call ``promote()`` first to lift a
        spooled continuation back onto device pages."""
        pt = self.page_tokens
        toks = tuple(int(t) for t in prompt)
        full: List[int] = []
        node = self._ROOT
        for lp in range(comp // pt):
            child = self._children.get(node, {}).get(
                toks[lp * pt:(lp + 1) * pt])
            if child is None or self._nodes[child]["page"] is None:
                break
            if touch_lru:
                self._lru.move_to_end(child)
                self._nodes[child]["used"] = self._bump()
            full.append(self._nodes[child]["page"])
            node = child
        boundary = None
        shared_tokens = len(full) * pt
        fill = comp % pt
        if fill and len(full) == comp // pt:
            ent = self._partials.get(node)
            if ent is not None and ent["page"] is not None:
                donor_toks = ent["toks"]
                if (len(donor_toks) >= fill
                        and donor_toks[:fill] == toks[comp - fill:comp]):
                    if touch_lru:
                        self._partials.move_to_end(node)
                        ent["used"] = self._bump()
                    boundary = ent["page"]
                    shared_tokens = comp
        return full, boundary, shared_tokens

    def probe(self, prompt, comp: int) -> int:
        """POTENTIAL shared tokens for ``prompt``, counting spooled entries
        the walk could promote back — what a router's affinity probe wants
        (a replica holding the chain in its host spool is still the cheap
        destination), where ``match()`` reports only immediately mappable
        device pages. Read-only: no LRU movement, no promotion."""
        pt = self.page_tokens
        toks = tuple(int(t) for t in prompt)
        node = self._ROOT
        depth = 0
        for lp in range(comp // pt):
            child = self._children.get(node, {}).get(
                toks[lp * pt:(lp + 1) * pt])
            if child is None:
                break
            depth += 1
            node = child
        shared = depth * pt
        fill = comp % pt
        if fill and depth == comp // pt:
            ent = self._partials.get(node)
            if ent is not None and len(ent["toks"]) >= fill \
                    and ent["toks"][:fill] == toks[comp - fill:comp]:
                shared = comp
        return shared

    def promote(self, prompt, comp: int, allocator: PageAllocator,
                cache) -> Tuple[Any, int]:
        """Lift spooled entries on ``prompt``'s path back onto device pages
        so the following ``match()`` can map them. Each promoted entry
        reserves + draws one page and scatters its host bytes back
        (byte-exact — compressed pages are immutable, so the round-trip
        through the spool preserves them bit-for-bit). Stops as soon as the
        pool cannot reserve another page; promoted entries get FRESH
        recency stamps so an immediately following eviction pass does not
        demote them right back (churn guard). Returns ``(cache,
        n_promoted)`` — pool leaves are donated through the scatter."""
        pt = self.page_tokens
        toks = tuple(int(t) for t in prompt)
        node = self._ROOT
        n_promoted = 0
        depth = 0
        for lp in range(comp // pt):
            child = self._children.get(node, {}).get(
                toks[lp * pt:(lp + 1) * pt])
            if child is None:
                break
            ent = self._nodes[child]
            if ent["page"] is None:
                if not allocator.can_reserve(1):
                    return cache, n_promoted
                allocator.reserve(1)
                page = allocator.draw_many(1)[0]
                cache = scatter_page_arrays(
                    cache, self.spool.take(ent["spool"]), [page])
                ent["page"], ent["spool"] = page, None
                self._lru[child] = None
                self._lru.move_to_end(child)
                ent["used"] = self._bump()
                self.promotions += 1
                n_promoted += 1
            depth += 1
            node = child
        fill = comp % pt
        if fill and depth == comp // pt:
            ent = self._partials.get(node)
            if ent is not None and ent["page"] is None \
                    and len(ent["toks"]) >= fill \
                    and ent["toks"][:fill] == toks[comp - fill:comp]:
                if not allocator.can_reserve(1):
                    return cache, n_promoted
                allocator.reserve(1)
                page = allocator.draw_many(1)[0]
                cache = scatter_page_arrays(
                    cache, self.spool.take(ent["spool"]), [page])
                ent["page"], ent["spool"] = page, None
                self._partials.move_to_end(node)
                ent["used"] = self._bump()
                self.promotions += 1
                n_promoted += 1
        return cache, n_promoted

    def register(self, prompt, comp: int, slot_pages: List[int],
                 allocator: PageAllocator) -> None:
        """Index a freshly-spliced request's prefill pages.

        ``slot_pages[lp]`` is the physical page backing logical page ``lp``
        (shared or owned — already-indexed prefixes are skipped). The index
        takes its own reference on every entry it adds; a boundary entry is
        replaced only by a strict extension of itself (longer fill, same
        leading tokens), releasing the superseded page. Registering over a
        SPOOLED entry re-adopts the slot's device page (and drops the
        spooled bytes) — the slot just recompressed the identical content,
        so adoption is a free promotion."""
        pt = self.page_tokens
        toks = tuple(int(t) for t in prompt)
        node = self._ROOT
        for lp in range(comp // pt):
            chunk = toks[lp * pt:(lp + 1) * pt]
            ch = self._children.setdefault(node, {})
            child = ch.get(chunk)
            if child is None:
                child = self._next_id
                self._next_id += 1
                self._nodes[child] = {
                    "page": allocator.share(slot_pages[lp]),
                    "spool": None,
                    "parent": node, "chunk": chunk,
                    "used": self._bump()}
                ch[chunk] = child
                self._lru[child] = None
            else:
                ent = self._nodes[child]
                if ent["page"] is None:
                    ent["page"] = allocator.share(slot_pages[lp])
                    self.spool.drop(ent["spool"])
                    ent["spool"] = None
                    self._lru[child] = None
                self._lru.move_to_end(child)
                ent["used"] = self._bump()
            node = child
        fill = comp % pt
        if fill:
            part = toks[comp - fill:comp]
            ent = self._partials.get(node)
            if ent is None:
                self._partials[node] = {
                    "toks": part,
                    "page": allocator.share(slot_pages[comp // pt]),
                    "spool": None, "used": self._bump()}
            else:
                donor_toks = ent["toks"]
                extends = (len(part) > len(donor_toks)
                           and part[:len(donor_toks)] == donor_toks)
                adoptable = (ent["page"] is None
                             and len(part) >= len(donor_toks)
                             and part[:len(donor_toks)] == donor_toks)
                if extends or adoptable:
                    if ent["page"] is not None:
                        allocator.release(ent["page"])
                    elif ent["spool"] is not None:
                        self.spool.drop(ent["spool"])
                    ent["toks"] = part
                    ent["page"] = allocator.share(slot_pages[comp // pt])
                    ent["spool"] = None
                    self._partials.move_to_end(node)
                    ent["used"] = self._bump()

    def _release_entry_storage(self, ent: Dict[str, Any],
                               allocator: PageAllocator) -> None:
        if ent["page"] is not None:
            allocator.release(ent["page"])
        elif ent["spool"] is not None:
            self.spool.drop(ent["spool"])

    def _drop_subtree(self, root: int, allocator: PageAllocator) -> None:
        """Release the trie subtree rooted at ``root`` (its pages — device
        or spooled — partials, and the edge from its parent)."""
        parent = self._nodes[root]
        self._children.get(parent["parent"], {}).pop(parent["chunk"], None)
        stack = [root]
        while stack:
            nid = stack.pop()
            stack.extend(self._children.pop(nid, {}).values())
            node = self._nodes.pop(nid)
            self._lru.pop(nid, None)
            self._release_entry_storage(node, allocator)
            self.evictions += 1
            ent = self._partials.pop(nid, None)
            if ent is not None:
                self._release_entry_storage(ent, allocator)
                self.evictions += 1

    def _oldest_device_entries(self) -> Tuple[Optional[int], Optional[int]]:
        """(oldest full node id, oldest device-resident partial base id)."""
        full = next(iter(self._lru), None)
        part = None
        for nid, ent in self._partials.items():
            if ent["page"] is not None:
                part = nid
                break
        return full, part

    def _demote_full(self, nid: int, allocator: PageAllocator,
                     cache) -> None:
        """Move one full node's page to the host spool and release it."""
        node = self._nodes[nid]
        node["spool"] = self.spool.put(
            gather_page_arrays(cache, [node["page"]]))
        allocator.release(node["page"])
        node["page"] = None
        self.demotions += 1
        self._lru.pop(nid, None)

    def _evict_one(self, allocator: PageAllocator, spool: bool = False,
                   cache=None) -> bool:
        """Evict the truly least-recently-used DEVICE entry, comparing the
        oldest full chain against the oldest resident partial by recency
        stamp (a just-matched boundary page must outlive a cold full
        chain, and vice versa). ``spool=True`` DEMOTES the entry — page
        bytes move to the host spool and the trie keeps the (now spooled)
        entry for later ``promote()`` — instead of dropping it. Dropping a
        full node also drops every descendant (an orphaned descendant can
        never match); demotion keeps descendants — a spooled ancestor
        shadows them from ``match()`` until promoted back."""
        full, part = self._oldest_device_entries()
        take_part = part is not None and (
            full is None
            or self._partials[part]["used"] < self._nodes[full]["used"])
        if take_part:
            ent = self._partials[part]
            if spool:
                ent["spool"] = self.spool.put(
                    gather_page_arrays(cache, [ent["page"]]))
                allocator.release(ent["page"])
                ent["page"] = None
                self.demotions += 1
            else:
                allocator.release(ent["page"])
                del self._partials[part]
                self.evictions += 1
            return True
        if full is None:
            return False
        if spool:
            self._demote_full(full, allocator, cache)
        else:
            self._drop_subtree(full, allocator)
        return True

    def evict_until(self, allocator: PageAllocator, n_pages: int,
                    spool: bool = False, cache=None) -> None:
        """LRU-evict entries until ``n_pages`` can be reserved (or no
        device-resident entry remains). Pages still mapped by live slots
        stay allocated — only the index's reference drops — so this can
        legitimately fall short; the caller then waits for retirements
        (or preempts) like any other admission.

        CONTRACT of ``spool=True``: entries are demoted to ``self.spool``
        (host bytes + intact trie keys) rather than forgotten, and
        ``cache`` must be passed so page bytes can be gathered before the
        device page is released. Demotion frees exactly as many device
        pages as dropping would, at host-memory cost ``page_bytes`` per
        entry; a later ``promote()`` on the same prompt path restores the
        bytes byte-exactly. Without ``spool`` the behavior is the legacy
        destructive drop."""
        while not allocator.can_reserve(n_pages):
            if not self._evict_one(allocator, spool=spool, cache=cache):
                return

    def save(self, path: str, cache=None,
             fingerprint: Optional[Dict[str, Any]] = None) -> int:
        """Persist every chain (token keys + page bytes + fill counts) so a
        redeployed scheduler restarts with a warm prefix cache. Device-
        resident entries are gathered from ``cache``; spooled entries come
        straight from the spool. ``fingerprint`` (see
        ``prefix_cache_fingerprint``) is stored and re-checked by
        ``load`` — a persisted cache is only valid for the exact config /
        pruning mode / page geometry that produced it. Returns the number
        of entries written."""
        import pickle
        def _bytes_of(ent):
            if ent["page"] is not None:
                if cache is None:
                    raise ValueError(
                        "save() needs cache= to read device-resident pages")
                return gather_page_arrays(cache, [ent["page"]])
            return self.spool.peek(ent["spool"])
        nodes = [(nid, n["parent"], n["chunk"], _bytes_of(n))
                 for nid, n in self._nodes.items()]
        partials = [(base, e["toks"], _bytes_of(e))
                    for base, e in self._partials.items()]
        blob = {"version": 1, "fingerprint": fingerprint,
                "page_tokens": self.page_tokens,
                "nodes": nodes, "partials": partials}
        with open(path, "wb") as f:
            pickle.dump(blob, f)
        return len(nodes) + len(partials)

    def load(self, path: str,
             fingerprint: Optional[Dict[str, Any]] = None) -> int:
        """Load a ``save()`` blob into this (empty) index. Every entry
        arrives SPOOLED — no device pages are drawn until an admission's
        ``promote()`` walks its path — so loading costs host memory only.
        Raises ValueError when the stored fingerprint does not match
        ``fingerprint`` (config / pruning mode / page geometry changed:
        compressed bytes would be silently wrong, so the persisted cache
        must be invalidated, not reinterpreted). Returns entries loaded."""
        import pickle
        if self._nodes or self._partials:
            raise ValueError("load() requires an empty PrefixIndex")
        with open(path, "rb") as f:
            blob = pickle.load(f)
        if blob.get("version") != 1:
            raise ValueError(f"unknown prefix-cache version "
                             f"{blob.get('version')!r}")
        if blob.get("page_tokens") != self.page_tokens:
            raise ValueError(
                f"persisted page_tokens={blob.get('page_tokens')} != "
                f"index page_tokens={self.page_tokens}")
        if fingerprint is not None and blob.get("fingerprint") != fingerprint:
            raise ValueError(
                "persisted prefix cache fingerprint mismatch — config or "
                "pruning mode changed; discard the persisted file")
        id_map = {self._ROOT: self._ROOT}
        # parents precede children in insertion order (register() creates
        # them top-down and _drop_subtree removes whole subtrees), so a
        # single pass can remap ids
        for nid, parent, chunk, data in blob["nodes"]:
            new = self._next_id
            self._next_id += 1
            id_map[nid] = new
            self._nodes[new] = {
                "page": None, "spool": self.spool.put(data, count=False),
                "parent": id_map[parent], "chunk": tuple(chunk),
                "used": self._bump()}
            self._children.setdefault(id_map[parent], {})[tuple(chunk)] = new
        for base, toks, data in blob["partials"]:
            self._partials[id_map[base]] = {
                "toks": tuple(toks), "page": None,
                "spool": self.spool.put(data, count=False),
                "used": self._bump()}
        return len(blob["nodes"]) + len(blob["partials"])

    def clear(self, allocator: PageAllocator) -> None:
        """Release every held reference — device pages AND spooled bytes
        (drain/shutdown path)."""
        for node in self._nodes.values():
            self._release_entry_storage(node, allocator)
        for ent in self._partials.values():
            self._release_entry_storage(ent, allocator)
        self._nodes.clear()
        self._children = {self._ROOT: {}}
        self._lru.clear()
        self._partials.clear()


@partial(jax.jit, donate_argnums=0)
def _copy_page_leaf(leaf: jax.Array, src: jax.Array,
                    dst: jax.Array) -> jax.Array:
    """One pool leaf with physical page ``dst`` overwritten by page ``src``.

    Jitted with the leaf DONATED and src/dst as traced scalars: the update
    runs in place at O(page_bytes) cost (one executable per leaf shape,
    reused for every page id), instead of XLA materialising a full new
    leaf — O(pool bytes) and a transient 2x pool footprint — per
    copy-on-write event."""
    return leaf.at[:, dst].set(leaf[:, src])


def copy_page(cache, src: int, dst: int):
    """Device-side copy of one physical page across every pool leaf of every
    attention layer — the COPY-ON-WRITE step. A slot about to compact into a
    shared (refcount > 1) page first duplicates it into a freshly drawn page
    and remaps its block-table entry; the original stays immutable for the
    other holders. Pool leaves are ``[n_periods, n_phys, Hkv, page_tokens,
    ·]`` under the period stack, so the copy is one in-place
    ``_copy_page_leaf`` per leaf. The input leaves are DONATED — callers
    must drop their reference to ``cache`` in favour of the returned one."""
    src = jnp.int32(src)
    dst = jnp.int32(dst)
    new_blocks = []
    for lc in cache["blocks"]:
        if _is_pool_layer(lc):
            nl = dict(lc)
            for name in _pool_keys(lc):
                nl[name] = _copy_page_leaf(lc[name], src, dst)
            new_blocks.append(nl)
        else:
            new_blocks.append(lc)
    out = dict(cache)
    out["blocks"] = tuple(new_blocks)
    return out


def layer_cache_shapes(cfg: ModelConfig, kind: str, B: int,
                       max_total_tokens: int, enc_ctx: int = 0,
                       plan_batch: Optional[int] = None) -> Dict[str, Any]:
    """Shape/dtype spec for one layer kind (without the stacked period dim).

    ``plan_batch`` overrides the batch used for pool *planning* (Tc_max
    alignment) without changing the allocated batch dim — a solo (B=1)
    prefill destined for one slot of an n-slot shared cache must plan with
    the shared batch so the pool shapes line up for the slot splice."""
    d = cfg.d_head
    Hkv = cfg.n_kv_heads
    W32 = pad_to_words(d) // 32
    m = cfg.mustafar
    cdt = jnp.dtype(cfg.dtype)
    if kind == "attn":
        Tc_max, Wbuf = plan_pools(cfg, max_total_tokens,
                                  batch=B if plan_batch is None else plan_batch)
        if m.enabled:
            kk = m.keep_k(d, m.key_sparsity)
            kv = m.keep_k(d, m.value_sparsity)
            pdt = pool_dtype(cfg)
            spec = {
                "ck_vals": ((B, Hkv, Tc_max, kk), pdt),
                "ck_bm": ((B, Hkv, Tc_max, W32), jnp.uint32),
                "cv_vals": ((B, Hkv, Tc_max, kv), pdt),
                "cv_bm": ((B, Hkv, Tc_max, W32), jnp.uint32),
            }
            if pool_quantized(cfg):
                # one fp32 absmax scale per (head, tile_tokens-token tile);
                # the row axis counts TILES — a leaf's quant tile is always
                # derivable as vals_rows // scale_rows, so readers need no
                # extra config threading.
                nt = Tc_max // m.tile_tokens
                spec["ck_scale"] = ((B, Hkv, nt, 1), SCALE_DTYPE)
                spec["cv_scale"] = ((B, Hkv, nt, 1), SCALE_DTYPE)
            spec["k_win"] = ((B, Hkv, Wbuf, d), cdt)
            spec["v_win"] = ((B, Hkv, Wbuf, d), cdt)
        else:
            spec = {
                "k": ((B, Hkv, max_total_tokens, d), cdt),
                "v": ((B, Hkv, max_total_tokens, d), cdt),
            }
        if cfg.family == "audio":
            spec["cross_k"] = ((B, enc_ctx, Hkv, d), cdt)
            spec["cross_v"] = ((B, enc_ctx, Hkv, d), cdt)
        return spec
    if kind == "mamba":
        st = mamba_mod.mamba_state_shapes(cfg, B)
        return {"conv": (st["conv"], jnp.float32), "ssm": (st["ssm"], jnp.float32)}
    # rwkv
    st = rwkv_mod.rwkv_state_shapes(cfg, B)
    return {"tm_shift": (st["tm_shift"], cdt), "wkv": (st["wkv"], jnp.float32),
            "cm_shift": (st["cm_shift"], cdt)}


# pool leaves that switch from slot-major [B, Hkv, Tc, ·] to page-major
# [n_pages, Hkv, page_tokens, ·] under paging. The scale leaves exist ONLY
# for quantized (int8) pools — every pool-generic path below iterates
# ``_pool_keys(lc)`` (present leaves) so bf16 caches keep their exact PR 9
# shapes — and their row axis counts TILES, not tokens (rows-per-page =
# page_tokens // tile_tokens), so generic page splicing must use each
# leaf's own rows-per-page rather than assuming page_tokens.
_VALUE_POOL_KEYS = ("ck_vals", "ck_bm", "cv_vals", "cv_bm")
_SCALE_KEYS = ("ck_scale", "cv_scale")
_POOL_KEYS = _VALUE_POOL_KEYS + _SCALE_KEYS


def _is_pool_layer(lc) -> bool:
    """True for an attention layer cache holding compressed pools."""
    return all(kn in lc for kn in _VALUE_POOL_KEYS)


def _pool_keys(lc):
    """The pool leaves actually present (scales only under int8)."""
    return tuple(kn for kn in _POOL_KEYS if kn in lc)


def init_cache(cfg: ModelConfig, B: int, max_total_tokens: int,
               enc_ctx: int = 0, page_tokens: Optional[int] = None,
               n_pages: Optional[int] = None):
    """Zero-filled cache pytree: (blocks=tuple over period positions of
    stacked [n_periods, ...] dicts, plus per-sequence [B] state vectors).

    ``page_tokens`` switches the compressed pools to the PAGED layout: one
    global pool ``[n_phys, Hkv, page_tokens, ·]`` per leaf (shared by all
    slots; ``n_phys = n_pages + 1`` — the last page is a write-discard
    scratch target for masked compactions) plus a per-slot int32
    ``block_table [B, max_pages]`` initialised to ``PAGE_UNMAPPED``. One
    block table serves every layer: compaction retires the same token range
    in all layers, so logical page p of a slot backs the same physical page
    index in each layer's pool. ``n_pages`` defaults to full contiguous
    capacity (``B * max_pages``) — pass less to overcommit and let the
    scheduler's page-budget admission gate ride the difference."""
    period = structural_period(cfg)
    n_periods = cfg.n_layers // period
    paged = page_tokens is not None
    if paged:
        if not cfg.mustafar.enabled or not cfg.attention_layers():
            raise ValueError("paged pools require mustafar.enabled and at "
                             "least one attention layer")
        max_pages = plan_pages(cfg, max_total_tokens, page_tokens, batch=B)
        if n_pages is None:
            n_pages = B * max_pages
    blocks = []
    for j in range(period):
        kind = cfg.layer_kind(j)
        spec = layer_cache_shapes(cfg, kind, B, max_total_tokens, enc_ctx)
        if paged and kind == "attn":
            for name in _POOL_KEYS:
                if name not in spec:
                    continue
                (_, _, _, c), dt = spec[name]
                # scale leaves hold one row per tile, not per token
                rows = (page_tokens // cfg.mustafar.tile_tokens
                        if name in _SCALE_KEYS else page_tokens)
                spec[name] = ((n_pages + 1, cfg.n_kv_heads, rows, c), dt)
        blocks.append({k: jnp.zeros((n_periods,) + shp, dt)
                       for k, (shp, dt) in spec.items()})
    out = {
        "blocks": tuple(blocks),
        "position": jnp.zeros((B,), jnp.int32),       # total tokens per slot
        "w_len": jnp.zeros((B,), jnp.int32),          # valid window per slot
        "n_compressed": jnp.zeros((B,), jnp.int32),   # pool tokens per slot
    }
    if paged:
        out["block_table"] = jnp.full((B, max_pages), PAGE_UNMAPPED,
                                      jnp.int32)
    return out


# ----------------------------------------------------------------------
# compaction (tile-group retirement: window -> compressed pools)

# leaves mutated by tile-group retirement (cross_k/cross_v etc. pass
# through; the scale leaves join only when present, i.e. int8 pools)
_COMPACT_KEYS = ("ck_vals", "ck_bm", "cv_vals", "cv_bm",
                 "ck_scale", "cv_scale", "k_win", "v_win")


def _compact_keys(lc):
    return tuple(k for k in _COMPACT_KEYS if k in lc)


def _compact_layer_seq(cfg: ModelConfig, lc: Dict[str, jax.Array],
                       n_compressed: jax.Array) -> Dict[str, jax.Array]:
    """ONE sequence's tile-group retirement: compress the oldest tile_tokens
    of its window into its pools at offset ``n_compressed`` (scalar) and roll
    the window left. Leaves carry no batch dim (k_win [Hkv, Wbuf, d]).
    Quantized pools additionally receive one absmax scale per head at tile
    slot ``n_compressed // tile_tokens`` — computed in the same compress
    dispatch, not an extra pass over the tile."""
    m = cfg.mustafar
    d = cfg.d_head
    tt = m.tile_tokens
    kk = m.keep_k(d, m.key_sparsity)
    kv = m.keep_k(d, m.value_sparsity)
    quant = pool_quantized(cfg)

    k_tile = lc["k_win"][:, :tt, :]                    # [Hkv,tt,d]
    v_tile = lc["v_win"][:, :tt, :]
    qt = tt if quant else None
    ck = kops.compress(k_tile, kk, quant_tile=qt)
    cv = kops.compress(v_tile, kv, quant_tile=qt)

    def upd(pool, tile, step=1):
        return jax.lax.dynamic_update_slice(
            pool, tile.astype(pool.dtype), (0, n_compressed // step, 0))

    out = dict(lc)
    out["ck_vals"] = upd(lc["ck_vals"], ck[0])
    out["ck_bm"] = upd(lc["ck_bm"], ck[1])
    out["cv_vals"] = upd(lc["cv_vals"], cv[0])
    out["cv_bm"] = upd(lc["cv_bm"], cv[1])
    if quant:
        out["ck_scale"] = upd(lc["ck_scale"], ck[2], step=tt)
        out["cv_scale"] = upd(lc["cv_scale"], cv[2], step=tt)
    # roll the window left by tile_tokens (retired tokens drop out)
    out["k_win"] = jnp.roll(lc["k_win"], -tt, axis=1)
    out["v_win"] = jnp.roll(lc["v_win"], -tt, axis=1)
    return out


def compact_layer(cfg: ModelConfig, lc: Dict[str, jax.Array],
                  n_compressed: jax.Array,
                  need: Optional[jax.Array] = None) -> Dict[str, jax.Array]:
    """Per-slot tile-group retirement on a batched layer cache.

    lc leaves are [B, Hkv, ...]; ``n_compressed`` is the per-sequence [B]
    pool fill. Each slot compacts at its own pool offset; slots where
    ``need`` is False keep their original contents via a masked select —
    no ``lax.cond``, so slots trigger independently of any global counter.
    (The compress runs for every slot every call; the select discards the
    unneeded ones. That is the static-shape price of raggedness.)"""
    keys = _compact_keys(lc)
    sub = {k: lc[k] for k in keys}
    comp = jax.vmap(lambda one, nc: _compact_layer_seq(cfg, one, nc))(
        sub, n_compressed)
    out = dict(lc)
    for k in keys:
        if need is None:
            out[k] = comp[k]
        else:
            mask = need.reshape((-1,) + (1,) * (comp[k].ndim - 1))
            out[k] = jnp.where(mask, comp[k], lc[k])
    return out


def compact_layer_paged(cfg: ModelConfig, lc: Dict[str, jax.Array],
                        n_compressed: jax.Array, block_table: jax.Array,
                        need: jax.Array) -> Dict[str, jax.Array]:
    """Per-slot tile-group retirement into PAGED pools.

    Pool leaves are page-major ``[n_phys, Hkv, page_tokens, ·]`` (no batch
    dim); windows stay slot-major ``[B, Hkv, Wbuf, d]``. Each needy slot's
    oldest tile compresses into physical page
    ``block_table[b, n_compressed[b] // page_tokens]`` at the in-page token
    offset; slots where ``need`` is False — and, defensively, needy slots
    whose target page is unmapped — write to the scratch page (last physical
    index) instead, which keeps the write unconditional (static shapes)
    while discarding it. Writes are a ``lax.scan`` of dynamic_update_slices
    over slots: the allocator guarantees live pages are never shared, so
    slot order cannot alias."""
    m = cfg.mustafar
    d = cfg.d_head
    tt = m.tile_tokens
    kk = m.keep_k(d, m.key_sparsity)
    kv = m.keep_k(d, m.value_sparsity)
    n_phys, _, pt, _ = lc["ck_vals"].shape

    quant = pool_quantized(cfg)
    k_tile = lc["k_win"][:, :, :tt, :]                 # [B,Hkv,tt,d]
    v_tile = lc["v_win"][:, :, :tt, :]
    qt = tt if quant else None
    ck = kops.compress(k_tile, kk, quant_tile=qt)      # [B,Hkv,tt,·]
    cv = kops.compress(v_tile, kv, quant_tile=qt)

    lp = n_compressed // pt                            # [B] logical page
    off = n_compressed % pt                            # [B] in-page offset
    phys = jnp.take_along_axis(block_table, lp[:, None], axis=1)[:, 0]
    ok = need & (phys >= 0)
    phys = jnp.where(ok, jnp.clip(phys, 0, n_phys - 1), n_phys - 1)
    off = jnp.where(ok, off, 0)

    def scatter(pool, tiles, offs):
        def body(p, xs):
            tile, pg, o = xs                           # tile [Hkv, tt, ·]
            return jax.lax.dynamic_update_slice(
                p, tile[None].astype(p.dtype), (pg, 0, o, 0)), None
        p, _ = jax.lax.scan(body, pool, (tiles, phys, offs))
        return p

    out = dict(lc)
    out["ck_vals"] = scatter(lc["ck_vals"], ck[0], off)
    out["ck_bm"] = scatter(lc["ck_bm"], ck[1], off)
    out["cv_vals"] = scatter(lc["cv_vals"], cv[0], off)
    out["cv_bm"] = scatter(lc["cv_bm"], cv[1], off)
    if quant:
        # scale pools hold one row per tile: in-page tile slot = off // tt
        out["ck_scale"] = scatter(lc["ck_scale"], ck[2], off // tt)
        out["cv_scale"] = scatter(lc["cv_scale"], cv[2], off // tt)
    wmask = need.reshape((-1, 1, 1, 1))
    out["k_win"] = jnp.where(wmask, jnp.roll(lc["k_win"], -tt, axis=2),
                             lc["k_win"])
    out["v_win"] = jnp.where(wmask, jnp.roll(lc["v_win"], -tt, axis=2),
                             lc["v_win"])
    return out


def compact_layer_paged_fused(cfg: ModelConfig, lc: Dict[str, jax.Array],
                              n_compressed: jax.Array, block_table: jax.Array,
                              need: jax.Array) -> Dict[str, jax.Array]:
    """Fused-epilogue tile-group retirement into PAGED pools: the whole
    PERIOD-STACKED layer cache in one compress-and-scatter dispatch.

    Unlike ``compact_layer_paged`` (per-period under vmap: one compress
    plus a scan of per-slot dynamic_update_slices), this resolves every
    slot's destination page once and hands ``kops.compress_scatter`` the
    period stack FOLDED into the kernel batch — leaf ``[n_periods, n_phys,
    Hkv, pt, ·]`` reshapes to one pool ``[n_periods·n_phys, ...]`` and row
    (p, b) targets ``phys[b] + p·n_phys`` — so a layer group's entire
    retirement is a single dispatch writing straight into the destination
    pages (each period's scratch page stays its own). Bit-identical to the
    two-dispatch oracle on every non-scratch page
    (tests/test_fused_compaction.py)."""
    m = cfg.mustafar
    tt = m.tile_tokens
    P, n_phys, _, pt, _ = lc["ck_vals"].shape

    lp = n_compressed // pt                            # [B] logical page
    off = n_compressed % pt                            # [B] in-page offset
    phys = jnp.take_along_axis(block_table, lp[:, None], axis=1)[:, 0]
    ok = need & (phys >= 0)
    phys = jnp.where(ok, jnp.clip(phys, 0, n_phys - 1), n_phys - 1)
    off = jnp.where(ok, off, 0)
    # fold periods into the batch: row (p, b) -> page phys[b] + p * n_phys
    phys_pb = (phys[None, :] + n_phys * jnp.arange(P)[:, None]).reshape(-1)
    off_pb = jnp.tile(off, P)

    k_tile = lc["k_win"][:, :, :, :tt, :]              # [P,B,Hkv,tt,d]
    v_tile = lc["v_win"][:, :, :, :tt, :]
    fold = lambda a: a.reshape((-1,) + a.shape[2:])
    names = _pool_keys(lc)                             # scales ride when int8
    pools = [fold(lc[name]) for name in names]
    new_pools = kops.compress_scatter(
        fold(k_tile), fold(v_tile), *pools[:4], phys_pb, off_pb,
        k_scale=pools[4] if len(pools) > 4 else None,
        v_scale=pools[5] if len(pools) > 4 else None)

    out = dict(lc)
    for name, pool in zip(names, new_pools):
        out[name] = pool.reshape(lc[name].shape)
    wmask = need.reshape((1, -1, 1, 1, 1))
    out["k_win"] = jnp.where(wmask, jnp.roll(lc["k_win"], -tt, axis=3),
                             lc["k_win"])
    out["v_win"] = jnp.where(wmask, jnp.roll(lc["v_win"], -tt, axis=3),
                             lc["v_win"])
    return out


def append_window(lc: Dict[str, jax.Array], k_new: jax.Array, v_new: jax.Array,
                  w_len: jax.Array) -> Dict[str, jax.Array]:
    """Append one token's K/V [B, Hkv, 1, d] at each sequence's own window
    offset ``w_len`` [B] (ragged slots write at different positions)."""

    def upd(buf, tok, wl):                             # per-sequence DUS
        return jax.lax.dynamic_update_slice(
            buf, tok.astype(buf.dtype), (0, wl, 0))

    out = dict(lc)
    out["k_win"] = jax.vmap(upd)(lc["k_win"], k_new, w_len)
    out["v_win"] = jax.vmap(upd)(lc["v_win"], v_new, w_len)
    return out


def prefill_split(cfg: ModelConfig, T: int) -> Tuple[int, int]:
    """(compressible_tokens, window_tokens) for a prefill of length T."""
    m = cfg.mustafar
    comp = max(0, (T - m.local_window) // m.tile_tokens) * m.tile_tokens
    return comp, T - comp


def build_layer_cache_from_prefill(cfg: ModelConfig, k: jax.Array, v: jax.Array,
                                   max_total_tokens: int,
                                   cross_kv=None,
                                   plan_batch: Optional[int] = None,
                                   shared_tokens: int = 0
                                   ) -> Dict[str, jax.Array]:
    """k/v [B, T, Hkv, d] from a dense prefill -> one layer's Mustafar cache
    (no period dim; the engine scans this per layer). ``plan_batch`` forces
    the pool planning batch (see layer_cache_shapes) for slot prefills.

    ``shared_tokens`` (static, multiple of tile_tokens, <= the prefill's
    compressed fill) skips compressing the first S tokens: those live in
    prefix pages shared from another request's bit-identical compression, so
    only the UNMATCHED suffix is pruned+compressed (pool region [0, S) stays
    zero and is never copied — the paged splice maps the shared pages there
    instead). ``n_compressed`` still covers the full fill."""
    B, T, Hkv, d = k.shape
    m = cfg.mustafar
    kT = jnp.swapaxes(k, 1, 2)                         # [B,Hkv,T,d]
    vT = jnp.swapaxes(v, 1, 2)
    spec = layer_cache_shapes(cfg, "attn", B, max_total_tokens,
                              enc_ctx=cross_kv[0].shape[1] if cross_kv else 0,
                              plan_batch=plan_batch)
    lc = {name: jnp.zeros(shp, dt) for name, (shp, dt) in spec.items()}
    if m.enabled:
        comp, win = prefill_split(cfg, T)
        S = shared_tokens
        assert 0 <= S <= comp and S % m.tile_tokens == 0, (S, comp)
        kk = m.keep_k(d, m.key_sparsity)
        kv_ = m.keep_k(d, m.value_sparsity)
        if comp > S:
            qt = m.tile_tokens if pool_quantized(cfg) else None
            ck = kops.compress(kT[:, :, S:comp], kk, quant_tile=qt)
            cv = kops.compress(vT[:, :, S:comp], kv_, quant_tile=qt)
            lc["ck_vals"] = jax.lax.dynamic_update_slice(
                lc["ck_vals"], ck[0].astype(lc["ck_vals"].dtype), (0, 0, S, 0))
            lc["ck_bm"] = jax.lax.dynamic_update_slice(lc["ck_bm"], ck[1], (0, 0, S, 0))
            lc["cv_vals"] = jax.lax.dynamic_update_slice(
                lc["cv_vals"], cv[0].astype(lc["cv_vals"].dtype), (0, 0, S, 0))
            lc["cv_bm"] = jax.lax.dynamic_update_slice(lc["cv_bm"], cv[1], (0, 0, S, 0))
            if qt is not None:
                St = S // m.tile_tokens                # tile-row offset
                lc["ck_scale"] = jax.lax.dynamic_update_slice(
                    lc["ck_scale"], ck[2].astype(SCALE_DTYPE), (0, 0, St, 0))
                lc["cv_scale"] = jax.lax.dynamic_update_slice(
                    lc["cv_scale"], cv[2].astype(SCALE_DTYPE), (0, 0, St, 0))
        lc["k_win"] = jax.lax.dynamic_update_slice(
            lc["k_win"], kT[:, :, comp:].astype(lc["k_win"].dtype), (0, 0, 0, 0))
        lc["v_win"] = jax.lax.dynamic_update_slice(
            lc["v_win"], vT[:, :, comp:].astype(lc["v_win"].dtype), (0, 0, 0, 0))
    else:
        lc["k"] = jax.lax.dynamic_update_slice(
            lc["k"], kT.astype(lc["k"].dtype), (0, 0, 0, 0))
        lc["v"] = jax.lax.dynamic_update_slice(
            lc["v"], vT.astype(lc["v"].dtype), (0, 0, 0, 0))
    if cross_kv is not None:
        lc["cross_k"], lc["cross_v"] = cross_kv
    return lc


# ----------------------------------------------------------------------
# slot splice (continuous batching: one sequence into a shared cache)

def write_slot(cache, solo_cache, slot):
    """Splice a single-sequence cache (batch dim 1, planned with the shared
    batch — see ``plan_batch``) into batch slot ``slot`` of a shared
    multi-slot cache.

    Every block leaf is written via ``dynamic_update_slice`` on the batch
    axis (axis 1 under the period stack) — compressed pools, bitmap planes,
    the right-padded window buffer, and mamba/rwkv/cross state alike — and
    the per-sequence state vectors take the solo values at index ``slot``.
    Because the solo cache leaves cover the slot's full extent, this also
    fully resets whatever a retired request left behind."""
    new_blocks = []
    for shared_lc, solo_lc in zip(cache["blocks"], solo_cache["blocks"]):
        nl = dict(shared_lc)
        for name, leaf in shared_lc.items():
            src = solo_lc[name].astype(leaf.dtype)
            start = (0, slot) + (0,) * (leaf.ndim - 2)
            nl[name] = jax.lax.dynamic_update_slice(leaf, src, start)
        new_blocks.append(nl)
    out = dict(cache)
    out["blocks"] = tuple(new_blocks)
    for key in ("position", "w_len", "n_compressed"):
        out[key] = cache[key].at[slot].set(solo_cache[key][0])
    return out


def write_slot_paged(cfg: ModelConfig, cache, solo_cache, slot,
                     pages, page_tokens: int, shared_pages=()):
    """Splice a single-sequence CONTIGUOUS cache into slot ``slot`` of a
    PAGED shared cache, optionally on top of a SHARED prefix.

    ``shared_pages`` are physical page ids another request (or the prefix
    index) already holds — they back logical pages ``0..len(shared)-1``
    read-only and are only MAPPED into the slot's block-table row, never
    written (the caller must hold a reference per page; a compaction that
    would later write the last of them copies-on-write first). ``pages``
    are the slot's OWNED pages for the next logical pages
    ``len(shared)..len(shared)+len(pages)-1`` (at least the rest of the
    prefill fill; later logical pages may be drawn lazily) — pool contents
    are copied into them page by page from the solo contiguous pool (token
    range ``[lp·pt, (lp+1)·pt)``), every other leaf takes the contiguous
    slot splice, and the slot's block-table row is rewritten
    (shared prefix + owned suffix + UNMAPPED tail), which also severs any
    retired tenant's mappings."""
    pt = page_tokens
    shared_pages = list(shared_pages)
    n_shared = len(shared_pages)
    new_blocks = []
    for shared_lc, solo_lc in zip(cache["blocks"], solo_cache["blocks"]):
        nl = dict(shared_lc)
        paged_attn = _is_pool_layer(shared_lc)
        for name, leaf in shared_lc.items():
            src = solo_lc[name].astype(leaf.dtype)
            if paged_attn and name in _POOL_KEYS:
                # each leaf's own rows-per-page: page_tokens for value/bitmap
                # planes, page_tokens // tile_tokens for scale leaves
                rpp = leaf.shape[3]
                for i, phys in enumerate(pages):
                    logical = n_shared + i
                    chunk = src[:, :, :, logical * rpp:(logical + 1) * rpp]
                    leaf = jax.lax.dynamic_update_slice(
                        leaf, chunk, (0, phys, 0, 0, 0))
                nl[name] = leaf
            else:
                start = (0, slot) + (0,) * (leaf.ndim - 2)
                nl[name] = jax.lax.dynamic_update_slice(leaf, src, start)
        new_blocks.append(nl)
    out = dict(cache)
    out["blocks"] = tuple(new_blocks)
    for key in ("position", "w_len", "n_compressed"):
        out[key] = cache[key].at[slot].set(solo_cache[key][0])
    max_pages = cache["block_table"].shape[1]
    row = jnp.full((max_pages,), PAGE_UNMAPPED, jnp.int32)
    mapped = shared_pages + list(pages)
    if mapped:
        row = row.at[:len(mapped)].set(jnp.asarray(mapped, jnp.int32))
    out["block_table"] = cache["block_table"].at[slot].set(row)
    return out


def pool_value_bytes(cfg: ModelConfig, tokens: int) -> int:
    """Packed-VALUE bytes (plus scale leaves when quantized) for ``tokens``
    compressed tokens per KV head per attention layer, summed over heads and
    layers — exactly the HBM term ``pool_dtype`` shrinks. Bitmap planes are
    dtype-independent and excluded (see ``page_bytes`` for the full page)."""
    m = cfg.mustafar
    d, Hkv = cfg.d_head, cfg.n_kv_heads
    pool_itemsize = jnp.dtype(pool_dtype(cfg)).itemsize
    kk = m.keep_k(d, m.key_sparsity)
    kv = m.keep_k(d, m.value_sparsity)
    n_attn = len(cfg.attention_layers())
    per_head = tokens * (kk + kv) * pool_itemsize
    if pool_quantized(cfg):
        per_head += 2 * (tokens // m.tile_tokens) * \
            jnp.dtype(SCALE_DTYPE).itemsize
    return n_attn * Hkv * per_head


def page_bytes(cfg: ModelConfig, page_tokens: int) -> int:
    """HBM bytes one physical page costs across all attention layers
    (packed K+V values at the configured ``pool_dtype`` width + both bitmap
    planes + the per-tile scale rows when quantized — scales ride IN the
    page, so a swapped or shared page stays self-contained)."""
    d, Hkv = cfg.d_head, cfg.n_kv_heads
    W32 = pad_to_words(d) // 32
    n_attn = len(cfg.attention_layers())
    return pool_value_bytes(cfg, page_tokens) \
        + n_attn * Hkv * page_tokens * 2 * W32 * 4


def cache_hbm_bytes(cfg: ModelConfig, B: int, max_total_tokens: int,
                    page_tokens: Optional[int] = None,
                    n_pages: Optional[int] = None,
                    mesh_model: int = 1) -> Dict[str, int]:
    """Static accounting of cache memory (dense vs Mustafar) — Fig. 6b terms.

    Packed values are sized at the configured ``pool_dtype`` width (bf16
    default, int8 adds the per-tile fp32 scale leaves; pools never widen
    with the compute dtype); the dense window and the dense baseline use
    the compute dtype.

    With ``page_tokens`` set, three paged keys are added: ``paged_pool``
    (``(n_pages + 1)`` physical pages incl. the scratch page, at
    ``page_bytes`` each), ``page_meta`` (the int32 block table), and
    ``paged`` (pool + metadata + the per-slot dense windows). Formula:

        paged = (n_pages + 1) · page_bytes(cfg, page_tokens)
              + 4 · B · max_pages                       (block table)
              + n_attn · B · Hkv · 2 · Wbuf · d · itemsize

    ``mesh_model`` > 1 reports PER-DEVICE bytes under the serving
    shard_map posture (``serving.sharded``): every Hkv-carrying term —
    pools, windows, dense baseline — divides by the model-axis size, while
    ``page_meta`` (the replicated int32 block table) does NOT; a
    ``paged_per_device`` key is added alongside the undivided fleet total:

        paged_per_device = paged_pool / mesh_model
                         + page_meta                    (replicated)
                         + win / mesh_model
    """
    itemsize = jnp.dtype(cfg.dtype).itemsize
    d, Hkv = cfg.d_head, cfg.n_kv_heads
    if mesh_model > 1 and Hkv % mesh_model:
        raise ValueError(f"n_kv_heads={Hkv} not divisible by "
                         f"mesh_model={mesh_model}")
    n_attn = len(cfg.attention_layers())
    dense = n_attn * B * Hkv * max_total_tokens * d * 2 * itemsize
    Tc_max, Wbuf = plan_pools(cfg, max_total_tokens, batch=B)
    W32 = pad_to_words(d) // 32
    win = n_attn * B * Hkv * 2 * Wbuf * d * itemsize
    must = B * pool_value_bytes(cfg, Tc_max) \
        + n_attn * B * Hkv * Tc_max * 2 * W32 * 4 + win
    out = {"dense": dense, "mustafar": must,
           "ratio": must / max(dense, 1)}
    if page_tokens is not None:
        max_pages = plan_pages(cfg, max_total_tokens, page_tokens, batch=B)
        if n_pages is None:
            n_pages = B * max_pages
        pool = (n_pages + 1) * page_bytes(cfg, page_tokens)
        meta = 4 * B * max_pages
        out["paged_pool"] = pool
        out["page_meta"] = meta
        out["paged"] = pool + meta + win
        if mesh_model > 1:
            out["paged_per_device"] = (pool // mesh_model + meta
                                       + win // mesh_model)
    return out
