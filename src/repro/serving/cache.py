"""Mustafar KV-cache manager (paper §3 + Appendix C, TPU static-shape form).

Per attention layer the cache is split into
  * compressed pools — fixed-k bitmap format, preallocated to the max
    context: values [P, B, Hkv, Tc_max, k] + bitmap [P, B, Hkv, Tc_max, W32]
    for K and V (P = stacked periods for lax.scan);
  * a dense local window buffer [P, B, Hkv, Wbuf, d] with
    Wbuf = local_window + tile_tokens. Tokens append densely; every time the
    buffer fills, the oldest ``tile_tokens`` (a tile group, paper Appx. C)
    are pruned+compressed into the pools and the window rolls left.

All updates are pure-functional ``dynamic_update_slice``s under jit —
the XLA/pjit analogue of the paper's CUDA-side cache pointer management.
Mamba layers carry (conv, ssm) state, RWKV layers carry (shift, wkv) state,
Whisper decoder layers additionally hold static cross-attention K/V.

Sequence-progress state (``position``, ``w_len``, ``n_compressed``) is
PER-SEQUENCE: ``[B]`` int32 vectors, one entry per batch slot. Slots advance
independently — each slot appends at its own window offset and retires a
tile group when *its own* window fills (per-slot masked updates; the engine
wraps them in an any-slot work-skip cond) — which is what lets the
continuous-batching scheduler in ``serving.engine`` admit/release ragged
requests without forcing the batch into lockstep.

PAGED POOLS (``init_cache(page_tokens=...)``) decouple slot capacity from
pool allocation: instead of ``[B, Hkv, Tc_max, k]`` per-slot compressed
pools (every slot pays worst-case context), one global page pool
``[n_pages + 1, Hkv, page_tokens, k]`` is shared by all slots through a
per-slot int32 block table — vLLM-style indirection over the fixed-k bitmap
format. ``PageAllocator`` manages the free list (reserve at admission, draw
lazily at compaction, free at retire); ``compact_layer_paged`` scatters tile
retirements through the table; reads gather pages back into the contiguous
view (bit-exact on CPU) or translate inside the fused kernel's
scalar-prefetch grid (TPU).
"""
from __future__ import annotations

import collections
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sparse_format import pad_to_words
from repro.kernels import ops as kops
from repro.models import mamba as mamba_mod
from repro.models import rwkv as rwkv_mod
from repro.models.model import structural_period


CONTEXT_SHARDS = 16  # production mesh "data" size; batch-1 pools shard Tc

# Compressed pools store packed values in bf16 REGARDLESS of the compute
# dtype: the decode kernels load bf16 and feed the MXU at native width (fp32
# only in the accumulators), so a wider pool would double compressed-cache
# HBM bytes for no accuracy the softmax can see. The dense window keeps the
# compute dtype (it is read-modified every step).
POOL_DTYPE = jnp.bfloat16


def plan_pools(cfg: ModelConfig, max_total_tokens: int,
               batch: int = 0) -> Tuple[int, int]:
    """(Tc_max, Wbuf): compressed-pool capacity and window buffer size.

    Tc_max rounds up to the decode-attention chunk (4096) so the online-
    softmax scan divides evenly; below one chunk it rounds to tile_tokens.
    For batch-1 long-context serving the pools are context-parallel (Tc
    sharded over "data"), so Tc additionally aligns to chunk×shards —
    otherwise the chunk reshape crosses shard boundaries and GSPMD
    all-gathers the whole pool (measured: 62 GiB/step at 524k)."""
    from repro.core.attention import DECODE_CHUNK
    m = cfg.mustafar
    Wbuf = m.local_window + m.tile_tokens
    unit = DECODE_CHUNK if max_total_tokens >= DECODE_CHUNK else m.tile_tokens
    if batch == 1 and max_total_tokens >= DECODE_CHUNK * CONTEXT_SHARDS:
        unit = DECODE_CHUNK * CONTEXT_SHARDS
    Tc_max = (max_total_tokens + unit - 1) // unit * unit
    return Tc_max, Wbuf


# ----------------------------------------------------------------------
# paged pools: a global page pool [n_pages, Hkv, page_tokens, ·] shared by
# every batch slot, indexed through a per-slot int32 block table — slot
# capacity (max_total_tokens) no longer dictates pool allocation, so short
# requests stop reserving long-request memory (vLLM-style paging over the
# fixed-k bitmap format).

PAGE_UNMAPPED = -1      # block-table entry for a logical page with no backing


def plan_pages(cfg: ModelConfig, max_total_tokens: int, page_tokens: int,
               batch: int = 0) -> int:
    """max_pages: block-table width so the paged view covers Tc_max.

    ``page_tokens`` must be a positive multiple of ``tile_tokens`` — a tile
    group is the compaction write granule and must never straddle a page
    boundary (one dynamic_update_slice per retirement, one page per tile)."""
    m = cfg.mustafar
    if page_tokens <= 0 or page_tokens % m.tile_tokens:
        raise ValueError(
            f"page_tokens={page_tokens} must be a positive multiple of "
            f"tile_tokens={m.tile_tokens}")
    Tc_max, _ = plan_pools(cfg, max_total_tokens, batch=batch)
    return (Tc_max + page_tokens - 1) // page_tokens


def max_compressed_tokens(cfg: ModelConfig, total_tokens: int) -> int:
    """Upper bound on a request's pool fill over its whole lifetime.

    A tile group retires only when the window holds Wbuf tokens, so at every
    compaction ``n_compressed = position − local_window``; position at a
    compacting step's entry is at most ``total − 1`` (the final token is
    appended after the last compaction can fire)."""
    m = cfg.mustafar
    return max(0, (total_tokens - 1 - m.local_window) // m.tile_tokens) \
        * m.tile_tokens


def pages_for_request(cfg: ModelConfig, total_tokens: int,
                      page_tokens: int) -> int:
    """Worst-case page budget for ``prompt + max_new_tokens`` total tokens."""
    comp = max_compressed_tokens(cfg, total_tokens)
    return (comp + page_tokens - 1) // page_tokens


class PageAllocator:
    """Refcounted free-list allocator over the global compressed-page pool.

    Two-phase discipline so admission can never deadlock mid-decode:
    ``reserve(n)`` promises n pages to a request at admission (fails upfront
    if the budget isn't there), ``draw()`` converts one promised page into a
    physical page id lazily — the scheduler draws right before the decode
    step whose compaction writes it — and ``free``/``unreserve`` return a
    retired request's drawn pages and unused promises. ``peak_in_use``
    tracks the high-water mark of physically drawn pages (the byte number
    BENCH_paging.json / BENCH_prefix.json compare against contiguous
    allocation; a shared page counts ONCE however many slots map it).

    SHARING: every drawn page carries a refcount (1 at ``draw()``).
    ``share(page)`` adds a holder — a second slot mapping a common-prefix
    page read-only, or the scheduler's prefix index caching it past its
    donor's lifetime — and ``release(page)`` drops one holder, returning the
    page to the free list only when the last holder lets go. The write rule
    the whole design stands on: a page with ``refcount > 1`` is IMMUTABLE —
    any writer (tile-group compaction into a shared boundary page) must
    copy-on-write first (``Scheduler._provision_pages``), and the fuzz
    harness asserts no write ever targets a shared page.
    """

    def __init__(self, n_pages: int):
        if n_pages <= 0:
            raise ValueError(f"n_pages={n_pages} must be positive")
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, -1, -1))   # LIFO: low ids first
        self._ref = [0] * n_pages                        # holders per page
        self.n_reserved = 0
        self.peak_in_use = 0

    @property
    def in_use(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def in_use_split(self) -> Tuple[int, int]:
        """(owned, shared) physical pages: ``owned`` have exactly one holder,
        ``shared`` more than one. Each physical page counts once, so
        ``owned + shared == in_use`` — utilization is never double-counted
        however many block-table rows alias a page."""
        owned = sum(1 for r in self._ref if r == 1)
        return owned, self.in_use - owned

    @property
    def available(self) -> int:
        """Pages neither drawn nor promised to an admitted request."""
        return len(self._free) - self.n_reserved

    def can_reserve(self, n: int) -> bool:
        return self.available >= n

    def reserve(self, n: int) -> None:
        if not self.can_reserve(n):
            raise ValueError(
                f"cannot reserve {n} pages: {self.available} available "
                f"({self.in_use} in use, {self.n_reserved} reserved, "
                f"{self.n_pages} total)")
        self.n_reserved += n

    def unreserve(self, n: int) -> None:
        assert 0 <= n <= self.n_reserved, (n, self.n_reserved)
        self.n_reserved -= n

    def draw(self) -> int:
        """Convert one reserved promise into a physical page id (refcount 1)."""
        assert self.n_reserved > 0, "draw() without a reservation"
        self.n_reserved -= 1
        page = self._free.pop()
        self._ref[page] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return page

    def draw_many(self, n: int) -> List[int]:
        """Convert ``n`` reserved promises into physical page ids in ONE
        transaction — the batched-provisioning path: the scheduler predicts
        every compaction target for the upcoming step on the host, draws
        all of them here, and applies the block-table updates as a single
        device splice. Pages come off the free list in exactly the order
        ``n`` repeated ``draw()`` calls would return them."""
        assert 0 <= n <= self.n_reserved, (n, self.n_reserved)
        self.n_reserved -= n
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return pages

    def refcount(self, page: int) -> int:
        assert 0 <= page < self.n_pages, page
        return self._ref[page]

    def share(self, page: int) -> int:
        """Add a holder to a live page (maps it read-only somewhere else)."""
        assert 0 <= page < self.n_pages and self._ref[page] >= 1, \
            f"share() of page {page} with refcount {self._ref[page]}"
        self._ref[page] += 1
        return page

    def release(self, page: int) -> None:
        """Drop one holder; the page frees when the last holder lets go."""
        assert 0 <= page < self.n_pages and self._ref[page] >= 1, \
            f"release() of page {page} with refcount {self._ref[page]}"
        self._ref[page] -= 1
        if self._ref[page] == 0:
            self._free.append(page)

    def free(self, pages) -> None:
        """Drop one holder from each page (uniquely-owned pages free now)."""
        for p in pages:
            self.release(p)


class PrefixIndex:
    """Token-trie (radix) index from PROMPT prefixes to retired compressed
    pages, for cross-request sharing.

    Per-token magnitude pruning (paper §3) is deterministic and position-
    independent within the compressed region: two prompts that agree on
    their first ``(lp+1)·page_tokens`` tokens produce BIT-IDENTICAL
    compressed content for logical page ``lp`` once that page is fully
    retired. The index therefore keys physical pages on the exact token
    prefix they compress:

      * FULL pages — one trie node per retired page, its parent edge keyed
        on that page's own ``page_tokens``-token slice (a node at depth
        ``lp+1`` therefore identifies the whole prefix
        ``prompt[: (lp+1)·page_tokens]``; match walks edges outward from
        the root and stops at the first miss, so a hit is always a
        contiguous chain).
      * BOUNDARY pages — a partially-filled last page (``comp % page_tokens
        != 0``) is shareable too: rows past a sharer's own ``n_compressed``
        are masked by every consumer, so a sharer may alias a donor page
        whose fill is >= its own as long as the covered tokens agree. These
        hang off their full-page base node, keyed on the partial tokens.

    The index holds ONE allocator reference per entry (``register`` shares,
    eviction releases), so cached pages survive their donor's retirement.
    Matching hands refs to the caller per matched page; eviction is LRU and
    drops a chain's descendants with it (an orphaned descendant could never
    match again — match walks from the root).

    STORAGE is a real trie over ``page_tokens``-token chunks (integer node
    ids, each edge keyed by ONE page's token slice), so a cached L-token
    prefix costs O(L) key storage and match/register do O(L) hashing total
    — not the O(L^2) a flat whole-prefix-keyed map would pay.
    """

    _ROOT = 0                              # virtual root node id

    def __init__(self, page_tokens: int):
        self.page_tokens = page_tokens
        # node id -> {"page": phys, "parent": id, "chunk": edge tokens}
        self._nodes: Dict[int, Dict[str, Any]] = {}
        # node id -> {edge chunk -> child node id}
        self._children: Dict[int, Dict[Tuple[int, ...], int]] = {
            self._ROOT: {}}
        self._next_id = self._ROOT + 1
        # full-page nodes in LRU order (oldest first)
        self._lru: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        # base node id -> (partial token tuple, phys page), LRU order
        self._partials: "collections.OrderedDict[int, Tuple[Tuple[int, ...], int]]" = \
            collections.OrderedDict()
        # sharing stats, bumped by the SCHEDULER at admission commit (not
        # in match() — a blocked head-of-queue admission re-matches every
        # engine step and would inflate them arbitrarily)
        self.hits = 0      # pages mapped from the index, admitted matches
        self.misses = 0    # committed admissions that matched nothing

    @property
    def held_pages(self) -> List[int]:
        """Pages the index itself holds a reference on (one per entry)."""
        return [n["page"] for n in self._nodes.values()] \
            + [p for _, p in self._partials.values()]

    def match(self, prompt, comp: int, touch_lru: bool = False):
        """Longest shared prefix for ``prompt`` with compressed fill ``comp``.

        Returns ``(full_pages, boundary_page, shared_tokens)``:
        ``full_pages`` are physical ids for logical pages ``0..n-1``,
        ``boundary_page`` (or None) backs the partially-filled last page,
        and ``shared_tokens`` is the compressed-token count the caller can
        skip re-compressing (``n·page_tokens``, or ``comp`` when the
        boundary matched too). The caller must ``share()`` each returned
        page before relying on it.

        LRU recency moves only under ``touch_lru`` — the scheduler sets it
        at ADMISSION COMMIT, like the hit/miss stats: a blocked
        head-of-queue admission probes every engine step, and letting
        probes refresh recency would pin the never-admitted request's
        chain while chains that live requests re-use get evicted."""
        pt = self.page_tokens
        toks = tuple(int(t) for t in prompt)
        full: List[int] = []
        node = self._ROOT
        for lp in range(comp // pt):
            child = self._children.get(node, {}).get(
                toks[lp * pt:(lp + 1) * pt])
            if child is None:
                break
            if touch_lru:
                self._lru.move_to_end(child)
            full.append(self._nodes[child]["page"])
            node = child
        boundary = None
        shared_tokens = len(full) * pt
        fill = comp % pt
        if fill and len(full) == comp // pt:
            ent = self._partials.get(node)
            if ent is not None:
                donor_toks, page = ent
                if (len(donor_toks) >= fill
                        and donor_toks[:fill] == toks[comp - fill:comp]):
                    if touch_lru:
                        self._partials.move_to_end(node)
                    boundary = page
                    shared_tokens = comp
        return full, boundary, shared_tokens

    def register(self, prompt, comp: int, slot_pages: List[int],
                 allocator: PageAllocator) -> None:
        """Index a freshly-spliced request's prefill pages.

        ``slot_pages[lp]`` is the physical page backing logical page ``lp``
        (shared or owned — already-indexed prefixes are skipped). The index
        takes its own reference on every entry it adds; a boundary entry is
        replaced only by a strict extension of itself (longer fill, same
        leading tokens), releasing the superseded page."""
        pt = self.page_tokens
        toks = tuple(int(t) for t in prompt)
        node = self._ROOT
        for lp in range(comp // pt):
            chunk = toks[lp * pt:(lp + 1) * pt]
            ch = self._children.setdefault(node, {})
            child = ch.get(chunk)
            if child is None:
                child = self._next_id
                self._next_id += 1
                self._nodes[child] = {
                    "page": allocator.share(slot_pages[lp]),
                    "parent": node, "chunk": chunk}
                ch[chunk] = child
                self._lru[child] = None
            node = child
        fill = comp % pt
        if fill:
            part = toks[comp - fill:comp]
            ent = self._partials.get(node)
            if ent is None:
                self._partials[node] = (part, allocator.share(
                    slot_pages[comp // pt]))
            else:
                donor_toks, old_page = ent
                if len(part) > len(donor_toks) \
                        and part[: len(donor_toks)] == donor_toks:
                    self._partials[node] = (part, allocator.share(
                        slot_pages[comp // pt]))
                    allocator.release(old_page)

    def _drop_subtree(self, root: int, allocator: PageAllocator) -> None:
        """Release the trie subtree rooted at ``root`` (its pages, partials
        and the edge from its parent)."""
        parent = self._nodes[root]
        self._children.get(parent["parent"], {}).pop(parent["chunk"], None)
        stack = [root]
        while stack:
            nid = stack.pop()
            stack.extend(self._children.pop(nid, {}).values())
            node = self._nodes.pop(nid)
            del self._lru[nid]
            allocator.release(node["page"])
            ent = self._partials.pop(nid, None)
            if ent is not None:
                allocator.release(ent[1])

    def _evict_one(self, allocator: PageAllocator) -> bool:
        """Drop the least-recently-used entry (and, for a full page, every
        descendant that extends it — an orphaned descendant can never match)."""
        oldest = next(iter(self._lru), None)
        if oldest is None:
            if not self._partials:
                return False
            _, (_, page) = self._partials.popitem(last=False)
            allocator.release(page)
            return True
        self._drop_subtree(oldest, allocator)
        return True

    def evict_until(self, allocator: PageAllocator, n_pages: int) -> None:
        """LRU-evict entries until ``n_pages`` can be reserved (or the index
        is empty). Pages still mapped by live slots stay allocated — only
        the index's reference drops — so this can legitimately fall short;
        the caller then waits for retirements like any other admission."""
        while not allocator.can_reserve(n_pages):
            if not self._evict_one(allocator):
                return

    def clear(self, allocator: PageAllocator) -> None:
        """Release every held reference (drain/shutdown path)."""
        for node in self._nodes.values():
            allocator.release(node["page"])
        for _, page in self._partials.values():
            allocator.release(page)
        self._nodes.clear()
        self._children = {self._ROOT: {}}
        self._lru.clear()
        self._partials.clear()


@partial(jax.jit, donate_argnums=0)
def _copy_page_leaf(leaf: jax.Array, src: jax.Array,
                    dst: jax.Array) -> jax.Array:
    """One pool leaf with physical page ``dst`` overwritten by page ``src``.

    Jitted with the leaf DONATED and src/dst as traced scalars: the update
    runs in place at O(page_bytes) cost (one executable per leaf shape,
    reused for every page id), instead of XLA materialising a full new
    leaf — O(pool bytes) and a transient 2x pool footprint — per
    copy-on-write event."""
    return leaf.at[:, dst].set(leaf[:, src])


def copy_page(cache, src: int, dst: int):
    """Device-side copy of one physical page across every pool leaf of every
    attention layer — the COPY-ON-WRITE step. A slot about to compact into a
    shared (refcount > 1) page first duplicates it into a freshly drawn page
    and remaps its block-table entry; the original stays immutable for the
    other holders. Pool leaves are ``[n_periods, n_phys, Hkv, page_tokens,
    ·]`` under the period stack, so the copy is one in-place
    ``_copy_page_leaf`` per leaf. The input leaves are DONATED — callers
    must drop their reference to ``cache`` in favour of the returned one."""
    src = jnp.int32(src)
    dst = jnp.int32(dst)
    new_blocks = []
    for lc in cache["blocks"]:
        if all(kn in lc for kn in _POOL_KEYS):
            nl = dict(lc)
            for name in _POOL_KEYS:
                nl[name] = _copy_page_leaf(lc[name], src, dst)
            new_blocks.append(nl)
        else:
            new_blocks.append(lc)
    out = dict(cache)
    out["blocks"] = tuple(new_blocks)
    return out


def layer_cache_shapes(cfg: ModelConfig, kind: str, B: int,
                       max_total_tokens: int, enc_ctx: int = 0,
                       plan_batch: Optional[int] = None) -> Dict[str, Any]:
    """Shape/dtype spec for one layer kind (without the stacked period dim).

    ``plan_batch`` overrides the batch used for pool *planning* (Tc_max
    alignment) without changing the allocated batch dim — a solo (B=1)
    prefill destined for one slot of an n-slot shared cache must plan with
    the shared batch so the pool shapes line up for the slot splice."""
    d = cfg.d_head
    Hkv = cfg.n_kv_heads
    W32 = pad_to_words(d) // 32
    m = cfg.mustafar
    cdt = jnp.dtype(cfg.dtype)
    if kind == "attn":
        Tc_max, Wbuf = plan_pools(cfg, max_total_tokens,
                                  batch=B if plan_batch is None else plan_batch)
        if m.enabled:
            kk = m.keep_k(d, m.key_sparsity)
            kv = m.keep_k(d, m.value_sparsity)
            spec = {
                "ck_vals": ((B, Hkv, Tc_max, kk), POOL_DTYPE),
                "ck_bm": ((B, Hkv, Tc_max, W32), jnp.uint32),
                "cv_vals": ((B, Hkv, Tc_max, kv), POOL_DTYPE),
                "cv_bm": ((B, Hkv, Tc_max, W32), jnp.uint32),
                "k_win": ((B, Hkv, Wbuf, d), cdt),
                "v_win": ((B, Hkv, Wbuf, d), cdt),
            }
        else:
            spec = {
                "k": ((B, Hkv, max_total_tokens, d), cdt),
                "v": ((B, Hkv, max_total_tokens, d), cdt),
            }
        if cfg.family == "audio":
            spec["cross_k"] = ((B, enc_ctx, Hkv, d), cdt)
            spec["cross_v"] = ((B, enc_ctx, Hkv, d), cdt)
        return spec
    if kind == "mamba":
        st = mamba_mod.mamba_state_shapes(cfg, B)
        return {"conv": (st["conv"], jnp.float32), "ssm": (st["ssm"], jnp.float32)}
    # rwkv
    st = rwkv_mod.rwkv_state_shapes(cfg, B)
    return {"tm_shift": (st["tm_shift"], cdt), "wkv": (st["wkv"], jnp.float32),
            "cm_shift": (st["cm_shift"], cdt)}


# pool leaves that switch from slot-major [B, Hkv, Tc, ·] to page-major
# [n_pages, Hkv, page_tokens, ·] under paging
_POOL_KEYS = ("ck_vals", "ck_bm", "cv_vals", "cv_bm")


def init_cache(cfg: ModelConfig, B: int, max_total_tokens: int,
               enc_ctx: int = 0, page_tokens: Optional[int] = None,
               n_pages: Optional[int] = None):
    """Zero-filled cache pytree: (blocks=tuple over period positions of
    stacked [n_periods, ...] dicts, plus per-sequence [B] state vectors).

    ``page_tokens`` switches the compressed pools to the PAGED layout: one
    global pool ``[n_phys, Hkv, page_tokens, ·]`` per leaf (shared by all
    slots; ``n_phys = n_pages + 1`` — the last page is a write-discard
    scratch target for masked compactions) plus a per-slot int32
    ``block_table [B, max_pages]`` initialised to ``PAGE_UNMAPPED``. One
    block table serves every layer: compaction retires the same token range
    in all layers, so logical page p of a slot backs the same physical page
    index in each layer's pool. ``n_pages`` defaults to full contiguous
    capacity (``B * max_pages``) — pass less to overcommit and let the
    scheduler's page-budget admission gate ride the difference."""
    period = structural_period(cfg)
    n_periods = cfg.n_layers // period
    paged = page_tokens is not None
    if paged:
        if not cfg.mustafar.enabled or not cfg.attention_layers():
            raise ValueError("paged pools require mustafar.enabled and at "
                             "least one attention layer")
        max_pages = plan_pages(cfg, max_total_tokens, page_tokens, batch=B)
        if n_pages is None:
            n_pages = B * max_pages
    blocks = []
    for j in range(period):
        kind = cfg.layer_kind(j)
        spec = layer_cache_shapes(cfg, kind, B, max_total_tokens, enc_ctx)
        if paged and kind == "attn":
            for name in _POOL_KEYS:
                (_, _, _, c), dt = spec[name]
                spec[name] = ((n_pages + 1, cfg.n_kv_heads, page_tokens, c),
                              dt)
        blocks.append({k: jnp.zeros((n_periods,) + shp, dt)
                       for k, (shp, dt) in spec.items()})
    out = {
        "blocks": tuple(blocks),
        "position": jnp.zeros((B,), jnp.int32),       # total tokens per slot
        "w_len": jnp.zeros((B,), jnp.int32),          # valid window per slot
        "n_compressed": jnp.zeros((B,), jnp.int32),   # pool tokens per slot
    }
    if paged:
        out["block_table"] = jnp.full((B, max_pages), PAGE_UNMAPPED,
                                      jnp.int32)
    return out


# ----------------------------------------------------------------------
# compaction (tile-group retirement: window -> compressed pools)

# leaves mutated by tile-group retirement (cross_k/cross_v etc. pass through)
_COMPACT_KEYS = ("ck_vals", "ck_bm", "cv_vals", "cv_bm", "k_win", "v_win")


def _compact_layer_seq(cfg: ModelConfig, lc: Dict[str, jax.Array],
                       n_compressed: jax.Array) -> Dict[str, jax.Array]:
    """ONE sequence's tile-group retirement: compress the oldest tile_tokens
    of its window into its pools at offset ``n_compressed`` (scalar) and roll
    the window left. Leaves carry no batch dim (k_win [Hkv, Wbuf, d])."""
    m = cfg.mustafar
    d = cfg.d_head
    tt = m.tile_tokens
    kk = m.keep_k(d, m.key_sparsity)
    kv = m.keep_k(d, m.value_sparsity)

    k_tile = lc["k_win"][:, :tt, :]                    # [Hkv,tt,d]
    v_tile = lc["v_win"][:, :tt, :]
    ck_v, ck_b = kops.compress(k_tile, kk)
    cv_v, cv_b = kops.compress(v_tile, kv)

    def upd(pool, tile):
        return jax.lax.dynamic_update_slice(
            pool, tile.astype(pool.dtype), (0, n_compressed, 0))

    out = dict(lc)
    out["ck_vals"] = upd(lc["ck_vals"], ck_v)
    out["ck_bm"] = upd(lc["ck_bm"], ck_b)
    out["cv_vals"] = upd(lc["cv_vals"], cv_v)
    out["cv_bm"] = upd(lc["cv_bm"], cv_b)
    # roll the window left by tile_tokens (retired tokens drop out)
    out["k_win"] = jnp.roll(lc["k_win"], -tt, axis=1)
    out["v_win"] = jnp.roll(lc["v_win"], -tt, axis=1)
    return out


def compact_layer(cfg: ModelConfig, lc: Dict[str, jax.Array],
                  n_compressed: jax.Array,
                  need: Optional[jax.Array] = None) -> Dict[str, jax.Array]:
    """Per-slot tile-group retirement on a batched layer cache.

    lc leaves are [B, Hkv, ...]; ``n_compressed`` is the per-sequence [B]
    pool fill. Each slot compacts at its own pool offset; slots where
    ``need`` is False keep their original contents via a masked select —
    no ``lax.cond``, so slots trigger independently of any global counter.
    (The compress runs for every slot every call; the select discards the
    unneeded ones. That is the static-shape price of raggedness.)"""
    sub = {k: lc[k] for k in _COMPACT_KEYS}
    comp = jax.vmap(lambda one, nc: _compact_layer_seq(cfg, one, nc))(
        sub, n_compressed)
    out = dict(lc)
    for k in _COMPACT_KEYS:
        if need is None:
            out[k] = comp[k]
        else:
            mask = need.reshape((-1,) + (1,) * (comp[k].ndim - 1))
            out[k] = jnp.where(mask, comp[k], lc[k])
    return out


def compact_layer_paged(cfg: ModelConfig, lc: Dict[str, jax.Array],
                        n_compressed: jax.Array, block_table: jax.Array,
                        need: jax.Array) -> Dict[str, jax.Array]:
    """Per-slot tile-group retirement into PAGED pools.

    Pool leaves are page-major ``[n_phys, Hkv, page_tokens, ·]`` (no batch
    dim); windows stay slot-major ``[B, Hkv, Wbuf, d]``. Each needy slot's
    oldest tile compresses into physical page
    ``block_table[b, n_compressed[b] // page_tokens]`` at the in-page token
    offset; slots where ``need`` is False — and, defensively, needy slots
    whose target page is unmapped — write to the scratch page (last physical
    index) instead, which keeps the write unconditional (static shapes)
    while discarding it. Writes are a ``lax.scan`` of dynamic_update_slices
    over slots: the allocator guarantees live pages are never shared, so
    slot order cannot alias."""
    m = cfg.mustafar
    d = cfg.d_head
    tt = m.tile_tokens
    kk = m.keep_k(d, m.key_sparsity)
    kv = m.keep_k(d, m.value_sparsity)
    n_phys, _, pt, _ = lc["ck_vals"].shape

    k_tile = lc["k_win"][:, :, :tt, :]                 # [B,Hkv,tt,d]
    v_tile = lc["v_win"][:, :, :tt, :]
    ck_v, ck_b = kops.compress(k_tile, kk)             # [B,Hkv,tt,·]
    cv_v, cv_b = kops.compress(v_tile, kv)

    lp = n_compressed // pt                            # [B] logical page
    off = n_compressed % pt                            # [B] in-page offset
    phys = jnp.take_along_axis(block_table, lp[:, None], axis=1)[:, 0]
    ok = need & (phys >= 0)
    phys = jnp.where(ok, jnp.clip(phys, 0, n_phys - 1), n_phys - 1)
    off = jnp.where(ok, off, 0)

    def scatter(pool, tiles):
        def body(p, xs):
            tile, pg, o = xs                           # tile [Hkv, tt, ·]
            return jax.lax.dynamic_update_slice(
                p, tile[None].astype(p.dtype), (pg, 0, o, 0)), None
        p, _ = jax.lax.scan(body, pool, (tiles, phys, off))
        return p

    out = dict(lc)
    out["ck_vals"] = scatter(lc["ck_vals"], ck_v)
    out["ck_bm"] = scatter(lc["ck_bm"], ck_b)
    out["cv_vals"] = scatter(lc["cv_vals"], cv_v)
    out["cv_bm"] = scatter(lc["cv_bm"], cv_b)
    wmask = need.reshape((-1, 1, 1, 1))
    out["k_win"] = jnp.where(wmask, jnp.roll(lc["k_win"], -tt, axis=2),
                             lc["k_win"])
    out["v_win"] = jnp.where(wmask, jnp.roll(lc["v_win"], -tt, axis=2),
                             lc["v_win"])
    return out


def compact_layer_paged_fused(cfg: ModelConfig, lc: Dict[str, jax.Array],
                              n_compressed: jax.Array, block_table: jax.Array,
                              need: jax.Array) -> Dict[str, jax.Array]:
    """Fused-epilogue tile-group retirement into PAGED pools: the whole
    PERIOD-STACKED layer cache in one compress-and-scatter dispatch.

    Unlike ``compact_layer_paged`` (per-period under vmap: one compress
    plus a scan of per-slot dynamic_update_slices), this resolves every
    slot's destination page once and hands ``kops.compress_scatter`` the
    period stack FOLDED into the kernel batch — leaf ``[n_periods, n_phys,
    Hkv, pt, ·]`` reshapes to one pool ``[n_periods·n_phys, ...]`` and row
    (p, b) targets ``phys[b] + p·n_phys`` — so a layer group's entire
    retirement is a single dispatch writing straight into the destination
    pages (each period's scratch page stays its own). Bit-identical to the
    two-dispatch oracle on every non-scratch page
    (tests/test_fused_compaction.py)."""
    m = cfg.mustafar
    tt = m.tile_tokens
    P, n_phys, _, pt, _ = lc["ck_vals"].shape

    lp = n_compressed // pt                            # [B] logical page
    off = n_compressed % pt                            # [B] in-page offset
    phys = jnp.take_along_axis(block_table, lp[:, None], axis=1)[:, 0]
    ok = need & (phys >= 0)
    phys = jnp.where(ok, jnp.clip(phys, 0, n_phys - 1), n_phys - 1)
    off = jnp.where(ok, off, 0)
    # fold periods into the batch: row (p, b) -> page phys[b] + p * n_phys
    phys_pb = (phys[None, :] + n_phys * jnp.arange(P)[:, None]).reshape(-1)
    off_pb = jnp.tile(off, P)

    k_tile = lc["k_win"][:, :, :, :tt, :]              # [P,B,Hkv,tt,d]
    v_tile = lc["v_win"][:, :, :, :tt, :]
    fold = lambda a: a.reshape((-1,) + a.shape[2:])
    pools = [fold(lc[name]) for name in _POOL_KEYS]
    new_pools = kops.compress_scatter(
        fold(k_tile), fold(v_tile), *pools, phys_pb, off_pb)

    out = dict(lc)
    for name, pool in zip(_POOL_KEYS, new_pools):
        out[name] = pool.reshape(lc[name].shape)
    wmask = need.reshape((1, -1, 1, 1, 1))
    out["k_win"] = jnp.where(wmask, jnp.roll(lc["k_win"], -tt, axis=3),
                             lc["k_win"])
    out["v_win"] = jnp.where(wmask, jnp.roll(lc["v_win"], -tt, axis=3),
                             lc["v_win"])
    return out


def append_window(lc: Dict[str, jax.Array], k_new: jax.Array, v_new: jax.Array,
                  w_len: jax.Array) -> Dict[str, jax.Array]:
    """Append one token's K/V [B, Hkv, 1, d] at each sequence's own window
    offset ``w_len`` [B] (ragged slots write at different positions)."""

    def upd(buf, tok, wl):                             # per-sequence DUS
        return jax.lax.dynamic_update_slice(
            buf, tok.astype(buf.dtype), (0, wl, 0))

    out = dict(lc)
    out["k_win"] = jax.vmap(upd)(lc["k_win"], k_new, w_len)
    out["v_win"] = jax.vmap(upd)(lc["v_win"], v_new, w_len)
    return out


def prefill_split(cfg: ModelConfig, T: int) -> Tuple[int, int]:
    """(compressible_tokens, window_tokens) for a prefill of length T."""
    m = cfg.mustafar
    comp = max(0, (T - m.local_window) // m.tile_tokens) * m.tile_tokens
    return comp, T - comp


def build_layer_cache_from_prefill(cfg: ModelConfig, k: jax.Array, v: jax.Array,
                                   max_total_tokens: int,
                                   cross_kv=None,
                                   plan_batch: Optional[int] = None,
                                   shared_tokens: int = 0
                                   ) -> Dict[str, jax.Array]:
    """k/v [B, T, Hkv, d] from a dense prefill -> one layer's Mustafar cache
    (no period dim; the engine scans this per layer). ``plan_batch`` forces
    the pool planning batch (see layer_cache_shapes) for slot prefills.

    ``shared_tokens`` (static, multiple of tile_tokens, <= the prefill's
    compressed fill) skips compressing the first S tokens: those live in
    prefix pages shared from another request's bit-identical compression, so
    only the UNMATCHED suffix is pruned+compressed (pool region [0, S) stays
    zero and is never copied — the paged splice maps the shared pages there
    instead). ``n_compressed`` still covers the full fill."""
    B, T, Hkv, d = k.shape
    m = cfg.mustafar
    kT = jnp.swapaxes(k, 1, 2)                         # [B,Hkv,T,d]
    vT = jnp.swapaxes(v, 1, 2)
    spec = layer_cache_shapes(cfg, "attn", B, max_total_tokens,
                              enc_ctx=cross_kv[0].shape[1] if cross_kv else 0,
                              plan_batch=plan_batch)
    lc = {name: jnp.zeros(shp, dt) for name, (shp, dt) in spec.items()}
    if m.enabled:
        comp, win = prefill_split(cfg, T)
        S = shared_tokens
        assert 0 <= S <= comp and S % m.tile_tokens == 0, (S, comp)
        kk = m.keep_k(d, m.key_sparsity)
        kv_ = m.keep_k(d, m.value_sparsity)
        if comp > S:
            ck_v, ck_b = kops.compress(kT[:, :, S:comp], kk)
            cv_v, cv_b = kops.compress(vT[:, :, S:comp], kv_)
            lc["ck_vals"] = jax.lax.dynamic_update_slice(
                lc["ck_vals"], ck_v.astype(lc["ck_vals"].dtype), (0, 0, S, 0))
            lc["ck_bm"] = jax.lax.dynamic_update_slice(lc["ck_bm"], ck_b, (0, 0, S, 0))
            lc["cv_vals"] = jax.lax.dynamic_update_slice(
                lc["cv_vals"], cv_v.astype(lc["cv_vals"].dtype), (0, 0, S, 0))
            lc["cv_bm"] = jax.lax.dynamic_update_slice(lc["cv_bm"], cv_b, (0, 0, S, 0))
        lc["k_win"] = jax.lax.dynamic_update_slice(
            lc["k_win"], kT[:, :, comp:].astype(lc["k_win"].dtype), (0, 0, 0, 0))
        lc["v_win"] = jax.lax.dynamic_update_slice(
            lc["v_win"], vT[:, :, comp:].astype(lc["v_win"].dtype), (0, 0, 0, 0))
    else:
        lc["k"] = jax.lax.dynamic_update_slice(
            lc["k"], kT.astype(lc["k"].dtype), (0, 0, 0, 0))
        lc["v"] = jax.lax.dynamic_update_slice(
            lc["v"], vT.astype(lc["v"].dtype), (0, 0, 0, 0))
    if cross_kv is not None:
        lc["cross_k"], lc["cross_v"] = cross_kv
    return lc


# ----------------------------------------------------------------------
# slot splice (continuous batching: one sequence into a shared cache)

def write_slot(cache, solo_cache, slot):
    """Splice a single-sequence cache (batch dim 1, planned with the shared
    batch — see ``plan_batch``) into batch slot ``slot`` of a shared
    multi-slot cache.

    Every block leaf is written via ``dynamic_update_slice`` on the batch
    axis (axis 1 under the period stack) — compressed pools, bitmap planes,
    the right-padded window buffer, and mamba/rwkv/cross state alike — and
    the per-sequence state vectors take the solo values at index ``slot``.
    Because the solo cache leaves cover the slot's full extent, this also
    fully resets whatever a retired request left behind."""
    new_blocks = []
    for shared_lc, solo_lc in zip(cache["blocks"], solo_cache["blocks"]):
        nl = dict(shared_lc)
        for name, leaf in shared_lc.items():
            src = solo_lc[name].astype(leaf.dtype)
            start = (0, slot) + (0,) * (leaf.ndim - 2)
            nl[name] = jax.lax.dynamic_update_slice(leaf, src, start)
        new_blocks.append(nl)
    out = dict(cache)
    out["blocks"] = tuple(new_blocks)
    for key in ("position", "w_len", "n_compressed"):
        out[key] = cache[key].at[slot].set(solo_cache[key][0])
    return out


def write_slot_paged(cfg: ModelConfig, cache, solo_cache, slot,
                     pages, page_tokens: int, shared_pages=()):
    """Splice a single-sequence CONTIGUOUS cache into slot ``slot`` of a
    PAGED shared cache, optionally on top of a SHARED prefix.

    ``shared_pages`` are physical page ids another request (or the prefix
    index) already holds — they back logical pages ``0..len(shared)-1``
    read-only and are only MAPPED into the slot's block-table row, never
    written (the caller must hold a reference per page; a compaction that
    would later write the last of them copies-on-write first). ``pages``
    are the slot's OWNED pages for the next logical pages
    ``len(shared)..len(shared)+len(pages)-1`` (at least the rest of the
    prefill fill; later logical pages may be drawn lazily) — pool contents
    are copied into them page by page from the solo contiguous pool (token
    range ``[lp·pt, (lp+1)·pt)``), every other leaf takes the contiguous
    slot splice, and the slot's block-table row is rewritten
    (shared prefix + owned suffix + UNMAPPED tail), which also severs any
    retired tenant's mappings."""
    pt = page_tokens
    shared_pages = list(shared_pages)
    n_shared = len(shared_pages)
    new_blocks = []
    for shared_lc, solo_lc in zip(cache["blocks"], solo_cache["blocks"]):
        nl = dict(shared_lc)
        paged_attn = all(kn in shared_lc for kn in _POOL_KEYS)
        for name, leaf in shared_lc.items():
            src = solo_lc[name].astype(leaf.dtype)
            if paged_attn and name in _POOL_KEYS:
                for i, phys in enumerate(pages):
                    logical = n_shared + i
                    chunk = src[:, :, :, logical * pt:(logical + 1) * pt]
                    leaf = jax.lax.dynamic_update_slice(
                        leaf, chunk, (0, phys, 0, 0, 0))
                nl[name] = leaf
            else:
                start = (0, slot) + (0,) * (leaf.ndim - 2)
                nl[name] = jax.lax.dynamic_update_slice(leaf, src, start)
        new_blocks.append(nl)
    out = dict(cache)
    out["blocks"] = tuple(new_blocks)
    for key in ("position", "w_len", "n_compressed"):
        out[key] = cache[key].at[slot].set(solo_cache[key][0])
    max_pages = cache["block_table"].shape[1]
    row = jnp.full((max_pages,), PAGE_UNMAPPED, jnp.int32)
    mapped = shared_pages + list(pages)
    if mapped:
        row = row.at[:len(mapped)].set(jnp.asarray(mapped, jnp.int32))
    out["block_table"] = cache["block_table"].at[slot].set(row)
    return out


def page_bytes(cfg: ModelConfig, page_tokens: int) -> int:
    """HBM bytes one physical page costs across all attention layers
    (packed K+V values at POOL_DTYPE width + both bitmap planes)."""
    m = cfg.mustafar
    d, Hkv = cfg.d_head, cfg.n_kv_heads
    pool_itemsize = jnp.dtype(POOL_DTYPE).itemsize
    W32 = pad_to_words(d) // 32
    kk = m.keep_k(d, m.key_sparsity)
    kv = m.keep_k(d, m.value_sparsity)
    n_attn = len(cfg.attention_layers())
    return n_attn * Hkv * page_tokens * (
        (kk + kv) * pool_itemsize + 2 * W32 * 4)


def cache_hbm_bytes(cfg: ModelConfig, B: int, max_total_tokens: int,
                    page_tokens: Optional[int] = None,
                    n_pages: Optional[int] = None,
                    mesh_model: int = 1) -> Dict[str, int]:
    """Static accounting of cache memory (dense vs Mustafar) — Fig. 6b terms.

    Packed values are sized at the bf16 ``POOL_DTYPE`` width (pools never
    widen with the compute dtype); the dense window and the dense baseline
    use the compute dtype.

    With ``page_tokens`` set, three paged keys are added: ``paged_pool``
    (``(n_pages + 1)`` physical pages incl. the scratch page, at
    ``page_bytes`` each), ``page_meta`` (the int32 block table), and
    ``paged`` (pool + metadata + the per-slot dense windows). Formula:

        paged = (n_pages + 1) · page_bytes(cfg, page_tokens)
              + 4 · B · max_pages                       (block table)
              + n_attn · B · Hkv · 2 · Wbuf · d · itemsize

    ``mesh_model`` > 1 reports PER-DEVICE bytes under the serving
    shard_map posture (``serving.sharded``): every Hkv-carrying term —
    pools, windows, dense baseline — divides by the model-axis size, while
    ``page_meta`` (the replicated int32 block table) does NOT; a
    ``paged_per_device`` key is added alongside the undivided fleet total:

        paged_per_device = paged_pool / mesh_model
                         + page_meta                    (replicated)
                         + win / mesh_model
    """
    itemsize = jnp.dtype(cfg.dtype).itemsize
    pool_itemsize = jnp.dtype(POOL_DTYPE).itemsize
    d, Hkv = cfg.d_head, cfg.n_kv_heads
    if mesh_model > 1 and Hkv % mesh_model:
        raise ValueError(f"n_kv_heads={Hkv} not divisible by "
                         f"mesh_model={mesh_model}")
    n_attn = len(cfg.attention_layers())
    dense = n_attn * B * Hkv * max_total_tokens * d * 2 * itemsize
    m = cfg.mustafar
    Tc_max, Wbuf = plan_pools(cfg, max_total_tokens, batch=B)
    W32 = pad_to_words(d) // 32
    kk = m.keep_k(d, m.key_sparsity)
    kv = m.keep_k(d, m.value_sparsity)
    win = n_attn * B * Hkv * 2 * Wbuf * d * itemsize
    must = n_attn * B * Hkv * Tc_max * (
        (kk + kv) * pool_itemsize + 2 * W32 * 4) + win
    out = {"dense": dense, "mustafar": must,
           "ratio": must / max(dense, 1)}
    if page_tokens is not None:
        max_pages = plan_pages(cfg, max_total_tokens, page_tokens, batch=B)
        if n_pages is None:
            n_pages = B * max_pages
        pool = (n_pages + 1) * page_bytes(cfg, page_tokens)
        meta = 4 * B * max_pages
        out["paged_pool"] = pool
        out["page_meta"] = meta
        out["paged"] = pool + meta + win
        if mesh_model > 1:
            out["paged_per_device"] = (pool // mesh_model + meta
                                       + win // mesh_model)
    return out
