"""Mustafar KV-cache manager (paper §3 + Appendix C, TPU static-shape form).

Per attention layer the cache is split into
  * compressed pools — fixed-k bitmap format, preallocated to the max
    context: values [P, B, Hkv, Tc_max, k] + bitmap [P, B, Hkv, Tc_max, W32]
    for K and V (P = stacked periods for lax.scan);
  * a dense local window buffer [P, B, Hkv, Wbuf, d] with
    Wbuf = local_window + tile_tokens. Tokens append densely; every time the
    buffer fills, the oldest ``tile_tokens`` (a tile group, paper Appx. C)
    are pruned+compressed into the pools and the window rolls left.

All updates are pure-functional ``dynamic_update_slice``s under jit —
the XLA/pjit analogue of the paper's CUDA-side cache pointer management.
Mamba layers carry (conv, ssm) state, RWKV layers carry (shift, wkv) state,
Whisper decoder layers additionally hold static cross-attention K/V.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sparse_format import pad_to_words
from repro.kernels import ops as kops
from repro.models import mamba as mamba_mod
from repro.models import rwkv as rwkv_mod
from repro.models.model import structural_period


CONTEXT_SHARDS = 16  # production mesh "data" size; batch-1 pools shard Tc


def plan_pools(cfg: ModelConfig, max_total_tokens: int,
               batch: int = 0) -> Tuple[int, int]:
    """(Tc_max, Wbuf): compressed-pool capacity and window buffer size.

    Tc_max rounds up to the decode-attention chunk (4096) so the online-
    softmax scan divides evenly; below one chunk it rounds to tile_tokens.
    For batch-1 long-context serving the pools are context-parallel (Tc
    sharded over "data"), so Tc additionally aligns to chunk×shards —
    otherwise the chunk reshape crosses shard boundaries and GSPMD
    all-gathers the whole pool (measured: 62 GiB/step at 524k)."""
    from repro.core.attention import DECODE_CHUNK
    m = cfg.mustafar
    Wbuf = m.local_window + m.tile_tokens
    unit = DECODE_CHUNK if max_total_tokens >= DECODE_CHUNK else m.tile_tokens
    if batch == 1 and max_total_tokens >= DECODE_CHUNK * CONTEXT_SHARDS:
        unit = DECODE_CHUNK * CONTEXT_SHARDS
    Tc_max = (max_total_tokens + unit - 1) // unit * unit
    return Tc_max, Wbuf


def layer_cache_shapes(cfg: ModelConfig, kind: str, B: int,
                       max_total_tokens: int, enc_ctx: int = 0) -> Dict[str, Any]:
    """Shape/dtype spec for one layer kind (without the stacked period dim)."""
    d = cfg.d_head
    Hkv = cfg.n_kv_heads
    W32 = pad_to_words(d) // 32
    m = cfg.mustafar
    cdt = jnp.dtype(cfg.dtype)
    if kind == "attn":
        Tc_max, Wbuf = plan_pools(cfg, max_total_tokens, batch=B)
        if m.enabled:
            kk = m.keep_k(d, m.key_sparsity)
            kv = m.keep_k(d, m.value_sparsity)
            spec = {
                "ck_vals": ((B, Hkv, Tc_max, kk), cdt),
                "ck_bm": ((B, Hkv, Tc_max, W32), jnp.uint32),
                "cv_vals": ((B, Hkv, Tc_max, kv), cdt),
                "cv_bm": ((B, Hkv, Tc_max, W32), jnp.uint32),
                "k_win": ((B, Hkv, Wbuf, d), cdt),
                "v_win": ((B, Hkv, Wbuf, d), cdt),
            }
        else:
            spec = {
                "k": ((B, Hkv, max_total_tokens, d), cdt),
                "v": ((B, Hkv, max_total_tokens, d), cdt),
            }
        if cfg.family == "audio":
            spec["cross_k"] = ((B, enc_ctx, Hkv, d), cdt)
            spec["cross_v"] = ((B, enc_ctx, Hkv, d), cdt)
        return spec
    if kind == "mamba":
        st = mamba_mod.mamba_state_shapes(cfg, B)
        return {"conv": (st["conv"], jnp.float32), "ssm": (st["ssm"], jnp.float32)}
    # rwkv
    st = rwkv_mod.rwkv_state_shapes(cfg, B)
    return {"tm_shift": (st["tm_shift"], cdt), "wkv": (st["wkv"], jnp.float32),
            "cm_shift": (st["cm_shift"], cdt)}


def init_cache(cfg: ModelConfig, B: int, max_total_tokens: int,
               enc_ctx: int = 0):
    """Zero-filled cache pytree: (blocks=tuple over period positions of
    stacked [n_periods, ...] dicts, position=0, w_len=0, n_compressed=0)."""
    period = structural_period(cfg)
    n_periods = cfg.n_layers // period
    blocks = []
    for j in range(period):
        spec = layer_cache_shapes(cfg, cfg.layer_kind(j), B,
                                  max_total_tokens, enc_ctx)
        blocks.append({k: jnp.zeros((n_periods,) + shp, dt)
                       for k, (shp, dt) in spec.items()})
    return {
        "blocks": tuple(blocks),
        "position": jnp.zeros((), jnp.int32),       # total tokens so far
        "w_len": jnp.zeros((), jnp.int32),          # valid window tokens
        "n_compressed": jnp.zeros((), jnp.int32),   # tokens in pools
    }


# ----------------------------------------------------------------------
# compaction (tile-group retirement: window -> compressed pools)

def compact_layer(cfg: ModelConfig, lc: Dict[str, jax.Array],
                  n_compressed: jax.Array) -> Dict[str, jax.Array]:
    """Compress the oldest tile_tokens of the window into the pools and
    roll the window left. Call only on attention-layer caches (no period
    dim — operates inside the scan body on a single layer slice)."""
    m = cfg.mustafar
    d = cfg.d_head
    tt = m.tile_tokens
    kk = m.keep_k(d, m.key_sparsity)
    kv = m.keep_k(d, m.value_sparsity)

    k_tile = lc["k_win"][:, :, :tt, :]                 # [B,Hkv,tt,d]
    v_tile = lc["v_win"][:, :, :tt, :]
    ck_v, ck_b = kops.compress(k_tile, kk)
    cv_v, cv_b = kops.compress(v_tile, kv)

    def upd(pool, tile):
        return jax.lax.dynamic_update_slice(
            pool, tile.astype(pool.dtype), (0, 0, n_compressed, 0))

    out = dict(lc)
    out["ck_vals"] = upd(lc["ck_vals"], ck_v)
    out["ck_bm"] = upd(lc["ck_bm"], ck_b)
    out["cv_vals"] = upd(lc["cv_vals"], cv_v)
    out["cv_bm"] = upd(lc["cv_bm"], cv_b)
    # roll the window left by tile_tokens (retired tokens drop out)
    out["k_win"] = jnp.roll(lc["k_win"], -tt, axis=2)
    out["v_win"] = jnp.roll(lc["v_win"], -tt, axis=2)
    return out


def append_window(lc: Dict[str, jax.Array], k_new: jax.Array, v_new: jax.Array,
                  w_len: jax.Array) -> Dict[str, jax.Array]:
    """Append one token's K/V [B, Hkv, 1, d] at window position w_len."""
    out = dict(lc)
    out["k_win"] = jax.lax.dynamic_update_slice(
        lc["k_win"], k_new.astype(lc["k_win"].dtype), (0, 0, w_len, 0))
    out["v_win"] = jax.lax.dynamic_update_slice(
        lc["v_win"], v_new.astype(lc["v_win"].dtype), (0, 0, w_len, 0))
    return out


def prefill_split(cfg: ModelConfig, T: int) -> Tuple[int, int]:
    """(compressible_tokens, window_tokens) for a prefill of length T."""
    m = cfg.mustafar
    comp = max(0, (T - m.local_window) // m.tile_tokens) * m.tile_tokens
    return comp, T - comp


def build_layer_cache_from_prefill(cfg: ModelConfig, k: jax.Array, v: jax.Array,
                                   max_total_tokens: int,
                                   cross_kv=None) -> Dict[str, jax.Array]:
    """k/v [B, T, Hkv, d] from a dense prefill -> one layer's Mustafar cache
    (no period dim; the engine scans this per layer)."""
    B, T, Hkv, d = k.shape
    m = cfg.mustafar
    kT = jnp.swapaxes(k, 1, 2)                         # [B,Hkv,T,d]
    vT = jnp.swapaxes(v, 1, 2)
    spec = layer_cache_shapes(cfg, "attn", B, max_total_tokens,
                              enc_ctx=cross_kv[0].shape[1] if cross_kv else 0)
    lc = {name: jnp.zeros(shp, dt) for name, (shp, dt) in spec.items()}
    if m.enabled:
        comp, win = prefill_split(cfg, T)
        kk = m.keep_k(d, m.key_sparsity)
        kv_ = m.keep_k(d, m.value_sparsity)
        if comp > 0:
            ck_v, ck_b = kops.compress(kT[:, :, :comp], kk)
            cv_v, cv_b = kops.compress(vT[:, :, :comp], kv_)
            lc["ck_vals"] = jax.lax.dynamic_update_slice(
                lc["ck_vals"], ck_v.astype(lc["ck_vals"].dtype), (0, 0, 0, 0))
            lc["ck_bm"] = jax.lax.dynamic_update_slice(lc["ck_bm"], ck_b, (0, 0, 0, 0))
            lc["cv_vals"] = jax.lax.dynamic_update_slice(
                lc["cv_vals"], cv_v.astype(lc["cv_vals"].dtype), (0, 0, 0, 0))
            lc["cv_bm"] = jax.lax.dynamic_update_slice(lc["cv_bm"], cv_b, (0, 0, 0, 0))
        lc["k_win"] = jax.lax.dynamic_update_slice(
            lc["k_win"], kT[:, :, comp:].astype(lc["k_win"].dtype), (0, 0, 0, 0))
        lc["v_win"] = jax.lax.dynamic_update_slice(
            lc["v_win"], vT[:, :, comp:].astype(lc["v_win"].dtype), (0, 0, 0, 0))
    else:
        lc["k"] = jax.lax.dynamic_update_slice(
            lc["k"], kT.astype(lc["k"].dtype), (0, 0, 0, 0))
        lc["v"] = jax.lax.dynamic_update_slice(
            lc["v"], vT.astype(lc["v"].dtype), (0, 0, 0, 0))
    if cross_kv is not None:
        lc["cross_k"], lc["cross_v"] = cross_kv
    return lc


def cache_hbm_bytes(cfg: ModelConfig, B: int, max_total_tokens: int) -> Dict[str, int]:
    """Static accounting of cache memory (dense vs Mustafar) — Fig. 6b terms."""
    itemsize = jnp.dtype(cfg.dtype).itemsize
    d, Hkv = cfg.d_head, cfg.n_kv_heads
    n_attn = len(cfg.attention_layers())
    dense = n_attn * B * Hkv * max_total_tokens * d * 2 * itemsize
    m = cfg.mustafar
    Tc_max, Wbuf = plan_pools(cfg, max_total_tokens, batch=B)
    W32 = pad_to_words(d) // 32
    kk = m.keep_k(d, m.key_sparsity)
    kv = m.keep_k(d, m.value_sparsity)
    must = n_attn * B * Hkv * (
        Tc_max * ((kk + kv) * itemsize + 2 * W32 * 4) + 2 * Wbuf * d * itemsize)
    return {"dense": dense, "mustafar": must,
            "ratio": must / max(dense, 1)}
