"""Config-driven model assembly for every assigned architecture.

Layers are stacked per *period position* and iterated with ``lax.scan`` so
72-layer models compile one period, not 72 bodies. The structural period is
``lcm(attn_every, moe_every)`` (Jamba: 8; everything else: 1).

Param tree:
    params = {
      "embed":   token table (+ lm head / learned positions)
      "blocks":  tuple over period positions j of a pytree whose leaves have
                 leading dim n_periods (scanned)
      "final_norm", and for enc-dec: "encoder" (same structure), "enc_norm"
      "vis_proj" for the VLM stub frontend
    }

``forward_train`` runs the full differentiable pass (causal attention, WKV /
SSM scans, MoE) and returns logits + aux losses. Serving prefill/decode live
in ``repro.serving`` on the same param tree.
"""
from __future__ import annotations

import math
import os
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models.layers import (cdtype, dense_init, embed_tokens, init_embed,
                                 init_mlp, init_norm, lm_logits, mlp_apply,
                                 norm_apply, pdtype)
from repro.sharding.constraints import DP, shard_activation


def layer_scan_unroll() -> int:
    """lax.scan unroll factor for the layer-period scan. The dry-run sets
    REPRO_UNROLL_LAYERS high so cost_analysis counts every layer (XLA counts
    a while-loop body once, not trip_count times)."""
    return max(1, int(os.environ.get("REPRO_UNROLL_LAYERS", "1")))


def structural_period(cfg: ModelConfig) -> int:
    p = 1
    if cfg.family == "hybrid":
        p = math.lcm(cfg.attn_every, cfg.moe_every if cfg.n_experts else 1)
    return p


# ----------------------------------------------------------------------
# init

def init_block(key, cfg: ModelConfig, i: int, decoder: bool = True):
    """Params for absolute layer index i (kind pattern is periodic)."""
    kind = cfg.layer_kind(i) if decoder else "attn"
    ffn = cfg.ffn_kind(i) if decoder else "dense"
    keys = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": init_norm(cfg), "norm2": init_norm(cfg)}
    if kind == "attn":
        p["mixer"] = attn.init_attention(keys[0], cfg)
    elif kind == "mamba":
        p["mixer"] = mamba_mod.init_mamba(keys[0], cfg)
    else:  # rwkv
        p["mixer"] = rwkv_mod.init_rwkv_time_mix(keys[0], cfg)
    if cfg.family == "audio" and decoder:
        p["cross"] = attn.init_attention(keys[2], cfg)
        p["norm_cross"] = init_norm(cfg)
    if ffn == "moe":
        p["ffn"] = moe_mod.init_moe(keys[1], cfg)
    elif kind == "rwkv":
        p["ffn"] = rwkv_mod.init_rwkv_channel_mix(keys[1], cfg)
    else:
        p["ffn"] = init_mlp(keys[1], cfg)
    return p


def _stack_blocks(key, cfg: ModelConfig, n_layers: int, decoder: bool = True):
    period = structural_period(cfg) if decoder else 1
    n_periods = n_layers // period
    assert n_layers % period == 0, (n_layers, period)
    blocks = []
    for j in range(period):
        per = [init_block(jax.random.fold_in(key, n * period + j), cfg,
                          n * period + j, decoder) for n in range(n_periods)]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per))
    return tuple(blocks)


def init_params(key, cfg: ModelConfig):
    keys = jax.random.split(key, 5)
    params: Dict[str, Any] = {
        "embed": init_embed(keys[0], cfg),
        "blocks": _stack_blocks(keys[1], cfg, cfg.n_layers),
        "final_norm": init_norm(cfg),
    }
    if cfg.family == "audio":
        params["encoder"] = _stack_blocks(keys[2], cfg, cfg.n_encoder_layers,
                                          decoder=False)
        params["enc_norm"] = init_norm(cfg)
    if cfg.family == "vlm":
        # stub frontend: a single projection of precomputed patch embeddings
        params["vis_proj"] = dense_init(keys[3], cfg.d_model, cfg.d_model,
                                        pdtype(cfg))
    return params


def param_shapes(cfg: ModelConfig):
    """ShapeDtypeStructs of the param tree — no allocation (dry-run path)."""
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.random.PRNGKey(0))


# ----------------------------------------------------------------------
# training forward

def _block_train(bp, x, cfg: ModelConfig, kind: str, ffn_kind: str,
                 positions, enc_out, cross_p=None):
    """One block, train mode. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = norm_apply(bp["norm1"], x, cfg.norm)
    if kind == "attn":
        mix = attn.self_attention_block(bp["mixer"], h, cfg, positions)
    elif kind == "mamba":
        B = x.shape[0]
        st = mamba_mod.mamba_state_shapes(cfg, B)
        mix, _ = mamba_mod.mamba_apply(
            bp["mixer"], h, cfg,
            jnp.zeros(st["conv"], jnp.float32), jnp.zeros(st["ssm"], jnp.float32))
    else:  # rwkv
        B = x.shape[0]
        st = rwkv_mod.rwkv_state_shapes(cfg, B)
        mix, _ = rwkv_mod.rwkv_time_mix(
            bp["mixer"], h, cfg,
            jnp.zeros(st["tm_shift"], x.dtype), jnp.zeros(st["wkv"], jnp.float32))
    x = x + mix
    if cfg.family == "audio" and "cross" in bp:
        h = norm_apply(bp["norm_cross"], x, cfg.norm)
        enc_kv = attn.encoder_kv(bp["cross"], enc_out, cfg)
        x = x + attn.cross_attention_block(bp["cross"], h, enc_kv, cfg)
    h = norm_apply(bp["norm2"], x, cfg.norm)
    if ffn_kind == "moe":
        f, aux = moe_mod.moe_apply(bp["ffn"], h, cfg)
    elif kind == "rwkv":
        B = x.shape[0]
        f, _ = rwkv_mod.rwkv_channel_mix(
            bp["ffn"], h, cfg, jnp.zeros((B, cfg.d_model), x.dtype))
    else:
        f = mlp_apply(bp["ffn"], h, cfg)
    return x + f, aux


def _scan_blocks_train(blocks, x, cfg: ModelConfig, positions, enc_out,
                       decoder: bool = True, remat: str = "block"):
    period = len(blocks)

    def body(carry, per_period):
        x, aux = carry
        for j in range(period):
            i = j  # absolute kind index within period
            kind = cfg.layer_kind(i) if decoder else "attn"
            ffn_kind = cfg.ffn_kind(i) if decoder else "dense"
            if not decoder:
                # encoder blocks: bidirectional attention
                bp = per_period[j]
                h = norm_apply(bp["norm1"], x, cfg.norm)
                q, k, v = attn.qkv_proj(bp["mixer"], h, cfg, rope=False)
                x = x + attn.o_proj(
                    bp["mixer"], attn.bidirectional_attention(q, k, v, cfg), cfg)
                h = norm_apply(bp["norm2"], x, cfg.norm)
                x = x + mlp_apply(bp["ffn"], h, cfg)
            else:
                x, a = _block_train(per_period[j], x, cfg, kind, ffn_kind,
                                    positions, enc_out)
                aux = aux + a
        return (x, aux), None

    if remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), blocks,
                               unroll=layer_scan_unroll())
    return x, aux


def encode(params, frames: jax.Array, cfg: ModelConfig,
           remat: str = "block") -> jax.Array:
    """Whisper encoder over stub frame embeddings [B, S, D]."""
    S = frames.shape[1]
    pos = params["embed"]["positions"][:S].astype(cdtype(cfg))
    x = frames.astype(cdtype(cfg)) + pos[None]
    x, _ = _scan_blocks_train(params["encoder"], x, cfg, None, None,
                              decoder=False, remat=remat)
    return norm_apply(params["enc_norm"], x, cfg.norm)


def forward_hidden(params, tokens: jax.Array, cfg: ModelConfig, *,
                   extra: Optional[Dict[str, jax.Array]] = None,
                   remat: str = "block") -> Tuple[jax.Array, jax.Array]:
    """tokens [B, T] -> (final hidden states [B, T_total, D], aux_loss).

    extra["frames"]  (audio): [B, encoder_ctx, D] stub frame embeddings.
    extra["patches"] (vlm):   [B, n_vision_tokens, D] stub patch embeddings —
    prepended to the token sequence.
    """
    extra = extra or {}
    x = embed_tokens(params["embed"], tokens, cfg)
    B, T = tokens.shape
    enc_out = None
    if cfg.family == "vlm":
        vis = extra["patches"].astype(cdtype(cfg))
        vis = jnp.einsum("bvd,de->bve", vis, params["vis_proj"].astype(cdtype(cfg)))
        x = jnp.concatenate([vis, x], axis=1)
    if cfg.family == "audio":
        enc_out = encode(params, extra["frames"], cfg, remat)
        pos_tab = params["embed"]["positions"]
        x = x + pos_tab[:T].astype(cdtype(cfg))[None]
    positions = jnp.arange(x.shape[1])[None, :]
    # pin the residual-stream layout (batch on data axes) before the blocks
    x = shard_activation(x, DP, None, None)
    x, aux = _scan_blocks_train(params["blocks"], x, cfg, positions, enc_out,
                                decoder=True, remat=remat)
    return norm_apply(params["final_norm"], x, cfg.norm), aux


def forward_train(params, tokens: jax.Array, cfg: ModelConfig, *,
                  extra: Optional[Dict[str, jax.Array]] = None,
                  remat: str = "block") -> Tuple[jax.Array, jax.Array]:
    """tokens [B, T] -> (logits [B, T_total, V], aux_loss)."""
    x, aux = forward_hidden(params, tokens, cfg, extra=extra, remat=remat)
    return lm_logits(params["embed"], x, cfg), aux


# ----------------------------------------------------------------------
# loss

CE_CHUNK = 512  # tokens per chunked-cross-entropy step


def lm_loss(params, batch: Dict[str, jax.Array], cfg: ModelConfig, *,
            z_loss: float = 1e-4, moe_aux: float = 1e-2,
            remat: str = "block") -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """batch: tokens [B,T], labels [B,T] (-1 = masked), optional extras.

    Cross-entropy is computed in T-chunks with the vocab projection INSIDE
    the (checkpointed) chunk scan — the full [B, T, V] fp32 logits tensor is
    never materialised (command-r: 256k vocab x 4k seq would be 1.3 TB).
    """
    extra = {k: batch[k] for k in ("frames", "patches") if k in batch}
    x, aux = forward_hidden(params, batch["tokens"], cfg,
                            extra=extra, remat=remat)
    labels = batch["labels"]
    if cfg.family == "vlm":  # hidden covers [vis ; text]; loss on text only
        x = x[:, cfg.n_vision_tokens:, :]
    B, T, D = x.shape
    from repro.models.attention import pick_chunk
    chunk = pick_chunk(T, CE_CHUNK)
    n_chunks = T // chunk
    labels_safe = jnp.maximum(labels, 0)
    mask = (labels >= 0).astype(jnp.float32)

    def body(carry, inp):
        nll_sum, z_sum = carry
        xc, lc, mc = inp                                   # [B,chunk,·]
        logits = lm_logits(params["embed"], xc, cfg).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll_sum = nll_sum + jnp.sum((logz - gold) * mc)
        z_sum = z_sum + jnp.sum(jnp.square(logz) * mc)
        return (nll_sum, z_sum), None

    def split(t):
        return jnp.moveaxis(t.reshape(B, n_chunks, chunk, *t.shape[2:]), 1, 0)

    body = jax.checkpoint(body, prevent_cse=False)
    (nll_sum, z_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (split(x), split(labels_safe), split(mask)))
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = nll_sum / denom
    zl = z_loss * z_sum / denom
    total = loss + zl + moe_aux * aux
    return total, {"nll": loss, "z_loss": zl, "moe_aux": aux}
