"""GQA attention layer (projections + causal core + cross-attention).

The differentiable training/prefill path is the XLA einsum formulation
(remat-friendly); the serving prefill can swap in the Pallas flash kernel;
the decode path lives in ``repro.serving`` on top of the Mustafar cache.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, cdtype, dense_init, pdtype
from repro.sharding.constraints import DP, shard_activation

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig):
    keys = jax.random.split(key, 4)
    dt = pdtype(cfg)
    n_q, n_kv = cfg.n_heads * cfg.d_head, cfg.n_kv_heads * cfg.d_head
    p = {"wq": dense_init(keys[0], cfg.d_model, n_q, dt),
         "wk": dense_init(keys[1], cfg.d_model, n_kv, dt),
         "wv": dense_init(keys[2], cfg.d_model, n_kv, dt),
         "wo": dense_init(keys[3], n_q, cfg.d_model, dt)}
    if cfg.use_bias:
        p["bq"] = jnp.zeros((n_q,), dt)
        p["bk"] = jnp.zeros((n_kv,), dt)
        p["bv"] = jnp.zeros((n_kv,), dt)
        p["bo"] = jnp.zeros((cfg.d_model,), dt)
    return p


def qkv_proj(p, x: jax.Array, cfg: ModelConfig,
             positions: Optional[jax.Array] = None,
             rope: bool = True) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x [B, T, D] -> q [B, T, Hq, dh], k/v [B, T, Hkv, dh] (RoPE applied).

    ``positions`` may be [T]/[1, T] (lockstep prefill) or a true per-sequence
    [B, T] — ragged continuous-batching decode rotates each batch row at its
    own offset; a [B] vector of scalar offsets is accepted as shorthand."""
    B, T, _ = x.shape
    dt = cdtype(cfg)
    q = jnp.einsum("btd,de->bte", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,de->bte", x, p["wk"].astype(dt))
    v = jnp.einsum("btd,de->bte", x, p["wv"].astype(dt))
    if cfg.use_bias:
        q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), v + p["bv"].astype(dt)
    q = q.reshape(B, T, cfg.n_heads, cfg.d_head)
    k = k.reshape(B, T, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(B, T, cfg.n_kv_heads, cfg.d_head)
    if rope and cfg.pos_embedding == "rope":
        if positions is None:
            positions = jnp.arange(T)[None, :]
        elif positions.ndim == 1 and T == 1:
            positions = positions[:, None]       # [B] ragged offsets -> [B,1]
        # rope expects [..., T, d]: swap to [B, H, T, d]
        q = apply_rope(q.swapaxes(1, 2), positions, cfg).swapaxes(1, 2)
        k = apply_rope(k.swapaxes(1, 2), positions, cfg).swapaxes(1, 2)
    # pin a consistent attention layout: batch on data axes, heads on
    # "model" iff divisible (else dropped) — prevents GSPMD full-batch
    # reshards at the head-split reshape for 24/56/14-head archs
    q = shard_activation(q, DP, None, "model", None)
    k = shard_activation(k, DP, None, "model", None)
    v = shard_activation(v, DP, None, "model", None)
    return q, k, v


def o_proj(p, out: jax.Array, cfg: ModelConfig) -> jax.Array:
    """out [B, T, Hq, dh] -> [B, T, D]."""
    B, T = out.shape[:2]
    out = shard_activation(out, DP, None, "model", None)
    dt = cdtype(cfg)
    y = jnp.einsum("bte,ed->btd", out.reshape(B, T, -1), p["wo"].astype(dt))
    if cfg.use_bias:
        y = y + p["bo"].astype(dt)
    return y


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """[B, T, Hkv, d] -> [B, T, Hq, d]."""
    Hkv = k.shape[2]
    if Hkv == n_heads:
        return k
    return jnp.repeat(k, n_heads // Hkv, axis=2)


# query lengths at or above this use the chunked (flash-style) formulation
CHUNKED_ATTN_THRESHOLD = 1024
CHUNK_Q = 512


def pick_chunk(T: int, target: int = CHUNK_Q) -> int:
    """Largest divisor of T that is <= target (chunked scan needs T % c == 0)."""
    for c in range(min(target, T), 0, -1):
        if T % c == 0:
            return c
    return T


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      cfg: ModelConfig, causal: bool,
                      chunk: int = 0) -> jax.Array:
    """Memory-efficient attention: lax.scan over query chunks — peak score
    memory [B, H, chunk, Tk] instead of [B, H, Tq, Tk]. Pure jnp
    (differentiable); the Pallas flash kernel covers the TPU inference path,
    this covers training/prefill lowering at long T. Handles self- (Tq == Tk,
    causal) and cross- (Tq != Tk, bidirectional) attention."""
    B, Tq, Hq, dh = q.shape
    Tk = k.shape[1]
    k = _expand_kv(k, cfg.n_heads)
    v = _expand_kv(v, cfg.n_heads)
    scale = cfg.d_head ** -0.5
    chunk = chunk or pick_chunk(Tq)
    n_chunks = Tq // chunk
    qc = q.reshape(B, n_chunks, chunk, Hq, dh)

    def body(_, inp):
        qi, ci = inp
        s = jnp.einsum("bqhd,bkhd->bhqk", qi, k,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            q_idx = ci * chunk + jnp.arange(chunk)[None, None, :, None]
            k_idx = jnp.arange(Tk)[None, None, None, :]
            s = jnp.where(q_idx >= k_idx, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", p, v,
                       preferred_element_type=jnp.float32)
        return None, o.astype(q.dtype)

    body = jax.checkpoint(body, prevent_cse=False)
    _, out = jax.lax.scan(body, None,
                          (jnp.moveaxis(qc, 1, 0), jnp.arange(n_chunks)))
    return jnp.moveaxis(out, 0, 1).reshape(B, Tq, Hq, dh)


def chunked_causal_attention(q, k, v, cfg: ModelConfig,
                             chunk: int = 0) -> jax.Array:
    return chunked_attention(q, k, v, cfg, causal=True, chunk=chunk)


def causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     cfg: ModelConfig,
                     segment_ids: Optional[jax.Array] = None) -> jax.Array:
    """Full causal attention [B, T, Hq, dh] (XLA path, fp32 softmax)."""
    T = q.shape[1]
    if T >= CHUNKED_ATTN_THRESHOLD and segment_ids is None:
        return chunked_causal_attention(q, k, v, cfg)
    k = _expand_kv(k, cfg.n_heads)
    v = _expand_kv(v, cfg.n_heads)
    scale = cfg.d_head ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.tril(jnp.ones((T, T), bool))
    if segment_ids is not None:
        mask = mask[None, None] & (segment_ids[:, None, :, None]
                                   == segment_ids[:, None, None, :])
    s = jnp.where(mask, s, NEG_INF)
    p_attn = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p_attn, v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def prefix_causal_attention(q: jax.Array, k_buf: jax.Array, v_buf: jax.Array,
                            q_positions: jax.Array,
                            cfg: ModelConfig) -> jax.Array:
    """Causal attention for one CHUNK of queries over a prefix K/V buffer.

    q [B, C, Hq, dh] are the chunk's queries at absolute positions
    ``q_positions`` [B, C]; k_buf/v_buf [B, T_buf, Hkv, dh] hold the K/V of
    every position processed so far (this chunk included), zero-padded past
    the current fill. The mask admits key index <= query position, which is
    exactly the tril mask ``causal_attention`` applies over a full
    sequence — and since masked scores hit the same NEG_INF and fp32
    softmax, exp underflows to exactly 0.0 for them, the chunked result is
    BIT-IDENTICAL to the full-prefill attention rows (asserted in
    tests/test_prefix_sharing.py). This is what lets the scheduler split an
    admission prefill into fixed-size chunks interleaved with decode steps
    without perturbing a single logit."""
    T_buf = k_buf.shape[1]
    k = _expand_kv(k_buf, cfg.n_heads)
    v = _expand_kv(v_buf, cfg.n_heads)
    scale = cfg.d_head ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(T_buf)[None, None, None, :] \
        <= q_positions[:, None, :, None]
    s = jnp.where(mask, s, NEG_INF)
    p_attn = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p_attn, v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def bidirectional_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                            cfg: ModelConfig) -> jax.Array:
    """Encoder / cross attention (no mask). Shapes as above, Tq may != Tk."""
    if q.shape[1] >= CHUNKED_ATTN_THRESHOLD:
        return chunked_attention(q, k, v, cfg, causal=False)
    k = _expand_kv(k, cfg.n_heads)
    v = _expand_kv(v, cfg.n_heads)
    scale = cfg.d_head ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    p_attn = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p_attn, v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


# ----------------------------------------------------------------------
# decode-path selection (serving hot path)

def decode_attention_auto(q: jax.Array, cache_view, cfg: ModelConfig,
                          scale: Optional[float] = None) -> jax.Array:
    """Pick the Mustafar decode-attention formulation for one step.

    q [B, Hq, d]; ``cache_view`` is a ``core.attention.MustafarCacheView``.

    * B == 1 or pool ≤ one decode chunk → two-pass jnp formulation: its
      partial softmax over a context-sharded Tc lowers to tiny all-reduces
      (the chunk reshape would defeat GSPMD propagation — measured 70
      GiB/step of pool all-gathers at B=1/524k), and at ≤ one chunk it keeps
      ragged-batch numerics bit-identical to a solo run.
    * multi-chunk batched on TPU → the fused Pallas kernel
      (``decode_attention_mustafar_kernelized``): gather decompression, bf16
      tile products, and a scalar-prefetch grid that skips the DMA of tiles
      past each row's own compressed depth.
    * multi-chunk batched elsewhere → the chunked online-softmax scan (same
      math as the kernel, temp memory bounded by one chunk).

    Accepts either a contiguous ``MustafarCacheView`` or a
    ``PagedMustafarCacheView``. Paged pools on TPU with a multi-chunk view
    take the paged fused kernel (tile→page translation in the
    scalar-prefetch grid — the gather is never materialised); everywhere
    else the paged view reads through ``to_contiguous()``'s gather and the
    selection below proceeds unchanged, so paged CPU numerics stay
    bit-identical to contiguous pools.
    """
    from repro.core.attention import (
        DECODE_CHUNK, PagedMustafarCacheView, decode_attention_mustafar,
        decode_attention_mustafar_chunked, decode_attention_mustafar_kernelized,
        decode_attention_mustafar_kernelized_paged)
    B = q.shape[0]
    scale = scale if scale is not None else cfg.d_head ** -0.5
    if isinstance(cache_view, PagedMustafarCacheView):
        Tc = cache_view.block_table.shape[1] * cache_view.ck_pool.shape[2]
        if B > 1 and Tc > DECODE_CHUNK and jax.default_backend() == "tpu":
            return decode_attention_mustafar_kernelized_paged(q, cache_view,
                                                              scale=scale)
        cache_view = cache_view.to_contiguous()
    Tc = cache_view.ck_values.shape[2]
    if B == 1 or Tc <= DECODE_CHUNK:
        return decode_attention_mustafar(q, cache_view, scale=scale)
    if jax.default_backend() == "tpu":
        return decode_attention_mustafar_kernelized(q, cache_view, scale=scale)
    return decode_attention_mustafar_chunked(q, cache_view, scale=scale)


def self_attention_block(p, x: jax.Array, cfg: ModelConfig,
                         positions: Optional[jax.Array] = None) -> jax.Array:
    """Full train-mode self-attention sublayer (proj → causal core → proj)."""
    q, k, v = qkv_proj(p, x, cfg, positions)
    out = causal_attention(q, k, v, cfg)
    return o_proj(p, out, cfg)


def cross_attention_block(p, x: jax.Array, enc_kv: Tuple[jax.Array, jax.Array],
                          cfg: ModelConfig) -> jax.Array:
    """Decoder cross-attention: q from x, K/V precomputed from encoder."""
    B, T, _ = x.shape
    dt = cdtype(cfg)
    q = jnp.einsum("btd,de->bte", x, p["wq"].astype(dt))
    if cfg.use_bias:
        q = q + p["bq"].astype(dt)
    q = q.reshape(B, T, cfg.n_heads, cfg.d_head)
    k, v = enc_kv
    out = bidirectional_attention(q, k, v, cfg)
    return o_proj(p, out, cfg)


def encoder_kv(p, enc_x: jax.Array, cfg: ModelConfig):
    """Project encoder output once into cross-attention K/V."""
    B, S, _ = enc_x.shape
    dt = cdtype(cfg)
    k = jnp.einsum("btd,de->bte", enc_x, p["wk"].astype(dt))
    v = jnp.einsum("btd,de->bte", enc_x, p["wv"].astype(dt))
    if cfg.use_bias:
        k, v = k + p["bk"].astype(dt), v + p["bv"].astype(dt)
    return (k.reshape(B, S, cfg.n_kv_heads, cfg.d_head),
            v.reshape(B, S, cfg.n_kv_heads, cfg.d_head))
