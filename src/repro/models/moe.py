"""Mixture-of-Experts FFN with sort-based top-k dispatch (MaxText-style).

Tokens are replicated top_k times, stably sorted by assigned expert, placed
into fixed-capacity per-expert slots (capacity-factor drop policy), run
through batched expert matmuls, and combined back with routing weights.
Everything is jit-able with static shapes; under pjit the [E, C, d] buffers
are sharded on the "model" (expert) axis, which makes the dispatch/combine
gathers lower to all-to-alls.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import cdtype, dense_init, pdtype

def init_moe(key, cfg: ModelConfig):
    e, d = cfg.n_experts, cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    keys = jax.random.split(key, 4)
    dt = pdtype(cfg)
    scale_in, scale_out = d ** -0.5, f ** -0.5

    def ew(key, d_in, d_out, scale):
        return (jax.random.normal(key, (e, d_in, d_out), jnp.float32)
                * scale).astype(dt)

    p = {"router": dense_init(keys[0], d, e, jnp.float32),
         "up": ew(keys[1], d, f, scale_in),
         "down": ew(keys[2], f, d, scale_out)}
    if cfg.activation == "silu":
        p["gate"] = ew(keys[3], d, f, scale_in)
    return p


def expert_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.expert_top_k * cfg.moe_capacity_factor / cfg.n_experts)
    return max(8, (c + 7) // 8 * 8)


def moe_apply(p, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x [B, T, D] -> (out [B, T, D], aux_loss scalar).

    Dispatch is PER SEQUENCE (vmapped over B): the argsort / scatter /
    gather stay local to each batch shard under GSPMD — a global sort over
    B·T·K (token,expert) pairs would be all-gathered to every device
    (measured: 398 GiB/device at qwen3's 32k prefill). Capacity is therefore
    per-sequence (T·K·cf/E), a slightly stricter drop policy (documented).
    The [B@data, E@model, C, ·] buffers give the expert einsums the standard
    expert-parallel all-to-all pattern.
    """
    B, T, D = x.shape
    E, K = cfg.n_experts, cfg.expert_top_k
    C = expert_capacity(T, cfg)
    dt = cdtype(cfg)

    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                    # [B, T, E]
    top_p, top_e = jax.lax.top_k(probs, K)                     # [B, T, K]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)     # renormalise

    # load-balancing aux loss (Switch): E * Σ_e f_e · p_e (global)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(
        jnp.ones((B * T * K,), jnp.float32)) / (B * T * K)
    aux = E * jnp.sum(me * ce)

    def dispatch_one(xf, te, tp):
        """xf [T,D]; te/tp [T,K] -> (buf [E,C,D], slot, st, contrib)."""
        NK = T * K
        flat_e = te.reshape(-1)
        flat_p = tp.reshape(-1)
        flat_tok = jnp.arange(NK, dtype=jnp.int32) // K
        order = jnp.argsort(flat_e, stable=True)
        se, sp, st = flat_e[order], flat_p[order], flat_tok[order]
        first_idx = jnp.searchsorted(se, jnp.arange(E), side="left")
        pos = jnp.arange(NK) - first_idx[se]
        keep = pos < C
        slot = jnp.where(keep, se * C + pos, E * C)
        buf = jnp.zeros((E * C + 1, D), dt).at[slot].set(
            xf[st].astype(dt), mode="drop")
        contrib = jnp.where(keep, sp, 0.0).astype(jnp.float32)
        return buf[:-1].reshape(E, C, D), slot, st, contrib

    buf, slot, st, contrib = jax.vmap(dispatch_one)(x, top_e, top_p)

    # ---- expert compute (batched over B and E; E sharded on "model") ----
    up = jnp.einsum("becd,edf->becf", buf, p["up"].astype(dt))
    if cfg.activation == "silu":
        gate = jnp.einsum("becd,edf->becf", buf, p["gate"].astype(dt))
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(dt) * up
    else:
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(dt)
    out_buf = jnp.einsum("becf,efd->becd", h, p["down"].astype(dt))

    def combine_one(out_flat2, slot, st, contrib):
        safe_slot = jnp.minimum(slot, E * C - 1)
        gathered = out_flat2.reshape(E * C, D)[safe_slot].astype(jnp.float32)
        gathered = gathered * contrib[:, None]
        return jnp.zeros((T, D), jnp.float32).at[st].add(gathered)

    out = jax.vmap(combine_one)(out_buf, slot, st, contrib)
    return out.astype(dt), aux
