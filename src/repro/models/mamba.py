"""Mamba (selective SSM) block for the Jamba hybrid (arXiv:2403.19887).

    h_t = exp(Δ_t A) h_{t-1} + (Δ_t B_t) x_t        h: [B, d_in, d_state]
    y_t = C_t · h_t + D x_t

Training/prefill: depthwise causal conv + ``lax.scan`` over time.
Decode: O(1) state update (conv ring + SSM state) — no KV cache, which is
why Jamba's Mamba layers need no Mustafar treatment (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import cdtype, dense_init, pdtype


def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, cfg.d_model // 16)


def init_mamba(key, cfg: ModelConfig):
    d = cfg.d_model
    d_in = cfg.mamba_expand * d
    ds, dc = cfg.mamba_d_state, cfg.mamba_d_conv
    dtr = _dt_rank(cfg)
    keys = jax.random.split(key, 7)
    dt = pdtype(cfg)
    # S4D-real init for A
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (d_in, 1))
    return {
        "in_proj": dense_init(keys[0], d, 2 * d_in, dt),
        "conv_w": (jax.random.normal(keys[1], (dc, d_in), jnp.float32)
                   * (dc ** -0.5)).astype(dt),
        "conv_b": jnp.zeros((d_in,), dt),
        "x_proj": dense_init(keys[2], d_in, dtr + 2 * ds, dt),
        "dt_proj": dense_init(keys[3], dtr, d_in, jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((d_in,), 1e-2, jnp.float32))),
        "A_log": jnp.log(A),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(keys[4], d_in, d, dt),
    }


def _ssm_scan(u, dt_, B_, C_, A, D, h0):
    """u/dt_ [B,T,din]; B_/C_ [B,T,ds]; A [din,ds]; h0 [B,din,ds] fp32.

    Discretisation (exp(Δ·A), Δ·B·u) happens INSIDE the scan body: the
    [B,T,din,ds] tensors would be ~1 TB at jamba's 32k-prefill shapes."""
    Ae = -jnp.exp(A)                                           # [din,ds]

    def step(h, inp):
        u_t, dt_t, B_t, C_t = inp          # [B,din],[B,din],[B,ds],[B,ds]
        dA_t = jnp.exp(dt_t[..., None] * Ae[None])             # [B,din,ds]
        dBu_t = (dt_t * u_t)[..., None] * B_t[:, None, :]
        h = dA_t * h + dBu_t                                   # [B,din,ds]
        y = jnp.einsum("bds,bs->bd", h, C_t)
        return h, y

    xs = (jnp.moveaxis(u, 1, 0), jnp.moveaxis(dt_, 1, 0),
          jnp.moveaxis(B_, 1, 0), jnp.moveaxis(C_, 1, 0))
    h, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + u * D[None, None]
    return y, h


def mamba_apply(p, x: jax.Array, cfg: ModelConfig,
                conv_state: jax.Array, ssm_state: jax.Array):
    """x [B,T,D] -> (out [B,T,D], (new_conv [B,dc-1,din], new_ssm))."""
    B, T, D = x.shape
    d_in = cfg.mamba_expand * D
    ds, dc = cfg.mamba_d_state, cfg.mamba_d_conv
    dtr = _dt_rank(cfg)
    dt = cdtype(cfg)

    xz = jnp.einsum("btd,de->bte", x, p["in_proj"].astype(dt))
    u, z = jnp.split(xz, 2, axis=-1)                           # [B,T,din]

    # depthwise causal conv over time (carry = last dc-1 inputs)
    u_pad = jnp.concatenate([conv_state.astype(dt), u], axis=1)  # [B,T+dc-1,din]
    conv = sum(u_pad[:, i:i + T, :] * p["conv_w"][i].astype(dt)
               for i in range(dc))
    conv = conv + p["conv_b"].astype(dt)
    new_conv = u_pad[:, T:, :] if dc == 1 else u_pad[:, -(dc - 1):, :]
    uc = jax.nn.silu(conv.astype(jnp.float32))                 # [B,T,din] fp32

    xdbc = jnp.einsum("bte,ef->btf", uc.astype(dt), p["x_proj"].astype(dt))
    dt_in, B_, C_ = jnp.split(xdbc.astype(jnp.float32),
                              [dtr, dtr + ds], axis=-1)
    delta = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"])  # [B,T,din]

    y, new_ssm = _ssm_scan(uc, delta, B_, C_, p["A_log"], p["D"], ssm_state)
    y = y.astype(dt) * jax.nn.silu(z.astype(jnp.float32)).astype(dt)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(dt))
    return out, (new_conv.astype(jnp.float32), new_ssm)


def mamba_state_shapes(cfg: ModelConfig, B: int):
    d_in = cfg.mamba_expand * cfg.d_model
    return {"conv": (B, cfg.mamba_d_conv - 1, d_in),
            "ssm": (B, d_in, cfg.mamba_d_state)}
