"""Shared layers: norms, embeddings, RoPE, MLPs. Pure-functional pytrees.

Params are nested dicts of jnp arrays. ``init_*`` builds params; ``*_apply``
consumes them. Compute dtype is cfg.dtype (bf16), norm/softmax accumulate in
fp32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


# ----------------------------------------------------------------------
# norms

def init_norm(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), pdtype(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), pdtype(cfg))
    return p


def norm_apply(p, x: jax.Array, kind: str) -> jax.Array:
    """Stats in fp32; the scale/bias affine runs in x.dtype so backward
    cotangents at layer boundaries stay bf16 (§Perf Cell A iter 6 — fp32
    cotangent tensors doubled the TP all-reduce bytes)."""
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = (xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True)
                                + 1e-6)).astype(x.dtype)
        return y * p["scale"].astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype)
    return y * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)


# ----------------------------------------------------------------------
# rotary embeddings

def rope_freqs(cfg: ModelConfig) -> jax.Array:
    d = cfg.d_head
    return 1.0 / (cfg.rope_theta ** (jnp.arange(0, d, 2, jnp.float32) / d))


def apply_rope(x: jax.Array, positions: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x [..., T, d_head] (T axis second-to-last); positions [..., T]."""
    if cfg.pos_embedding != "rope":
        return x
    freqs = rope_freqs(cfg)                                  # [d/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., T, d/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    # match broadcasting: x may have a heads dim before T
    while cos.ndim < x.ndim:
        cos, sin = cos[..., None, :, :], sin[..., None, :, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# embeddings

def init_embed(key, cfg: ModelConfig):
    p = {"tokens": (jax.random.normal(key, (cfg.vocab_size, cfg.d_model),
                                      jnp.float32) * 0.02).astype(pdtype(cfg))}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(jax.random.fold_in(key, 1),
                                  cfg.d_model, cfg.vocab_size, pdtype(cfg))
    if cfg.pos_embedding == "learned":
        n_pos = max(cfg.encoder_ctx, cfg.max_position)
        p["positions"] = (jax.random.normal(jax.random.fold_in(key, 2),
                                            (n_pos, cfg.d_model), jnp.float32)
                          * 0.02).astype(pdtype(cfg))
    return p


def embed_tokens(p, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    return p["tokens"].astype(cdtype(cfg))[tokens]


def lm_logits(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = p["tokens"] if cfg.tie_embeddings else p["lm_head"]
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", x, w.astype(cdtype(cfg)))
    return jnp.einsum("...d,dv->...v", x, w.astype(cdtype(cfg)))


# ----------------------------------------------------------------------
# dense MLP (gated SiLU / plain GELU)

def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    keys = jax.random.split(key, 3)
    dt = pdtype(cfg)
    if cfg.activation == "silu":
        p = {"gate": dense_init(keys[0], cfg.d_model, d_ff, dt),
             "up": dense_init(keys[1], cfg.d_model, d_ff, dt),
             "down": dense_init(keys[2], d_ff, cfg.d_model, dt)}
    else:
        p = {"up": dense_init(keys[0], cfg.d_model, d_ff, dt),
             "down": dense_init(keys[1], d_ff, cfg.d_model, dt)}
    if cfg.use_bias:
        p["up_b"] = jnp.zeros((d_ff,), dt)
        p["down_b"] = jnp.zeros((cfg.d_model,), dt)
    return p


def mlp_apply(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = cdtype(cfg)
    up = jnp.einsum("...d,df->...f", x, p["up"].astype(dt))
    if cfg.use_bias:
        up = up + p["up_b"].astype(dt)
    if cfg.activation == "silu":
        gate = jnp.einsum("...d,df->...f", x, p["gate"].astype(dt))
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(dt) * up
    elif cfg.activation == "relu_sq":
        h = jnp.square(jax.nn.relu(up))
    else:
        h = jax.nn.gelu(up.astype(jnp.float32)).astype(dt)
    out = jnp.einsum("...f,fd->...d", h, p["down"].astype(dt))
    if cfg.use_bias:
        out = out + p["down_b"].astype(dt)
    return out
