"""Config-driven model zoo (dense GQA, MoE, RWKV6, Mamba/Jamba, Whisper, VLM)."""
from repro.models.model import (forward_train, init_params, lm_loss,
                                param_shapes, structural_period)
