"""RWKV6 "Finch" blocks — attention-free, data-dependent decay (arXiv:2404.05892).

Implements the WKV6 recurrence with per-channel data-dependent decay:

    S_t = diag(w_t) · S_{t-1} + k_tᵀ v_t          (state  [B, H, hs, hs])
    o_t = r_t · (S_{t-1} + diag(u·k_t) v_t)

Training/prefill runs the recurrence with ``lax.scan`` over time (single HLO
while-loop — compile-friendly at 500k tokens); decode is the O(1) single-step
update, which is why this arch (no KV cache — Mustafar inapplicable, see
DESIGN.md) runs the ``long_500k`` shape natively.

Simplifications vs the full release (documented): static token-shift mix
coefficients (RWKV5-style lerp) for r/k/v/g; the *decay* keeps the Finch
signature — a per-token LoRA: w_t = exp(-exp(w0 + tanh(x·A)·B)).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import cdtype, dense_init, norm_apply, pdtype

DECAY_LORA = 64


def init_rwkv_time_mix(key, cfg: ModelConfig):
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    H = d // hs
    keys = jax.random.split(key, 10)
    dt = pdtype(cfg)
    p = {
        "wr": dense_init(keys[0], d, d, dt),
        "wk": dense_init(keys[1], d, d, dt),
        "wv": dense_init(keys[2], d, d, dt),
        "wg": dense_init(keys[3], d, d, dt),
        "wo": dense_init(keys[4], d, d, dt),
        # token-shift mix coefficients in [0,1]
        "mix_r": jnp.full((d,), 0.5, dt), "mix_k": jnp.full((d,), 0.5, dt),
        "mix_v": jnp.full((d,), 0.5, dt), "mix_g": jnp.full((d,), 0.5, dt),
        "mix_w": jnp.full((d,), 0.5, dt),
        # data-dependent decay LoRA (Finch): w0 + tanh(x A) B
        "w0": jnp.zeros((d,), jnp.float32),
        "wA": dense_init(keys[5], d, DECAY_LORA, jnp.float32),
        "wB": dense_init(keys[6], DECAY_LORA, d, jnp.float32, scale=0.01),
        "u": (jax.random.normal(keys[7], (H, hs), jnp.float32) * 0.1),
        # per-head group norm on the wkv output
        "ln_x_scale": jnp.ones((d,), dt), "ln_x_bias": jnp.zeros((d,), dt),
    }
    return p


def init_rwkv_channel_mix(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    keys = jax.random.split(key, 2)
    dt = pdtype(cfg)
    return {"cm_k": dense_init(keys[0], d, f, dt),
            "cm_v": dense_init(keys[1], f, d, dt),
            "mix_k": jnp.full((d,), 0.5, dt)}


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """x [B,T,D]; prev [B,D] (last token of previous segment) -> shifted x."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _wkv_scan(r, k, v, w, u, state):
    """Run the WKV6 recurrence over time.

    r/k/v/w: [B, T, H, hs]; u: [H, hs]; state: [B, H, hs, hs] fp32.
    Returns (out [B, T, H, hs] fp32, new_state).
    """
    def step(S, inp):
        r_t, k_t, v_t, w_t = inp            # [B, H, hs]
        kv = k_t[..., :, None] * v_t[..., None, :]           # [B,H,hs,hs]
        out = jnp.einsum("bhi,bhij->bhj", r_t, S + u[..., :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, out

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))  # time-major
    state, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1), state


def rwkv_time_mix(p, x: jax.Array, cfg: ModelConfig,
                  shift_state: jax.Array, wkv_state: jax.Array):
    """x [B,T,D] -> (out, (new_shift, new_wkv))."""
    B, T, D = x.shape
    hs = cfg.rwkv_head_size
    H = D // hs
    dt = cdtype(cfg)
    xs = _token_shift(x, shift_state)

    def mixed(name):
        m = p["mix_" + name].astype(dt)
        return x * m + xs * (1.0 - m)

    r = jnp.einsum("btd,de->bte", mixed("r"), p["wr"].astype(dt))
    k = jnp.einsum("btd,de->bte", mixed("k"), p["wk"].astype(dt))
    v = jnp.einsum("btd,de->bte", mixed("v"), p["wv"].astype(dt))
    g = jnp.einsum("btd,de->bte", mixed("g"), p["wg"].astype(dt))
    xw = mixed("w").astype(jnp.float32)
    # Finch data-dependent decay
    lora = jnp.tanh(xw @ p["wA"]) @ p["wB"]
    w = jnp.exp(-jnp.exp(p["w0"] + lora))                    # (0,1), [B,T,D]

    shp = (B, T, H, hs)
    out, wkv_state = _wkv_scan(
        r.reshape(shp).astype(jnp.float32), k.reshape(shp).astype(jnp.float32),
        v.reshape(shp).astype(jnp.float32), w.reshape(shp),
        p["u"], wkv_state)

    # per-head group norm
    mu = jnp.mean(out, axis=-1, keepdims=True)
    var = jnp.var(out, axis=-1, keepdims=True)
    out = (out - mu) * jax.lax.rsqrt(var + 1e-5)
    out = out.reshape(B, T, D) * p["ln_x_scale"].astype(jnp.float32) \
        + p["ln_x_bias"].astype(jnp.float32)
    out = out.astype(dt) * jax.nn.silu(g.astype(jnp.float32)).astype(dt)
    y = jnp.einsum("btd,de->bte", out, p["wo"].astype(dt))
    return y, (x[:, -1, :], wkv_state)


def rwkv_channel_mix(p, x: jax.Array, cfg: ModelConfig, shift_state: jax.Array):
    dt = cdtype(cfg)
    xs = _token_shift(x, shift_state)
    m = p["mix_k"].astype(dt)
    xk = x * m + xs * (1.0 - m)
    h = jnp.square(jax.nn.relu(
        jnp.einsum("btd,df->btf", xk, p["cm_k"].astype(dt))))
    y = jnp.einsum("btf,fd->btd", h, p["cm_v"].astype(dt))
    return y, x[:, -1, :]


def rwkv_state_shapes(cfg: ModelConfig, B: int):
    """Per-layer decode state: (tm_shift [B,D], wkv [B,H,hs,hs], cm_shift [B,D])."""
    hs = cfg.rwkv_head_size
    H = cfg.d_model // hs
    return {
        "tm_shift": (B, cfg.d_model),
        "wkv": (B, H, hs, hs),
        "cm_shift": (B, cfg.d_model),
    }
