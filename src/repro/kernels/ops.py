"""Public jit'd wrappers for the Pallas kernels.

Shape normalisation ([B, H, ...] <-> [B·H, ...]), GQA head grouping, and
backend dispatch: on TPU the Pallas kernels run compiled; on CPU they run
with ``interpret=True`` (kernel body executed in Python — correctness path),
and the pure-jnp reference is used inside traced/pjit graphs (the dry-run
lowers the jnp formulation, whose HBM traffic is equivalent).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import bitmap_compress, ref, sparse_decode


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ----------------------------------------------------------------------
def compress(x: jax.Array, k: int, *, use_pallas: Optional[bool] = None):
    """Per-token top-k prune + pack. x [..., T, d] -> (values, bitmap)."""
    lead = x.shape[:-2]
    T, d = x.shape[-2:]
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return ref.mustafar_compress_ref(x, k)
    xr = x.reshape(-1, T, d)
    vals, bm = bitmap_compress.mustafar_compress(xr, k, interpret=not _on_tpu())
    return (vals.reshape(*lead, T, k), bm.reshape(*lead, T, bm.shape[-1]))


def _group_q(q: jax.Array, n_kv_heads: int):
    """[B, Hq, d] -> [B·Hkv, G, d] (query head h attends kv head h//G)."""
    B, Hq, d = q.shape
    G = Hq // n_kv_heads
    return q.reshape(B * n_kv_heads, G, d), G


def sparse_qk(q: jax.Array, values: jax.Array, bitmap: jax.Array, *,
              scale: float, use_pallas: Optional[bool] = None) -> jax.Array:
    """q [B,Hq,d], compressed K [B,Hkv,T,·] -> scores [B,Hq,T] fp32."""
    B, Hkv, T, k = values.shape
    d = q.shape[-1]
    qg, G = _group_q(q, Hkv)
    v2, b2 = values.reshape(B * Hkv, T, k), bitmap.reshape(B * Hkv, T, -1)
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        s = sparse_decode.sparse_qk(qg, v2, b2, scale=scale,
                                    interpret=not _on_tpu(),
                                    tile_t=min(T, sparse_decode.TILE_T))
    else:
        s = ref.sparse_qk_ref(qg, v2, b2, d, scale)
    return s.reshape(B, Hkv * G, T)


def sparse_av(p: jax.Array, values: jax.Array, bitmap: jax.Array, *, d: int,
              use_pallas: Optional[bool] = None) -> jax.Array:
    """p [B,Hq,T], compressed V [B,Hkv,T,·] -> out [B,Hq,d] fp32."""
    B, Hkv, T, k = values.shape
    Hq = p.shape[1]
    G = Hq // Hkv
    pg = p.reshape(B * Hkv, G, T)
    v2, b2 = values.reshape(B * Hkv, T, k), bitmap.reshape(B * Hkv, T, -1)
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        o = sparse_decode.sparse_av(pg, v2, b2, interpret=not _on_tpu(),
                                    tile_t=min(T, sparse_decode.TILE_T))
        o = o[..., :d]
    else:
        o = ref.sparse_av_ref(pg, v2, b2, d)
    return o.reshape(B, Hq, d)


def decode_attention_fused(q: jax.Array,
                           ck_values: jax.Array, ck_bitmap: jax.Array,
                           cv_values: jax.Array, cv_bitmap: jax.Array,
                           n_valid: jax.Array, *, scale: Optional[float] = None,
                           use_pallas: Optional[bool] = None) -> jax.Array:
    """Fused single-pass decode attention over the compressed cache.

    q [B,Hq,d]; caches [B,Hkv,T,·]; n_valid [B] -> out [B,Hq,d] fp32.
    """
    B, Hkv, T, kk = ck_values.shape
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    qg, G = _group_q(q, Hkv)
    nv = jnp.repeat(n_valid.astype(jnp.int32), Hkv)
    args = (qg,
            ck_values.reshape(B * Hkv, T, kk), ck_bitmap.reshape(B * Hkv, T, -1),
            cv_values.reshape(B * Hkv, T, -1), cv_bitmap.reshape(B * Hkv, T, -1))
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        o = sparse_decode.decode_attention_fused(
            *args, nv, d=d, scale=scale, interpret=not _on_tpu(),
            tile_t=min(T, sparse_decode.TILE_T))
    else:
        o = ref.decode_attention_fused_ref(*args, nv, d, scale)
    return o.reshape(B, Hkv * G, d)
