"""Public jit'd wrappers for the Pallas kernels.

Shape normalisation ([B, H, ...] <-> [B·H, ...]), GQA head grouping, and
backend dispatch: on TPU the Pallas kernels run compiled; on CPU they run
with ``interpret=True`` (kernel body executed in Python — correctness path),
and the pure-jnp reference is used inside traced/pjit graphs (the dry-run
lowers the jnp formulation, whose HBM traffic is equivalent).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.sparse_format import (dequantize_fixedk, gather_pages,
                                      quantize_fixedk)
from repro.kernels import bitmap_compress, ref, sparse_decode


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _auto_tile(T: int, cap: int) -> int:
    """Largest divisor of T that is <= cap (the grid needs T % tile_t == 0;
    e.g. a prefill of 80 compressible tokens under the default cap of 64
    tiles as 2 x 40)."""
    t = min(cap, T)
    while T % t:
        t -= 1
    return t


def _auto_tile_q(T: int, cap: int, qt: int) -> int:
    """Largest divisor of T that is <= cap AND a multiple of the quant block
    ``qt`` (a kernel tile must cover whole quant blocks so the per-tile scale
    slice lines up). T % qt == 0 by construction (scales exist), so qt itself
    is always a valid floor."""
    t = (min(cap, T) // qt) * qt
    while t > qt and T % t:
        t -= qt
    return max(t, qt)


# ----------------------------------------------------------------------
def compress(x: jax.Array, k: int, *, use_pallas: Optional[bool] = None,
             tile_t: Optional[int] = None,
             quant_tile: Optional[int] = None):
    """Per-token top-k prune + pack. x [..., T, d] -> (values, bitmap).

    ``tile_t`` overrides the kernel's token-tile grid step; by default the
    largest divisor of T at or under ``bitmap_compress.TILE_T`` is used, so
    any token count the callers produce (tile groups, ragged prefills)
    tiles cleanly.

    ``quant_tile`` switches on int8 pool storage: the packed values come
    back int8 plus a third output — one fp32 symmetric absmax scale per
    ``quant_tile`` tokens, [..., T // quant_tile, 1]. The bitmap plane is
    bit-identical to the unquantized call (pruning happens BEFORE
    quantization)."""
    lead = x.shape[:-2]
    T, d = x.shape[-2:]
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        vals, bm = ref.mustafar_compress_ref(x, k)
        if quant_tile is None:
            return vals, bm
        q, s = quantize_fixedk(vals, quant_tile)
        return q, bm, s
    xr = x.reshape(-1, T, d)
    if quant_tile is None:
        tt = tile_t if tile_t is not None \
            else _auto_tile(T, bitmap_compress.TILE_T)
        vals, bm = bitmap_compress.mustafar_compress(
            xr, k, interpret=not _on_tpu(), tile_t=tt)
        return (vals.reshape(*lead, T, k), bm.reshape(*lead, T, bm.shape[-1]))
    tt = tile_t if tile_t is not None \
        else _auto_tile_q(T, bitmap_compress.TILE_T, quant_tile)
    vals, bm, scales = bitmap_compress.mustafar_compress(
        xr, k, interpret=not _on_tpu(), tile_t=tt, quant_tile=quant_tile)
    return (vals.reshape(*lead, T, k), bm.reshape(*lead, T, bm.shape[-1]),
            scales.reshape(*lead, T // quant_tile, 1))


def compress_scatter(k_tile: jax.Array, v_tile: jax.Array,
                     ck_vals: jax.Array, ck_bm: jax.Array,
                     cv_vals: jax.Array, cv_bm: jax.Array,
                     phys: jax.Array, off: jax.Array, *,
                     k_scale: Optional[jax.Array] = None,
                     v_scale: Optional[jax.Array] = None,
                     use_pallas: Optional[bool] = None):
    """Fused tile-group retirement into paged pools (compress-as-you-evict).

    ``k_tile``/``v_tile`` [B, Hkv, tt, d] retiring window tiles; pool leaves
    [n_phys, Hkv, page_tokens, ·]; ``phys`` [B] pre-resolved destination
    page per row (scratch page for masked rows); ``off`` [B] in-page TOKEN
    offset (tile-aligned). Returns the four updated pool leaves — six when
    ``k_scale``/``v_scale`` [n_phys, Hkv, page_tokens // tt, 1] are given
    (int8 pools): values are quantized in the same dispatch, one symmetric
    absmax fp32 scale per retiring tile lands in the sibling scale pool at
    tile row ``off // tt``.

    On TPU this is ONE Pallas dispatch — the compressed values/bitmaps DMA
    straight into their destination page blocks through scalar-prefetched
    output index maps over aliased (donated) pools. Off-TPU the reference
    compress feeds a single vectorized scatter — bit-identical to the
    two-dispatch ``compress`` + scan-of-DUS oracle on every non-scratch
    page (scratch rows may resolve duplicate writes in either order; the
    scratch page is write-discard and never read)."""
    B, Hkv, tt, d = k_tile.shape
    kk = ck_vals.shape[-1]
    kv = cv_vals.shape[-1]
    quant = k_scale is not None
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return bitmap_compress.mustafar_compress_scatter(
            k_tile, v_tile, ck_vals, ck_bm, cv_vals, cv_bm,
            phys, off // tt, k_scale=k_scale, v_scale=v_scale,
            interpret=not _on_tpu())
    ck_v, ck_b = ref.mustafar_compress_ref(k_tile, kk)   # [B,Hkv,tt,·]
    cv_v, cv_b = ref.mustafar_compress_ref(v_tile, kv)
    if quant:
        ck_v, ck_s = quantize_fixedk(ck_v, tt)           # scales [B,Hkv,1,1]
        cv_v, cv_s = quantize_fixedk(cv_v, tt)
    idx_p = phys[:, None]                                # [B,1] page
    idx_t = off[:, None] + jnp.arange(tt)[None, :]       # [B,tt] token rows
    def scat(pool, tiles):
        # advanced indices on dims 0/2 -> [B, tt, Hkv, c] value layout
        return pool.at[idx_p, :, idx_t].set(
            jnp.swapaxes(tiles, 1, 2).astype(pool.dtype))
    out = (scat(ck_vals, ck_v), scat(ck_bm, ck_b),
           scat(cv_vals, cv_v), scat(cv_bm, cv_b))
    if not quant:
        return out
    idx_ts = (off // tt)[:, None]                        # [B,1] tile rows
    def scat_scale(pool, s):
        return pool.at[idx_p, :, idx_ts].set(
            jnp.swapaxes(s, 1, 2).astype(pool.dtype))
    return out + (scat_scale(k_scale, ck_s), scat_scale(v_scale, cv_s))


def _group_q(q: jax.Array, n_kv_heads: int):
    """[B, Hq, d] -> [B·Hkv, G, d] (query head h attends kv head h//G)."""
    B, Hq, d = q.shape
    G = Hq // n_kv_heads
    return q.reshape(B * n_kv_heads, G, d), G


def sparse_qk(q: jax.Array, values: jax.Array, bitmap: jax.Array, *,
              scale: float, use_pallas: Optional[bool] = None) -> jax.Array:
    """q [B,Hq,d], compressed K [B,Hkv,T,·] -> scores [B,Hq,T] fp32."""
    B, Hkv, T, k = values.shape
    d = q.shape[-1]
    qg, G = _group_q(q, Hkv)
    v2, b2 = values.reshape(B * Hkv, T, k), bitmap.reshape(B * Hkv, T, -1)
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        s = sparse_decode.sparse_qk(qg, v2, b2, scale=scale,
                                    interpret=not _on_tpu(),
                                    tile_t=min(T, sparse_decode.TILE_T))
    else:
        s = ref.sparse_qk_ref(qg, v2, b2, d, scale)
    return s.reshape(B, Hkv * G, T)


def sparse_av(p: jax.Array, values: jax.Array, bitmap: jax.Array, *, d: int,
              use_pallas: Optional[bool] = None) -> jax.Array:
    """p [B,Hq,T], compressed V [B,Hkv,T,·] -> out [B,Hq,d] fp32."""
    B, Hkv, T, k = values.shape
    Hq = p.shape[1]
    G = Hq // Hkv
    pg = p.reshape(B * Hkv, G, T)
    v2, b2 = values.reshape(B * Hkv, T, k), bitmap.reshape(B * Hkv, T, -1)
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        o = sparse_decode.sparse_av(pg, v2, b2, d=d, interpret=not _on_tpu(),
                                    tile_t=min(T, sparse_decode.TILE_T))
    else:
        o = ref.sparse_av_ref(pg, v2, b2, d)
    return o.reshape(B, Hq, d)


def decode_attention_fused(q: jax.Array,
                           ck_values: jax.Array, ck_bitmap: jax.Array,
                           cv_values: jax.Array, cv_bitmap: jax.Array,
                           n_valid: jax.Array, *, scale: Optional[float] = None,
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None,
                           use_pallas: Optional[bool] = None,
                           return_state: bool = False):
    """Fused single-pass decode attention over the compressed cache.

    q [B,Hq,d]; caches [B,Hkv,T,·]; n_valid [B] -> out [B,Hq,d] fp32.

    On TPU this runs the DMA-skipping scalar-prefetch kernel: per-row
    ``n_valid`` bounds the tiles fetched from HBM, so ragged rows pay bytes
    proportional to their own compressed depth. ``return_state=True`` also
    returns ``(acc, m, l)`` [B,Hq,d]/[B,Hq,1]/[B,Hq,1] — the unnormalised
    online-softmax state — so callers can merge further operands (the dense
    local window) into the same running softmax before normalising.

    ``k_scale``/``v_scale`` [B,Hkv,T//qt,1] fp32 mark int8 caches: the
    Pallas kernel dequantizes in-register after the (int8-width) HBM read;
    the jnp path dequantizes eagerly and runs the same reference.
    """
    B, Hkv, T, kk = ck_values.shape
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    qg, G = _group_q(q, Hkv)
    nv = jnp.repeat(n_valid.astype(jnp.int32), Hkv)
    quant = k_scale is not None
    ks = vs = None
    if quant:
        ks = k_scale.reshape(B * Hkv, -1, 1)
        vs = v_scale.reshape(B * Hkv, -1, 1)
    args = (qg,
            ck_values.reshape(B * Hkv, T, kk), ck_bitmap.reshape(B * Hkv, T, -1),
            cv_values.reshape(B * Hkv, T, -1), cv_bitmap.reshape(B * Hkv, T, -1))
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        tile_t = min(T, sparse_decode.TILE_T) if not quant else \
            _auto_tile_q(T, sparse_decode.TILE_T, T // ks.shape[1])
        res = sparse_decode.decode_attention_fused(
            *args, nv, d=d, scale=scale, k_scale=ks, v_scale=vs,
            interpret=not _on_tpu(), tile_t=tile_t, return_state=return_state)
    else:
        if quant:
            qg_, ckv, ckb, cvv, cvb = args
            args = (qg_, dequantize_fixedk(ckv, ks), ckb,
                    dequantize_fixedk(cvv, vs), cvb)
        if return_state:
            res = ref.decode_attention_fused_state_ref(*args, nv, d, scale)
        else:
            res = ref.decode_attention_fused_ref(*args, nv, d, scale)
    if return_state:
        o, acc, m, l = res
        return (o.reshape(B, Hkv * G, d), acc.reshape(B, Hkv * G, d),
                m.reshape(B, Hkv * G, 1), l.reshape(B, Hkv * G, 1))
    return res.reshape(B, Hkv * G, d)


def decode_attention_fused_paged(q: jax.Array,
                                 ck_pool: jax.Array, ck_bitmap: jax.Array,
                                 cv_pool: jax.Array, cv_bitmap: jax.Array,
                                 block_table: jax.Array, n_valid: jax.Array,
                                 *, scale: Optional[float] = None,
                                 k_scale: Optional[jax.Array] = None,
                                 v_scale: Optional[jax.Array] = None,
                                 use_pallas: Optional[bool] = None,
                                 return_state: bool = False):
    """Fused decode attention over PAGED compressed pools.

    q [B,Hq,d]; pools [n_phys,Hkv,page_tokens,·]; block_table [B,max_pages]
    int32; n_valid [B] -> out [B,Hq,d] fp32 (+ raw (acc, m, l) state with
    ``return_state=True``).

    On TPU the Pallas kernel translates tile→page in the scalar-prefetch
    index maps (block-table rows live in SMEM beside ``n_valid``), keeping
    per-row DMA proportional to each slot's own compressed depth. Off-TPU
    (and inside traced pjit graphs) the pools are gathered into the
    contiguous layout and the jnp oracle runs — bit-identical numerics, so
    the CPU serving path needs no special casing.

    ``k_scale``/``v_scale`` [n_phys,Hkv,page_tokens//qt,1] fp32 mark int8
    pools: scales ride IN the page (same block table, one gather), values
    dequantize in-register on TPU / eagerly on the gathered view off-TPU.
    """
    B, Hq, d = q.shape
    n_phys, Hkv, page_tokens, kk = ck_pool.shape
    scale = scale if scale is not None else d ** -0.5
    qg, G = _group_q(q, Hkv)
    nv = jnp.repeat(n_valid.astype(jnp.int32), Hkv)
    quant = k_scale is not None
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        tile_t = _auto_tile(page_tokens, sparse_decode.TILE_T) if not quant \
            else _auto_tile_q(page_tokens, sparse_decode.TILE_T,
                              page_tokens // k_scale.shape[2])
        res = sparse_decode.decode_attention_fused_paged(
            qg, ck_pool, ck_bitmap, cv_pool, cv_bitmap,
            block_table, nv, d=d, scale=scale,
            k_scale=k_scale, v_scale=v_scale, interpret=not _on_tpu(),
            tile_t=tile_t, return_state=return_state)
    else:
        T = block_table.shape[1] * page_tokens
        args = tuple(
            gather_pages(pool, block_table).reshape(B * Hkv, T, -1)
            for pool in (ck_pool, ck_bitmap, cv_pool, cv_bitmap))
        if quant:
            # scale "token" axis counts TILES per page — gather_pages is
            # agnostic to the row unit, pagewise order matches the values
            ks = gather_pages(k_scale, block_table).reshape(B * Hkv, -1, 1)
            vs = gather_pages(v_scale, block_table).reshape(B * Hkv, -1, 1)
            args = (dequantize_fixedk(args[0], ks), args[1],
                    dequantize_fixedk(args[2], vs), args[3])
        if return_state:
            res = ref.decode_attention_fused_state_ref(qg, *args, nv, d, scale)
        else:
            res = ref.decode_attention_fused_ref(qg, *args, nv, d, scale)
    if return_state:
        o, acc, m, l = res
        return (o.reshape(B, Hkv * G, d), acc.reshape(B, Hkv * G, d),
                m.reshape(B, Hkv * G, 1), l.reshape(B, Hkv * G, 1))
    return res.reshape(B, Hkv * G, d)
