"""Pallas TPU kernels: bitmap-SpMV decode attention (paper §3 / Appendix C).

Load-as-compressed, compute-as-dense (FlashLLM/SpInfer paradigm, re-tiled
for TPU): each grid step DMAs one compressed tile — values ``[TILE_T, k]``
+ bitmap ``[TILE_T, d/32]`` — from HBM into VMEM (≈(2k+d/8)/2d of the dense
bytes), expands the bitmap with broadcasted shifts (VPU), reconstructs the
dense tile via the rank-match one-hot contraction (MXU), then runs the dense
tile product on the MXU.

Two kernels mirror the paper's Fig. 5a decomposition:
  * ``sparse_qk`` :  scores = q · K̂ᵀ      (grid: rows × token tiles)
  * ``sparse_av`` :  out    = α · V̂       (accumulated over token tiles)

plus ``decode_attention_fused`` — a beyond-paper flash-decoding-style fusion
(single pass, online softmax, no [BH,G,T] score round-trip through HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.sparse_format import pad_to_words

TILE_T = 128          # compressed tokens per grid step
NEG_INF = -1e30


def _decompress(vals, bm, d: int, k: int):
    """(values [T,k], bitmap [T,W] uint32) -> dense [T, d_pad] fp32 in VMEM."""
    T, W = bm.shape
    d_pad = W * 32
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    bits = ((bm[:, :, None] >> shifts) & jnp.uint32(1))            # [T, W, 32]
    bits = bits.reshape(T, d_pad).astype(jnp.float32)
    pos = jnp.cumsum(bits, axis=1) - 1.0                            # [T, d_pad]
    j = lax.broadcasted_iota(jnp.float32, (T, d_pad, k), 2)
    onehot = ((pos[:, :, None] == j) & (bits[:, :, None] > 0)).astype(jnp.float32)
    dense = jnp.einsum("tcj,tj->tc", onehot, vals.astype(jnp.float32),
                       preferred_element_type=jnp.float32)          # [T, d_pad]
    return dense


# ----------------------------------------------------------------------
# SpMV #1: scores = q · K̂ᵀ

def _qk_kernel(q_ref, vals_ref, bm_ref, out_ref, *, d, k, scale):
    q = q_ref[0].astype(jnp.float32)                     # [G, d]
    dense = _decompress(vals_ref[0], bm_ref[0], d, k)    # [T, d_pad]
    s = jax.lax.dot_general(q, dense[:, :d], (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    out_ref[0] = (s * scale).astype(out_ref.dtype)       # [G, T]


@functools.partial(jax.jit, static_argnames=("scale", "interpret", "tile_t"))
def sparse_qk(q: jax.Array, values: jax.Array, bitmap: jax.Array, *,
              scale: float, interpret: bool = False, tile_t: int = TILE_T):
    """q [BH, G, d]; values [BH, T, k]; bitmap [BH, T, W] -> scores [BH, G, T] fp32."""
    BH, G, d = q.shape
    _, T, k = values.shape
    W = bitmap.shape[-1]
    assert T % tile_t == 0, (T, tile_t)
    grid = (BH, T // tile_t)
    kernel = functools.partial(_qk_kernel, d=d, k=k, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, d), lambda b, t: (b, 0, 0)),
            pl.BlockSpec((1, tile_t, k), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, tile_t, W), lambda b, t: (b, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, tile_t), lambda b, t: (b, 0, t)),
        out_shape=jax.ShapeDtypeStruct((BH, G, T), jnp.float32),
        interpret=interpret,
    )(q, values, bitmap)


# ----------------------------------------------------------------------
# SpMV #2: out = α · V̂  (accumulate over token tiles)

def _av_kernel(p_ref, vals_ref, bm_ref, out_ref, *, d, k):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    p = p_ref[0].astype(jnp.float32)                     # [G, T]
    dense = _decompress(vals_ref[0], bm_ref[0], d, k)    # [T, d_pad]
    acc = jax.lax.dot_general(p, dense[:, :d], (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    out_ref[0] += acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "tile_t"))
def sparse_av(p: jax.Array, values: jax.Array, bitmap: jax.Array, *,
              interpret: bool = False, tile_t: int = TILE_T):
    """p [BH, G, T]; values [BH, T, k] -> out [BH, G, d_pad→sliced d] fp32."""
    BH, G, T = p.shape
    k = values.shape[-1]
    W = bitmap.shape[-1]
    d = W * 32  # padded width; caller slices to true d
    assert T % tile_t == 0, (T, tile_t)
    grid = (BH, T // tile_t)
    kernel = functools.partial(_av_kernel, d=d, k=k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, tile_t), lambda b, t: (b, 0, t)),
            pl.BlockSpec((1, tile_t, k), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, tile_t, W), lambda b, t: (b, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, d), lambda b, t: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, G, d), jnp.float32),
        interpret=interpret,
    )(p, values, bitmap)


# ----------------------------------------------------------------------
# Beyond-paper: fused single-pass decode attention (online softmax).
# Avoids materialising [BH, G, T] scores in HBM — the paper's two-kernel
# formulation pays 2·G·T fp32 of extra HBM traffic that this removes.

def _fused_kernel(q_ref, kv_ref, kb_ref, vv_ref, vb_ref, nv_ref,
                  out_ref, m_ref, l_ref, acc_ref, *, d, kk, kv, scale, tile_t):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Per-batch-row early-out: tiles entirely past THIS row's n_valid
    # contribute nothing, so skip the bitmap expansion + both MXU products.
    # Ragged continuous-batching rows differ in compressed depth, so short
    # rows skip most of the grid. Also fixes the n_valid == 0 edge (a fully
    # masked tile used to push exp(-inf - -inf) = 1 into l; skipped tiles
    # leave l = 0 and the finalize guard returns a zero vector).
    @pl.when(t * tile_t < nv_ref[0])
    def _tile():
        q = q_ref[0].astype(jnp.float32)                       # [G, d]
        k_dense = _decompress(kv_ref[0], kb_ref[0], d, kk)     # [T, d_pad]
        s = jax.lax.dot_general(q, k_dense[:, :d], (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale  # [G, T]
        # mask invalid tokens of the last (partially valid) tile
        token_idx = t * tile_t + lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(token_idx < nv_ref[0], s, NEG_INF)

        m_prev, l_prev = m_ref[0], l_ref[0]                    # [G, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)              # [G, 1]
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)                        # rescale factor
        p = jnp.exp(s - m_new)                                 # [G, T]
        v_dense = _decompress(vv_ref[0], vb_ref[0], d, kv)     # [T, d_pad]
        pv = jax.lax.dot_general(p, v_dense[:, :d], (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [G, d]
        acc_ref[0] = acc_ref[0] * alpha + pv
        l_ref[0] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[0] = m_new

    @pl.when(t == pl.num_programs(1) - 1)
    def _finalize():
        out_ref[0] = (acc_ref[0] / jnp.maximum(l_ref[0], 1e-30)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d", "scale", "interpret", "tile_t"))
def decode_attention_fused(q: jax.Array,
                           ck_values: jax.Array, ck_bitmap: jax.Array,
                           cv_values: jax.Array, cv_bitmap: jax.Array,
                           n_valid: jax.Array, *, d: int, scale: float,
                           interpret: bool = False, tile_t: int = TILE_T):
    """Fused compressed-cache decode attention.

    q [BH, G, d]; caches [BH, T, ·]; n_valid [BH] int32 -> out [BH, G, d] fp32.
    """
    BH, G, _ = q.shape
    T, kk = ck_values.shape[1:]
    kv = cv_values.shape[-1]
    W = ck_bitmap.shape[-1]
    d_pad = W * 32
    assert T % tile_t == 0, (T, tile_t)
    grid = (BH, T // tile_t)
    kernel = functools.partial(_fused_kernel, d=d, kk=kk, kv=kv,
                               scale=scale, tile_t=tile_t)
    from jax.experimental.pallas import tpu as pltpu
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, d), lambda b, t: (b, 0, 0)),
            pl.BlockSpec((1, tile_t, kk), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, tile_t, W), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, tile_t, kv), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, tile_t, W), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1,), lambda b, t: (b,)),
        ],
        out_specs=pl.BlockSpec((1, G, d), lambda b, t: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, G, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1, G, 1), jnp.float32),   # running max
            pltpu.VMEM((1, G, 1), jnp.float32),   # running sum
            pltpu.VMEM((1, G, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, ck_values, ck_bitmap, cv_values, cv_bitmap, n_valid)
    return out
