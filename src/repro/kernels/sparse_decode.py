"""Pallas TPU kernels: bitmap-SpMV decode attention (paper §3 / Appendix C).

Load-as-compressed, compute-as-dense (FlashLLM/SpInfer paradigm, re-tiled
for TPU): each grid step DMAs one compressed tile — values ``[TILE_T, k]``
+ bitmap ``[TILE_T, d/32]`` — from HBM into VMEM (≈(2k+d/8)/2d of the dense
bytes), expands the bitmap with broadcasted shifts (VPU), reconstructs the
dense tile via a rank→gather (``take_along_axis``) in O(TILE_T·d_pad) VPU
work, then runs the dense tile product on the MXU.

Cost model per tile (post PR-2 overhaul):
  * decompress: O(T·d_pad) VPU ops (bit expand + cumsum + gather + select)
    and one [T, d_pad] VMEM intermediate in the CACHE dtype — the previous
    one-hot formulation paid an O(T·d_pad·k) MXU contraction plus a
    k-times-larger fp32 ``[T, d_pad, k]`` one-hot in VMEM.
  * products: bf16 caches stay bf16 into the MXU (fp32 accumulation only),
    so compressed-value HBM reads and the VMEM dense tile are half the old
    fp32-upcast cost.

Two kernels mirror the paper's Fig. 5a decomposition:
  * ``sparse_qk`` :  scores = q · K̂ᵀ      (grid: rows × token tiles)
  * ``sparse_av`` :  out    = α · V̂       (accumulated over token tiles)

plus ``decode_attention_fused`` — a beyond-paper flash-decoding-style fusion
(single pass, online softmax, no [BH,G,T] score round-trip through HBM) on a
scalar-prefetch grid: ``n_valid`` is prefetched into SMEM and the BlockSpec
index maps clamp each row's tile index to its own compressed depth, so tiles
past a ragged row's fill are never DMA'd from HBM at all (PR 1's per-row
early-out skipped the FLOPs but still paid the DMA — the dominant cost in a
memory-bound kernel).

``decode_attention_fused_paged`` extends the fused kernel to PAGED pools
(``serving.cache`` block-table indirection): the per-slot block-table rows
join ``n_valid`` in SMEM and the tile→page translation happens in the same
index maps, after the ragged clamp — so the DMA-skipping property holds per
page and the gather view is never materialised on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.sparse_format import pad_to_words

TILE_T = 128          # compressed tokens per grid step
NEG_INF = -1e30


def _decompress(vals, bm, d: int, k: int):
    """(values [T,k], bitmap [T,W] uint32) -> dense [T, d_pad] in vals.dtype.

    Gather expansion: ``pos = cumsum(bits) - 1`` ranks each set channel into
    its packed slot; ``take_along_axis`` pulls ``vals[t, pos[t,c]]`` and the
    bit mask zeroes unset channels. O(T·d_pad) VPU work, no MXU contraction,
    and the only VMEM intermediate is [T, d_pad] in the cache dtype (bf16
    caches are never upcast — fp32 enters only at the MXU accumulators).
    """
    T, W = bm.shape
    d_pad = W * 32
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    bits = ((bm[:, :, None] >> shifts) & jnp.uint32(1))            # [T, W, 32]
    bits = bits.reshape(T, d_pad).astype(jnp.int32)
    pos = jnp.cumsum(bits, axis=1) - 1                              # [T, d_pad]
    pos = jnp.clip(pos, 0, k - 1)
    gathered = jnp.take_along_axis(vals, pos, axis=1)               # [T, d_pad]
    return jnp.where(bits > 0, gathered, jnp.zeros((), vals.dtype))


def _dot_compressed(a, b, dims):
    """MXU product in the common operand dtype, fp32 accumulation.

    bf16 × bf16 runs the MXU at native width; mixed operands promote (fp32
    query against a bf16 cache keeps fp32).
    """
    ct = jnp.promote_types(a.dtype, b.dtype)
    return jax.lax.dot_general(a.astype(ct), b.astype(ct), dims,
                               preferred_element_type=jnp.float32)


# ----------------------------------------------------------------------
# SpMV #1: scores = q · K̂ᵀ

def _qk_kernel(q_ref, vals_ref, bm_ref, out_ref, *, d, k, scale):
    q = q_ref[0]                                         # [G, d]
    dense = _decompress(vals_ref[0], bm_ref[0], d, k)    # [T, d_pad]
    s = _dot_compressed(q, dense[:, :d], (((1,), (1,)), ((), ())))
    out_ref[0] = (s * scale).astype(out_ref.dtype)       # [G, T]


@functools.partial(jax.jit, static_argnames=("scale", "interpret", "tile_t"))
def sparse_qk(q: jax.Array, values: jax.Array, bitmap: jax.Array, *,
              scale: float, interpret: bool = False, tile_t: int = TILE_T):
    """q [BH, G, d]; values [BH, T, k]; bitmap [BH, T, W] -> scores [BH, G, T] fp32."""
    BH, G, d = q.shape
    _, T, k = values.shape
    W = bitmap.shape[-1]
    assert T % tile_t == 0, (T, tile_t)
    grid = (BH, T // tile_t)
    kernel = functools.partial(_qk_kernel, d=d, k=k, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, d), lambda b, t: (b, 0, 0)),
            pl.BlockSpec((1, tile_t, k), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, tile_t, W), lambda b, t: (b, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, tile_t), lambda b, t: (b, 0, t)),
        out_shape=jax.ShapeDtypeStruct((BH, G, T), jnp.float32),
        interpret=interpret,
    )(q, values, bitmap)


# ----------------------------------------------------------------------
# SpMV #2: out = α · V̂  (accumulate over token tiles)

def _av_kernel(p_ref, vals_ref, bm_ref, out_ref, *, d, k):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    p = p_ref[0]                                         # [G, T]
    dense = _decompress(vals_ref[0], bm_ref[0], d, k)    # [T, d_pad]
    acc = _dot_compressed(p, dense[:, :d], (((1,), (0,)), ((), ())))
    out_ref[0] += acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d", "interpret", "tile_t"))
def sparse_av(p: jax.Array, values: jax.Array, bitmap: jax.Array, *, d: int,
              interpret: bool = False, tile_t: int = TILE_T):
    """p [BH, G, T]; values [BH, T, k] -> out [BH, G, d] fp32 (true d — the
    bitmap-word padding is dropped inside, callers never see d_pad)."""
    BH, G, T = p.shape
    k = values.shape[-1]
    W = bitmap.shape[-1]
    assert d <= W * 32, (d, W * 32)
    assert T % tile_t == 0, (T, tile_t)
    grid = (BH, T // tile_t)
    kernel = functools.partial(_av_kernel, d=d, k=k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, G, tile_t), lambda b, t: (b, 0, t)),
            pl.BlockSpec((1, tile_t, k), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, tile_t, W), lambda b, t: (b, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, d), lambda b, t: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, G, d), jnp.float32),
        interpret=interpret,
    )(p, values, bitmap)


# ----------------------------------------------------------------------
# Beyond-paper: fused single-pass decode attention (online softmax).
# Avoids materialising [BH, G, T] scores in HBM — the paper's two-kernel
# formulation pays 2·G·T fp32 of extra HBM traffic that this removes.

def _dequant_rows(vals, scale_col, qt: int):
    """vals [T, k] int8, scale_col [T//qt, 1] fp32 -> fp32 [T, k].

    In-register dequantization of a packed tile: row r's scale is
    ``scale_col[r // qt]`` (one symmetric absmax scale per qt-token quant
    block). Runs on the already-resident VMEM tile right before the MXU
    product — int8 pools pay int8 HBM bytes, never a widened pool."""
    T = vals.shape[0]
    rows = lax.broadcasted_iota(jnp.int32, (T, 1), 0) // qt
    return vals.astype(jnp.float32) * \
        jnp.take_along_axis(scale_col, rows, axis=0)


def _fused_kernel(nv_ref, q_ref, kv_ref, kb_ref, vv_ref, vb_ref,
                  *refs, d, kk, kv, scale, tile_t, qt=None):
    # refs: (acc, m, l) outputs, preceded by (ks, vs) scale inputs when
    # quantized (qt = quant-block tokens, None for bf16 pools)
    if qt is None:
        ks_ref = vs_ref = None
        acc_ref, m_ref, l_ref = refs
    else:
        ks_ref, vs_ref, acc_ref, m_ref, l_ref = refs
    b = pl.program_id(0)
    t = pl.program_id(1)
    nv = nv_ref[b]

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Per-batch-row gating: tiles entirely past THIS row's n_valid contribute
    # nothing. The BlockSpec index maps already clamp those steps to re-fetch
    # the row's last valid tile (a no-op DMA — same block as the previous
    # step), so skipping here costs neither bytes nor FLOPs. Also fixes the
    # n_valid == 0 edge (a fully masked tile used to push
    # exp(-inf - -inf) = 1 into l; skipped tiles leave l = 0 and the caller's
    # finalize guard returns a zero vector).
    @pl.when(t * tile_t < nv)
    def _tile():
        q = q_ref[0]                                           # [G, d]
        kvals, vvals = kv_ref[0], vv_ref[0]                    # [T, k]
        if qt is not None:
            kvals = _dequant_rows(kvals, ks_ref[0], qt)
            vvals = _dequant_rows(vvals, vs_ref[0], qt)
        k_dense = _decompress(kvals, kb_ref[0], d, kk)         # [T, d_pad]
        s = _dot_compressed(q, k_dense[:, :d],
                            (((1,), (1,)), ((), ()))) * scale  # [G, T]
        # mask invalid tokens of the last (partially valid) tile
        token_idx = t * tile_t + lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(token_idx < nv, s, NEG_INF)

        m_prev, l_prev = m_ref[0], l_ref[0]                    # [G, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)              # [G, 1]
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)                        # rescale factor
        p = jnp.exp(s - m_new)                                 # [G, T]
        v_dense = _decompress(vvals, vb_ref[0], d, kv)         # [T, d_pad]
        pv = _dot_compressed(p, v_dense[:, :d],
                             (((1,), (0,)), ((), ())))         # [G, d]
        acc_ref[0] = acc_ref[0] * alpha + pv.astype(acc_ref.dtype)
        l_ref[0] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[0] = m_new


@functools.partial(jax.jit,
                   static_argnames=("d", "scale", "interpret", "tile_t",
                                    "return_state"))
def decode_attention_fused(q: jax.Array,
                           ck_values: jax.Array, ck_bitmap: jax.Array,
                           cv_values: jax.Array, cv_bitmap: jax.Array,
                           n_valid: jax.Array, *, d: int, scale: float,
                           k_scale: jax.Array | None = None,
                           v_scale: jax.Array | None = None,
                           interpret: bool = False, tile_t: int = TILE_T,
                           return_state: bool = False):
    """Fused compressed-cache decode attention on a scalar-prefetch grid.

    q [BH, G, d]; caches [BH, T, ·]; n_valid [BH] int32 -> out [BH, G, d] fp32.

    ``n_valid`` is prefetched into SMEM (``PrefetchScalarGridSpec``) and the
    compressed-tile index maps clamp grid step ``t`` to row ``b``'s last
    valid tile: once a ragged row's depth is exhausted, every remaining step
    maps to the block already resident in VMEM, so the pipeline issues NO new
    HBM DMA for it. A short row in a deep batch therefore pays bytes
    proportional to ITS depth, not the pool capacity.

    ``return_state=True`` additionally returns the raw online-softmax state
    ``(acc [BH,G,d] unnormalised, m [BH,G,1], l [BH,G,1])`` so a caller can
    continue the running softmax over extra operands (the dense local
    window) before normalising.

    ``k_scale``/``v_scale`` [BH, T//qt, 1] fp32 switch on int8 pools:
    values are dequantized in-register (``_dequant_rows``) right before the
    MXU products, so HBM reads stay at int8 width. ``tile_t`` must be a
    multiple of the quant block ``qt = T // k_scale.shape[1]``.
    """
    BH, G, _ = q.shape
    T, kk = ck_values.shape[1:]
    kv = cv_values.shape[-1]
    W = ck_bitmap.shape[-1]
    assert T % tile_t == 0, (T, tile_t)
    quant = k_scale is not None
    assert quant == (v_scale is not None), "pass both scale planes or neither"
    qt = None
    if quant:
        qt = T // k_scale.shape[1]
        assert k_scale.shape == v_scale.shape == (BH, T // qt, 1), \
            (k_scale.shape, v_scale.shape, BH, T, qt)
        assert tile_t % qt == 0, (tile_t, qt)
    grid = (BH, T // tile_t)
    kernel = functools.partial(_fused_kernel, d=d, kk=kk, kv=kv,
                               scale=scale, tile_t=tile_t, qt=qt)

    def tile_idx(b, t, nv_ref):
        # clamp to the row's last valid tile: steps past the row's depth
        # re-map to the resident block => the pipeline skips their DMA
        last = jnp.maximum((nv_ref[b] + tile_t - 1) // tile_t - 1, 0)
        return (b, jnp.minimum(t, last), 0)

    in_specs = [
        pl.BlockSpec((1, G, d), lambda b, t, nv: (b, 0, 0)),
        pl.BlockSpec((1, tile_t, kk), tile_idx),
        pl.BlockSpec((1, tile_t, W), tile_idx),
        pl.BlockSpec((1, tile_t, kv), tile_idx),
        pl.BlockSpec((1, tile_t, W), tile_idx),
    ]
    operands = [n_valid.astype(jnp.int32), q,
                ck_values, ck_bitmap, cv_values, cv_bitmap]
    if quant:
        # scale planes tile with the values: block index t covers scale
        # rows [t·tile_t/qt, (t+1)·tile_t/qt) — same index map, smaller rows
        in_specs += [pl.BlockSpec((1, tile_t // qt, 1), tile_idx)] * 2
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, G, d), lambda b, t, nv: (b, 0, 0)),
            pl.BlockSpec((1, G, 1), lambda b, t, nv: (b, 0, 0)),
            pl.BlockSpec((1, G, 1), lambda b, t, nv: (b, 0, 0)),
        ],
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BH, G, d), jnp.float32),   # unnormalised acc
            jax.ShapeDtypeStruct((BH, G, 1), jnp.float32),   # running max
            jax.ShapeDtypeStruct((BH, G, 1), jnp.float32),   # running sum
        ],
        interpret=interpret,
    )(*operands)
    out = acc / jnp.maximum(l, 1e-30)
    if return_state:
        return out, acc, m, l
    return out


# ----------------------------------------------------------------------
# Paged variant: same fused online-softmax decode, but the compressed
# operands live in a global page pool [n_phys, Hkv, page_tokens, ·] indexed
# through a per-slot block table. The tile→page translation happens in the
# BlockSpec index maps on the scalar-prefetch grid — the block-table rows
# sit in SMEM next to n_valid — so the DMA-skipping property survives
# paging: a clamped (past-depth) step translates to the same physical page
# block as the previous step and the pipeline issues no new HBM DMA.

def _fused_paged_kernel(nv_ref, bt_ref, q_ref, kv_ref, kb_ref, vv_ref, vb_ref,
                        *refs, d, kk, kv, scale, tile_t, qt=None):
    # refs: (acc, m, l) outputs, preceded by (ks, vs) scale inputs when the
    # pools are int8-quantized (qt = quant-block tokens, None for bf16)
    if qt is None:
        ks_ref = vs_ref = None
        acc_ref, m_ref, l_ref = refs
    else:
        ks_ref, vs_ref, acc_ref, m_ref, l_ref = refs
    b = pl.program_id(0)
    t = pl.program_id(1)
    nv = nv_ref[b]

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # identical math to _fused_kernel; only the residency of the compressed
    # tile differs (one page's sub-tile instead of a contiguous-pool tile)
    @pl.when(t * tile_t < nv)
    def _tile():
        q = q_ref[0]                                           # [G, d]
        kvals, vvals = kv_ref[0, 0], vv_ref[0, 0]              # [T, k]
        if qt is not None:
            kvals = _dequant_rows(kvals, ks_ref[0, 0], qt)
            vvals = _dequant_rows(vvals, vs_ref[0, 0], qt)
        k_dense = _decompress(kvals, kb_ref[0, 0], d, kk)
        s = _dot_compressed(q, k_dense[:, :d],
                            (((1,), (1,)), ((), ()))) * scale  # [G, T]
        token_idx = t * tile_t + lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(token_idx < nv, s, NEG_INF)

        m_prev, l_prev = m_ref[0], l_ref[0]                    # [G, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        v_dense = _decompress(vvals, vb_ref[0, 0], d, kv)
        pv = _dot_compressed(p, v_dense[:, :d], (((1,), (0,)), ((), ())))
        acc_ref[0] = acc_ref[0] * alpha + pv.astype(acc_ref.dtype)
        l_ref[0] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[0] = m_new


@functools.partial(jax.jit,
                   static_argnames=("d", "scale", "interpret", "tile_t",
                                    "return_state"))
def decode_attention_fused_paged(q: jax.Array,
                                 ck_pool: jax.Array, ck_bitmap: jax.Array,
                                 cv_pool: jax.Array, cv_bitmap: jax.Array,
                                 block_table: jax.Array, n_valid: jax.Array,
                                 *, d: int, scale: float,
                                 k_scale: jax.Array | None = None,
                                 v_scale: jax.Array | None = None,
                                 interpret: bool = False,
                                 tile_t: int = TILE_T,
                                 return_state: bool = False):
    """Fused decode attention over PAGED compressed pools.

    q [BH, G, d] (BH = B·Hkv, batch-major); pools [n_phys, Hkv, page_tokens,
    ·]; block_table [B, max_pages] int32 (-1 unmapped); n_valid [BH] int32.
    Returns out [BH, G, d] fp32 (plus raw (acc, m, l) state with
    ``return_state=True`` — same contract as ``decode_attention_fused``).

    ``tile_t`` must divide ``page_tokens`` so a kernel tile never straddles
    a page. Index maps clamp step t to the row's last valid tile exactly as
    the contiguous kernel does, THEN translate tile→(physical page, in-page
    tile) through the prefetched block table; unmapped / garbage entries
    clamp into range and their compute is skipped by the same per-row
    ``n_valid`` guard, so they cost one harmless resident-block fetch at
    most. Numerics are bit-identical to ``decode_attention_fused`` on the
    equivalent contiguous pool (asserted in tests/test_paged_equivalence).

    ALIASED ROWS ARE LEGAL: under prefix sharing several block-table rows
    may map the SAME physical page (refcounted copy-on-write in
    ``serving.cache``/``serving.engine``). This kernel only ever READS
    through the table — the index maps translate addresses, nothing writes
    the pools — so aliasing cannot race; two rows mapping one page simply
    fetch identical tiles (and consecutive grid steps on the same physical
    block skip the DMA as usual). The write-side invariant (no compaction
    may target a refcount>1 page) is the scheduler's to uphold —
    ``validate_block_table`` below is the checkable statement of both
    halves, asserted by the fuzz harness."""
    BH, G, _ = q.shape
    n_phys, Hkv, page_tokens, kk = ck_pool.shape
    kv = cv_pool.shape[-1]
    W = ck_bitmap.shape[-1]
    max_pages = block_table.shape[1]
    T = max_pages * page_tokens
    assert page_tokens % tile_t == 0, (page_tokens, tile_t)
    assert BH == block_table.shape[0] * Hkv, (BH, block_table.shape, Hkv)
    quant = k_scale is not None
    assert quant == (v_scale is not None), "pass both scale planes or neither"
    qt = None
    if quant:
        # scale pools [n_phys, Hkv, page_tokens // qt, 1] ride in the page
        qt = page_tokens // k_scale.shape[2]
        assert k_scale.shape == v_scale.shape == \
            (n_phys, Hkv, page_tokens // qt, 1), \
            (k_scale.shape, v_scale.shape, n_phys, Hkv, page_tokens, qt)
        assert tile_t % qt == 0, (tile_t, qt)
    grid = (BH, T // tile_t)
    kernel = functools.partial(_fused_paged_kernel, d=d, kk=kk, kv=kv,
                               scale=scale, tile_t=tile_t, qt=qt)

    def page_idx(b, t, nv_ref, bt_ref):
        # clamp to the row's last valid tile (DMA-skip), then translate the
        # logical token offset through the slot's block-table row
        last = jnp.maximum((nv_ref[b] + tile_t - 1) // tile_t - 1, 0)
        tok = jnp.minimum(t, last) * tile_t
        phys = bt_ref[b // Hkv, tok // page_tokens]
        phys = jnp.clip(phys, 0, n_phys - 1)
        return (phys, b % Hkv, (tok % page_tokens) // tile_t, 0)

    in_specs = [
        pl.BlockSpec((1, G, d), lambda b, t, nv, bt: (b, 0, 0)),
        pl.BlockSpec((1, 1, tile_t, kk), page_idx),
        pl.BlockSpec((1, 1, tile_t, W), page_idx),
        pl.BlockSpec((1, 1, tile_t, kv), page_idx),
        pl.BlockSpec((1, 1, tile_t, W), page_idx),
    ]
    operands = [n_valid.astype(jnp.int32), block_table.astype(jnp.int32),
                q, ck_pool, ck_bitmap, cv_pool, cv_bitmap]
    if quant:
        # the scale rows count TILES not tokens, but page_idx already
        # returns BLOCK indices — identical arithmetic for the smaller rows
        in_specs += [pl.BlockSpec((1, 1, tile_t // qt, 1), page_idx)] * 2
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, G, d), lambda b, t, nv, bt: (b, 0, 0)),
            pl.BlockSpec((1, G, 1), lambda b, t, nv, bt: (b, 0, 0)),
            pl.BlockSpec((1, G, 1), lambda b, t, nv, bt: (b, 0, 0)),
        ],
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BH, G, d), jnp.float32),
            jax.ShapeDtypeStruct((BH, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((BH, G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
    out = acc / jnp.maximum(l, 1e-30)
    if return_state:
        return out, acc, m, l
    return out


# ----------------------------------------------------------------------
# paged-operand invariant checks (host-side: Scheduler._provision_pages
# asserts the full read+write contract before every decode under
# debug_invariants; the scheduler fuzz harness re-checks the read side
# after every step)

def validate_block_table(block_table, n_phys: int, *,
                         page_tokens: int = 0,
                         n_compressed=None,
                         refcounts=None,
                         will_compact=None) -> None:
    """Assert the invariants the paged decode/compaction kernels stand on.

    READ side (always checked): every mapped entry must be a real physical
    page (``0 <= p < n_phys``, the scratch page excluded — decode must never
    read it), and with ``n_compressed``/``page_tokens`` given, every row
    must map all logical pages its valid depth covers. Aliasing between
    rows is LEGAL here — the kernels only read (see
    ``decode_attention_fused_paged``).

    WRITE side (checked when ``refcounts`` and ``will_compact`` are given —
    the scheduler's host mirrors): a row about to compact targets logical
    page ``n_compressed[b] // page_tokens``; that page must be mapped and
    its refcount must be exactly 1 — a shared (refcount > 1) page is
    immutable and must have been copied-on-write BEFORE the decode step
    fires. This is the machine-checkable form of "no write ever lands in a
    shared page".
    """
    import numpy as np

    bt = np.asarray(block_table)
    mapped = bt >= 0
    assert (bt[mapped] < n_phys - 1).all(), \
        f"block table maps past the last real page (n_phys={n_phys}): " \
        f"{bt[mapped][bt[mapped] >= n_phys - 1]}"
    if n_compressed is not None and page_tokens:
        nc = np.asarray(n_compressed)
        for b in range(bt.shape[0]):
            need = -(-int(nc[b]) // page_tokens)
            row = bt[b, :need]
            assert (row >= 0).all(), \
                f"row {b}: depth {int(nc[b])} needs {need} mapped pages, " \
                f"got {row}"
    if refcounts is not None and will_compact is not None:
        assert n_compressed is not None and page_tokens, \
            "write-side check needs n_compressed and page_tokens " \
            "(the compaction target is n_compressed[b] // page_tokens)"
        nc = np.asarray(n_compressed)
        rc = list(refcounts)
        for b, compacting in enumerate(will_compact):
            if not compacting:
                continue
            lp = int(nc[b]) // page_tokens
            tgt = int(bt[b, lp])
            assert tgt >= 0, f"row {b}: compaction target page unmapped"
            assert rc[tgt] == 1, \
                f"row {b}: compaction would write physical page {tgt} " \
                f"with refcount {rc[tgt]} (copy-on-write missed)"
