"""Pallas TPU kernel: causal flash attention for the prefill phase.

The paper keeps prefill dense and FlashAttention-compatible (§3). This is the
TPU flash kernel used by the serving engine's prefill step (inference-only;
the differentiable training path uses the XLA formulation with remat).

GQA is handled in the BlockSpec index map (kv block row = q_head // G) — no
materialised head expansion. Causal blocks above the diagonal are skipped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
BLOCK_Q = 256
BLOCK_K = 256


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale, block_q, block_k):
    qi, ki = pl.program_id(2), pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: process only blocks with k_start <= q_end
    @pl.when(ki * block_k <= qi * block_q + block_q - 1)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)               # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)               # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_idx = qi * block_q + lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_idx = ki * block_k + lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(q_idx >= k_idx, s, NEG_INF)

        m_prev, l_prev = m_ref[0, 0], l_ref[0, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        acc_ref[0, 0] = acc_ref[0, 0] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        l_ref[0, 0] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[0, 0] = m_new

    @pl.when(ki == pl.num_programs(3) - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[0, 0] /
                       jnp.maximum(l_ref[0, 0], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("scale", "interpret", "block_q", "block_k"))
def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  scale: float, interpret: bool = False,
                  block_q: int = BLOCK_Q, block_k: int = BLOCK_K) -> jax.Array:
    """Causal attention. q [B,Hq,T,d]; k,v [B,Hkv,T,d] -> [B,Hq,T,d]."""
    B, Hq, T, d = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    block_q = min(block_q, T)
    block_k = min(block_k, T)
    assert T % block_q == 0 and T % block_k == 0
    grid = (B, Hq, T // block_q, T // block_k)
    kernel = functools.partial(_flash_kernel, scale=scale,
                               block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, T, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1, block_q, 1), jnp.float32),
            pltpu.VMEM((1, 1, block_q, 1), jnp.float32),
            pltpu.VMEM((1, 1, block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
