"""Interpret-mode kernel smoke: compress → fused-decode round-trip.

A fast (< 1 min, CPU) canary for the Pallas kernel stack, run as its own CI
job so kernel regressions fail before the full tier-1 matrix:

    PYTHONPATH=src python -m repro.kernels.smoke

Tiny config: d=64, k=24 (s=0.625), T=64, ragged n_valid covering the empty /
partial-tile / full edges. Asserts the Pallas kernels (interpret=True)
against the jnp oracles, including the scalar-prefetch fused kernel's state
outputs and a tile_t=64 compress.
"""
from __future__ import annotations

import sys

import jax.numpy as jnp
import numpy as np


def main() -> int:
    from repro.kernels import ref
    from repro.kernels.bitmap_compress import mustafar_compress
    from repro.kernels.sparse_decode import decode_attention_fused

    rng = np.random.default_rng(0)
    BH, G, T, d, k, tile_t = 3, 2, 64, 64, 24, 16
    kx = jnp.asarray(rng.normal(size=(BH, T, d)).astype(np.float32))
    vx = jnp.asarray(rng.normal(size=(BH, T, d)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(BH, G, d)).astype(np.float32))

    # compress (threshold top-k + gather compaction, tile_t = 64)
    kv_, kb_ = mustafar_compress(kx, k, interpret=True, tile_t=64)
    vv_, vb_ = mustafar_compress(vx, k, interpret=True, tile_t=64)
    kv_r, kb_r = ref.mustafar_compress_ref(kx, k)
    vv_r, vb_r = ref.mustafar_compress_ref(vx, k)
    np.testing.assert_array_equal(np.asarray(kb_), np.asarray(kb_r))
    np.testing.assert_array_equal(np.asarray(kv_), np.asarray(kv_r))
    np.testing.assert_array_equal(np.asarray(vb_), np.asarray(vb_r))
    np.testing.assert_array_equal(np.asarray(vv_), np.asarray(vv_r))

    # fused decode over the round-tripped pools, ragged rows incl. empty
    n_valid = jnp.asarray([T, tile_t + 1, 0], jnp.int32)
    out, acc, m, l = decode_attention_fused(
        q, kv_, kb_, vv_, vb_, n_valid, d=d, scale=d ** -0.5,
        interpret=True, tile_t=tile_t, return_state=True)
    o_ref, acc_ref, m_ref, l_ref = ref.decode_attention_fused_state_ref(
        q, kv_r, kb_r, vv_r, vb_r, n_valid, d, scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(l), np.asarray(l_ref),
                               rtol=1e-4, atol=1e-4)
    assert np.all(np.asarray(out)[2] == 0.0), "empty row must be zeros"

    # paged decode: scatter the same pools into shuffled pages and run the
    # block-table-translating kernel — must be BIT-exact vs the contiguous
    # kernel (same tile math, only the residency differs)
    from repro.kernels.sparse_decode import decode_attention_fused_paged

    pt = 2 * tile_t                       # page_tokens = 32, 2 tiles/page
    MP = T // pt
    Hkv = 1                               # BH rows act as B slots here
    n_phys = BH * MP + 1                  # + write-discard scratch page
    perm = rng.permutation(BH * MP)
    bt = np.full((BH, MP), -1, np.int32)
    paged = []
    for arr in (kv_, kb_, vv_, vb_):
        a = np.asarray(arr)
        pool = np.zeros((n_phys, Hkv, pt, a.shape[-1]), a.dtype)
        for b in range(BH):
            for lp in range(MP):
                bt[b, lp] = perm[b * MP + lp]
                pool[bt[b, lp], 0] = a[b, lp * pt:(lp + 1) * pt]
        paged.append(jnp.asarray(pool))
    out_p = decode_attention_fused_paged(
        q, *paged, jnp.asarray(bt), n_valid, d=d, scale=d ** -0.5,
        interpret=True, tile_t=tile_t)
    np.testing.assert_array_equal(
        np.asarray(out_p), np.asarray(acc / jnp.maximum(l, 1e-30)))

    # fused compress-scatter (the decode epilogue's compress-as-you-evict):
    # one dispatch compresses retiring window tiles AND lands them at their
    # destination page offsets through scalar-prefetched output index maps
    # over aliased pools — must match the two-dispatch formulation
    # (separate compress + scatter) bit-for-bit on non-scratch pages
    from repro.kernels.ops import compress_scatter

    B2, Hkv2, tt2 = 3, 2, 16
    n_phys2 = 4                            # pages 0..2 + scratch page 3
    kt = jnp.asarray(rng.normal(size=(B2, Hkv2, tt2, d)).astype(np.float32))
    vt = jnp.asarray(rng.normal(size=(B2, Hkv2, tt2, d)).astype(np.float32))
    nw = kb_.shape[-1]
    pools2 = tuple(
        jnp.asarray(rng.integers(0, 2 ** 31,
                                 size=(n_phys2, Hkv2, pt, c)), jnp.uint32)
        if bm else
        jnp.asarray(rng.normal(size=(n_phys2, Hkv2, pt, c)), jnp.bfloat16)
        for bm, c in ((False, k), (True, nw), (False, k), (True, nw)))
    phys2 = jnp.asarray([2, n_phys2 - 1, 0], jnp.int32)  # row 1 -> scratch
    off2 = jnp.asarray([tt2, 0, 0], jnp.int32)           # page-end fill
    got = compress_scatter(kt, vt, *pools2, phys2, off2, use_pallas=True)
    want = compress_scatter(kt, vt, *pools2, phys2, off2, use_pallas=False)
    for name, g, w in zip(("ck_vals", "ck_bm", "cv_vals", "cv_bm"),
                          got, want):
        np.testing.assert_array_equal(
            np.asarray(g.astype(jnp.float32))[:n_phys2 - 1],
            np.asarray(w.astype(jnp.float32))[:n_phys2 - 1],
            err_msg=f"compress-scatter {name} diverged")

    # int8 quantized pools (PR 10): the SAME dispatch also emits per-tile
    # symmetric absmax scales; bitmap unchanged, quantization matches the
    # jnp storage round-trip bit-for-bit, and the fused decode dequantizes
    # in-register to match a reference run over dequantized fp pools
    from repro.core.sparse_format import dequantize_fixedk, quantize_fixedk

    qt = 16
    kvq, kbq, ks = mustafar_compress(kx, k, interpret=True, tile_t=64,
                                     quant_tile=qt)
    vvq, vbq, vs = mustafar_compress(vx, k, interpret=True, tile_t=64,
                                     quant_tile=qt)
    np.testing.assert_array_equal(np.asarray(kbq), np.asarray(kb_r))
    kq_ref, ks_ref = quantize_fixedk(kv_r, qt)
    np.testing.assert_array_equal(np.asarray(kvq), np.asarray(kq_ref))
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(ks_ref))
    assert kvq.dtype == jnp.int8 and ks.dtype == jnp.float32

    out_q, acc_q, _, l_q = decode_attention_fused(
        q, kvq, kbq, vvq, vbq, n_valid, d=d, scale=d ** -0.5,
        k_scale=ks, v_scale=vs, interpret=True, tile_t=tile_t,
        return_state=True)
    o_qref, *_ = ref.decode_attention_fused_state_ref(
        q, dequantize_fixedk(kvq, ks), kb_r,
        dequantize_fixedk(vvq, vs), vb_r, n_valid, d, scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(out_q), np.asarray(o_qref),
                               rtol=1e-4, atol=1e-4)

    # paged quantized decode: scatter int8 pools + scale pools into the
    # same shuffled pages — bit-exact vs the contiguous quantized kernel
    paged_q = []
    for arr, rows in ((kvq, pt), (kbq, pt), (vvq, pt), (vbq, pt),
                      (ks, pt // qt), (vs, pt // qt)):
        a = np.asarray(arr)
        pool = np.zeros((n_phys, Hkv, rows, a.shape[-1]), a.dtype)
        for b in range(BH):
            for lp in range(MP):
                pool[bt[b, lp], 0] = a[b, lp * rows:(lp + 1) * rows]
        paged_q.append(jnp.asarray(pool))
    out_pq = decode_attention_fused_paged(
        q, *paged_q[:4], jnp.asarray(bt), n_valid, d=d, scale=d ** -0.5,
        k_scale=paged_q[4], v_scale=paged_q[5], interpret=True,
        tile_t=tile_t)
    np.testing.assert_array_equal(
        np.asarray(out_pq), np.asarray(acc_q / jnp.maximum(l_q, 1e-30)))

    # quantized compress-scatter parity (int8 pools + sibling scale pools)
    pools_q = tuple(
        jnp.asarray(rng.integers(0, 2 ** 31,
                                 size=(n_phys2, Hkv2, pt, c)), jnp.uint32)
        if bm else
        jnp.asarray(rng.integers(-127, 128,
                                 size=(n_phys2, Hkv2, pt, c)), jnp.int8)
        for bm, c in ((False, k), (True, nw), (False, k), (True, nw)))
    scales_q = tuple(
        jnp.asarray(rng.normal(size=(n_phys2, Hkv2, pt // tt2, 1)),
                    jnp.float32) for _ in range(2))
    got_q = compress_scatter(kt, vt, *pools_q, phys2, off2,
                             k_scale=scales_q[0], v_scale=scales_q[1],
                             use_pallas=True)
    want_q = compress_scatter(kt, vt, *pools_q, phys2, off2,
                              k_scale=scales_q[0], v_scale=scales_q[1],
                              use_pallas=False)
    assert len(got_q) == 6 and got_q[4].dtype == jnp.float32
    for name, g, w in zip(("ck_vals", "ck_bm", "cv_vals", "cv_bm",
                           "ck_scale", "cv_scale"), got_q, want_q):
        np.testing.assert_array_equal(
            np.asarray(g.astype(jnp.float32))[:n_phys2 - 1],
            np.asarray(w.astype(jnp.float32))[:n_phys2 - 1],
            err_msg=f"quantized compress-scatter {name} diverged")

    print("kernel smoke OK: compress -> fused decode round-trip matches "
          f"oracle (BH={BH}, T={T}, d={d}, k={k}, "
          f"n_valid={list(map(int, n_valid))}); paged decode bit-exact "
          f"(page_tokens={pt}, {BH * MP} pages shuffled); fused "
          f"compress-scatter bit-exact (B={B2}, scratch-masked row); "
          f"int8 pools (quant_tile={qt}) bit-match the jnp round-trip, "
          "contiguous+paged quantized decode and scatter parity OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
