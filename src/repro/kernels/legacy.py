"""Pre-overhaul kernel-body formulations, kept as equivalence oracles.

PR 2 replaced these hot-path formulations with arithmetic-efficient ones:

* ``decompress_onehot`` — the original bitmap expansion: rank-match one-hot
  contraction on the MXU. O(T·d_pad·k) FLOPs plus a ``[T, d_pad, k]`` fp32
  one-hot in VMEM. Superseded by the O(T·d_pad) gather expansion in
  ``sparse_decode._decompress``.
* ``topk_mask_rankcube`` — the original exact top-k: all-pairs rank count
  on the VPU. O(T·d²) compares and a ``[T, d_pad, d_pad]`` compare cube in
  VMEM (this is what pinned the compress kernel at TILE_T=8). Superseded by
  the O(T·d·32) binary-search threshold in ``bitmap_compress``.
* ``compact_onehot`` — the original value compaction: rank-match one-hot
  matmul, O(T·d_pad·k). Superseded by the O(T·k·log d) gather compaction.

They remain the ground truth the new kernels are asserted bit-identical
against (fp32) in tests/test_kernels.py, and the baselines bench_kernel.py
measures the overhaul's speedup over.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def decompress_onehot(vals: jnp.ndarray, bm: jnp.ndarray, k: int) -> jnp.ndarray:
    """(values [T,k], bitmap [T,W] uint32) -> dense [T, W*32] fp32.

    The pre-PR-2 ``_decompress``: expand bits, exclusive-cumsum ranks, then
    reconstruct via the ``[T, d_pad, k]`` one-hot einsum on the MXU.
    """
    T, W = bm.shape
    d_pad = W * 32
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    bits = ((bm[:, :, None] >> shifts) & jnp.uint32(1))            # [T, W, 32]
    bits = bits.reshape(T, d_pad).astype(jnp.float32)
    pos = jnp.cumsum(bits, axis=1) - 1.0                            # [T, d_pad]
    j = lax.broadcasted_iota(jnp.float32, (T, d_pad, k), 2)
    onehot = ((pos[:, :, None] == j) & (bits[:, :, None] > 0)).astype(jnp.float32)
    return jnp.einsum("tcj,tj->tc", onehot, vals.astype(jnp.float32),
                      preferred_element_type=jnp.float32)           # [T, d_pad]


def topk_mask_rankcube(x: jnp.ndarray, k: int, d: int) -> jnp.ndarray:
    """x [T, d_pad] -> bool keep mask with exactly k True per row.

    The pre-PR-2 compress selection: all-pairs rank count
    ``rank[t,c] = #{c' : |x[t,c']| > |x[t,c]|}`` with index tie-break,
    materialising the ``[T, d_pad, d_pad]`` compare cube.
    """
    T, d_pad = x.shape
    mag = jnp.abs(x.astype(jnp.float32))
    ch = lax.broadcasted_iota(jnp.int32, (T, d_pad), 1)
    mag = jnp.where(ch < d, mag, -1.0)
    m_c = mag[:, :, None]                                 # [T, d, 1] candidate
    m_o = mag[:, None, :]                                 # [T, 1, d] other
    i_c = lax.broadcasted_iota(jnp.int32, (T, d_pad, d_pad), 1)
    i_o = lax.broadcasted_iota(jnp.int32, (T, d_pad, d_pad), 2)
    beats = (m_o > m_c) | ((m_o == m_c) & (i_o < i_c))
    rank = jnp.sum(beats.astype(jnp.int32), axis=2)       # [T, d_pad]
    return (rank < k) & (ch < d)


def compact_onehot(x: jnp.ndarray, keep: jnp.ndarray, k: int) -> jnp.ndarray:
    """x [T, d_pad], keep mask -> values [T, k] via the one-hot contraction."""
    keep_f = keep.astype(jnp.float32)
    pos = jnp.cumsum(keep_f, axis=1) - 1.0                # [T, d_pad]
    T, d_pad = x.shape
    j = lax.broadcasted_iota(jnp.float32, (T, d_pad, k), 2)
    onehot = ((pos[:, :, None] == j) & keep[:, :, None]).astype(jnp.float32)
    return jnp.einsum("tcj,tc->tj", onehot, x.astype(jnp.float32),
                      preferred_element_type=jnp.float32)  # [T, k]
