"""Pure-jnp oracles for every Pallas kernel (asserted allclose in tests).

These are the ground truth for:
  * ``mustafar_compress``  — per-token top-k prune + fixed-k bitmap pack
  * ``sparse_qk``          — q · K̂ᵀ over the compressed Key cache (SpMV #1)
  * ``sparse_av``          — α · V̂ over the compressed Value cache (SpMV #2)
  * ``decode_attention_fused`` — both SpMVs + joint online softmax
  * ``flash_prefill``      — causal flash attention (dense prefill path)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.sparse_format import (pack_fixedk, pad_to_words, topk_mask,
                                      unpack_fixedk)

NEG_INF = -1e30


def mustafar_compress_ref(x: jax.Array, k: int):
    """x [..., T, d] -> (values [..., T, k], bitmap [..., T, ceil32(d)//32])."""
    return pack_fixedk(x, topk_mask(x, k), k)


def sparse_qk_ref(q: jax.Array, values: jax.Array, bitmap: jax.Array,
                  d: int, scale: float) -> jax.Array:
    """q [BH, G, d], values [BH, T, k], bitmap [BH, T, W] -> scores [BH, G, T]."""
    k_dense = unpack_fixedk(values, bitmap, d).astype(jnp.float32)
    return jnp.einsum("bgd,btd->bgt", q.astype(jnp.float32), k_dense) * scale


def sparse_av_ref(p: jax.Array, values: jax.Array, bitmap: jax.Array,
                  d: int) -> jax.Array:
    """p [BH, G, T], values [BH, T, k] -> out [BH, G, d]."""
    v_dense = unpack_fixedk(values, bitmap, d).astype(jnp.float32)
    return jnp.einsum("bgt,btd->bgd", p.astype(jnp.float32), v_dense)


def decode_attention_fused_ref(q: jax.Array,
                               ck_values: jax.Array, ck_bitmap: jax.Array,
                               cv_values: jax.Array, cv_bitmap: jax.Array,
                               n_valid: jax.Array, d: int,
                               scale: Optional[float] = None) -> jax.Array:
    """Fused compressed-cache decode attention (softmax inside).

    q [BH, G, d]; caches [BH, T, ·]; n_valid [BH] -> out [BH, G, d].
    """
    scale = scale if scale is not None else d ** -0.5
    T = ck_values.shape[1]
    s = sparse_qk_ref(q, ck_values, ck_bitmap, d, scale)
    valid = jnp.arange(T)[None, None, :] < n_valid[:, None, None]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = sparse_av_ref(p, cv_values, cv_bitmap, d)
    # rows with no valid tokens produce a zero vector (the kernel's l == 0
    # finalize guard), not the softmax-of-all-masked uniform average
    return jnp.where(n_valid[:, None, None] > 0, out, 0.0)


def decode_attention_fused_state_ref(q: jax.Array,
                                     ck_values: jax.Array, ck_bitmap: jax.Array,
                                     cv_values: jax.Array, cv_bitmap: jax.Array,
                                     n_valid: jax.Array, d: int,
                                     scale: Optional[float] = None):
    """Fused decode attention WITH the raw online-softmax state.

    Returns ``(out, acc, m, l)`` matching the Pallas kernel's
    ``return_state=True`` semantics: ``m`` is the running max over valid
    tokens (NEG_INF where a row has none), ``l`` the exp-sum, ``acc`` the
    unnormalised numerator — so a caller can continue the running softmax
    over further operands (e.g. the dense local window).
    """
    scale = scale if scale is not None else d ** -0.5
    T = ck_values.shape[1]
    s = sparse_qk_ref(q, ck_values, ck_bitmap, d, scale)
    valid = jnp.arange(T)[None, None, :] < n_valid[:, None, None]
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(valid, jnp.exp(s - m), 0.0)   # guard the all-masked row
    l = jnp.sum(p, axis=-1, keepdims=True)
    acc = sparse_av_ref(p, cv_values, cv_bitmap, d)
    out = acc / jnp.maximum(l, 1e-30)
    return out, acc, m, l


def flash_prefill_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                      scale: Optional[float] = None) -> jax.Array:
    """Causal attention oracle. q,k,v [B, H, T, d] (k/v already GQA-expanded)."""
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    T = q.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    causal = jnp.tril(jnp.ones((T, T), bool))
    s = jnp.where(causal, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
