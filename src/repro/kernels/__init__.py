"""Pallas TPU kernels for Mustafar hot spots + pure-jnp oracles.

compress (prune+pack: threshold top-k + gather compaction),
sparse_qk / sparse_av (bitmap SpMV via gather decompression, paper Fig. 5a),
decode_attention_fused (beyond-paper online-softmax fusion on a DMA-skipping
scalar-prefetch grid), flash_prefill. ``legacy`` keeps the pre-overhaul
one-hot/rank-cube formulations as equivalence oracles.
"""
from repro.kernels.ops import (compress, decode_attention_fused, sparse_av,
                               sparse_qk)
