"""Pallas TPU kernels for Mustafar hot spots + pure-jnp oracles.

compress (prune+pack), sparse_qk / sparse_av (bitmap SpMV, paper Fig. 5a),
decode_attention_fused (beyond-paper online-softmax fusion), flash_prefill.
"""
from repro.kernels.ops import (compress, decode_attention_fused, sparse_av,
                               sparse_qk)
