"""Pallas TPU kernel: per-token top-k prune + fixed-k bitmap compression.

Paper §3 performs pruning + compression on-the-fly with a Triton kernel as
64-token tile groups retire from the local dense window. TPU adaptation:

* grid over (rows, token-tiles); each step owns a ``[tile_t, d]`` VMEM tile.
* exact top-k per token via a binary search for the k-th magnitude: |x| is
  bitcast to int32 (IEEE-754 ordering of non-negative floats matches integer
  ordering), then 31 halvings of the bit range find the per-row threshold —
  O(31·T·d) VPU compares and O(T·d) VMEM. Ties at the threshold are broken
  by ascending channel index (exclusive cumsum), reproducing the stable
  magnitude-desc/index-asc order of the jnp oracle bit-for-bit.
* value compaction via gather: the j-th kept channel is located by a
  7-step binary search over the inclusive keep-cumsum (nondecreasing per
  row), then ``take_along_axis`` pulls ``x[t, idx[t,j]]`` — O(T·k·log d).
* bit-packing with broadcasted shifts into uint32 words.

The previous formulation ranked channels with an all-pairs ``[T, d, d]``
compare cube (O(T·d²) and the VMEM term that pinned TILE_T at 8) and
compacted values with an O(T·d·k) one-hot MXU matmul (kept in
``repro.kernels.legacy`` as the equivalence oracle). VMEM working set per
step is now just a few [tile_t, d] planes: tile_t=64, d=128 ≈ 0.2 MB, so
tile_t=128+ also fits and the compress grid shrinks 8×.

Values pass through in the input dtype (bf16 stays bf16 — the gather never
upcasts), matching the bf16 compressed pools in ``serving.cache``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.sparse_format import pad_to_words

TILE_T = 64  # token rows per grid step (default; see mustafar_compress)

_FP32_KEY_HI = 0x7F800000  # +inf bit pattern: > any finite |x| key


def _topk_threshold_keep(x: jax.Array, k: int, d: int) -> jax.Array:
    """x [T, d_pad] -> bool keep mask with exactly k True per row.

    Binary search on the int32-bitcast magnitude for the k-th largest key,
    then fill threshold ties in ascending channel order.
    """
    T, d_pad = x.shape
    mag = jnp.abs(x.astype(jnp.float32))
    key = lax.bitcast_convert_type(mag, jnp.int32)        # order-preserving
    ch = lax.broadcasted_iota(jnp.int32, (T, d_pad), 1)
    key = jnp.where(ch < d, key, -1)      # word-padding channels never win

    # invariant: #{key > lo} >= k  and  #{key > hi} < k; converges on the
    # k-th largest key (31 halvings cover the non-negative fp32 bit range)
    def body(_, lohi):
        lo, hi = lohi
        mid = lo + (hi - lo) // 2                         # [T, 1]
        n_gt = jnp.sum((key > mid).astype(jnp.int32), axis=1, keepdims=True)
        take_hi = n_gt < k
        return (jnp.where(take_hi, lo, mid + 1), jnp.where(take_hi, mid, hi))

    lo0 = jnp.full((T, 1), -1, jnp.int32)
    hi0 = jnp.full((T, 1), _FP32_KEY_HI, jnp.int32)
    _, thr = lax.fori_loop(0, 31, body, (lo0, hi0))       # [T, 1]

    above = key > thr
    n_above = jnp.sum(above.astype(jnp.int32), axis=1, keepdims=True)
    tie = key == thr
    tie_rank = jnp.cumsum(tie.astype(jnp.int32), axis=1) - tie  # exclusive
    return above | (tie & (n_above + tie_rank < k))       # exactly k per row


def _compact_gather(x: jax.Array, keep: jax.Array, k: int) -> jax.Array:
    """x [T, d_pad], keep (exactly k True/row) -> values [T, k] in x.dtype.

    idx[t, j] = the channel holding the j-th kept element = the first c where
    the inclusive keep-cumsum reaches j+1, found by binary search over the
    nondecreasing cumsum (log2(d_pad) take_along_axis probes).
    """
    T, d_pad = x.shape
    cnt = jnp.cumsum(keep.astype(jnp.int32), axis=1)      # [T, d_pad]
    tgt = lax.broadcasted_iota(jnp.int32, (1, k), 1) + 1  # [1, k]
    n_iters = max(1, (d_pad - 1).bit_length())

    def body(_, lohi):
        lo, hi = lohi
        mid = lo + (hi - lo) // 2
        ge = jnp.take_along_axis(cnt, mid, axis=1) >= tgt
        return (jnp.where(ge, lo, mid + 1), jnp.where(ge, mid, hi))

    lo0 = jnp.zeros((T, k), jnp.int32)
    hi0 = jnp.full((T, k), d_pad - 1, jnp.int32)
    _, idx = lax.fori_loop(0, n_iters, body, (lo0, hi0))
    return jnp.take_along_axis(x, idx, axis=1)


def _compress_kernel(x_ref, vals_ref, bm_ref, *, k: int, d: int):
    x = x_ref[0]                                          # [T, d_pad]
    T, d_pad = x.shape
    keep = _topk_threshold_keep(x, k, d)
    vals_ref[0] = _compact_gather(x, keep, k).astype(vals_ref.dtype)

    # --- bit-packing into uint32 words ---
    n_words = d_pad // 32
    bits = keep.astype(jnp.uint32).reshape(T, n_words, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    bm_ref[0] = jnp.sum(bits << shifts, axis=2, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("k", "interpret", "tile_t"))
def mustafar_compress(x: jax.Array, k: int, *, interpret: bool = False,
                      tile_t: int = TILE_T):
    """x [R, T, d] -> (values [R, T, k], bitmap [R, T, ceil32(d)/32] uint32).

    R = flattened batch·heads·…; ``tile_t`` is the token-tile grid step
    (clamped to T). T must be a multiple of the (clamped) tile.
    """
    R, T, d = x.shape
    assert k <= d, (k, d)
    d_pad = pad_to_words(d)
    if d_pad != d:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, d_pad - d)))
    tile_t = min(tile_t, T)
    if T % tile_t != 0:
        raise ValueError(
            f"mustafar_compress: T={T} is not a multiple of tile_t={tile_t}; "
            f"pad the token dim or pass a tile_t that divides T")
    n_words = d_pad // 32
    grid = (R, T // tile_t)
    kernel = functools.partial(_compress_kernel, k=k, d=d)
    vals, bm = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, tile_t, d_pad), lambda r, t: (r, t, 0))],
        out_specs=[
            pl.BlockSpec((1, tile_t, k), lambda r, t: (r, t, 0)),
            pl.BlockSpec((1, tile_t, n_words), lambda r, t: (r, t, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, T, k), x.dtype),
            jax.ShapeDtypeStruct((R, T, n_words), jnp.uint32),
        ],
        interpret=interpret,
    )(x)
    return vals, bm
