"""Pallas TPU kernel: per-token top-k prune + fixed-k bitmap compression.

Paper §3 performs pruning + compression on-the-fly with a Triton kernel as
64-token tile groups retire from the local dense window. TPU adaptation:

* grid over (rows, token-tiles); each step owns a ``[TILE_T, d]`` VMEM tile.
* exact top-k per token via an all-pairs rank count on the VPU
  (``rank[t,c] = #{c' : |x[t,c']| > |x[t,c]|}`` with index tie-break) —
  no sort primitive needed, O(d²) compares vectorise across lanes.
* value compaction via the rank-match contraction
  ``vals[t,j] = Σ_c [pos[t,c]==j]·x[t,c]`` (MXU-shaped one-hot matmul).
* bit-packing with broadcasted shifts into uint32 words.

VMEM working set per step (TILE_T=8, d=128, k≤128):
dense 8·128·4 + rank scratch 8·128·128·4 ≈ 0.5 MB — fits comfortably;
the [TILE_T, d, d] compare cube bounds TILE_T.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.sparse_format import pad_to_words

TILE_T = 8  # token rows per grid step (bounds the [T,d,d] compare cube)


def _compress_kernel(x_ref, vals_ref, bm_ref, *, k: int, d: int):
    x = x_ref[0].astype(jnp.float32)                      # [T, d_pad]
    T, d_pad = x.shape
    mag = jnp.abs(x)
    # channels beyond d (word padding, e.g. d_head=80) never win top-k
    ch = lax.broadcasted_iota(jnp.int32, (T, d_pad), 1)
    mag = jnp.where(ch < d, mag, -1.0)

    # --- exact top-k via all-pairs rank (VPU) ---
    m_c = mag[:, :, None]                                 # [T, d, 1] candidate
    m_o = mag[:, None, :]                                 # [T, 1, d] other
    i_c = lax.broadcasted_iota(jnp.int32, (T, d_pad, d_pad), 1)
    i_o = lax.broadcasted_iota(jnp.int32, (T, d_pad, d_pad), 2)
    beats = (m_o > m_c) | ((m_o == m_c) & (i_o < i_c))
    rank = jnp.sum(beats.astype(jnp.int32), axis=2)       # [T, d_pad]
    keep = (rank < k) & (ch < d)                          # exactly k per row
    keep_f = keep.astype(jnp.float32)

    # --- value compaction: vals[t,j] = Σ_c [pos==j]·x ---
    pos = jnp.cumsum(keep_f, axis=1) - 1.0                # [T, d_pad]
    j = lax.broadcasted_iota(jnp.float32, (T, d_pad, k), 2)
    onehot = ((pos[:, :, None] == j) & keep[:, :, None]).astype(jnp.float32)
    vals = jnp.einsum("tcj,tc->tj", onehot, x,
                      preferred_element_type=jnp.float32)  # [T, k]
    vals_ref[0] = vals.astype(vals_ref.dtype)

    # --- bit-packing into uint32 words ---
    n_words = d_pad // 32
    bits = keep.astype(jnp.uint32).reshape(T, n_words, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    bm_ref[0] = jnp.sum(bits << shifts, axis=2, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def mustafar_compress(x: jax.Array, k: int, *, interpret: bool = False):
    """x [R, T, d] -> (values [R, T, k], bitmap [R, T, ceil32(d)/32] uint32).

    R = flattened batch·heads·…; T must be a multiple of TILE_T.
    """
    R, T, d = x.shape
    d_pad = pad_to_words(d)
    if d_pad != d:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, d_pad - d)))
    assert T % TILE_T == 0, f"T={T} not a multiple of TILE_T={TILE_T}"
    n_words = d_pad // 32
    grid = (R, T // TILE_T)
    kernel = functools.partial(_compress_kernel, k=k, d=d)
    vals, bm = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, TILE_T, d_pad), lambda r, t: (r, t, 0))],
        out_specs=[
            pl.BlockSpec((1, TILE_T, k), lambda r, t: (r, t, 0)),
            pl.BlockSpec((1, TILE_T, n_words), lambda r, t: (r, t, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, T, k), x.dtype),
            jax.ShapeDtypeStruct((R, T, n_words), jnp.uint32),
        ],
        interpret=interpret,
    )(x)
    return vals, bm
