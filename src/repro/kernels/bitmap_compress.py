"""Pallas TPU kernel: per-token top-k prune + fixed-k bitmap compression.

Paper §3 performs pruning + compression on-the-fly with a Triton kernel as
64-token tile groups retire from the local dense window. TPU adaptation:

* grid over (rows, token-tiles); each step owns a ``[tile_t, d]`` VMEM tile.
* exact top-k per token via a binary search for the k-th magnitude: |x| is
  bitcast to int32 (IEEE-754 ordering of non-negative floats matches integer
  ordering), then 31 halvings of the bit range find the per-row threshold —
  O(31·T·d) VPU compares and O(T·d) VMEM. Ties at the threshold are broken
  by ascending channel index (exclusive cumsum), reproducing the stable
  magnitude-desc/index-asc order of the jnp oracle bit-for-bit.
* value compaction via gather: the j-th kept channel is located by a
  7-step binary search over the inclusive keep-cumsum (nondecreasing per
  row), then ``take_along_axis`` pulls ``x[t, idx[t,j]]`` — O(T·k·log d).
* bit-packing with broadcasted shifts into uint32 words.

The previous formulation ranked channels with an all-pairs ``[T, d, d]``
compare cube (O(T·d²) and the VMEM term that pinned TILE_T at 8) and
compacted values with an O(T·d·k) one-hot MXU matmul (kept in
``repro.kernels.legacy`` as the equivalence oracle). VMEM working set per
step is now just a few [tile_t, d] planes: tile_t=64, d=128 ≈ 0.2 MB, so
tile_t=128+ also fits and the compress grid shrinks 8×.

Values pass through in the input dtype (bf16 stays bf16 — the gather never
upcasts), matching the bf16 compressed pools in ``serving.cache``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from jax.experimental.pallas import tpu as pltpu

from repro.core.sparse_format import pad_to_words

TILE_T = 64  # token rows per grid step (default; see mustafar_compress)

_FP32_KEY_HI = 0x7F800000  # +inf bit pattern: > any finite |x| key


def _topk_threshold_keep(x: jax.Array, k: int, d: int) -> jax.Array:
    """x [T, d_pad] -> bool keep mask with exactly k True per row.

    Binary search on the int32-bitcast magnitude for the k-th largest key,
    then fill threshold ties in ascending channel order.
    """
    T, d_pad = x.shape
    mag = jnp.abs(x.astype(jnp.float32))
    key = lax.bitcast_convert_type(mag, jnp.int32)        # order-preserving
    ch = lax.broadcasted_iota(jnp.int32, (T, d_pad), 1)
    key = jnp.where(ch < d, key, -1)      # word-padding channels never win

    # invariant: #{key > lo} >= k  and  #{key > hi} < k; converges on the
    # k-th largest key (31 halvings cover the non-negative fp32 bit range)
    def body(_, lohi):
        lo, hi = lohi
        mid = lo + (hi - lo) // 2                         # [T, 1]
        n_gt = jnp.sum((key > mid).astype(jnp.int32), axis=1, keepdims=True)
        take_hi = n_gt < k
        return (jnp.where(take_hi, lo, mid + 1), jnp.where(take_hi, mid, hi))

    lo0 = jnp.full((T, 1), -1, jnp.int32)
    hi0 = jnp.full((T, 1), _FP32_KEY_HI, jnp.int32)
    _, thr = lax.fori_loop(0, 31, body, (lo0, hi0))       # [T, 1]

    above = key > thr
    n_above = jnp.sum(above.astype(jnp.int32), axis=1, keepdims=True)
    tie = key == thr
    tie_rank = jnp.cumsum(tie.astype(jnp.int32), axis=1) - tie  # exclusive
    return above | (tie & (n_above + tie_rank < k))       # exactly k per row


def _compact_gather(x: jax.Array, keep: jax.Array, k: int) -> jax.Array:
    """x [T, d_pad], keep (exactly k True/row) -> values [T, k] in x.dtype.

    idx[t, j] = the channel holding the j-th kept element = the first c where
    the inclusive keep-cumsum reaches j+1, found by binary search over the
    nondecreasing cumsum (log2(d_pad) take_along_axis probes).
    """
    T, d_pad = x.shape
    cnt = jnp.cumsum(keep.astype(jnp.int32), axis=1)      # [T, d_pad]
    tgt = lax.broadcasted_iota(jnp.int32, (1, k), 1) + 1  # [1, k]
    n_iters = max(1, (d_pad - 1).bit_length())

    def body(_, lohi):
        lo, hi = lohi
        mid = lo + (hi - lo) // 2
        ge = jnp.take_along_axis(cnt, mid, axis=1) >= tgt
        return (jnp.where(ge, lo, mid + 1), jnp.where(ge, mid, hi))

    lo0 = jnp.zeros((T, k), jnp.int32)
    hi0 = jnp.full((T, k), d_pad - 1, jnp.int32)
    _, idx = lax.fori_loop(0, n_iters, body, (lo0, hi0))
    return jnp.take_along_axis(x, idx, axis=1)


def _compress_tile(x: jax.Array, k: int, d: int):
    """One [T, d_pad] tile -> (values [T, k] in x.dtype, words [T, d_pad/32]
    uint32). Shared by the standalone compress kernel and the fused
    compress-and-scatter epilogue below."""
    T, d_pad = x.shape
    keep = _topk_threshold_keep(x, k, d)
    vals = _compact_gather(x, keep, k)
    n_words = d_pad // 32
    bits = keep.astype(jnp.uint32).reshape(T, n_words, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
    words = jnp.sum(bits << shifts, axis=2, dtype=jnp.uint32)
    return vals, words


def _quantize_block(vals: jax.Array, qt: int):
    """vals [T, k] fp -> (int8 [T, k], fp32 scales [T//qt, 1]).

    Symmetric absmax per [qt, k] sub-block — the SAME jnp ops as the storage
    oracle ``sparse_format.quantize_fixedk`` (fp32 math, round-half-to-even,
    all-zero blocks keep scale 1.0 so they stay exact zeros), so kernel and
    oracle agree bit-for-bit. Runs in the same dispatch as the compress: the
    packed values are already in registers, no extra pass over the tile."""
    T, k = vals.shape
    xt = vals.astype(jnp.float32).reshape(T // qt, qt * k)
    # reciprocal multiply (not /127.0): bit-identical across XLA lowerings
    # — the oracle does the same (sparse_format.quantize_fixedk)
    scale = jnp.max(jnp.abs(xt), axis=1, keepdims=True) \
        * jnp.float32(1.0 / 127.0)
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.clip(jnp.round(xt / scale), -127, 127)
    return q.reshape(T, k).astype(jnp.int8), scale


def _compress_kernel(x_ref, vals_ref, bm_ref, *, k: int, d: int):
    x = x_ref[0]                                          # [T, d_pad]
    vals, words = _compress_tile(x, k, d)
    vals_ref[0] = vals.astype(vals_ref.dtype)
    bm_ref[0] = words


def _compress_quant_kernel(x_ref, vals_ref, bm_ref, scale_ref, *,
                           k: int, d: int, qt: int):
    x = x_ref[0]                                          # [T, d_pad]
    vals, words = _compress_tile(x, k, d)
    q, s = _quantize_block(vals, qt)
    vals_ref[0] = q
    bm_ref[0] = words
    scale_ref[0] = s


@functools.partial(jax.jit,
                   static_argnames=("k", "interpret", "tile_t", "quant_tile"))
def mustafar_compress(x: jax.Array, k: int, *, interpret: bool = False,
                      tile_t: int = TILE_T, quant_tile: int | None = None):
    """x [R, T, d] -> (values [R, T, k], bitmap [R, T, ceil32(d)/32] uint32).

    R = flattened batch·heads·…; ``tile_t`` is the token-tile grid step
    (clamped to T). T must be a multiple of the (clamped) tile.

    ``quant_tile`` switches on int8 pool emission: the packed values are
    symmetric-absmax quantized per ``quant_tile``-token block IN THE SAME
    dispatch and a third output ``scales [R, T//quant_tile, 1]`` fp32 is
    returned (values come back int8). Requires ``tile_t % quant_tile == 0``
    so a grid step owns whole quant blocks.
    """
    R, T, d = x.shape
    assert k <= d, (k, d)
    d_pad = pad_to_words(d)
    if d_pad != d:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, d_pad - d)))
    tile_t = min(tile_t, T)
    if T % tile_t != 0:
        raise ValueError(
            f"mustafar_compress: T={T} is not a multiple of tile_t={tile_t}; "
            f"pad the token dim or pass a tile_t that divides T")
    n_words = d_pad // 32
    grid = (R, T // tile_t)
    if quant_tile is None:
        kernel = functools.partial(_compress_kernel, k=k, d=d)
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[pl.BlockSpec((1, tile_t, d_pad),
                                   lambda r, t: (r, t, 0))],
            out_specs=[
                pl.BlockSpec((1, tile_t, k), lambda r, t: (r, t, 0)),
                pl.BlockSpec((1, tile_t, n_words), lambda r, t: (r, t, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((R, T, k), x.dtype),
                jax.ShapeDtypeStruct((R, T, n_words), jnp.uint32),
            ],
            interpret=interpret,
        )(x)
    if tile_t % quant_tile:
        raise ValueError(
            f"mustafar_compress: tile_t={tile_t} must be a multiple of "
            f"quant_tile={quant_tile} (a grid step owns whole quant blocks)")
    nt = tile_t // quant_tile
    kernel = functools.partial(_compress_quant_kernel, k=k, d=d,
                               qt=quant_tile)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, tile_t, d_pad), lambda r, t: (r, t, 0))],
        out_specs=[
            pl.BlockSpec((1, tile_t, k), lambda r, t: (r, t, 0)),
            pl.BlockSpec((1, tile_t, n_words), lambda r, t: (r, t, 0)),
            pl.BlockSpec((1, nt, 1), lambda r, t: (r, t, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, T, k), jnp.int8),
            jax.ShapeDtypeStruct((R, T, n_words), jnp.uint32),
            jax.ShapeDtypeStruct((R, T // quant_tile, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)


# ----------------------------------------------------------------------
# fused compaction epilogue: compress-as-you-evict straight into the paged
# pools. The retiring window tiles are already in VMEM when the decode
# kernel's epilogue runs, so instead of a standalone compress (HBM round
# trip) followed by a scan of per-slot dynamic_update_slices, ONE dispatch
# compresses each slot's K and V tiles and DMAs the packed values + bitmap
# words directly into their destination page. The pool leaves are ALIASED
# input->output (donated): grid cells write only their own [tile, ·] block
# and every untouched block keeps its bytes — the pallas analogue of the
# paper's in-place CUDA cache-pointer update.

def _compress_scatter_kernel(phys_ref, offt_ref, kx_ref, vx_ref,
                             ckv_in, ckb_in, cvv_in, cvb_in,
                             ckv_ref, ckb_ref, cvv_ref, cvb_ref, *,
                             kk: int, kv: int, d: int):
    del phys_ref, offt_ref, ckv_in, ckb_in, cvv_in, cvb_in  # index-map/alias
    vals, words = _compress_tile(kx_ref[0, 0], kk, d)
    ckv_ref[0, 0] = vals.astype(ckv_ref.dtype)
    ckb_ref[0, 0] = words
    vals, words = _compress_tile(vx_ref[0, 0], kv, d)
    cvv_ref[0, 0] = vals.astype(cvv_ref.dtype)
    cvb_ref[0, 0] = words


def _compress_scatter_quant_kernel(phys_ref, offt_ref, kx_ref, vx_ref,
                                   ckv_in, ckb_in, cvv_in, cvb_in,
                                   cks_in, cvs_in,
                                   ckv_ref, ckb_ref, cvv_ref, cvb_ref,
                                   cks_ref, cvs_ref, *,
                                   kk: int, kv: int, d: int):
    """Quantized fused retirement: the retiring tile IS one quant block
    (quant tile == tile_tokens), so each grid cell emits int8 values, bitmap
    words, and ONE fp32 scale per head — all in the same dispatch."""
    del phys_ref, offt_ref, ckv_in, ckb_in, cvv_in, cvb_in, cks_in, cvs_in
    vals, words = _compress_tile(kx_ref[0, 0], kk, d)
    q, s = _quantize_block(vals, vals.shape[0])
    ckv_ref[0, 0] = q
    ckb_ref[0, 0] = words
    cks_ref[0, 0] = s
    vals, words = _compress_tile(vx_ref[0, 0], kv, d)
    q, s = _quantize_block(vals, vals.shape[0])
    cvv_ref[0, 0] = q
    cvb_ref[0, 0] = words
    cvs_ref[0, 0] = s


@functools.partial(jax.jit, static_argnames=("interpret",))
def mustafar_compress_scatter(k_tile: jax.Array, v_tile: jax.Array,
                              ck_vals: jax.Array, ck_bm: jax.Array,
                              cv_vals: jax.Array, cv_bm: jax.Array,
                              phys: jax.Array, off_tile: jax.Array, *,
                              k_scale: jax.Array | None = None,
                              v_scale: jax.Array | None = None,
                              interpret: bool = False):
    """Fused tile-group retirement: compress + scatter in ONE dispatch.

    ``k_tile``/``v_tile`` [B, Hkv, tt, d] are the retiring window tiles;
    pool leaves are page-major [n_phys, Hkv, page_tokens, ·]. ``phys`` [B]
    is each row's pre-resolved physical destination page (the caller points
    masked rows at the write-discard scratch page) and ``off_tile`` [B] the
    in-page TILE index (token offset // tt — compaction offsets are always
    tile-aligned). Returns the four updated pool leaves — SIX with
    ``k_scale``/``v_scale`` given (int8 pools): the retiring tile is exactly
    one quant block, so each grid cell also emits one fp32 absmax scale per
    head into block (phys[b], h, off_tile[b]) of the aliased scale pools
    ``[n_phys, Hkv, page_tokens // tt, 1]``, still in the SAME dispatch.

    Scalar-prefetched ``phys``/``off_tile`` feed the OUTPUT index maps: grid
    cell (b, h) compresses row b's head-h tiles and emits the packed values
    and bitmap words straight into block (phys[b], h, off_tile[b]) of the
    aliased pools. Rows sharing a destination (scratch) are legal — the
    sequential grid makes the last write win, and scratch is never read.
    Everything outside the visited blocks keeps its bytes via the aliasing,
    so the two-dispatch path (``kops.compress`` + scan-of-DUS, kept as the
    oracle) and this kernel produce bit-identical non-scratch pools
    (tests/test_fused_compaction.py)."""
    B, Hkv, tt, d = k_tile.shape
    n_phys, _, pt, kk = ck_vals.shape
    kv = cv_vals.shape[-1]
    n_words = ck_bm.shape[-1]
    d_pad = pad_to_words(d)
    if d_pad != d:
        pad = ((0, 0), (0, 0), (0, 0), (0, d_pad - d))
        k_tile = jnp.pad(k_tile, pad)
        v_tile = jnp.pad(v_tile, pad)
    assert pt % tt == 0, (pt, tt)
    quant = k_scale is not None
    assert quant == (v_scale is not None), "pass both scale pools or neither"

    page_blk = lambda c: pl.BlockSpec(
        (1, 1, tt, c), lambda b, h, ph, ot: (ph[b], h, ot[b], 0))
    in_specs = [
        pl.BlockSpec((1, 1, tt, d_pad), lambda b, h, ph, ot: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, tt, d_pad), lambda b, h, ph, ot: (b, h, 0, 0)),
    ] + [pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)] * (6 if quant
                                                                 else 4)
    out_specs = [page_blk(kk), page_blk(n_words),
                 page_blk(kv), page_blk(n_words)]
    out_shape = [
        jax.ShapeDtypeStruct(ck_vals.shape, ck_vals.dtype),
        jax.ShapeDtypeStruct(ck_bm.shape, ck_bm.dtype),
        jax.ShapeDtypeStruct(cv_vals.shape, cv_vals.dtype),
        jax.ShapeDtypeStruct(cv_bm.shape, cv_bm.dtype),
    ]
    operands = [phys.astype(jnp.int32), off_tile.astype(jnp.int32),
                k_tile, v_tile, ck_vals, ck_bm, cv_vals, cv_bm]
    # inputs: 0=phys 1=off_tile 2=k_tile 3=v_tile 4..=pool leaves; the
    # leaves alias outputs (donated — unvisited blocks keep their bytes)
    aliases = {4: 0, 5: 1, 6: 2, 7: 3}
    if quant:
        assert k_scale.shape == (n_phys, Hkv, pt // tt, 1), k_scale.shape
        scale_blk = pl.BlockSpec(
            (1, 1, 1, 1), lambda b, h, ph, ot: (ph[b], h, ot[b], 0))
        out_specs += [scale_blk, scale_blk]
        out_shape += [jax.ShapeDtypeStruct(k_scale.shape, k_scale.dtype),
                      jax.ShapeDtypeStruct(v_scale.shape, v_scale.dtype)]
        operands += [k_scale, v_scale]
        aliases.update({8: 4, 9: 5})
        kernel = functools.partial(_compress_scatter_quant_kernel,
                                   kk=kk, kv=kv, d=d)
    else:
        kernel = functools.partial(_compress_scatter_kernel, kk=kk, kv=kv,
                                   d=d)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv),
        in_specs=in_specs,
        out_specs=out_specs,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(*operands)
