"""Mustafar decode attention — reference formulation (paper §3, Fig. 5a).

Decode attention is reformulated into two parts:
  1. SpMV over the compressed cache:  q·K̂ᵀ and α·V̂ on (values, bitmap)
  2. dense MV over the local window (recent ≤ local_window + un-compacted
     tokens, kept dense)
followed by a single joint softmax. This module is the pure-jnp oracle and
the CPU execution path; ``repro.kernels.ops`` provides the Pallas TPU path
with identical semantics (asserted in tests).

Shapes (GQA): q [B, Hq, d]; compressed K/V values [B, Hkv, Tc, k] with
bitmap [B, Hkv, Tc, d//32]; window K/V [B, Hkv, W, d].
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.sparse_format import (dequantize_fixedk, gather_pages,
                                      pad_to_words, unpack_fixedk)

NEG_INF = -1e30


class MustafarCacheView(NamedTuple):
    """One layer's decode-attention operands.

    ``n_compressed`` / ``n_window`` are TRUE per-sequence vectors (not a
    broadcast scalar): ragged continuous-batching slots sit at different
    depths, so each batch row masks its own pool and window extent in both
    the two-pass and chunked formulations below."""
    ck_values: jax.Array      # [B, Hkv, Tc, k_k]
    ck_bitmap: jax.Array      # [B, Hkv, Tc, d//32] uint32
    cv_values: jax.Array      # [B, Hkv, Tc, k_v]
    cv_bitmap: jax.Array      # [B, Hkv, Tc, d//32] uint32
    n_compressed: jax.Array   # [B] int32 — valid compressed tokens per row
    k_window: jax.Array       # [B, Hkv, W, d]
    v_window: jax.Array       # [B, Hkv, W, d]
    n_window: jax.Array       # [B] int32 — valid window tokens per row
    # int8 pools only (pool_dtype="int8"): per-tile symmetric absmax fp32
    # scales [B, Hkv, Tc//qt, 1]; None for bf16 pools (the PR 9 layout)
    ck_scale: Optional[jax.Array] = None
    cv_scale: Optional[jax.Array] = None


class PagedMustafarCacheView(NamedTuple):
    """Decode-attention operands when the compressed pools are PAGED.

    The four pool leaves are page-major ``[n_phys, Hkv, page_tokens, ·]``
    globals shared by every batch slot; ``block_table [B, max_pages]``
    (int32, -1 = unmapped) maps each slot's logical pages to physical ones.
    Window operands and the per-row validity vectors are identical to
    ``MustafarCacheView``. ``to_contiguous()`` materialises the gather view
    — the CPU/jnp decode paths read through it, which keeps their numerics
    bit-identical to contiguous pools; the fused TPU kernel instead
    translates tile→page inside its scalar-prefetch grid and never
    materialises the gather."""
    ck_pool: jax.Array        # [n_phys, Hkv, page_tokens, k_k]
    ck_bitmap: jax.Array      # [n_phys, Hkv, page_tokens, d//32] uint32
    cv_pool: jax.Array        # [n_phys, Hkv, page_tokens, k_v]
    cv_bitmap: jax.Array      # [n_phys, Hkv, page_tokens, d//32] uint32
    block_table: jax.Array    # [B, max_pages] int32
    n_compressed: jax.Array   # [B] int32 — valid compressed tokens per row
    k_window: jax.Array       # [B, Hkv, W, d]
    v_window: jax.Array       # [B, Hkv, W, d]
    n_window: jax.Array       # [B] int32 — valid window tokens per row
    # int8 pools only: scale pools [n_phys, Hkv, page_tokens//qt, 1] fp32 —
    # scales ride IN the page (same block table); None for bf16 pools
    ck_scale: Optional[jax.Array] = None
    cv_scale: Optional[jax.Array] = None

    def to_contiguous(self) -> "MustafarCacheView":
        # the scale pools' row axis counts TILES per page; gather_pages is
        # agnostic to the row unit, so the gathered scale rows concatenate
        # pagewise in the same order as the gathered value rows
        return MustafarCacheView(
            ck_values=gather_pages(self.ck_pool, self.block_table),
            ck_bitmap=gather_pages(self.ck_bitmap, self.block_table),
            cv_values=gather_pages(self.cv_pool, self.block_table),
            cv_bitmap=gather_pages(self.cv_bitmap, self.block_table),
            n_compressed=self.n_compressed,
            k_window=self.k_window, v_window=self.v_window,
            n_window=self.n_window,
            ck_scale=None if self.ck_scale is None
            else gather_pages(self.ck_scale, self.block_table),
            cv_scale=None if self.cv_scale is None
            else gather_pages(self.cv_scale, self.block_table))


def _expand_gqa(x: jax.Array, n_q_heads: int) -> jax.Array:
    """[B, Hkv, ...] -> [B, Hq, ...] by repeating each KV head."""
    B, Hkv = x.shape[:2]
    rep = n_q_heads // Hkv
    return jnp.repeat(x, rep, axis=1) if rep > 1 else x


def decode_attention_dense(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                           length: jax.Array, scale: Optional[float] = None) -> jax.Array:
    """Baseline dense decode attention (the cuBLAS-MV analogue).

    q [B,Hq,d]; k/v_cache [B,Hkv,T,d]; length [B] valid tokens.
    """
    B, Hq, d = q.shape
    T = k_cache.shape[2]
    scale = scale if scale is not None else d ** -0.5
    k = _expand_gqa(k_cache, Hq)
    v = _expand_gqa(v_cache, Hq)
    s = jnp.einsum("bhd,bhtd->bht", q.astype(k.dtype), k,
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(T)[None, None, :] < length[:, None, None]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bht,bhtd->bhd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def _dequantized(cache: MustafarCacheView) -> MustafarCacheView:
    """Resolve int8 packed values to fp32 via the sibling scale leaves.

    No-op for bf16 views (ck_scale is None). The jnp reference paths below
    call this first, so everything downstream — unpack, einsum dtypes —
    sees a plain float view; the Pallas kernels instead dequantize
    in-register and never materialise the widened values."""
    if cache.ck_scale is None:
        return cache
    return cache._replace(
        ck_values=dequantize_fixedk(cache.ck_values, cache.ck_scale),
        cv_values=dequantize_fixedk(cache.cv_values, cache.cv_scale),
        ck_scale=None, cv_scale=None)


def decode_attention_mustafar(q: jax.Array, cache: MustafarCacheView,
                              scale: Optional[float] = None) -> jax.Array:
    """Two-part decode attention over (compressed ⊕ window) with joint softmax."""
    cache = _dequantized(cache)
    B, Hq, d = q.shape
    Tc = cache.ck_values.shape[2]
    W = cache.k_window.shape[2]
    scale = scale if scale is not None else d ** -0.5

    # --- part 1: scores over the compressed cache (SpMV q·K̂ᵀ) ---
    k_dense = unpack_fixedk(cache.ck_values, cache.ck_bitmap, d)     # [B,Hkv,Tc,d]
    s_c = jnp.einsum("bhd,bhtd->bht", q.astype(k_dense.dtype),
                     _expand_gqa(k_dense, Hq),
                     preferred_element_type=jnp.float32) * scale
    valid_c = jnp.arange(Tc)[None, None, :] < cache.n_compressed[:, None, None]
    s_c = jnp.where(valid_c, s_c, NEG_INF)

    # --- part 2: scores over the dense local window ---
    s_w = jnp.einsum("bhd,bhtd->bht", q.astype(cache.k_window.dtype),
                     _expand_gqa(cache.k_window, Hq),
                     preferred_element_type=jnp.float32) * scale
    valid_w = jnp.arange(W)[None, None, :] < cache.n_window[:, None, None]
    s_w = jnp.where(valid_w, s_w, NEG_INF)

    # --- joint softmax ---
    s = jnp.concatenate([s_c, s_w], axis=-1)
    p = jax.nn.softmax(s, axis=-1)
    p_c, p_w = p[..., :Tc], p[..., Tc:]

    # --- α·V: SpMV over compressed V + dense MV over window V ---
    v_dense = unpack_fixedk(cache.cv_values, cache.cv_bitmap, d)
    pd = v_dense.dtype
    out = jnp.einsum("bht,bhtd->bhd", p_c.astype(pd),
                     _expand_gqa(v_dense, Hq),
                     preferred_element_type=jnp.float32)
    out += jnp.einsum("bht,bhtd->bhd", p_w.astype(pd),
                      _expand_gqa(cache.v_window, Hq),
                      preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


DECODE_CHUNK = 4096  # pool tokens per online-softmax chunk (mirrors the
                     # fused Pallas kernel's grid; plan_pools rounds Tc to it)


def _merge_window(q: jax.Array, cache: MustafarCacheView, scale: float,
                  m: jax.Array, l: jax.Array, acc: jax.Array) -> jax.Array:
    """Join the dense local window into a running online softmax.

    (m, l, acc) is the softmax state accumulated over the compressed pools
    — by the chunked jnp scan or the fused Pallas kernel — with shapes
    [B, Hq, 1] / [B, Hq, 1] / [B, Hq, d]. Returns the normalised output.
    """
    B, Hq, d = q.shape
    W = cache.k_window.shape[2]
    s_w = jnp.einsum("bhd,bhtd->bht", q.astype(cache.k_window.dtype),
                     _expand_gqa(cache.k_window, Hq),
                     preferred_element_type=jnp.float32) * scale
    valid_w = jnp.arange(W)[None, None, :] < cache.n_window[:, None, None]
    s_w = jnp.where(valid_w, s_w, NEG_INF)
    m_w = jnp.max(s_w, axis=-1, keepdims=True)
    m_fin = jnp.maximum(m, m_w)
    alpha = jnp.exp(m - m_fin)
    p_w = jnp.exp(s_w - m_fin)
    pv_w = jnp.einsum("bht,bhtd->bhd", p_w.astype(cache.v_window.dtype),
                      _expand_gqa(cache.v_window, Hq),
                      preferred_element_type=jnp.float32)
    acc = acc * alpha[..., 0][..., None] + pv_w
    l_fin = l * alpha + jnp.sum(p_w, axis=-1, keepdims=True)
    return acc / jnp.maximum(l_fin, 1e-30)


def decode_attention_mustafar_chunked(q: jax.Array, cache: MustafarCacheView,
                                      scale: Optional[float] = None,
                                      chunk: int = DECODE_CHUNK) -> jax.Array:
    """Single-pass decode attention over the compressed pools with an online
    softmax over Tc chunks (flash-decoding style). Identical math to
    ``decode_attention_mustafar`` (asserted in tests) but with temp memory
    bounded by one chunk — this is the jnp mirror of the fused Pallas kernel
    and the production decode path.
    """
    cache = _dequantized(cache)
    B, Hq, d = q.shape
    Tc = cache.ck_values.shape[2]
    scale = scale if scale is not None else d ** -0.5
    chunk = min(chunk, Tc)
    assert Tc % chunk == 0, (Tc, chunk)
    n_chunks = Tc // chunk
    Hkv = cache.ck_values.shape[1]
    cdt = cache.ck_values.dtype

    def reshape_c(x):  # [B,Hkv,Tc,·] -> chunk-major [n,B,Hkv,chunk,·]
        return jnp.moveaxis(
            x.reshape(B, Hkv, n_chunks, chunk, x.shape[-1]), 2, 0)

    xs = (reshape_c(cache.ck_values), reshape_c(cache.ck_bitmap),
          reshape_c(cache.cv_values), reshape_c(cache.cv_bitmap),
          jnp.arange(n_chunks))

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        ckv, ckb, cvv, cvb, ci = inp
        k_dense = unpack_fixedk(ckv, ckb, d)               # [B,Hkv,chunk,d]
        s = jnp.einsum("bhd,bhtd->bht", q.astype(cdt),
                       _expand_gqa(k_dense, Hq),
                       preferred_element_type=jnp.float32) * scale
        tok = ci * chunk + jnp.arange(chunk)[None, None, :]
        s = jnp.where(tok < cache.n_compressed[:, None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        v_dense = unpack_fixedk(cvv, cvb, d)
        pv = jnp.einsum("bht,bhtd->bhd", p.astype(cdt),
                        _expand_gqa(v_dense, Hq),
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., 0][..., None] + pv
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        return (m_new, l_new, acc), None

    init = (jnp.full((B, Hq, 1), NEG_INF, jnp.float32),
            jnp.zeros((B, Hq, 1), jnp.float32),
            jnp.zeros((B, Hq, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, xs)

    # window part joins the same online softmax as the final chunk
    return _merge_window(q, cache, scale, m, l, acc).astype(q.dtype)


def decode_attention_mustafar_kernelized(q: jax.Array, cache: MustafarCacheView,
                                         scale: Optional[float] = None) -> jax.Array:
    """Decode attention with the fused Pallas kernel over the compressed pools.

    The kernel (``repro.kernels.ops.decode_attention_fused``) runs both
    bitmap-SpMVs and the online softmax in one pass on a DMA-skipping
    scalar-prefetch grid — each batch row fetches only the tiles below its
    own ``n_compressed`` — and hands back the raw softmax state
    ``(acc, m, l)``; the dense local window then joins the same running
    softmax here (identical merge math to the epilogue of
    ``decode_attention_mustafar_chunked``). On CPU the kernel dispatch falls
    back to the jnp oracle, so this path is backend-portable.
    """
    from repro.kernels import ops as kops
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    _, acc, m, l = kops.decode_attention_fused(
        q, cache.ck_values, cache.ck_bitmap, cache.cv_values, cache.cv_bitmap,
        cache.n_compressed, scale=scale, k_scale=cache.ck_scale,
        v_scale=cache.cv_scale, return_state=True)
    # window part joins the same online softmax (shared chunked epilogue)
    return _merge_window(q, cache, scale, m, l, acc).astype(q.dtype)


def decode_attention_mustafar_kernelized_paged(
        q: jax.Array, cache: PagedMustafarCacheView,
        scale: Optional[float] = None) -> jax.Array:
    """Decode attention with the fused Pallas kernel over PAGED pools.

    Same epilogue as ``decode_attention_mustafar_kernelized`` — the kernel
    hands back raw ``(acc, m, l)`` softmax state and the dense local window
    merges into the same running softmax here; only the compressed operands'
    residency differs (tile→page translation in the kernel's scalar-prefetch
    grid instead of contiguous tiles). On CPU the dispatch gathers the pages
    and runs the jnp oracle, so the path stays backend-portable."""
    from repro.kernels import ops as kops
    d = q.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    _, acc, m, l = kops.decode_attention_fused_paged(
        q, cache.ck_pool, cache.ck_bitmap, cache.cv_pool, cache.cv_bitmap,
        cache.block_table, cache.n_compressed, scale=scale,
        k_scale=cache.ck_scale, v_scale=cache.cv_scale,
        return_state=True)
    return _merge_window(q, cache, scale, m, l, acc).astype(q.dtype)


def hbm_bytes_dense(T: int, d: int, itemsize: int = 2) -> int:
    """Decode-step HBM traffic model: read K + V rows."""
    return 2 * T * d * itemsize


def hbm_bytes_mustafar(Tc: int, W: int, d: int, k_k: int, k_v: int,
                       itemsize: int = 2, *,
                       pool_itemsize: Optional[int] = None,
                       quant_tile: Optional[int] = None) -> int:
    """Compressed K + V reads plus the dense window (paper Fig. 6a model).

    ``pool_itemsize`` is the PACKED-VALUE width (defaults to ``itemsize``,
    the dense-window width): bf16 pools stream 2 bytes per non-zero, int8
    pools (``pool_dtype="int8"``) stream 1 plus — when ``quant_tile`` is
    given — one fp32 scale per quant tile per plane. The window stays in
    the model dtype regardless of pool_dtype, which is why the two widths
    are separate knobs (the seed conflated them). Bitmap planes are stored
    as whole uint32 words, so a non-multiple-of-32 head dim (d=80:
    stablelm) reads pad_to_words(d)/8 bytes per row, not d/8.

    ``Tc`` should be the row's VALID compressed depth, not the pool
    capacity: the fused kernel's scalar-prefetch grid never DMAs tiles past
    ``n_valid``, so a ragged row's bytes scale with its own fill.
    """
    pool_itemsize = itemsize if pool_itemsize is None else pool_itemsize
    comp = Tc * ((k_k + k_v) * pool_itemsize + 2 * (pad_to_words(d) // 8))
    if quant_tile:
        comp += 2 * (-(-Tc // quant_tile)) * 4      # K + V fp32 scale rows
    return comp + 2 * W * d * itemsize
