"""Mustafar core: pruning strategies, bitmap sparse format, decode attention."""
from repro.core.attention import (MustafarCacheView, decode_attention_dense,
                                  decode_attention_mustafar)
from repro.core.pruning import STRATEGIES, prune, prune_mask
from repro.core.sparse_format import (compressed_bytes, compression_rate,
                                      pack_fixedk, prune_and_pack, topk_mask,
                                      unpack_bits, unpack_fixedk)

__all__ = [
    "MustafarCacheView", "decode_attention_dense", "decode_attention_mustafar",
    "STRATEGIES", "prune", "prune_mask",
    "compressed_bytes", "compression_rate", "pack_fixedk", "prune_and_pack",
    "topk_mask", "unpack_bits", "unpack_fixedk",
]
