"""KV-cache pruning strategies (paper §2, Tables 1/2/7/8/12).

All strategies operate on cache tensors shaped ``[..., T, d]`` where the last
two dims are (tokens, head channels); leading dims are batch/heads.

The paper's verdict — ``per_token_magnitude`` for both K and V — is the
production path; every alternative it was compared against is implemented as
a baseline so the accuracy-ordering experiments reproduce:

    per_token_magnitude      exact top-k |.| per token row          (Mustafar)
    per_token_output_aware   |K| ⊙ broadcast(Σ_t |Q_t|)             (Fig. 3)
    per_channel_magnitude    top-k |.| per channel, 32-token groups (Table 2)
    per_channel_output_aware |V| ⊙ broadcast(Σ_t |α_t|)             (§2.2)
    think                    ThinK structured channel removal       (baseline)
    semi_structured_2_4      2:4 pattern on channel dim             (Appx. B)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.sparse_format import topk_mask

STRATEGIES = ("per_token_magnitude", "per_token_output_aware",
              "per_channel_magnitude", "per_channel_output_aware",
              "think", "semi_structured_2_4")


# ----------------------------------------------------------------------
# scores

def gqa_query_accumulate(q_window: jax.Array, n_kv_heads: int) -> jax.Array:
    """Σ_t |Q_t| over the score window, summed over query heads per KV head.

    q_window: [B, H_q, W, d] -> [B, H_kv, d]   (paper §2.1: "for GQA we sum
    the pruning score of all queries mapped to each KV cache")
    """
    B, Hq, W, d = q_window.shape
    acc = jnp.sum(jnp.abs(q_window.astype(jnp.float32)), axis=2)   # [B, Hq, d]
    acc = acc.reshape(B, n_kv_heads, Hq // n_kv_heads, d)
    return jnp.sum(acc, axis=2)                                    # [B, Hkv, d]


def key_output_aware_scores(k_cache: jax.Array, q_acc: jax.Array) -> jax.Array:
    """S = |K| ⊙ broadcast(Σ|Q|)  — paper Fig. 3 / eq. in §2.1.

    k_cache: [B, H_kv, T, d]; q_acc: [B, H_kv, d] -> scores [B, H_kv, T, d]
    """
    return jnp.abs(k_cache.astype(jnp.float32)) * q_acc[..., None, :]


def value_output_aware_scores(v_cache: jax.Array, attn_acc: jax.Array) -> jax.Array:
    """S = |V| ⊙ broadcast(Σ|α|)  — paper §2.2 (per-channel value pruning).

    v_cache: [B, H, T, d]; attn_acc: [B, H, T] (Σ of the window's attention
    scores per cached token) -> scores [B, H, T, d]
    """
    return jnp.abs(v_cache.astype(jnp.float32)) * attn_acc[..., :, None]


def think_channel_scores(k_cache: jax.Array, q_acc: jax.Array) -> jax.Array:
    """ThinK-style per-channel structured score: channel importance =
    (Σ_t |Q_t[c]|) · ‖K[:, c]‖₁ — one scalar per channel, whole channels
    pruned (the structured baseline Mustafar beats).
    Returns [B, H, d].
    """
    return q_acc * jnp.sum(jnp.abs(k_cache.astype(jnp.float32)), axis=-2)


# ----------------------------------------------------------------------
# masks

def per_token_topk_mask(x: jax.Array, k: int) -> jax.Array:
    """Keep the k largest-magnitude elements of each token row. [..., T, d]"""
    return topk_mask(x, k)


def per_token_score_mask(scores: jax.Array, k: int) -> jax.Array:
    """Top-k per token row under an arbitrary score tensor."""
    return topk_mask(jnp.where(scores >= 0, scores, -scores), k)  # scores >= 0 anyway


def per_channel_group_mask(scores: jax.Array, sparsity: float,
                           group: int = 32) -> jax.Array:
    """Per-channel pruning in token groups (paper: groups of 32 for local-
    window compatibility). scores [..., T, d]; within each (group, channel)
    column keep the top (1-s) fraction of tokens.
    """
    *lead, T, d = scores.shape
    assert T % group == 0, f"T={T} not divisible by group={group}"
    keep = max(1, int(round(group * (1.0 - sparsity))))
    g = scores.reshape(*lead, T // group, group, d)
    gt = jnp.swapaxes(g, -1, -2)                     # [..., G, d, group]
    mask = topk_mask(gt, keep)
    return jnp.swapaxes(mask, -1, -2).reshape(*lead, T, d)


def think_mask(k_cache: jax.Array, q_acc: jax.Array, sparsity: float) -> jax.Array:
    """Structured: remove whole channels (lowest ThinK score). [B,H,T,d]"""
    d = k_cache.shape[-1]
    keep = max(1, int(round(d * (1.0 - sparsity))))
    ch_scores = think_channel_scores(k_cache, q_acc)        # [B, H, d]
    ch_mask = topk_mask(ch_scores, keep)                    # [B, H, d]
    return jnp.broadcast_to(ch_mask[..., None, :], k_cache.shape)


def semi_structured_2_4_mask(x: jax.Array) -> jax.Array:
    """2:4 semi-structured — keep 2 of each 4 consecutive channels (Appx. B)."""
    *lead, T, d = x.shape
    assert d % 4 == 0
    g = jnp.abs(x.astype(jnp.float32)).reshape(*lead, T, d // 4, 4)
    mask = topk_mask(g, 2)
    return mask.reshape(*lead, T, d)


# ----------------------------------------------------------------------
# dispatcher

def prune_mask(cache: jax.Array, sparsity: float, strategy: str, *,
               keep_k: Optional[int] = None,
               q_acc: Optional[jax.Array] = None,
               attn_acc: Optional[jax.Array] = None,
               group: int = 32) -> jax.Array:
    """Boolean keep-mask for ``cache`` [..., T, d] under a named strategy.

    ``keep_k`` overrides the per-token k (lane-aligned fixed-k format);
    defaults to round(d*(1-s)).
    """
    d = cache.shape[-1]
    k = keep_k if keep_k is not None else max(1, int(round(d * (1.0 - sparsity))))
    if strategy == "per_token_magnitude":
        return per_token_topk_mask(cache, k)
    if strategy == "per_token_output_aware":
        if q_acc is None:
            raise ValueError("per_token_output_aware needs q_acc (Σ|Q| window)")
        return per_token_score_mask(key_output_aware_scores(cache, q_acc), k)
    if strategy == "per_channel_magnitude":
        return per_channel_group_mask(jnp.abs(cache.astype(jnp.float32)),
                                      sparsity, group)
    if strategy == "per_channel_output_aware":
        if attn_acc is None:
            raise ValueError("per_channel_output_aware needs attn_acc (Σ|α| window)")
        return per_channel_group_mask(value_output_aware_scores(cache, attn_acc),
                                      sparsity, group)
    if strategy == "think":
        if q_acc is None:
            raise ValueError("think needs q_acc")
        return think_mask(cache, q_acc, sparsity)
    if strategy == "semi_structured_2_4":
        return semi_structured_2_4_mask(cache)
    raise ValueError(f"unknown strategy {strategy!r}; have {STRATEGIES}")


def prune(cache: jax.Array, sparsity: float, strategy: str, **kw) -> jax.Array:
    """Return the pruned (masked, still dense) cache."""
    mask = prune_mask(cache, sparsity, strategy, **kw)
    return jnp.where(mask, cache, jnp.zeros_like(cache))
