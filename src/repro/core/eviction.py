"""H2O-style token eviction (paper §4.2.1 joint-application baseline).

H2O keeps a fixed budget of (a) heavy-hitter tokens — highest accumulated
attention score — and (b) recent tokens. Joint with Mustafar, the retained
tokens' K/V rows are additionally pruned per-token (paper Table 5: 10% budget
each for heavy hitters and recent tokens).

Pure-functional: returns a boolean keep-mask over token positions, suitable
for static-shape serving (evicted rows are zeroed / skipped by masking).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def h2o_keep_mask(attn_acc: jax.Array, T: int,
                  heavy_budget: int, recent_budget: int) -> jax.Array:
    """attn_acc: [..., T] accumulated attention mass per cached token.

    Returns bool [..., T]: True for tokens kept (heavy hitters ∪ recent).
    """
    positions = jnp.arange(T)
    recent = positions >= (T - recent_budget)                      # [T]
    # heavy hitters chosen among non-recent tokens
    masked_scores = jnp.where(recent, -jnp.inf, attn_acc)
    thresh_idx = jnp.argsort(-masked_scores, axis=-1)[..., :heavy_budget]
    heavy = jnp.zeros(attn_acc.shape, bool)
    heavy = jnp.put_along_axis(heavy, thresh_idx, True, axis=-1,
                               inplace=False)
    return heavy | recent


def accumulate_attention(probs: jax.Array) -> jax.Array:
    """probs: [..., Q, T] attention probabilities -> [..., T] accumulated mass."""
    return jnp.sum(probs, axis=-2)
