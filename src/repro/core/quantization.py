"""KIVI-style KV-cache quantization (paper §4.2.2 joint-application baseline)
and the symmetric absmax oracle for the REAL int8 pools.

KIVI: per-CHANNEL asymmetric quantization of the Key cache, per-TOKEN of the
Value cache. We implement fake-quant (quantize→dequantize) since the accuracy
experiments in the paper were likewise run on a sparse-quantized cache ("the
current Mustafar kernel does not support low-bit precision").

Following Harma et al. (paper §4.2.2): prune FIRST, then quantize. With the
fixed-k format only the packed non-zeros are quantized.

Since PR 10 the serving pools can actually STORE int8
(``MustafarConfig(pool_dtype="int8")``): packed non-zeros are quantized by
symmetric absmax per (head, ``tile_tokens``-token tile) with one fp32 scale
per tile riding in a sibling pool leaf. ``symmetric_fake_quant`` below is the
accuracy oracle for that path — the storage round-trip
(``sparse_format.quantize_fixedk`` → ``dequantize_fixedk``) must reproduce it
to fp32 tolerance (tests/test_joint_compression.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _asym_quant(x: jax.Array, bits: int, axis: int, group: int = 32):
    """Asymmetric group quantization along ``axis``. Returns dequantized x."""
    x = x.astype(jnp.float32)
    orig_shape = x.shape
    axis = axis % x.ndim
    n = x.shape[axis]
    pad = (-n) % group
    if pad:
        pad_width = [(0, 0)] * x.ndim
        pad_width[axis] = (0, pad)
        x = jnp.pad(x, pad_width)
    # split axis into (groups, group)
    new_shape = x.shape[:axis] + (x.shape[axis] // group, group) + x.shape[axis + 1:]
    xg = x.reshape(new_shape)
    ax = axis + 1
    lo = jnp.min(xg, axis=ax, keepdims=True)
    hi = jnp.max(xg, axis=ax, keepdims=True)
    levels = (1 << bits) - 1
    scale = jnp.maximum(hi - lo, 1e-8) / levels
    q = jnp.clip(jnp.round((xg - lo) / scale), 0, levels)
    deq = (q * scale + lo).reshape(x.shape)
    if pad:
        sl = [slice(None)] * deq.ndim
        sl[axis] = slice(0, n)
        deq = deq[tuple(sl)]
    return deq.reshape(orig_shape)


def kivi_quantize_key(k_cache: jax.Array, bits: int = 4, group: int = 32) -> jax.Array:
    """Per-channel quantization: group along the TOKEN axis (axis=-2)."""
    return _asym_quant(k_cache, bits, axis=-2, group=group).astype(k_cache.dtype)


def kivi_quantize_value(v_cache: jax.Array, bits: int = 4, group: int = 32) -> jax.Array:
    """Per-token quantization: group along the CHANNEL axis (axis=-1)."""
    return _asym_quant(v_cache, bits, axis=-1, group=group).astype(v_cache.dtype)


def symmetric_fake_quant(vals: jax.Array, tile: int) -> jax.Array:
    """Quantize→dequantize oracle for the shipped int8 pool layout.

    ``vals`` [..., T, k] are packed non-zeros; one symmetric absmax scale is
    taken per (leading dims, ``tile``-token tile) — the whole [tile, k] block
    shares a scalar, exactly the granularity of the pools' sibling scale
    leaves. fp32 math, round-half-to-even, zero-blocks quantize to zeros.
    ``T`` must be a multiple of ``tile``."""
    x = vals.astype(jnp.float32)
    T = x.shape[-2]
    assert T % tile == 0, (T, tile)
    xt = x.reshape(x.shape[:-2] + (T // tile, tile * x.shape[-1]))
    # reciprocal multiply (not /127.0) — matches the kernel and the storage
    # round-trip bit-for-bit across XLA lowerings (see quantize_fixedk)
    scale = jnp.max(jnp.abs(xt), axis=-1, keepdims=True) \
        * jnp.float32(1.0 / 127.0)
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.clip(jnp.round(xt / scale), -127, 127)
    return (q * scale).reshape(x.shape)


def quant_bytes_per_token(d: int, bits: int, tile_tokens: int = 64) -> float:
    """Storage model for the SHIPPED layout: packed symmetric ints + one fp32
    absmax scale per ``tile_tokens``-token tile (amortized per token). This
    replaced the seed model (per-group-of-32 asymmetric fp16 scale+zero),
    which described a layout nothing ever stored."""
    return d * bits / 8 + 4.0 / tile_tokens
