"""KIVI-style KV-cache quantization (paper §4.2.2 joint-application baseline).

KIVI: per-CHANNEL asymmetric quantization of the Key cache, per-TOKEN of the
Value cache. We implement fake-quant (quantize→dequantize) since the accuracy
experiments in the paper were likewise run on a sparse-quantized cache ("the
current Mustafar kernel does not support low-bit precision").

Following Harma et al. (paper §4.2.2): prune FIRST, then quantize. With the
fixed-k format only the packed non-zeros are quantized; scales/zeros are kept
per group of 32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _asym_quant(x: jax.Array, bits: int, axis: int, group: int = 32):
    """Asymmetric group quantization along ``axis``. Returns dequantized x."""
    x = x.astype(jnp.float32)
    orig_shape = x.shape
    axis = axis % x.ndim
    n = x.shape[axis]
    pad = (-n) % group
    if pad:
        pad_width = [(0, 0)] * x.ndim
        pad_width[axis] = (0, pad)
        x = jnp.pad(x, pad_width)
    # split axis into (groups, group)
    new_shape = x.shape[:axis] + (x.shape[axis] // group, group) + x.shape[axis + 1:]
    xg = x.reshape(new_shape)
    ax = axis + 1
    lo = jnp.min(xg, axis=ax, keepdims=True)
    hi = jnp.max(xg, axis=ax, keepdims=True)
    levels = (1 << bits) - 1
    scale = jnp.maximum(hi - lo, 1e-8) / levels
    q = jnp.clip(jnp.round((xg - lo) / scale), 0, levels)
    deq = (q * scale + lo).reshape(x.shape)
    if pad:
        sl = [slice(None)] * deq.ndim
        sl[axis] = slice(0, n)
        deq = deq[tuple(sl)]
    return deq.reshape(orig_shape)


def kivi_quantize_key(k_cache: jax.Array, bits: int = 4, group: int = 32) -> jax.Array:
    """Per-channel quantization: group along the TOKEN axis (axis=-2)."""
    return _asym_quant(k_cache, bits, axis=-2, group=group).astype(k_cache.dtype)


def kivi_quantize_value(v_cache: jax.Array, bits: int = 4, group: int = 32) -> jax.Array:
    """Per-token quantization: group along the CHANNEL axis (axis=-1)."""
    return _asym_quant(v_cache, bits, axis=-1, group=group).astype(v_cache.dtype)


def quant_bytes_per_token(d: int, bits: int, group: int = 32) -> float:
    """Storage model: packed ints + fp16 scale/zero per group."""
    return d * bits / 8 + (d / group) * 4
