"""Fixed-k bitmap sparse format (paper §3, Figure 5b — TPU adaptation).

The paper packs non-zeros of each 1x64 tile with a 64-bit bitmap plus a
tile-offset array (nnz varies per tile on GPU). Our per-token exact top-k
pruning makes nnz *constant* (= k) per token row, so the layout is regular:

    values : [..., T, k]        bf16/fp32   packed non-zeros, row-major order
    bitmap : [..., T, d // 32]  uint32      bit c%32 of word c//32 = keep(c)

No offsets, no padding. Compressed bytes per token row (bf16):
``2*k + d/8`` vs dense ``2*d``.

This module is the pure-jnp oracle; the Pallas kernels in
``repro/kernels/`` implement the same format with VMEM tiling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

BITS_PER_WORD = 32


def topk_mask(x: jax.Array, k: int) -> jax.Array:
    """Boolean mask of the k largest-|.| elements per last-dim row.

    Deterministic tie-break: lower channel index wins (matches the Pallas
    kernel's rank comparison).
    """
    d = x.shape[-1]
    if k >= d:
        return jnp.ones_like(x, dtype=bool)
    mag = jnp.abs(x).astype(jnp.float32)
    # strictly ordered key: magnitude desc, then channel index asc
    idx = jnp.argsort(-mag, axis=-1, stable=True)
    ranks = jnp.argsort(idx, axis=-1)          # rank of each channel in sort order
    return ranks < k


def pad_to_words(d: int) -> int:
    """Channels padded up to a whole number of 32-bit bitmap words."""
    return (d + BITS_PER_WORD - 1) // BITS_PER_WORD * BITS_PER_WORD


def pack_fixedk(x: jax.Array, mask: jax.Array, k: int):
    """Compress ``x`` under ``mask`` (exactly k True per row) into (values, bitmap)."""
    d = x.shape[-1]
    d_pad = pad_to_words(d)
    if d_pad != d:  # e.g. d_head=80 (stablelm): pad channels, bits stay 0
        pad = [(0, 0)] * (x.ndim - 1) + [(0, d_pad - d)]
        x = jnp.pad(x, pad)
        mask = jnp.pad(mask, pad)
        d = d_pad
    x = jnp.where(mask, x, jnp.zeros_like(x))
    # positions of kept elements in ascending channel order
    order = jnp.argsort(jnp.where(mask, jnp.arange(d), d), axis=-1, stable=True)
    nz_pos = order[..., :k]
    values = jnp.take_along_axis(x, nz_pos, axis=-1)
    bits = mask.astype(jnp.uint32).reshape(*mask.shape[:-1], d // BITS_PER_WORD, BITS_PER_WORD)
    weights = (jnp.uint32(1) << jnp.arange(BITS_PER_WORD, dtype=jnp.uint32))
    bitmap = jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32)
    return values, bitmap


def unpack_bits(bitmap: jax.Array, d: int) -> jax.Array:
    """uint32 bitmap [..., d//32] -> float {0,1} mask [..., d]."""
    words = bitmap[..., :, None]                       # [..., d//32, 1]
    shifts = jnp.arange(BITS_PER_WORD, dtype=jnp.uint32)
    bits = (words >> shifts) & jnp.uint32(1)           # [..., d//32, 32]
    return bits.reshape(*bitmap.shape[:-1], d).astype(jnp.float32)


def unpack_fixedk(values: jax.Array, bitmap: jax.Array, d: int) -> jax.Array:
    """Decompress (values, bitmap) back to a dense [..., d] array.

    dense[t, c] = bits[t, c] ? values[t, rank[t, c]] : 0
    where rank = exclusive prefix-sum of bits along c — the same rank-match
    the Pallas kernel computes on the VPU.
    """
    d_pad = pad_to_words(d)
    words = bitmap[..., :, None]
    shifts = jnp.arange(BITS_PER_WORD, dtype=jnp.uint32)
    bits = ((words >> shifts) & jnp.uint32(1)).astype(jnp.int8)
    bits = bits.reshape(*bitmap.shape[:-1], d_pad)
    rank = jnp.cumsum(bits.astype(jnp.int32), axis=-1) - 1
    gathered = jnp.take_along_axis(
        values, jnp.clip(rank, 0, values.shape[-1] - 1), axis=-1)
    dense = jnp.where(bits > 0, gathered, jnp.zeros((), values.dtype))
    return dense[..., :d]


def prune_and_pack(x: jax.Array, k: int):
    """One-shot: per-token top-k magnitude prune + compress."""
    mask = topk_mask(x, k)
    return pack_fixedk(x, mask, k)


# ----------------------------------------------------------------------
# int8 quantized storage (PR 10): packed non-zeros stored int8 under the
# UNCHANGED bitmap plane, with one symmetric absmax fp32 scale per
# (leading dims, ``tile``-token tile). These two functions are the
# canonical storage round-trip; ``core.quantization.symmetric_fake_quant``
# is the independent oracle they must match to fp32 tolerance.

def quantize_fixedk(values: jax.Array, tile: int):
    """[..., T, k] float packed values -> (int8 [..., T, k],
    fp32 scales [..., T//tile, 1]).

    Symmetric absmax per [tile, k] block: ``scale = absmax/127`` (1.0 for
    all-zero blocks so they stay exact zeros), ``q = clip(round(v/scale))``.
    Because per-token top-k keeps each row's largest magnitude, the absmax
    over packed values equals the absmax over the dense tile — quantizing
    after packing loses nothing vs quantizing before."""
    x = values.astype(jnp.float32)
    T, k = x.shape[-2:]
    assert T % tile == 0, (T, tile)
    xt = x.reshape(x.shape[:-2] + (T // tile, tile * k))
    # explicit reciprocal multiply, NOT division: XLA rewrites x/127.0 to
    # x*(1/127) in some lowerings (the Pallas interpreter) but not others,
    # which would put the kernel and this oracle one ulp apart
    scale = jnp.max(jnp.abs(xt), axis=-1, keepdims=True) \
        * jnp.float32(1.0 / 127.0)
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.clip(jnp.round(xt / scale), -127, 127).astype(jnp.int8)
    return q.reshape(values.shape), scale


def dequantize_fixedk(qvalues: jax.Array, scales: jax.Array,
                      dtype=jnp.float32) -> jax.Array:
    """Inverse of ``quantize_fixedk``. The quant tile is DERIVED from the
    shapes (``T // n_scale_rows``), so readers need no config threading —
    this also makes the function correct on page-gathered views, where both
    leaves concatenate pagewise in the same order."""
    T, k = qvalues.shape[-2:]
    nt = scales.shape[-2]
    assert T % nt == 0, (T, nt)
    xt = qvalues.astype(jnp.float32).reshape(
        qvalues.shape[:-2] + (nt, (T // nt) * k))
    out = xt * scales.astype(jnp.float32)
    return out.reshape(qvalues.shape).astype(dtype)


# ----------------------------------------------------------------------
# paged layout (vLLM-style block indirection over the fixed-k format)

def gather_pages(pool: jax.Array, block_table: jax.Array) -> jax.Array:
    """Materialise a per-slot contiguous view of a paged pool.

    pool        [n_pages, Hkv, page_tokens, c]  (c = k values or d//32 words)
    block_table [B, max_pages] int32            (-1 = unmapped)
    returns     [B, Hkv, max_pages * page_tokens, c]

    Unmapped entries clamp to page 0: the gathered rows there are garbage,
    but every consumer masks tokens at or past ``n_compressed``, and since
    pool values are finite the masked contributions are exactly zero — the
    gathered view is therefore bit-identical to a contiguous pool wherever
    the token index is valid (the paged differential tests assert this).

    ALIASED rows are fine: under prefix sharing several rows may map the
    same physical page (refcounted, copy-on-write before any write — see
    ``serving.cache``). The gather just reads the page once per mapping;
    each row's contiguous view is bit-identical to the view it would get
    from a private copy of that page, which is the whole point of sharing.
    """
    idx = jnp.clip(block_table, 0, pool.shape[0] - 1)   # [B, MP]
    g = pool[idx]                                       # [B, MP, Hkv, pt, c]
    B, MP, Hkv, pt, c = g.shape
    return jnp.moveaxis(g, 2, 1).reshape(B, Hkv, MP * pt, c)


def mapped_page_counts(block_table):
    """(unique_mapped, total_mapped) over a block table — the gap between
    them is exactly the pages deduplicated by prefix sharing. This is the
    standalone checkable statement of the no-double-counting rule
    (asserted in tests/test_prefix_sharing.py); production accounting
    counts unique physical pages at the allocator instead
    (``serving.cache.PageAllocator.in_use_split``)."""
    bt = np.asarray(block_table)
    mapped = bt[bt >= 0]
    return len(np.unique(mapped)), int(mapped.size)


# ----------------------------------------------------------------------
# accounting (paper Fig. 6b — compression rate)

def dense_bytes(T: int, d: int, itemsize: int = 2) -> int:
    return T * d * itemsize


def compressed_bytes(T: int, d: int, k: int, itemsize: int = 2) -> int:
    """Stored bytes per T compressed rows: packed values + bitmap planes.

    The bitmap is stored as whole uint32 words (pad_to_words), so d=80
    models (stablelm) pay ceil(80/32)=3 words = 12 bytes per row, not 10.
    """
    return T * (k * itemsize + pad_to_words(d) // 8)


def compression_rate(d: int, k: int, itemsize: int = 2) -> float:
    """Compressed size as a fraction of dense (paper reports ~0.45 at s=0.7)."""
    return compressed_bytes(1, d, k, itemsize) / dense_bytes(1, d, itemsize)


def paper_compression_rate(d: int, sparsity: float, itemsize: int = 2) -> float:
    """Paper's GPU format: nnz + bitmap + tile offsets + multiples-of-8 padding."""
    tiles = d // 64
    nnz = d * (1 - sparsity)
    nnz_padded = np.ceil(nnz / 8) * 8      # coalescing padding
    per_row = nnz_padded * itemsize + tiles * 8 + tiles * 4  # values+bitmap+offset
    return float(per_row / (d * itemsize))
