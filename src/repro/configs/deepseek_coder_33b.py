"""DeepSeek-Coder-33B [arXiv:2401.14196; hf] — llama-arch dense GQA."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=19200, vocab_size=32256,
    norm="rmsnorm", activation="silu", rope_theta=1e5,
)
