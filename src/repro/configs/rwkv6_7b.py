"""RWKV6-7B "Finch" [arXiv:2404.05892; hf] — attention-free, data-dependent decay.

No KV cache exists; Mustafar is inapplicable (see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import MustafarConfig, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, d_head=64,
    d_ff=14336, vocab_size=65536,
    norm="layernorm", activation="relu_sq", pos_embedding="none",
    rwkv_head_size=64,
    mustafar=MustafarConfig(enabled=False),
)
