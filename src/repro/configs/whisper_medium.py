"""Whisper-medium [arXiv:2212.04356; unverified] — enc-dec, conv frontend STUB.

input_specs() provides precomputed frame embeddings [B, 1500, d_model]
(the conv1d x2 + GELU frontend output), per the assignment's stub rule.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
    d_ff=4096, vocab_size=51865,
    norm="layernorm", activation="gelu", use_bias=True,
    pos_embedding="learned", n_encoder_layers=24, encoder_ctx=1500, max_position=32768,
    tie_embeddings=True,
)
