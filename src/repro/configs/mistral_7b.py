"""Mistral-7B-Instruct-v0.2 — paper evaluation model (Tables 3,4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab_size=32000,
    norm="rmsnorm", activation="silu", rope_theta=1e6,
)
