"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B; hf] — MoE 128 experts top-8."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=768, vocab_size=151936,
    norm="rmsnorm", activation="silu", rope_theta=1e6,
    n_experts=128, expert_top_k=8, moe_every=1, moe_d_ff=768,
)
