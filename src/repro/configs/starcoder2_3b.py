"""StarCoder2-3B [arXiv:2402.19173; hf] — dense GQA transformer."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_head=128,
    d_ff=12288, vocab_size=49152,
    norm="layernorm", activation="gelu", use_bias=True,
    rope_theta=1e5, tie_embeddings=True,
)
