"""Phi-3.5-MoE [hf:microsoft/Phi-3.5-MoE-instruct; hf] — 16 experts top-2."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=6400, vocab_size=32064,
    norm="layernorm", activation="silu", use_bias=False, rope_theta=1e4,
    n_experts=16, expert_top_k=2, moe_every=1, moe_d_ff=6400,
)
