"""Config system for Mustafar-JAX.

Every architecture in the assigned pool is expressed as a ``ModelConfig``.
Configs are plain frozen dataclasses so they hash, print, and diff cleanly;
``reduced()`` derives the small smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MustafarConfig:
    """Paper technique knobs (Section 2/3 of the paper)."""
    enabled: bool = True
    key_sparsity: float = 0.7        # K_s — fraction of elements pruned per key row
    value_sparsity: float = 0.7      # V_s — fraction pruned per value row
    local_window: int = 32           # recent tokens kept dense (paper: 32)
    tile_tokens: int = 64            # compression granularity (paper: 64-token tile groups)
    # pruning strategy: 'per_token_magnitude' is the paper's verdict; others
    # are implemented as paper baselines (Tables 1/2/12).
    key_strategy: str = "per_token_magnitude"
    value_strategy: str = "per_token_magnitude"
    # k values are rounded to a multiple of this for lane alignment.
    k_align: int = 8
    # storage dtype of the packed non-zero value pools: "bf16" (default) or
    # "int8" (symmetric absmax per (head, tile_tokens) tile; a sibling fp32
    # scale leaf rides beside each value pool — see serving.cache).
    pool_dtype: str = "bf16"

    def keep_k(self, d_head: int, sparsity: float) -> int:
        """#nonzeros kept per token row, lane-aligned (fixed-k format)."""
        k = int(round(d_head * (1.0 - sparsity)))
        k = max(self.k_align, (k + self.k_align - 1) // self.k_align * self.k_align)
        return min(k, d_head)


@dataclass(frozen=True)
class ModelConfig:
    """One architecture. ``family`` selects the block program:

    dense   — pre-norm GQA transformer (RoPE)
    moe     — dense + mixture-of-experts FFN
    ssm     — RWKV6 (attention-free)
    hybrid  — Jamba: Mamba + attention (1:7) + MoE every other layer
    audio   — Whisper enc-dec (conv frontend stubbed)
    vlm     — LM backbone consuming stub patch embeddings
    """
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None          # defaults to d_model // n_heads
    # --- norm / act / misc ---
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    activation: str = "silu"              # silu | gelu
    use_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    pos_embedding: str = "rope"           # rope | learned | none
    max_position: int = 1 << 20
    # --- MoE ---
    n_experts: int = 0
    expert_top_k: int = 0
    moe_every: int = 1                    # apply MoE FFN every Nth layer (1 = all)
    moe_d_ff: Optional[int] = None        # per-expert hidden dim (defaults d_ff)
    moe_capacity_factor: float = 1.25
    n_shared_experts: int = 0
    # --- hybrid (Jamba) ---
    attn_every: int = 1                   # 1 attn layer per N (jamba: 8)
    attn_offset: int = 0                  # which residual index inside the period is attention
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # --- ssm (RWKV6) ---
    rwkv_head_size: int = 64
    # --- enc-dec (Whisper) ---
    n_encoder_layers: int = 0
    encoder_ctx: int = 0                  # #frames after conv frontend (whisper: 1500)
    # --- vlm ---
    n_vision_tokens: int = 0              # stub patch embeddings prepended
    # --- dtypes ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # --- paper technique ---
    mustafar: MustafarConfig = field(default_factory=MustafarConfig)

    def __post_init__(self):
        if self.d_head is None:
            object.__setattr__(self, "d_head", self.d_model // max(self.n_heads, 1))

    # ------------------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_encoder(self) -> bool:
        return self.family == "audio"

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def layer_kind(self, i: int) -> str:
        """'attn' | 'mamba' | 'rwkv' — the mixer kind for layer i."""
        if self.family == "ssm":
            return "rwkv"
        if self.family == "hybrid":
            return "attn" if (i % self.attn_every) == self.attn_offset else "mamba"
        return "attn"

    def ffn_kind(self, i: int) -> str:
        """'moe' | 'dense' — the FFN kind for layer i."""
        if self.n_experts > 0 and (i % self.moe_every) == (self.moe_every - 1):
            return "moe"
        return "dense"

    def attention_layers(self) -> Tuple[int, ...]:
        return tuple(i for i in range(self.n_layers) if self.layer_kind(i) == "attn")

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, dff = self.d_model, self.d_ff
        n_q = self.n_heads * self.d_head
        n_kv = self.n_kv_heads * self.d_head
        total = self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d                 # lm head
        if self.has_encoder:
            total += self.encoder_ctx * d                # learned enc positions
            total += self.max_decoder_position() * d
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind == "attn":
                total += d * (n_q + 2 * n_kv) + n_q * d  # qkv + o
                if self.family == "audio":               # cross-attention too
                    total += d * (n_q + 2 * n_kv) + n_q * d
            elif kind == "mamba":
                d_in = self.mamba_expand * d
                total += d * 2 * d_in                    # in_proj
                total += d_in * self.mamba_d_conv        # conv
                total += d_in * (self.mamba_d_state * 2 + 1)  # B,C,dt proj (selective)
                total += d_in * d                        # out_proj
                total += d_in * self.mamba_d_state       # A
            elif kind == "rwkv":
                a = self.d_model
                total += 4 * a * a + 6 * a               # time-mix r,k,v,o (+decay/first)
            # FFN
            if self.ffn_kind(i) == "moe":
                e_dff = self.moe_d_ff or dff
                n_mat = 3 if self.activation == "silu" else 2
                total += self.n_experts * n_mat * d * e_dff
                total += d * self.n_experts              # router
                if self.n_shared_experts:
                    total += self.n_shared_experts * n_mat * d * e_dff
            else:
                if kind == "rwkv":
                    total += 2 * d * dff                 # rwkv channel-mix (k,v)
                else:
                    n_mat = 3 if self.activation == "silu" else 2
                    total += n_mat * d * dff
            total += 2 * d                               # 2 norms
        if self.has_encoder:
            enc = self.n_encoder_layers * (4 * d * d + (2 if self.activation != "silu" else 3) * d * dff + 2 * d)
            total += enc
        total += d                                       # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE top-k instead of all experts)."""
        if self.n_experts == 0:
            return self.param_count()
        dense_cfg = replace(self, n_experts=0, expert_top_k=0)
        # dense version counts d_ff FFN everywhere; rebuild manually:
        total = dense_cfg.param_count()
        # remove the dense-FFN the replacement added for moe layers, add top-k experts
        d = self.d_model
        e_dff = self.moe_d_ff or self.d_ff
        n_mat = 3 if self.activation == "silu" else 2
        for i in range(self.n_layers):
            if self.ffn_kind(i) == "moe":
                total -= n_mat * d * self.d_ff
                total += (self.expert_top_k + self.n_shared_experts) * n_mat * d * e_dff
                total += d * self.n_experts
        return total

    def max_decoder_position(self) -> int:
        return 448 if self.family == "audio" else 0

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if self.family != "hybrid" else 8),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_head=32,
            d_ff=256,
            vocab_size=512,
            max_position=4096,
        )
        if self.n_experts:
            kw.update(n_experts=4, expert_top_k=min(self.expert_top_k, 2), moe_d_ff=128)
        if self.family == "audio":
            kw.update(n_encoder_layers=2, encoder_ctx=64)
        if self.family == "vlm":
            kw.update(n_vision_tokens=8)
        if self.family == "ssm":
            kw.update(rwkv_head_size=32)
        if self.family == "hybrid":
            kw.update(attn_every=min(self.attn_every, 4), mamba_d_state=8)
        kw["mustafar"] = replace(self.mustafar, local_window=8, tile_tokens=16)
        return replace(self, **kw)

    def with_sparsity(self, ks: float, vs: float) -> "ModelConfig":
        return replace(self, mustafar=replace(self.mustafar, key_sparsity=ks, value_sparsity=vs))


# ----------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""
    name: str
    seq_len: int
    global_batch: int
    kind: str          # 'train' | 'prefill' | 'decode'


LM_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}; have {[s.name for s in LM_SHAPES]}")


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    microbatch: int = 0            # 0 = no gradient accumulation
    remat: str = "block"           # none | block | full
    seed: int = 0
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    z_loss: float = 1e-4
    moe_aux_loss: float = 1e-2
