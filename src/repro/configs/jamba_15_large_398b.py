"""Jamba-1.5-Large [arXiv:2403.19887; hf] — Mamba+attn 1:7 interleave, MoE 16e top-2.

Attention appears once per 8 layers; MoE replaces the dense FFN every other
layer. Mustafar applies to the attention layers' KV cache only; Mamba layers
carry O(1) recurrent state (see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=24576, vocab_size=65536,
    norm="rmsnorm", activation="silu", rope_theta=0.0, pos_embedding="none",
    n_experts=16, expert_top_k=2, moe_every=2, moe_d_ff=24576,
    attn_every=8, attn_offset=4,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
)
