"""Llama-2-7B — paper evaluation model (Tables 5,7-9), MHA."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_head=128,
    d_ff=11008, vocab_size=32000,
    norm="rmsnorm", activation="silu", rope_theta=1e4,
)
