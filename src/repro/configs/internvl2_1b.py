"""InternVL2-1B [arXiv:2404.16821; hf] — InternLM2 backbone, stub InternViT frontend.

The modality frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings [B, n_vision_tokens, d_model].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_head=64,
    d_ff=4864, vocab_size=151655,
    norm="rmsnorm", activation="silu", rope_theta=1e6,
    n_vision_tokens=256, tie_embeddings=True,
)
