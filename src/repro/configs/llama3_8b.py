"""Llama-3-8B-Instruct — the paper's primary evaluation model (Tables 1-4,6)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab_size=128256,
    norm="rmsnorm", activation="silu", rope_theta=5e5,
)
