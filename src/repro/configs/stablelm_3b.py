"""StableLM-3B [hf:stabilityai/stablelm-2-1_6b; unverified] — MHA (kv=heads)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, d_head=80,
    d_ff=6912, vocab_size=50304,
    norm="layernorm", activation="silu", use_bias=False, rope_theta=1e4,
)
