"""Config registry: ``get_config('<arch-id>')`` for every assigned arch."""
from repro.configs.base import (LM_SHAPES, ModelConfig, MustafarConfig,
                                ShapeConfig, TrainConfig, get_shape)

from repro.configs import (command_r_35b, deepseek_coder_33b,
                           internvl2_1b, jamba_15_large_398b, llama2_7b,
                           llama3_8b, mistral_7b, phi35_moe_42b_a66b,
                           qwen3_moe_30b_a3b, rwkv6_7b, stablelm_3b,
                           starcoder2_3b, whisper_medium)

_REGISTRY = {}
for _mod in (starcoder2_3b, deepseek_coder_33b, stablelm_3b, command_r_35b,
             internvl2_1b, rwkv6_7b, whisper_medium, qwen3_moe_30b_a3b,
             phi35_moe_42b_a66b, jamba_15_large_398b,
             llama3_8b, llama2_7b, mistral_7b):
    _REGISTRY[_mod.CONFIG.name] = _mod.CONFIG

# assigned pool (dry-run grid) vs paper's own models
ASSIGNED_ARCHS = (
    "starcoder2-3b", "deepseek-coder-33b", "stablelm-3b", "command-r-35b",
    "internvl2-1b", "rwkv6-7b", "whisper-medium", "qwen3-moe-30b-a3b",
    "phi3.5-moe-42b-a6.6b", "jamba-1.5-large-398b",
)
PAPER_ARCHS = ("llama3-8b", "llama2-7b", "mistral-7b")


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs():
    return dict(_REGISTRY)


__all__ = ["ModelConfig", "MustafarConfig", "ShapeConfig", "TrainConfig",
           "LM_SHAPES", "get_shape", "get_config", "all_configs",
           "ASSIGNED_ARCHS", "PAPER_ARCHS"]
