"""Activation sharding constraints with a process-level mesh context.

``jax.lax.with_sharding_constraint`` needs a concrete mesh when given bare
PartitionSpecs; model code calls ``shard_activation`` which is a no-op unless
a launcher (dryrun/train/serve) installed a mesh via ``constraint_mesh``.
Axis entries are silently dropped when the axis is absent from the installed
mesh or doesn't divide the dimension — the same graceful degradation as the
param rules.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None


@contextlib.contextmanager
def constraint_mesh(mesh: Optional[Mesh]):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        yield
    finally:
        _MESH = prev


def current_mesh() -> Optional[Mesh]:
    return _MESH


def _normalize(entry, dim: int, mesh: Mesh):
    if entry is None:
        return None
    axes = (entry,) if isinstance(entry, str) else tuple(entry)
    axes = tuple(a for a in axes if a in mesh.axis_names)
    if not axes:
        return None
    size = int(np.prod([mesh.shape[a] for a in axes]))
    if size == 1 or dim % size != 0:
        return None
    return axes if len(axes) > 1 else axes[0]


def shard_activation(x: jax.Array, *entries) -> jax.Array:
    """Constrain ``x`` to PartitionSpec(*entries) under the installed mesh.
    No-op without a mesh (CPU tests) or on non-divisible/absent axes."""
    mesh = _MESH
    if mesh is None:
        return x
    assert len(entries) == x.ndim, (entries, x.shape)
    norm = tuple(_normalize(e, d, mesh) for e, d in zip(entries, x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*norm)))


DP = ("data", "pod")   # canonical batch axes tuple for model code
