"""Sharding rules: mesh axes (pod, data, model) → PartitionSpecs per tensor.

Training posture (DESIGN.md §5): tensor parallel on "model" (attention heads,
FFN columns, MoE experts), ZeRO-3/FSDP over ("data","pod") for params and
optimizer state (GSPMD all-gathers per layer inside the scan, reduce-scatters
gradients), batch data-parallel over ("pod","data").

Serving posture: batch on ("pod","data") where divisible; for batch-1
long-context decode the compressed-pool *token/tile* dimension shards on
"data" (context parallel — flash-decoding-style split with GSPMD inserting
the partial-softmax reductions) and heads on "model" where divisible.

Every rule degrades to replication when a dim isn't divisible by the axis —
non-divisible cases (24 q-heads on a 16-way model axis) keep the *fused*
projection dim sharded instead (192 columns/chip), which GSPMD reshards at
the head-split reshape.
"""
from __future__ import annotations

import os
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

MODEL = "model"


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """All pure-data axes present in the mesh, biggest first."""
    return tuple(a for a in ("data", "pod") if a in mesh.axis_names)


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    return dim % _axsize(mesh, axes) == 0


def _maybe(dim: int, mesh: Mesh, axes):
    """axes if divisible else None."""
    return axes if axes is not None and _fits(dim, mesh, axes) else None


# ----------------------------------------------------------------------
# parameter rules

_TP_LAST = {"wq", "wk", "wv", "wr", "wg", "up", "gate", "cm_k", "in_proj",
            "conv_w", "dt_proj", "vis_proj"}
_TP_FIRST = {"wo", "down", "cm_v", "x_proj", "out_proj", "A_log"}
_TP_VEC = {"bq", "bk", "bv", "up_b", "conv_b", "dt_bias", "D"}
_REPLICATED = {"scale", "bias", "router", "w0", "wA", "wB", "u",
               "ln_x_scale", "ln_x_bias", "positions", "bo", "down_b"}


_ATTN_Q = {"wq", "wo", "bq"}
_ATTN_KV = {"wk", "wv", "bk", "bv"}


def param_partition_spec(path_names, shape, mesh: Mesh,
                         fsdp: bool = True,
                         cfg: Optional[ModelConfig] = None) -> P:
    """PartitionSpec for one param leaf given its path and (global) shape."""
    name = path_names[-1]
    stacked = any(n in ("blocks", "encoder") for n in path_names)
    lead = (None,) if stacked else ()
    core = shape[1:] if stacked else shape
    fs = data_axes(mesh) if fsdp else None
    rank = len(core)

    def spec(*entries):
        return P(*(lead + entries))

    # Attention projections: tensor-parallel ONLY when the head count divides
    # the model axis — otherwise the [.., H, d] head-split reshape of a
    # model-sharded fused dim forces GSPMD into full-batch reshards (measured:
    # 90 GiB/device of gratuitous all-gathers on starcoder's 24 heads / 16-way
    # axis). Non-divisible archs run attention data-parallel (FSDP weights).
    # Measured to be WORSE than TP+activation-constraints (§Perf iteration 2)
    # so off by default; REPRO_ATTN_DP_FALLBACK=1 re-enables for comparison.
    if (os.environ.get("REPRO_ATTN_DP_FALLBACK") == "1"
            and cfg is not None and (name in _ATTN_Q or name in _ATTN_KV)):
        kind = "attn"
        if path_names and path_names[0] == "blocks" and len(path_names) > 1:
            try:
                kind = cfg.layer_kind(int(path_names[1]))
            except (ValueError, IndexError):
                kind = "attn"
        if kind == "attn":
            msize = mesh.shape[MODEL] if MODEL in mesh.axis_names else 1
            heads = cfg.n_heads if name in _ATTN_Q else cfg.n_kv_heads
            if heads % msize != 0:
                if rank == 1:
                    return spec(None)
                if name == "wo":                  # [Hq·dh, D]
                    return spec(_maybe(core[0], mesh, fs), None)
                return spec(_maybe(core[0], mesh, fs), None)  # wq/wk/wv [D, ·]

    if name.startswith("mix_") or name in _REPLICATED:
        return spec(*([None] * rank))
    if name in _TP_VEC:
        return spec(_maybe(core[0], mesh, MODEL))
    # Embedding: vocab on "model" ONLY. Sharding D on the data axes makes the
    # token-gather output inherit D-on-"data", which conflicts with
    # batch-on-"data" and unshards the batch for the WHOLE residual stream
    # (measured: 500+ GiB/device of full-batch collectives).
    if name == "tokens":                         # [V, D]
        v, d = core
        if _fits(v, mesh, MODEL):
            return spec(MODEL, None)
        return spec(None, _maybe(d, mesh, MODEL))
    if name == "lm_head":                        # [D, V]
        d, v = core
        if _fits(v, mesh, MODEL):
            return spec(None, MODEL)
        return spec(_maybe(d, mesh, MODEL), None)
    if rank == 3 and name in ("up", "gate", "down"):   # MoE experts [E, d, f]
        e, a, b = core
        return spec(_maybe(e, mesh, MODEL), None, _maybe(b, mesh, fs))
    if name in _TP_LAST and rank == 2:
        a, b = core
        return spec(_maybe(a, mesh, fs), _maybe(b, mesh, MODEL))
    if name in _TP_FIRST and rank == 2:
        a, b = core
        return spec(_maybe(a, mesh, MODEL), _maybe(b, mesh, fs))
    return spec(*([None] * rank))


def param_specs(params_or_shapes, mesh: Mesh, fsdp: bool = True,
                cfg: Optional[ModelConfig] = None):
    """Tree of PartitionSpecs matching the param tree."""
    flat = jax.tree_util.tree_flatten_with_path(params_or_shapes)
    specs = []
    for path, leaf in flat[0]:
        names = [str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
                 for p in path]
        specs.append(param_partition_spec(names, leaf.shape, mesh, fsdp, cfg))
    return jax.tree.unflatten(flat[1], specs)


# ----------------------------------------------------------------------
# serving tensor-parallel param rules (shard_map posture)

def serving_param_partition_spec(path_names, shape, cfg: ModelConfig,
                                 mesh: Mesh) -> P:
    """Param leaf rule for the SERVING shard_map posture
    (``serving.sharded``): Megatron-style tensor parallelism over "model"
    with everything else replicated.

    wq/wk/wv column-shard their fused projection dim — the head-split
    reshape is head-MAJOR, so a contiguous column block per device IS a
    contiguous block of whole heads (requires ``n_heads % model == 0``
    and ``n_kv_heads % model == 0``; enforced by
    ``serving.sharded.sharding_supported``). wo row-shards to match (each
    device contracts its own heads' outputs; the per-layer ``psum`` in the
    engine completes the sum). bq/bk/bv shard with their heads; ``bo``
    stays REPLICATED — it sits before the psum point, so the shard_map
    body divides it by the axis size instead (see
    ``serving.sharded._rescale_o_bias``). Norms, FFN, embeddings and the
    LM head replicate: their compute is identical on every device, which
    is what lets the final logits come out replicated with no extra
    collective."""
    name = path_names[-1]
    stacked = any(n in ("blocks", "encoder") for n in path_names)
    lead = (None,) if stacked else ()
    core = shape[1:] if stacked else shape
    rank = len(core)

    def spec(*entries):
        return P(*(lead + entries))

    heads = cfg.n_heads if name in ("wq", "bq", "wo") else cfg.n_kv_heads
    tp_ok = _fits(heads, mesh, MODEL)
    if name in ("wq", "wk", "wv") and rank == 2 and tp_ok:
        return spec(None, MODEL)                  # [D, H·dh] column shard
    if name in ("bq", "bk", "bv") and rank == 1 and tp_ok:
        return spec(MODEL)                        # [H·dh] with its heads
    if name == "wo" and rank == 2 and tp_ok:
        return spec(MODEL, None)                  # [H·dh, D] row shard
    return spec(*([None] * rank))


def serving_param_specs(params_or_shapes, cfg: ModelConfig, mesh: Mesh):
    """Tree of serving-TP PartitionSpecs matching the param tree."""
    flat = jax.tree_util.tree_flatten_with_path(params_or_shapes)
    specs = []
    for path, leaf in flat[0]:
        names = [str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
                 for p in path]
        specs.append(serving_param_partition_spec(names, leaf.shape, cfg,
                                                  mesh))
    return jax.tree.unflatten(flat[1], specs)


# ----------------------------------------------------------------------
# batch / activation / state rules

def batch_spec(B: int, mesh: Mesh, extra_dims: int = 1) -> P:
    dp = data_axes(mesh)
    lead = dp if _fits(B, mesh, dp) else (
        ("data",) if _fits(B, mesh, "data") else None)
    return P(lead, *([None] * extra_dims))


def train_batch_specs(cfg: ModelConfig, B: int, mesh: Mesh):
    out = {"tokens": batch_spec(B, mesh), "labels": batch_spec(B, mesh)}
    if cfg.family == "audio":
        out["frames"] = batch_spec(B, mesh, extra_dims=2)
    if cfg.family == "vlm":
        out["patches"] = batch_spec(B, mesh, extra_dims=2)
    return out


def opt_state_specs(pspecs, step_like=None):
    """OptState(step, mu, nu, master) specs mirroring param specs."""
    from repro.training.optimizer import OptState
    return OptState(P(), pspecs, pspecs, pspecs)


def cache_partition_spec(path_names, shape, cfg: ModelConfig, mesh: Mesh,
                         paged: bool = False) -> P:
    """Serving-cache leaf rule. Leaves under 'blocks' carry a leading
    period-stack dim (never sharded).

    PAGED pools (``paged=True``): the four compressed-pool leaves are a
    GLOBAL page pool ``[n_phys, Hkv, page_tokens, k]`` under the period
    stack — no leading batch dim. Hkv shards on "model" (each device holds
    its KV-head slice of EVERY physical page, so the host-side allocator /
    block-table arithmetic is device-agnostic) and the physical-page dim
    stays unsharded: page ids must mean the same thing on every device or
    the replicated block table would be wrong. The ``block_table`` and
    ``n_valid``-style metadata leaves are REPLICATED — they are int32 and
    tiny (``4·B·max_pages``), and every device needs every mapping to
    translate its own head shard's tiles. Per-device pool bytes are thus
    ``pool_bytes / mesh.shape["model"] + metadata_bytes`` (see
    ``serving.cache.cache_hbm_bytes(mesh_model=...)``)."""
    name = path_names[-1]
    if name in ("position", "w_len", "n_compressed", "block_table"):
        return P()
    dp = data_axes(mesh)
    core = shape[1:]                      # strip period stack

    def with_lead(*entries):
        return P(None, *entries)

    if paged and name in ("ck_vals", "ck_bm", "cv_vals", "cv_bm",
                          "ck_scale", "cv_scale"):
        # paged pool leaf [n_phys, Hkv, page_tokens, k] (scale pools
        # [n_phys, Hkv, page_tokens//qt, 1] shard the same way): heads on
        # "model", physical pages replicated (ids must be device-agnostic)
        _, Hkv, _, _ = core
        return with_lead(None, _maybe(Hkv, mesh, MODEL), None, None)

    B = core[0]
    b_ax = dp if _fits(B, mesh, dp) else (
        ("data",) if _fits(B, mesh, ("data",)) else None)

    if name in ("ck_vals", "ck_bm", "cv_vals", "cv_bm",
                "ck_scale", "cv_scale"):                    # [B,Hkv,Tc,k]
        # (scale leaves [B,Hkv,Tc//qt,1] ride beside the value pools and
        # shard identically — the token-tile dim splits with the token dim)
        _, Hkv, Tc, _ = core
        h_ax = _maybe(Hkv, mesh, MODEL)
        if b_ax is not None:
            return with_lead(b_ax, h_ax, None, None)
        # batch-1 long context: context-parallel over the pool token dim
        return with_lead(None, h_ax, _maybe(Tc, mesh, ("data",)), None)
    if name in ("k_win", "v_win"):                          # [B,Hkv,W,d]
        _, Hkv, _, _ = core
        return with_lead(b_ax, _maybe(Hkv, mesh, MODEL), None, None)
    if name in ("k", "v"):                                  # dense [B,Hkv,T,d]
        _, Hkv, T, _ = core
        h_ax = _maybe(Hkv, mesh, MODEL)
        if b_ax is not None:
            return with_lead(b_ax, h_ax, None, None)
        return with_lead(None, h_ax, _maybe(T, mesh, ("data",)), None)
    if name in ("cross_k", "cross_v"):                      # [B,S,Hkv,d]
        return with_lead(b_ax, None, None, None)
    if name == "conv":                                      # [B,dc-1,din]
        return with_lead(b_ax, None, _maybe(core[2], mesh, MODEL))
    if name == "ssm":                                       # [B,din,ds]
        return with_lead(b_ax, _maybe(core[1], mesh, MODEL), None)
    if name == "wkv":                                       # [B,H,hs,hs]
        return with_lead(b_ax, _maybe(core[1], mesh, MODEL), None, None)
    if name in ("tm_shift", "cm_shift"):                    # [B,D]
        return with_lead(b_ax, _maybe(core[1], mesh, MODEL))
    return with_lead(*([None] * len(core)))


def cache_specs(cache_shapes, cfg: ModelConfig, mesh: Mesh,
                paged: Optional[bool] = None):
    """Tree of PartitionSpecs for a serving cache (or its shapes).

    ``paged`` selects the paged-pool leaf rules; default autodetects from
    the presence of a ``block_table`` key (paged caches always carry one)."""
    flat = jax.tree_util.tree_flatten_with_path(cache_shapes)
    if paged is None:
        paged = any(
            any(str(getattr(p, "key", "")) == "block_table" for p in path)
            for path, _ in flat[0])
    specs = []
    for path, leaf in flat[0]:
        names = [str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
                 for p in path]
        shape = leaf.shape
        if names[-1] in ("position", "w_len", "n_compressed", "block_table"):
            specs.append(P())
        else:
            specs.append(cache_partition_spec(names, shape, cfg, mesh,
                                              paged=paged))
    return jax.tree.unflatten(flat[1], specs)


# ----------------------------------------------------------------------
def to_named(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def shaped(tree_shapes, tree_specs, mesh: Mesh):
    """ShapeDtypeStructs with shardings attached (dry-run inputs)."""
    named = to_named(tree_specs, mesh)
    return jax.tree.map(
        lambda sd, sh: jax.ShapeDtypeStruct(sd.shape, sd.dtype, sharding=sh),
        tree_shapes, named)
