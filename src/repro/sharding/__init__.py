"""Mesh-axis sharding rules for params, optimizer state, batches, caches."""
from repro.sharding.specs import (batch_spec, cache_specs, data_axes,
                                  opt_state_specs, param_specs, shaped,
                                  to_named, train_batch_specs)
